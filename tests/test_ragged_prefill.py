"""Ragged packed-prefill tests: the flat-batch Pallas kernel and its
densifying oracle, the fused KV-write variant vs a separate scatter, the
pack/unpack layout round-trip, multi-chunk scheduler plans, multi-page
kernel fetch (``pages_per_compute_block``), and engine byte-identity of
packed (prefill_pack > 1) vs single-chunk serving — packing must be a
pure throughput optimization, never a numerics change."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.kernels.paged_attention import (paged_attention,
                                           paged_prefill_attention,
                                           ragged_paged_prefill_attention)
from repro.kernels.ref import (paged_attention_partial_ref,
                               paged_attention_ref,
                               paged_prefill_attention_ref,
                               ragged_paged_prefill_attention_ref)
from repro.models.attention import (ragged_chunk_attention_xla,
                                    update_paged_cache_ragged)
from repro.serving.engine import pack_ragged, unpack_ragged
from repro.serving.kv_cache import BlockManager
from repro.serving.scheduler import Request, Scheduler

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# Ragged packed-prefill kernel vs oracle
# ---------------------------------------------------------------------------


def _ragged_case(S, H, K, hd, bs, nblk, dtype, lens, pad=0):
    """Random pools + disjoint per-seq tables + a packed flat chunk batch:
    sequence i owns flat rows [starts[i], ends[i]) of length lens[i]; the
    trailing ``pad`` rows belong to nobody. ctx counts the chunk itself."""
    assert len(lens) == S
    T = int(sum(lens)) + pad
    N = 1 + S * nblk
    q = jnp.asarray(RNG.normal(0, 1, (T, H, hd)), jnp.float32).astype(dtype)
    kp = jnp.asarray(RNG.normal(0, 1, (N, bs, K, hd)),
                     jnp.float32).astype(dtype)
    vp = jnp.asarray(RNG.normal(0, 1, (N, bs, K, hd)),
                     jnp.float32).astype(dtype)
    perm = RNG.permutation(np.arange(1, N))[:S * nblk].reshape(S, nblk)
    bt = jnp.asarray(perm, jnp.int32)
    starts = np.zeros(S, np.int32)
    ends = np.zeros(S, np.int32)
    row_seq = np.zeros(T, np.int32)
    off = 0
    for i, L in enumerate(lens):
        starts[i], ends[i] = off, off + L
        row_seq[off:off + L] = i
        off += L
    ctx = np.array([L + RNG.integers(0, nblk * bs - L + 1) if L else 0
                    for L in lens], np.int32)
    return (q, kp, vp, bt, jnp.asarray(ctx), jnp.asarray(starts),
            jnp.asarray(ends), jnp.asarray(row_seq))


RAGGED_CASES = [
    # S, H, K, hd, block_size, blocks_per_seq, lens, pad, window, cap, dtype
    (3, 4, 2, 16, 8, 4, (5, 3, 8), 2, None, None, jnp.float32),   # GQA + pad
    (2, 6, 6, 16, 8, 5, (7, 9), 0, 12, None, jnp.float32),        # MHA + win
    (3, 8, 1, 64, 8, 4, (1, 8, 4), 3, None, 50.0, jnp.bfloat16),  # MQA + cap
    (4, 4, 2, 32, 16, 3, (16, 0, 5, 11), 4, 8, 30.0, jnp.bfloat16),
    # ^ empty pack slot (starts == ends) + window + cap + pad rows
]


@pytest.mark.parametrize("case", RAGGED_CASES)
def test_ragged_kernel_vs_ref(case):
    S, H, K, hd, bs, nblk, lens, pad, window, cap, dt = case
    q, kp, vp, bt, ctx, starts, ends, row_seq = _ragged_case(
        S, H, K, hd, bs, nblk, dt, lens, pad)
    o_k = ragged_paged_prefill_attention(q, kp, vp, bt, ctx, starts, ends,
                                         window=window, cap=cap,
                                         interpret=True)
    o_r = ragged_paged_prefill_attention_ref(q, kp, vp, bt, ctx, starts,
                                             ends, row_seq, window=window,
                                             cap=cap)
    tol = 1e-2 if dt == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), atol=tol)
    if pad:                               # rows owned by nobody: exact zeros
        assert np.all(np.asarray(o_k)[sum(lens):] == 0)
        assert np.all(np.asarray(o_r)[sum(lens):] == 0)
    assert np.all(np.isfinite(np.asarray(o_k, np.float32)))


@pytest.mark.parametrize("case", RAGGED_CASES)
def test_ragged_xla_path_vs_ref(case):
    """The pure-XLA packed path (dense gather + the single-chunk
    ``paged_chunk_attention_xla``) agrees with the flat oracle."""
    S, H, K, hd, bs, nblk, lens, pad, window, cap, dt = case
    q, kp, vp, bt, ctx, starts, ends, row_seq = _ragged_case(
        S, H, K, hd, bs, nblk, dt, lens, pad)
    o_x = ragged_chunk_attention_xla(q, kp, vp, bt, ctx, starts, ends,
                                     row_seq, window=window, cap=cap)
    o_r = ragged_paged_prefill_attention_ref(q, kp, vp, bt, ctx, starts,
                                             ends, row_seq, window=window,
                                             cap=cap)
    tol = 1e-2 if dt == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(o_x, np.float32),
                               np.asarray(o_r, np.float32), atol=tol)
    if pad:
        assert np.all(np.asarray(o_x)[sum(lens):] == 0)


def test_ragged_kernel_single_seq_matches_chunk_kernel():
    """S == 1 with starts = [0] is exactly the single-chunk prefill kernel
    (same streaming-softmax math, flat vs batched layout)."""
    H, K, hd, bs, nblk, C = 4, 2, 16, 8, 4, 12
    q, kp, vp, bt, ctx, starts, ends, _ = _ragged_case(
        1, H, K, hd, bs, nblk, jnp.float32, (C,), 0)
    o_ragged = ragged_paged_prefill_attention(q, kp, vp, bt, ctx, starts,
                                              ends, interpret=True)
    o_chunk = paged_prefill_attention(q[None], kp, vp, bt, ctx,
                                      jnp.asarray([C], jnp.int32),
                                      interpret=True)
    np.testing.assert_array_equal(np.asarray(o_ragged),
                                  np.asarray(o_chunk)[0])


def test_ragged_fused_write_matches_separate_scatter():
    """The fused-KV-write kernel (chunk K/V merged into the visited pages
    through aliased pool outputs) produces the same pool bytes as the
    separate ``update_paged_cache_ragged`` scatter, and its attention
    output matches the oracle run on the updated pools. Trash row 0 is
    excluded: the XLA scatter parks padding rows there, the kernel just
    redirects dead table entries to it."""
    S, H, K, hd, bs, nblk = 3, 4, 2, 16, 8, 4
    lens, pad = (5, 3, 8), 2
    q, kp, vp, bt, ctx, starts, ends, row_seq = _ragged_case(
        S, H, K, hd, bs, nblk, jnp.float32, lens, pad)
    T = q.shape[0]
    k_new = jnp.asarray(RNG.normal(0, 1, (T, K, hd)), jnp.float32)
    v_new = jnp.asarray(RNG.normal(0, 1, (T, K, hd)), jnp.float32)
    o_f, kp_f, vp_f = ragged_paged_prefill_attention(
        q, kp, vp, bt, ctx, starts, ends, k_new=k_new, v_new=v_new,
        interpret=True)
    kc = update_paged_cache_ragged(kp, k_new[None], bt, ctx, starts, ends,
                                   row_seq)
    vc = update_paged_cache_ragged(vp, v_new[None], bt, ctx, starts, ends,
                                   row_seq)
    np.testing.assert_array_equal(np.asarray(kp_f)[1:], np.asarray(kc)[1:])
    np.testing.assert_array_equal(np.asarray(vp_f)[1:], np.asarray(vc)[1:])
    o_r = ragged_paged_prefill_attention_ref(q, kc, vc, bt, ctx, starts,
                                             ends, row_seq)
    np.testing.assert_allclose(np.asarray(o_f), np.asarray(o_r), atol=1e-5)
    assert np.all(np.asarray(o_f)[sum(lens):] == 0)


# ---------------------------------------------------------------------------
# Multi-page fetch (pages_per_compute_block)
# ---------------------------------------------------------------------------


def _paged_case(B, H, K, hd, bs, nblk, dtype):
    N = 1 + B * nblk
    q = jnp.asarray(RNG.normal(0, 1, (B, H, hd)), jnp.float32).astype(dtype)
    kp = jnp.asarray(RNG.normal(0, 1, (N, bs, K, hd)),
                     jnp.float32).astype(dtype)
    vp = jnp.asarray(RNG.normal(0, 1, (N, bs, K, hd)),
                     jnp.float32).astype(dtype)
    perm = RNG.permutation(np.arange(1, N))[:B * nblk].reshape(B, nblk)
    bt = jnp.asarray(perm, jnp.int32)
    ctx = jnp.asarray(RNG.integers(1, nblk * bs + 1, (B,)), jnp.int32)
    return q, kp, vp, bt, ctx


@pytest.mark.parametrize("P", [2, 3])
@pytest.mark.parametrize("window,cap", [(None, None), (12, 50.0)])
def test_decode_kernel_multipage_vs_ref(P, window, cap):
    """P pages per grid step (non-divisible P included: 5 blocks / P=2|3
    leaves a partially-dead last tile) matches the single-page oracle."""
    B, H, K, hd, bs, nblk = 3, 4, 2, 16, 8, 5
    q, kp, vp, bt, ctx = _paged_case(B, H, K, hd, bs, nblk, jnp.float32)
    o_k = paged_attention(q, kp, vp, bt, ctx, window=window, cap=cap,
                          interpret=True, pages_per_compute_block=P)
    o_r = paged_attention_ref(q, kp, vp, bt, ctx, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-5)


@pytest.mark.parametrize("P", [2, 3])
def test_prefill_kernel_multipage_vs_ref(P):
    B, H, K, hd, bs, nblk, C = 2, 6, 2, 16, 8, 5, 20
    N = 1 + B * nblk
    q = jnp.asarray(RNG.normal(0, 1, (B, C, H, hd)), jnp.float32)
    kp = jnp.asarray(RNG.normal(0, 1, (N, bs, K, hd)), jnp.float32)
    vp = jnp.asarray(RNG.normal(0, 1, (N, bs, K, hd)), jnp.float32)
    perm = RNG.permutation(np.arange(1, N))[:B * nblk].reshape(B, nblk)
    bt = jnp.asarray(perm, jnp.int32)
    qlen = np.array([C, C // 2])
    ctx = np.array([RNG.integers(ql, nblk * bs + 1) for ql in qlen])
    o_k = paged_prefill_attention(q, kp, vp, bt,
                                  jnp.asarray(ctx, jnp.int32),
                                  jnp.asarray(qlen, jnp.int32), window=12,
                                  interpret=True, pages_per_compute_block=P)
    o_r = paged_prefill_attention_ref(q, kp, vp, bt,
                                      jnp.asarray(ctx, jnp.int32),
                                      jnp.asarray(qlen, jnp.int32),
                                      window=12)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-5)


def test_decode_kernel_multipage_block_mask_lse():
    """The P knob composes with the pool-sharded partial-softmax path:
    masked table entries stay skipped inside multi-page tiles and the
    returned LSE matches the partial oracle."""
    B, H, K, hd, bs, nblk = 2, 4, 2, 16, 8, 4
    q, kp, vp, bt, ctx = _paged_case(B, H, K, hd, bs, nblk, jnp.float32)
    mask = jnp.asarray(RNG.integers(0, 2, (B, nblk)), jnp.int32)
    mask = mask.at[:, 0].set(1)            # keep at least one live block
    o_k, lse_k = paged_attention(q, kp, vp, bt, ctx, block_mask=mask,
                                 return_lse=True, interpret=True,
                                 pages_per_compute_block=2)
    o_r, lse_r = paged_attention_partial_ref(q, kp, vp, bt, ctx, mask)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(lse_k), np.asarray(lse_r),
                               atol=1e-5)


def test_decode_kernel_multipage_clamps_to_table_width():
    """P larger than the table is clamped, not an error."""
    B, H, K, hd, bs, nblk = 2, 4, 2, 16, 8, 3
    q, kp, vp, bt, ctx = _paged_case(B, H, K, hd, bs, nblk, jnp.float32)
    o_k = paged_attention(q, kp, vp, bt, ctx, interpret=True,
                          pages_per_compute_block=16)
    o_r = paged_attention_ref(q, kp, vp, bt, ctx)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-5)


# ---------------------------------------------------------------------------
# pack_ragged / unpack_ragged round-trip
# ---------------------------------------------------------------------------


def test_pack_unpack_roundtrip_basic():
    rows = [np.array([3, 1, 4], np.int32), np.array([1], np.int32),
            np.array([5, 9, 2, 6], np.int32)]
    tok, seq, starts, ends = pack_ragged(rows, width=10, max_seqs=4)
    assert tok.shape == (10,) and starts.shape == (4,)
    back = unpack_ragged(tok, starts, ends, 3)
    for r, b in zip(rows, back):
        np.testing.assert_array_equal(r, b)
    np.testing.assert_array_equal(seq[:8], [0, 0, 0, 1, 2, 2, 2, 2])
    assert starts[3] == ends[3] == 0       # unused slot marks empty range


def test_pack_unpack_roundtrip_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.data())
    @hyp.settings(max_examples=80, deadline=None)
    def prop(data):
        max_seqs = data.draw(st.integers(1, 6))
        n = data.draw(st.integers(0, max_seqs))
        lens = [data.draw(st.integers(0, 8)) for _ in range(n)]
        width = sum(lens) + data.draw(st.integers(0, 5))
        width = max(width, 1)
        rows = [np.arange(L, dtype=np.int32) + 100 * i
                for i, L in enumerate(lens)]
        tok, seq, starts, ends = pack_ragged(rows, width, max_seqs)
        back = unpack_ragged(tok, starts, ends, n)
        assert len(back) == n
        for r, b in zip(rows, back):
            np.testing.assert_array_equal(r, b)
        # layout invariants the kernel's ownership masks rely on:
        # back-to-back packing, owner id per flat position, pad rows
        # outside every [start, end) range
        off = 0
        for i, L in enumerate(lens):
            assert starts[i] == off and ends[i] == off + L
            assert (seq[off:off + L] == i).all()
            off += L
        assert (seq[off:] == 0).all() and (tok[off:] == 0).all()

    prop()


# ---------------------------------------------------------------------------
# Scheduler: multi-chunk plans
# ---------------------------------------------------------------------------


def _req(n_prompt=8, max_new=4, **kw):
    return Request(np.arange(n_prompt, dtype=np.int32), max_new=max_new,
                   **kw)


def _sched(bm, max_batch=4, max_blocks_per_seq=8, budget=40, chunk=32, **kw):
    return Scheduler(bm, max_batch, max_blocks_per_seq, budget, chunk, **kw)


def test_scheduler_packs_multiple_prefills():
    bm = BlockManager(num_blocks=33, block_size=4)
    s = _sched(bm, budget=40, chunk=32, prefill_pack=4)
    reqs = [_req(n_prompt=n) for n in (12, 8, 6)]
    for r in reqs:
        s.add(r)
    plan = s.schedule()
    assert plan.admitted == 3
    assert [(c[1], c[2]) for c in plan.chunks] == [
        (reqs[0], 12), (reqs[1], 8), (reqs[2], 6)]
    assert plan.chunk == plan.chunks[0]     # compat accessor
    assert plan.scheduled_tokens == 26 <= 40


def test_scheduler_pack_shares_one_budget():
    """Chunks are funded by ONE leftover budget, in FCFS order; a request
    that doesn't fit this step gets the next step's budget."""
    bm = BlockManager(num_blocks=33, block_size=4)
    s = _sched(bm, budget=20, chunk=32, prefill_pack=4)
    reqs = [_req(n_prompt=n) for n in (12, 8, 6)]
    for r in reqs:
        s.add(r)
    p1 = s.schedule()
    assert [(c[1], c[2]) for c in p1.chunks] == [(reqs[0], 12), (reqs[1], 8)]
    for _, r, n in p1.chunks:
        r.num_computed += n
        r.out.append(7)
    p2 = s.schedule()                       # 2 decodes + the deferred chunk
    assert len(p2.decodes) == 2
    assert [(c[1], c[2]) for c in p2.chunks] == [(reqs[2], 6)]


def test_scheduler_pack_shares_chunk_width():
    """The packed flat batch is one compiled buffer: chunks also share the
    chunk_width allowance."""
    bm = BlockManager(num_blocks=33, block_size=4)
    s = _sched(bm, budget=40, chunk=16, prefill_pack=4)
    reqs = [_req(n_prompt=n) for n in (12, 8, 6)]
    for r in reqs:
        s.add(r)
    plan = s.schedule()
    assert [(c[1], c[2]) for c in plan.chunks] == [(reqs[0], 12),
                                                  (reqs[1], 4)]


def test_scheduler_pack_one_is_single_chunk():
    """prefill_pack=1 (the default) never plans more than one chunk — the
    old single-chunk contract."""
    bm = BlockManager(num_blocks=33, block_size=4)
    s = _sched(bm, budget=40, chunk=32)     # default pack
    assert s.prefill_pack == 1
    for n in (12, 8, 6):
        s.add(_req(n_prompt=n))
    while s.has_work:
        plan = s.schedule()
        assert len(plan.chunks) <= 1
        for _, r, n in plan.chunks:
            r.num_computed += n
            if r.num_computed == r.context_len:
                r.out.append(7)
        for _, r in plan.decodes:
            r.out.append(7)
        for slot, r in list(s.running.items()):
            if r.done:
                s.retire(slot)


def test_scheduler_pack_rejects_zero():
    with pytest.raises(ValueError):
        _sched(BlockManager(num_blocks=9, block_size=4), prefill_pack=0)


def test_scheduler_quantum_remainder_rolls_and_counts():
    """With a chunk quantum, a chunk's rounded-off remainder stays in the
    shared budget (funding the NEXT chunk) instead of evaporating; only
    the final chunk's loss is unrecoverable and lands in
    ``quantum_dropped_tokens``."""
    bm = BlockManager(num_blocks=33, block_size=4)
    s = _sched(bm, budget=23, chunk=32, prefill_pack=4, chunk_quantum=4)
    reqs = [_req(n_prompt=n, max_new=2) for n in (10, 10)]
    for r in reqs:
        s.add(r)
    p1 = s.schedule()
    # req0: want min(23, 32, 10) = 10 = remaining -> final chunk, exempt
    # req1: want min(13, 22, 10) = 10 -> final too: both run whole
    assert [(c[1], c[2]) for c in p1.chunks] == [(reqs[0], 10), (reqs[1], 10)]
    assert s.quantum_dropped_tokens == 0

    s2 = _sched(bm, budget=23, chunk=32, prefill_pack=4, chunk_quantum=4)
    reqs2 = [_req(n_prompt=n, max_new=2) for n in (14, 14)]
    for r in reqs2:
        s2.add(r)
    p = s2.schedule()
    # req0: want 14 = remaining, final, takes 14; req1: want min(9, 18, 14)
    # = 9, non-final -> quantized to 8, ONE token dropped and counted
    assert [(c[1], c[2]) for c in p.chunks] == [(reqs2[0], 14), (reqs2[1], 8)]
    assert s2.quantum_dropped_tokens == 1


# ---------------------------------------------------------------------------
# Engine: packed prefill is byte-identical to single-chunk serving
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def glm_params(tiny_mesh):
    from repro.models import api
    cfg = get_config("glm4_9b", smoke=True)
    with jax.set_mesh(tiny_mesh):
        params_f32, _ = api.init_model(cfg, jax.random.key(0))
        params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params_f32)
    return cfg, params


def test_engine_packed_prefill_matches_unpacked(tiny_mesh, glm_params):
    """A burst of short prompts: prefill_pack=4 packs several prompts into
    each step (fewer steps) with byte-identical greedy outputs."""
    from repro.serving import InferenceEngine, Request
    cfg, params = glm_params
    prompts = [RNG.integers(0, cfg.vocab_size, 24).astype(np.int32)
               for _ in range(6)]
    kw = dict(max_batch=8, block_size=16, max_len=96,
              max_num_batched_tokens=8 + 48, params=params,
              debug_invariants=True)
    plain = InferenceEngine(cfg, tiny_mesh, **kw)
    reqs_p = [Request(p.copy(), max_new=6) for p in prompts]
    want = plain.run(reqs_p, arrival_steps=[0] * 6)
    packed = InferenceEngine(cfg, tiny_mesh, prefill_pack=4, **kw)
    assert packed.prefill_pack == 4
    reqs_k = [Request(p.copy(), max_new=6) for p in prompts]
    got = packed.run(reqs_k, arrival_steps=[0] * 6)
    for a, b in zip(reqs_p, reqs_k):
        np.testing.assert_array_equal(want[a.rid], got[b.rid])
    # two 24-token chunks fit the 48-wide packed buffer per step
    assert packed.stats["steps"] < plain.stats["steps"]
    assert packed.stats["prefill_chunks"] == plain.stats["prefill_chunks"]


def test_engine_packed_prefix_cache_hits_match(tiny_mesh, glm_params):
    """Prefix-cache adoption under packing: staggered requests sharing a
    prompt adopt published blocks mid-pack, outputs stay identical."""
    from repro.serving import InferenceEngine, Request
    cfg, params = glm_params
    prompt = RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
    kw = dict(max_batch=4, block_size=16, max_len=96, params=params,
              debug_invariants=True)
    plain = InferenceEngine(cfg, tiny_mesh, **kw)
    reqs_p = [Request(prompt.copy(), max_new=6) for _ in range(3)]
    want = plain.run(reqs_p, arrival_steps=[0, 2, 4])
    packed = InferenceEngine(cfg, tiny_mesh, prefill_pack=4, **kw)
    reqs_k = [Request(prompt.copy(), max_new=6) for _ in range(3)]
    got = packed.run(reqs_k, arrival_steps=[0, 2, 4])
    assert packed.stats["cache_hit_tokens"] > 0
    for a, b in zip(reqs_p, reqs_k):
        np.testing.assert_array_equal(want[a.rid], got[b.rid])


def test_engine_packed_preemption_matches(tiny_mesh, glm_params):
    """Recompute-preemption with packing on: the re-admitted victim's
    recompute chunk rides a packed batch; outputs match the unconstrained
    single-chunk engine byte for byte."""
    from repro.serving import InferenceEngine, Request
    cfg, params = glm_params
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(2)]
    base = InferenceEngine(cfg, tiny_mesh, max_batch=2, block_size=16,
                           max_len=96, params=params, debug_invariants=True)
    want = base.run([Request(p.copy(), max_new=20) for p in prompts])
    want = list(want.values())
    tight = InferenceEngine(cfg, tiny_mesh, max_batch=2, block_size=16,
                            max_len=96, num_blocks=8, params=params,
                            prefill_pack=4, debug_invariants=True)
    reqs = [Request(p.copy(), max_new=20) for p in prompts]
    got = tight.run(reqs)
    assert tight.stats["preemptions"] >= 1
    for w, r in zip(want, reqs):
        np.testing.assert_array_equal(got[r.rid], w)


def test_engine_packed_speculative_matches(tiny_mesh):
    """Speculative decoding (k=2, self-draft) with packed prefill: both
    the draft and target prefill the packed batch; greedy outputs equal
    the single-chunk speculative engine byte for byte."""
    from repro.models import api
    from repro.serving import InferenceEngine, Request, SpeculativeRunner
    cfg = get_config("starcoder2_3b", smoke=True)
    with jax.set_mesh(tiny_mesh):
        params_f32, _ = api.init_model(cfg, jax.random.key(0))
        params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params_f32)
    prompts = [RNG.integers(0, cfg.vocab_size, 24).astype(np.int32)
               for _ in range(4)]
    kw = dict(max_batch=4, block_size=16, max_len=96, params=params,
              num_speculative_tokens=2, draft_params=params,
              debug_invariants=True)
    plain = InferenceEngine(cfg, tiny_mesh, **kw)
    reqs_p = [Request(p.copy(), max_new=8) for p in prompts]
    want = plain.run(reqs_p, arrival_steps=[0] * 4)
    packed = InferenceEngine(cfg, tiny_mesh, prefill_pack=4, **kw)
    assert isinstance(packed.runner, SpeculativeRunner)
    assert packed.prefill_pack == 4
    reqs_k = [Request(p.copy(), max_new=8) for p in prompts]
    got = packed.run(reqs_k, arrival_steps=[0] * 4)
    for a, b in zip(reqs_p, reqs_k):
        np.testing.assert_array_equal(want[a.rid], got[b.rid])
    assert packed.stats["spec_decodes"] >= 1


def test_engine_packed_forced_off_for_unsupported_runner(tiny_mesh):
    """Runners without a ragged prefill path (SSM) silently fall back to
    single-chunk plans instead of crashing."""
    from repro.serving import InferenceEngine
    cfg = get_config("mamba2_370m", smoke=True)
    eng = InferenceEngine(cfg, tiny_mesh, max_batch=2, block_size=16,
                          max_len=96, prefill_pack=4)
    assert eng.prefill_pack == 1
    assert eng.sched.prefill_pack == 1


# ---------------------------------------------------------------------------
# Front-end: dropped-stream counter surfaces in /metrics
# ---------------------------------------------------------------------------


def test_dropped_streams_metric_renders(tiny_mesh, glm_params):
    from repro.serving import InferenceEngine
    from repro.serving.frontend import AsyncEngineDriver
    from repro.serving.frontend.metrics import render_metrics
    cfg, params = glm_params
    eng = InferenceEngine(cfg, tiny_mesh, max_batch=2, block_size=16,
                          max_len=96, params=params)
    drv = AsyncEngineDriver(eng)
    assert "repro_frontend_dropped_streams_total 0" in render_metrics(
        eng, drv)
    drv.dropped_streams += 1            # what http.py does on SSE reset
    text = render_metrics(eng, drv)
    assert "repro_frontend_dropped_streams_total 1" in text
    assert "repro_engine_quantum_dropped_tokens_total 0" in text
