"""Prefill/decode cache correctness: decoding token t+1 from a prefilled
cache must equal running the full forward on the extended sequence. This is
the strongest single check of the KV-cache / SSM-state plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ParallelConfig, get_config
from repro.models import api

RNG = np.random.default_rng(7)
PCFG = ParallelConfig(remat="none")


@pytest.mark.parametrize("arch", ["glm4_9b", "gemma2_27b", "mamba2_370m",
                                  "zamba2_2p7b", "qwen3_moe_30b_a3b"])
def test_decode_equals_fresh_prefill(arch, tiny_mesh):
    """prefill(S) -> decode(token at S) must produce the same next token as
    prefill(S+1) on the extended sequence."""
    import dataclasses
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:
        # capacity drops are path-dependent (a dropped prefill token has no
        # decode analogue); use a no-drop capacity for the equality check
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    B, S = 2, 12
    toks = RNG.integers(0, cfg.vocab_size, (B, S + 1)).astype(np.int32)
    with jax.set_mesh(tiny_mesh):
        params, _ = api.init_model(cfg, jax.random.key(0))

        # ground truth: prefill the full S+1 prefix
        full_batch = {"tokens": jnp.asarray(toks)}
        if cfg.frontend == "vision":
            full_batch["positions"] = jnp.broadcast_to(
                jnp.arange(S + 1, dtype=jnp.int32)[None, None],
                (3, B, S + 1))
        _, tok_truth = api.prefill_fn(params, full_batch, cfg, PCFG)

        # prefill S tokens, then decode the (S+1)-th
        batch = {"tokens": jnp.asarray(toks[:, :S])}
        if cfg.frontend == "vision":
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S))
        cache, _ = api.prefill_fn(params, batch, cfg, PCFG)
        # grow attention caches S -> S+1 capacity
        def grow(x):
            if (x.ndim == 5 and x.shape[2] == S and cfg.num_kv_heads
                    and x.shape[-1] == cfg.head_dim):
                return jnp.pad(x, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0)))
            return x
        cache = jax.tree.map(grow, cache)
        tok_dec, _ = api.decode_fn(
            params, cache,
            {"token": jnp.asarray(toks[:, S:S + 1]),
             "pos": jnp.full((B,), S, jnp.int32)}, cfg, PCFG)

    np.testing.assert_array_equal(np.asarray(tok_dec),
                                  np.asarray(tok_truth))


def test_multi_step_decode_matches_teacher_forcing(tiny_mesh):
    """Decode 4 steps against teacher-forced prefill next-tokens (glm4)."""
    cfg = get_config("glm4_9b", smoke=True)
    B, S, N = 1, 8, 4
    toks = RNG.integers(0, cfg.vocab_size, (B, S + N)).astype(np.int32)
    with jax.set_mesh(tiny_mesh):
        params, _ = api.init_model(cfg, jax.random.key(1))
        cache, _ = api.prefill_fn(
            params, {"tokens": jnp.asarray(toks[:, :S])}, cfg, PCFG)

        def grow(x):
            if (x.ndim == 5 and x.shape[2] == S and cfg.num_kv_heads
                    and x.shape[-1] == cfg.head_dim):
                return jnp.pad(x, ((0, 0), (0, 0), (0, N), (0, 0), (0, 0)))
            return x
        cache = jax.tree.map(grow, cache)
        for i in range(N):
            truth_batch = {"tokens": jnp.asarray(toks[:, :S + i + 1])}
            _, tok_truth = api.prefill_fn(params, truth_batch, cfg, PCFG)
            tok_dec, cache = api.decode_fn(
                params, cache,
                {"token": jnp.asarray(toks[:, S + i:S + i + 1]),
                 "pos": jnp.full((B,), S + i, jnp.int32)}, cfg, PCFG)
            np.testing.assert_array_equal(np.asarray(tok_dec),
                                          np.asarray(tok_truth),
                                          err_msg=f"step {i}")
