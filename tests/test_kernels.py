"""Pallas kernel validation (interpret mode): shape/dtype sweeps against the
pure-jnp oracles in repro.kernels.ref, plus hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.embedding import gather
from repro.kernels.flash_attention import flash_attention
from repro.kernels.sampled_softmax import sampled_softmax_loss
from repro.kernels.ssd import ssd
from repro.models.attention import chunked_attention, dense_attention
from repro.models.ssm import ssd_chunked

RNG = np.random.default_rng(0)


def arr(*shape, dtype=jnp.float32, scale=1.0):
    return (scale * jnp.asarray(RNG.normal(0, 1, shape), jnp.float32)
            ).astype(dtype)


ATTN_CASES = [
    # B, Sq, Skv, H, K, hd, causal, window, cap, dtype
    (2, 256, 256, 4, 2, 64, True, None, None, jnp.bfloat16),
    (1, 128, 384, 4, 4, 128, True, None, 50.0, jnp.float32),
    (2, 256, 256, 8, 2, 64, True, 64, None, jnp.bfloat16),
    (1, 200, 200, 2, 1, 64, False, None, None, jnp.float32),
    (1, 64, 512, 6, 2, 32, True, 128, 30.0, jnp.float32),
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_vs_oracle(case):
    B, Sq, Skv, H, K, hd, causal, window, cap, dt = case
    q, k, v = arr(B, Sq, H, hd, dtype=dt), arr(B, Skv, K, hd, dtype=dt), \
        arr(B, Skv, K, hd, dtype=dt)
    o = flash_attention(q, k, v, causal, window, cap, None, 0, 128, 128,
                        True)
    r = ref.attention_ref(q, k, v, causal=causal, window=window, cap=cap)
    tol = 0.05 if dt == jnp.bfloat16 else 5e-3
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=tol)


@pytest.mark.parametrize("case", ATTN_CASES[:3])
def test_xla_attention_paths_vs_oracle(case):
    B, Sq, Skv, H, K, hd, causal, window, cap, dt = case
    q, k, v = arr(B, Sq, H, hd, dtype=dt), arr(B, Skv, K, hd, dtype=dt), \
        arr(B, Skv, K, hd, dtype=dt)
    r = ref.attention_ref(q, k, v, causal=causal, window=window, cap=cap)
    for fn in (dense_attention, chunked_attention):
        o = fn(q, k, v, causal=causal, window=window, cap=cap)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(r, np.float32), atol=0.05)


def test_flash_attention_grads_match_ref():
    q = arr(1, 128, 4, 64)
    k = arr(1, 128, 2, 64)
    v = arr(1, 128, 2, 64)

    def f_k(q, k, v):
        return jnp.sum(flash_attention(q, k, v, True, None, 30.0, None, 0,
                                       64, 64, True) ** 2)

    def f_r(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v, causal=True, cap=30.0)**2)

    gk = jax.grad(f_k, (0, 1, 2))(q, k, v)
    gr = jax.grad(f_r, (0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3,
                                   rtol=2e-2)


SSD_CASES = [
    (2, 64, 4, 16, 1, 16, 16),
    (1, 128, 8, 64, 1, 64, 32),
    (2, 96, 4, 32, 2, 16, 16),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_kernel_vs_oracle(case):
    b, S, nh, hp, G, N, Q = case
    x = arr(b, S, nh, hp)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, S, nh)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 4, (nh,)), jnp.float32)
    B = arr(b, S, G, N)
    C = arr(b, S, G, N)
    h0 = arr(b, nh, hp, N, scale=0.5)
    yr, hr = ref.ssd_ref(x, dt, A, B, C, h0=h0)
    yk, hk = ssd(x, dt, A, B, C, chunk=Q, h0=h0, interpret=True)
    yc, hc = ssd_chunked(x, dt, A, B, C, chunk=Q, h0=h0)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=1e-3)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr), atol=1e-3)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yr), atol=1e-3)


def test_ssd_decode_matches_scan_tail():
    """Chunked prefill state + one decode step == running S+1 steps."""
    from repro.models.ssm import ssd_decode_step
    b, S, nh, hp, G, N = 1, 32, 2, 16, 1, 16
    x = arr(b, S + 1, nh, hp)
    dt = jnp.asarray(RNG.uniform(0.01, 0.1, (b, S + 1, nh)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2, (nh,)), jnp.float32)
    B = arr(b, S + 1, G, N)
    C = arr(b, S + 1, G, N)
    y_all, h_all = ref.ssd_ref(x, dt, A, B, C)
    _, h_prefill = ssd_chunked(x[:, :S], dt[:, :S], A, B[:, :S], C[:, :S],
                               chunk=8)
    y1, h1 = ssd_decode_step(h_prefill, x[:, S], dt[:, S], A, B[:, S, :],
                             C[:, S, :])
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y_all[:, S]),
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h_all), atol=1e-3)


@given(st.integers(2, 50), st.integers(2, 9), st.integers(1, 64))
@settings(max_examples=10, deadline=None)
def test_gather_property(v, d, n):
    """Pallas gather == table[ids] for random sizes (hypothesis)."""
    rng = np.random.default_rng(v * 1000 + d)
    table = jnp.asarray(rng.normal(0, 1, (v, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, v, (n,)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(gather(table, ids, interpret=True)),
        np.asarray(table[ids]))


@pytest.mark.parametrize("T,d,V,n,cap", [
    (100, 64, 512, 32, None), (256, 128, 1024, 64, 30.0),
    (513, 64, 300, 16, None)])
def test_sampled_softmax_vs_oracle(T, d, V, n, cap):
    x = arr(T, d)
    table = arr(V, d, scale=0.05)
    labels = jnp.asarray(RNG.integers(0, V, (T,)), jnp.int32)
    sids = jnp.asarray(RNG.choice(V, n, replace=False), jnp.int32)
    lk = sampled_softmax_loss(x, table, labels, sids, cap=cap,
                              interpret=True)
    lr = ref.sampled_softmax_loss_ref(x, table, labels, sids, cap=cap)
    assert abs(float(lk) - float(lr)) < 1e-4


@pytest.mark.parametrize("S,window", [(512, None), (384, 128), (700, None)])
def test_block_causal_attention_vs_oracle(S, window):
    from repro.models.attention import block_causal_attention
    q = arr(1, S, 4, 32)
    k = arr(1, S, 2, 32)
    v = arr(1, S, 2, 32)
    o = block_causal_attention(q, k, v, window=window, chunk_kv=128,
                               block_q=256)
    r = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-4)


@given(st.integers(1, 3), st.integers(1, 4), st.integers(16, 64))
@settings(max_examples=8, deadline=None)
def test_attention_softmax_rows_sum_to_one(b, h, s):
    """Property: output of attention is a convex combination of v rows, so
    with constant v the output equals that constant."""
    s = (s // 8) * 8
    q = arr(b, s, h, 16)
    k = arr(b, s, h, 16)
    v = jnp.ones((b, s, h, 16), jnp.float32) * 3.5
    o = flash_attention(q, k, v, True, None, None, None, 0, 32, 32, True)
    np.testing.assert_allclose(np.asarray(o), 3.5, atol=1e-3)
