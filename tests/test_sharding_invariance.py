"""Property: the SPMD step is *placement-invariant* — the same model, batch
and seed produce the same loss on any mesh shape. This is the §3.3 claim
("the same program can be deployed to a cluster…") made executable. Runs in
subprocesses with 8 virtual devices."""

import pytest

from helpers import run_with_devices

CODE = """
import jax, jax.numpy as jnp, numpy as np
from repro.config import ParallelConfig, ShapeConfig, get_config
from repro.models import api

cfg = get_config("{arch}", smoke=True)
pcfg = ParallelConfig(remat="full")
shape = ShapeConfig("t", seq_len=16, global_batch=4, kind="train")
losses = []
for dshape in [(1, 1), (4, 1), (1, 4), (2, 4), (8, 1)]:
    mesh = jax.make_mesh(dshape, ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    with jax.set_mesh(mesh):
        params, _ = api.init_model(cfg, jax.random.key(0))
        batch = api.make_batch(cfg, shape, seed=1)
        loss, _ = jax.jit(lambda p, b: api.loss_fn(p, b, cfg, pcfg))(
            params, batch)
        losses.append(float(loss))
print("LOSSES", losses)
ref = losses[0]
for l in losses[1:]:
    assert abs(l - ref) / abs(ref) < 2e-2, losses
"""


@pytest.mark.parametrize("arch", ["glm4_9b", "qwen3_moe_30b_a3b",
                                  "mamba2_370m", "gemma2_27b"])
def test_loss_invariant_across_meshes(arch):
    out = run_with_devices(CODE.format(arch=arch), n_devices=8,
                           timeout=1200)
    assert "LOSSES" in out


DECODE_CODE = """
import jax, jax.numpy as jnp, numpy as np
from repro.config import ParallelConfig, get_config
from repro.models import api

cfg = get_config("glm4_9b", smoke=True)
pcfg = ParallelConfig(remat="none")
rng = np.random.default_rng(0)
toks = rng.integers(0, cfg.vocab_size, (4, 16)).astype(np.int32)
results = []
for dshape in [(1, 1), (2, 4)]:
    mesh = jax.make_mesh(dshape, ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    with jax.set_mesh(mesh):
        params, _ = api.init_model(cfg, jax.random.key(0))
        cache, tok = api.prefill_fn(params, {"tokens": jnp.asarray(toks)},
                                    cfg, pcfg)
        results.append(np.asarray(tok))
np.testing.assert_array_equal(results[0], results[1])
print("DECODE-INVARIANT OK")
"""


def test_prefill_tokens_invariant_across_meshes():
    out = run_with_devices(DECODE_CODE, n_devices=8, timeout=1200)
    assert "DECODE-INVARIANT OK" in out
