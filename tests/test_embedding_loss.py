"""Vocab-parallel embedding/losses vs naive oracles (paper §4.2/§6.4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.kernels.ref import sampled_softmax_loss_ref, softmax_xent_ref
from repro.models import embedding as emb

RNG = np.random.default_rng(3)


@pytest.fixture()
def cfg():
    return get_config("glm4_9b", smoke=True)


def test_embed_matches_table_rows(cfg, tiny_mesh):
    with jax.set_mesh(tiny_mesh):
        params, _ = emb.init_embedding(cfg, jax.random.key(0))
        toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
        out = emb.embed(params["table"], toks, cfg)
        expect = params["table"][toks].astype(out.dtype)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(expect, np.float32), atol=1e-2)


def test_lm_loss_matches_full_softmax(cfg, tiny_mesh):
    B, S, d = 2, 8, cfg.d_model
    with jax.set_mesh(tiny_mesh):
        params, _ = emb.init_embedding(cfg, jax.random.key(0))
        x = jnp.asarray(RNG.normal(0, 1, (B, S, d)), jnp.float32)
        labels = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)),
                             jnp.int32)
        loss = emb.lm_loss(x, params["table"], labels, cfg, chunk=4)
        logits = np.asarray(x.reshape(-1, d) @ params["table"].T,
                            np.float32)
        # padded vocab columns must not contribute
        logits = logits[:, :cfg.vocab_size]
        ref = softmax_xent_ref(jnp.asarray(logits), labels.reshape(-1))
    assert abs(float(loss) - float(ref)) < 1e-3


def test_lm_loss_grads_flow(cfg, tiny_mesh):
    with jax.set_mesh(tiny_mesh):
        params, _ = emb.init_embedding(cfg, jax.random.key(0))
        x = jnp.asarray(RNG.normal(0, 1, (2, 4, cfg.d_model)), jnp.float32)
        labels = jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 4)),
                             jnp.int32)

        def f(x, t):
            return emb.lm_loss(x, t, labels, cfg)

        gx, gt = jax.grad(f, (0, 1))(x, params["table"])
        assert float(jnp.max(jnp.abs(gx))) > 0
        assert float(jnp.max(jnp.abs(gt))) > 0
        assert bool(jnp.all(jnp.isfinite(gx)))
        # padded rows get zero gradient
        pad_rows = np.asarray(gt)[cfg.vocab_size:]
        if pad_rows.size:
            np.testing.assert_allclose(pad_rows, 0.0)


def test_sampled_softmax_matches_ref(cfg, tiny_mesh):
    B, S, d = 2, 8, cfg.d_model
    with jax.set_mesh(tiny_mesh):
        params, _ = emb.init_embedding(cfg, jax.random.key(0))
        x = jnp.asarray(RNG.normal(0, 1, (B, S, d)), jnp.float32)
        labels = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)),
                             jnp.int32)
        sids = jnp.asarray(RNG.choice(cfg.vocab_size, 16, replace=False),
                           jnp.int32)
        loss = emb.sampled_softmax_loss(x, params["table"], labels, sids,
                                        cfg)
        ref = sampled_softmax_loss_ref(
            x.reshape(-1, d), params["table"], labels.reshape(-1), sids)
    assert abs(float(loss) - float(ref)) < 1e-4


def test_decode_argmax_matches_naive(cfg, tiny_mesh):
    with jax.set_mesh(tiny_mesh):
        params, _ = emb.init_embedding(cfg, jax.random.key(0))
        x = jnp.asarray(RNG.normal(0, 1, (4, 1, cfg.d_model)), jnp.float32)
        tok = emb.decode_logits_argmax(x, params["table"], cfg)
        logits = np.asarray(x[:, 0] @ params["table"].T)[:, :cfg.vocab_size]
        np.testing.assert_array_equal(np.asarray(tok), logits.argmax(-1))


def test_padded_vocab_multiple_of_256():
    for arch in ("mamba2_370m", "whisper_large_v3", "glm4_9b"):
        cfg = get_config(arch)
        assert cfg.padded_vocab_size % 256 == 0
        assert cfg.padded_vocab_size >= cfg.vocab_size
        assert cfg.padded_vocab_size - cfg.vocab_size < 256
