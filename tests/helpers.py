"""Test helpers: subprocess runner with N virtual XLA devices, and the
static-batch serving oracle the engine equivalence tests compare against."""

from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


class StaticServerOracle:
    """The pre-refactor static-batch serving path, kept as a test oracle.

    This is the deleted legacy ``launch.serve.Server`` verbatim: pad every
    request to a common prompt length, monolithic prefill through
    ``api.prefill_fn``, grow the dense caches to max_len, then decode
    max(max_new) steps for the whole batch through ``api.decode_fn``. The
    engine's continuous-batching path must reproduce its greedy outputs
    byte for byte — serving is a latency/memory optimization, never a
    numerics change.
    """

    def __init__(self, cfg, mesh, pcfg=None, max_batch: int = 8,
                 prompt_len: int = 32, max_len: int = 128, seed: int = 0,
                 params=None):
        import jax
        import jax.numpy as jnp
        from repro.config import ParallelConfig
        from repro.models import api
        from repro.spmd import steps as steps_mod
        self.cfg, self.mesh = cfg, mesh
        self.pcfg = pcfg or ParallelConfig(remat="none")
        self.max_batch, self.prompt_len, self.max_len = (max_batch,
                                                         prompt_len, max_len)
        with jax.set_mesh(mesh):
            if params is None:
                params_f32, _ = api.init_model(cfg, jax.random.key(seed))
                params = jax.tree.map(
                    lambda x: x.astype(jnp.bfloat16), params_f32)
            self.params = params
            self._prefill = jax.jit(
                steps_mod.make_prefill_step(cfg, self.pcfg))
            self._decode = jax.jit(
                steps_mod.make_decode_step(cfg, self.pcfg),
                donate_argnums=(1,))

    def serve_batch(self, prompts, max_news, frames=None):
        """prompts: list of (prompt_len,) int32; max_news: list of int;
        frames: optional list of (T_enc, d_model) arrays (enc-dec).
        Returns a list of (max_new,) int32 generated-token arrays."""
        import jax
        import jax.numpy as jnp
        import numpy as np
        assert len(prompts) <= self.max_batch
        B = len(prompts)
        toks = np.stack([p[:self.prompt_len] for p in prompts])
        with jax.set_mesh(self.mesh):
            # prefill at full cache capacity: pad prompt region
            batch = {"tokens": jnp.asarray(toks, jnp.int32)}
            if self.cfg.frontend == "vision":
                batch["positions"] = jnp.broadcast_to(
                    jnp.arange(self.prompt_len, dtype=jnp.int32)[None, None],
                    (3, B, self.prompt_len))
            if self.cfg.frontend == "audio":
                if frames is None:
                    batch["frames"] = jnp.zeros(
                        (B, self.cfg.encoder_seq_len, self.cfg.d_model),
                        jnp.bfloat16)
                else:
                    batch["frames"] = jnp.asarray(
                        np.stack(frames), jnp.bfloat16)
            cache, tok = self._prefill(self.params, batch)
            # grow attention caches to max_len capacity
            cache = jax.tree_util.tree_map_with_path(self._grow, cache)
            outs = [tok]
            max_new = max(max_news)
            pos = jnp.full((B,), self.prompt_len, jnp.int32)
            for _ in range(max_new - 1):
                tok, cache = self._decode(
                    self.params, cache,
                    {"token": tok[:, None], "pos": pos})
                outs.append(tok)
                pos = pos + 1
        gen = np.stack([np.asarray(t) for t in outs], axis=1)
        return [gen[i, :max_news[i]] for i in range(B)]

    def _grow(self, path, x):
        """Pad self-attention K/V caches (L, B, S, K, hd) from prompt_len
        to max_len. Keyed on the cache pytree *path* (leaves named "k"/"v"),
        not shape sniffing: SSM conv/state leaves and enc-dec cross caches
        ("xk"/"xv") whose shapes happen to collide are left alone."""
        import jax
        import jax.numpy as jnp
        keys = [p.key for p in path
                if isinstance(p, jax.tree_util.DictKey)]
        if not (keys and keys[-1] in ("k", "v")):
            return x
        if not (x.ndim == 5 and x.shape[2] == self.prompt_len
                and x.shape[3] == self.cfg.num_kv_heads
                and x.shape[-1] == self.cfg.head_dim):
            return x
        pad = self.max_len - self.prompt_len
        return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                        + env.get("XLA_FLAGS", "")).strip()
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode}):\n--- stdout ---\n"
            f"{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout
