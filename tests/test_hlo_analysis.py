"""Roofline HLO analysis: trip-count scaling of collectives and dot flops
verified against a hand-checkable scanned SPMD program."""

import numpy as np

from helpers import run_with_devices

CODE = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.analysis import hlo

L, B, D = 7, 64, 128
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)

def step(ws, x):
    def body(h, w):
        return jnp.tanh(h @ w), None
    h, _ = jax.lax.scan(body, x, ws)
    return jnp.sum(h)

with jax.set_mesh(mesh):
    lowered = jax.jit(step, in_shardings=(
        NamedSharding(mesh, P(None, None, "model")),
        NamedSharding(mesh, P("data", None)))).lower(
        jax.ShapeDtypeStruct((L, D, D), jnp.float32),
        jax.ShapeDtypeStruct((B, D), jnp.float32))
    compiled = lowered.compile()
text = compiled.as_text()
ana = hlo.analyze(text, default_trip=L)
comps = hlo.split_computations(text)

# the per-layer all-gather (x over model before the matmul) must be x L
ag = [c for c in ana.collectives if c.kind == "all-gather"]
assert any(c.trip_mult == L for c in ag), [
    (c.kind, c.trip_mult, c.computation) for c in ana.collectives]

# dot flops: per device (B/2) x D x (D/4) x 2 x L
flops = hlo.dot_flops(comps, default_trip=L)
expect = 2 * (B // 2) * D * (D // 4) * L
assert abs(flops - expect) / expect < 0.05, (flops, expect)

# bytes estimate is positive and trip-scaled (>= L x one dot's operands)
bts = hlo.hlo_bytes(comps, default_trip=L)
assert bts > L * (B // 2) * D * 4
print("HLO ANALYSIS OK", flops, expect)
"""


def test_trip_scaled_flops_and_collectives():
    out = run_with_devices(CODE, n_devices=8)
    assert "HLO ANALYSIS OK" in out


def test_replica_group_size_parsing():
    from repro.analysis.hlo import replica_group_size
    assert replica_group_size("[16,16]<=[256]") == 16
    assert replica_group_size("[2,4]<=[8]") == 4
    assert replica_group_size("[64,4]<=[4,64]T(1,0)") == 4
    assert replica_group_size("{{0,1},{2,3}}") == 2


def test_shape_bytes():
    from repro.analysis.hlo import _shape_bytes
    assert _shape_bytes("f32[4,4096,4096]") == 4 * 4096 * 4096 * 4
    assert _shape_bytes("bf16[2,8]{1,0}") == 32
    assert _shape_bytes("(f32[2], s32[3])") == 8 + 12
