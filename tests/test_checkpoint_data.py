"""Checkpoint (§4.3) + data pipeline tests: roundtrip, retention policies,
best-metric keeps, async save, elastic restore on a different mesh, and
queue-pipeline backpressure/sharding."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import run_with_devices
from repro.checkpoint.checkpoint import CheckpointManager
from repro.config import get_config
from repro.data.pipeline import Pipeline, ShardedSource


def _state(v):
    return {"params": {"w": np.full((4, 2), v, np.float32),
                       "b": np.arange(3).astype(np.float32) * v},
            "opt": ({"m": np.ones(2, np.float32) * v},)}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(10, _state(3.0), metric=1.0)
    step, restored = mgr.restore(_state(0.0))
    assert step == 10
    np.testing.assert_allclose(restored["params"]["w"],
                               _state(3.0)["params"]["w"])
    np.testing.assert_allclose(restored["opt"][0]["m"], 3.0)


def test_retention_keeps_last_k(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    for s in range(5):
        mgr.save(s, _state(float(s)))
    assert mgr.steps() == [3, 4]


def test_retention_keeps_best_metric(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=1, keep_best=1, async_save=False)
    metrics = {0: 5.0, 1: 1.0, 2: 3.0, 3: 2.0}
    for s, m in metrics.items():
        mgr.save(s, _state(float(s)), metric=m)
    # step 1 (best metric) survives alongside the latest (3)
    assert set(mgr.steps()) == {1, 3}


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=True)
    mgr.save(7, _state(9.0))
    step, restored = mgr.restore(_state(0.0))   # restore waits for writer
    assert step == 7
    np.testing.assert_allclose(restored["params"]["b"],
                               np.arange(3) * 9.0)


ELASTIC_CODE = """
import repro.compat
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint.checkpoint import CheckpointManager
from repro.checkpoint.elastic import restore_for_mesh, save_global
from jax.sharding import NamedSharding, PartitionSpec as P
import tempfile

d = tempfile.mkdtemp()
mgr = CheckpointManager(d, async_save=False)
mesh_a = jax.make_mesh((4, 2), ("data", "model"),
                       axis_types=(jax.sharding.AxisType.Auto,) * 2)
mesh_b = jax.make_mesh((2, 2), ("data", "model"),
                       axis_types=(jax.sharding.AxisType.Auto,) * 2)
w = jnp.arange(64.0).reshape(8, 8)
sh_a = NamedSharding(mesh_a, P("data", "model"))
sh_b = NamedSharding(mesh_b, P(None, "model"))
state = {"w": jax.device_put(w, sh_a)}
save_global(mgr, 1, state)
step, restored = restore_for_mesh(mgr, {"w": w}, {"w": sh_b})
assert step == 1
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
assert restored["w"].sharding == sh_b
print("ELASTIC OK: 8 devices (4,2) -> 4 devices (2,2)")
"""


def test_elastic_restore_different_mesh():
    out = run_with_devices(ELASTIC_CODE, n_devices=8)
    assert "ELASTIC OK" in out


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_source_rank_sharding_disjoint_and_deterministic():
    cfg = get_config("glm4_9b", smoke=True)
    s0 = ShardedSource(cfg, 16, rank=0, world=2, seed=1)
    s1 = ShardedSource(cfg, 16, rank=1, world=2, seed=1)
    b0 = s0.batch(0, 8)
    b1 = s1.batch(0, 8)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    # determinism: same (rank, index, seed) -> identical batch
    np.testing.assert_array_equal(b0["tokens"], s0.batch(0, 8)["tokens"])
    # labels shifted by one
    full = ShardedSource(cfg, 16, seed=1).batch(3, 4)
    np.testing.assert_array_equal(full["tokens"][:, 1:],
                                  full["labels"][:, :-1])


def test_pipeline_backpressure_and_flow():
    cfg = get_config("glm4_9b", smoke=True)
    src = ShardedSource(cfg, 8, seed=0)
    pipe = Pipeline(src, 4, capacity=2, producers=1)
    time.sleep(0.3)
    assert pipe.q.qsize() <= 2          # bounded despite fast producer
    seen = [pipe.get() for _ in range(5)]
    assert all(b["tokens"].shape == (4, 8) for b in seen)
    pipe.close()
