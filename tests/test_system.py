"""End-to-end system behaviour: the full train driver (pipeline ->
microbatched mixed-precision step -> checkpoint -> resume) and the serving
driver (prefill -> batched KV-cache decode), on smoke configs."""

import numpy as np

from repro.config import OptimizerConfig, ParallelConfig, get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.train import train


def test_train_driver_learns_and_resumes(tmp_path):
    cfg = get_config("glm4_9b", smoke=True)
    mesh = make_host_mesh(1, 1)
    pcfg = ParallelConfig(remat="full", microbatches=2)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=5, total_steps=40)
    _, _, losses = train(cfg, steps=20, batch=4, seq=32, mesh=mesh,
                         pcfg=pcfg, ocfg=ocfg, ckpt_dir=tmp_path,
                         ckpt_every=10, log_every=100)
    assert len(losses) == 20
    assert all(np.isfinite(losses))
    # resume from the step-20 checkpoint and continue to 30
    _, _, losses2 = train(cfg, steps=30, batch=4, seq=32, mesh=mesh,
                          pcfg=pcfg, ocfg=ocfg, ckpt_dir=tmp_path,
                          ckpt_every=10, resume=True, log_every=100)
    assert len(losses2) == 10                       # resumed at step 20
    assert np.mean(losses2) < np.mean(losses[:5])   # still descending


def test_serve_driver_batched_decode():
    from repro.serving import InferenceEngine, Request
    cfg = get_config("qwen3_32b", smoke=True)       # qk-norm path
    eng = InferenceEngine(cfg, make_host_mesh(1, 1), max_batch=4,
                          block_size=16, max_len=32)
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                    max_new=8) for _ in range(4)]
    outs = eng.run(reqs)
    assert len(outs) == 4
    for r in reqs:
        assert outs[r.rid].shape == (8,)
        assert int(outs[r.rid].max()) < cfg.vocab_size
