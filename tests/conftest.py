"""Shared fixtures. NOTE: no global XLA_FLAGS here — smoke tests and benches
must see the real single CPU device; multi-device tests spawn subprocesses
(tests/helpers.py) that set --xla_force_host_platform_device_count first."""

import jax
import pytest

import repro.compat  # noqa: F401  (installs jax version shims for all tests)


@pytest.fixture(scope="session")
def tiny_mesh():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


ALL_ARCHS = [
    "glm4_9b", "starcoder2_3b", "gemma2_27b", "qwen3_32b",
    "whisper_large_v3", "zamba2_2p7b", "qwen2_vl_2b",
    "qwen3_moe_30b_a3b", "grok1_314b", "mamba2_370m",
]
