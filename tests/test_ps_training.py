"""§4.4 replication modes: all three learn; backup workers discard
stragglers' updates and beat plain sync wall-clock under injected straggle."""

import numpy as np
import pytest

from repro.core.cluster import Cluster
from repro.core.graph import Graph
from repro.ps.training import PSTrainer, linear_model

RNG = np.random.default_rng(0)
W_TRUE = RNG.normal(0, 1, (16, 8)).astype(np.float32)


def batch_fn(w, s):
    x = RNG.normal(0, 1, (32, 16)).astype(np.float32)
    return x, (x @ W_TRUE).argmax(-1)


def _make(mode, backup=0, strag=0.0):
    g = Graph()
    cl = Cluster(ps=2, worker=4)
    model = linear_model(g, 16, 8, n_shards=2)
    return PSTrainer(model, cl, mode=mode, n_workers=4,
                     backup_workers=backup, lr=0.5, straggler_s=strag,
                     straggler_every=3 if strag else 0)


@pytest.mark.parametrize("mode,backup", [("async", 0), ("sync", 0),
                                         ("backup", 1)])
def test_modes_learn(mode, backup):
    tr = _make(mode, backup)
    stats = tr.train(12, batch_fn)
    assert np.mean(stats.losses[-4:]) < np.mean(stats.losses[:4])


def test_backup_discards_stragglers():
    tr = _make("backup", backup=1, strag=0.05)
    stats = tr.train(8, batch_fn)
    assert stats.discarded > 0


def test_backup_faster_than_sync_under_straggle():
    sync = _make("sync", strag=0.05).train(8, batch_fn)
    backup = _make("backup", backup=1, strag=0.05).train(8, batch_fn)
    assert np.median(backup.step_times) < np.median(sync.step_times)


def test_params_live_on_ps_tasks():
    tr = _make("sync")
    tr.train(2, batch_fn)   # placement happens at plan-build time
    devs = {h.op.assigned_device for h in tr.model.var_handles}
    assert devs == {"ps:0", "ps:1"}
