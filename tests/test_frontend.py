"""Streaming front-end tests: Prometheus histogram semantics, SLO
admission-control math, and the async driver's equivalence contract —
requests streamed through ``AsyncEngineDriver`` (staggered submissions,
prefix-cache hits, preemption victims, speculative k=2) must produce
byte-identical token streams to ``engine.run()`` on the same workload,
with matching scheduling stats. Plus queue saturation / shed signals,
FCFS ordering, graceful drain, and the stdlib HTTP/SSE + /metrics +
/health surface end to end."""

import asyncio
import json

import jax
import numpy as np
import pytest

from repro.config import get_config
from repro.serving.frontend import (AdmissionController, AsyncEngineDriver,
                                    FrontendServer, ShedError,
                                    render_metrics)
from repro.serving.frontend.admission import MIN_RETRY_AFTER_S
from repro.serving.scheduler import Request, SamplingParams
from repro.serving.stats import Histogram

RNG = np.random.default_rng(7)

# ---------------------------------------------------------------------------
# Histogram (stats.py) — Prometheus exposition semantics
# ---------------------------------------------------------------------------


def test_histogram_observe_mean_percentile():
    h = Histogram((1.0, 2.0, 4.0))
    assert h.mean == 0.0 and h.percentile(95) == 0.0     # empty
    for v in (0.5, 1.5, 1.5, 3.0, 100.0):                # 100 -> +Inf bucket
        h.observe(v)
    assert h.count == 5
    assert h.mean == pytest.approx((0.5 + 1.5 + 1.5 + 3.0 + 100.0) / 5)
    assert h.counts == [1, 2, 1, 1]
    # conservative bucket-upper-bound estimates
    assert h.percentile(20) == 1.0
    assert h.percentile(50) == 2.0
    assert h.percentile(80) == 4.0
    assert h.percentile(99) == 4.0          # +Inf clamps to last finite


def test_histogram_prometheus_render_cumulative():
    h = Histogram((0.1, 1.0))
    for v in (0.05, 0.5, 0.5, 7.0):
        h.observe(v)
    out = []
    h.render("x_seconds", "help text", out)
    assert out[0] == "# HELP x_seconds help text"
    assert out[1] == "# TYPE x_seconds histogram"
    assert out[2] == 'x_seconds_bucket{le="0.1"} 1'
    assert out[3] == 'x_seconds_bucket{le="1"} 3'        # cumulative
    assert out[4] == 'x_seconds_bucket{le="+Inf"} 4'     # == _count
    assert out[5] == "x_seconds_sum 8.05"
    assert out[6] == "x_seconds_count 4"


def test_histogram_rejects_empty_buckets():
    with pytest.raises(ValueError):
        Histogram(())


def test_histogram_merge_equals_concatenated_samples():
    """Fleet aggregation contract: merging per-replica histograms is
    indistinguishable — counts, sum, percentiles, rendered text — from
    one histogram that observed every sample."""
    uppers = (0.1, 1.0, 4.0)
    samples = [[0.05, 0.5, 7.0], [0.5, 2.0], [], [0.09, 3.9, 100.0, 0.2]]
    parts = []
    whole = Histogram(uppers)
    for chunk in samples:
        h = Histogram(uppers)
        for v in chunk:
            h.observe(v)
            whole.observe(v)
        parts.append(h)
    merged = Histogram(uppers)
    for h in parts:
        assert merged.merge(h) is merged         # returns self (foldable)
    assert merged.counts == whole.counts
    assert merged.count == whole.count
    assert merged.total == pytest.approx(whole.total)
    for q in (5, 50, 95, 99):
        assert merged.percentile(q) == whole.percentile(q)
    got, want = [], []
    merged.render("m_seconds", "h", got)
    whole.render("m_seconds", "h", want)
    assert got == want
    # merging into a populated histogram keeps prior observations
    assert merged.merge(parts[0]).count == whole.count + 3


def test_histogram_merge_rejects_bucket_mismatch():
    with pytest.raises(ValueError):
        Histogram((1.0, 2.0)).merge(Histogram((1.0, 3.0)))


def test_histogram_render_labels_and_header():
    h = Histogram((0.5,))
    h.observe(0.1)
    out = []
    h.render("x_seconds", "h", out, labels={"replica": "1"}, header=False)
    assert out == ['x_seconds_bucket{replica="1",le="0.5"} 1',
                   'x_seconds_bucket{replica="1",le="+Inf"} 1',
                   'x_seconds_sum{replica="1"} 0.1',
                   'x_seconds_count{replica="1"} 1']


# ---------------------------------------------------------------------------
# AdmissionController — projection math and shed signals
# ---------------------------------------------------------------------------


def test_admission_cold_start_admits():
    """Empty TTFT window: the SLO projection is disabled (an estimator
    with no data must not shed) — only the queue bound applies."""
    adm = AdmissionController(ttft_slo_p95_s=0.001, max_queue=4)
    d = adm.decide(queue_depth=3)
    assert d.admit and d.reason == "" and d.projected_ttft_s == 0.0


def test_admission_queue_full_shed():
    adm = AdmissionController(max_queue=2)
    assert adm.decide(1).admit
    d = adm.decide(2)
    assert not d.admit and d.reason == "queue_full"
    assert d.retry_after_s >= MIN_RETRY_AFTER_S
    adm0 = AdmissionController(max_queue=0)
    assert not adm0.decide(0).admit          # zero queue sheds everything


def test_admission_slo_projection_and_retry():
    adm = AdmissionController(ttft_slo_p95_s=2.5)
    for _ in range(4):
        adm.note_ttft(2.0)                   # realized p95 = 2.0
    for t in (10.0, 11.0, 12.0):             # drain rate: 1 admit / 1.0s
        adm.note_admit(t)
    assert adm.ttft_p95() == 2.0
    assert adm.mean_admit_interval() == pytest.approx(1.0)
    assert adm.projected_ttft_p95(3) == pytest.approx(5.0)
    ok = adm.decide(queue_depth=0)           # projected 2.0 <= 2.5
    assert ok.admit and ok.projected_ttft_s == pytest.approx(2.0)
    shed = adm.decide(queue_depth=1)         # projected 3.0 > 2.5
    assert not shed.admit and shed.reason == "ttft_slo"
    assert shed.projected_ttft_s == pytest.approx(3.0)
    assert shed.retry_after_s == pytest.approx(0.5)      # projected - target
    # tiny overshoot still carries a positive retry hint
    adm2 = AdmissionController(ttft_slo_p95_s=2.0 - 1e-6)
    adm2.note_ttft(2.0)
    assert adm2.decide(0).retry_after_s >= MIN_RETRY_AFTER_S


def test_admission_counters_and_queue_peak():
    adm = AdmissionController()
    adm.note_submitted(queue_depth=0)
    adm.note_submitted(queue_depth=1)
    adm.note_submitted(queue_depth=2)
    adm.note_shed()
    adm.note_completed()
    assert (adm.submitted, adm.shed, adm.completed) == (3, 1, 1)
    assert adm.queue_peak == 3               # depth *after* each submit


def test_admission_rejects_negative_queue():
    with pytest.raises(ValueError):
        AdmissionController(max_queue=-1)


def test_admission_drain_rate_scales_with_replicas():
    """Regression for the dp fleet: the queue-drain term divides by the
    replica count — at a load dp=1 sheds on the TTFT projection, dp=2
    still admits (two replicas drain the shared queue twice as fast)."""
    def controller(n):
        adm = AdmissionController(ttft_slo_p95_s=2.5, n_replicas=n)
        for _ in range(4):
            adm.note_ttft(2.0)               # realized p95 = 2.0
        for t in (10.0, 11.0, 12.0):         # 1 admit / 1.0s observed
            adm.note_admit(t)
        return adm

    depth = 1                                # dp=1 projects 3.0 > 2.5
    assert not controller(1).decide(depth).admit
    d2 = controller(2).decide(depth)         # dp=2 projects 2.5 <= 2.5
    assert d2.admit and d2.projected_ttft_s == pytest.approx(2.5)
    assert controller(2).projected_ttft_p95(4) == pytest.approx(4.0)
    with pytest.raises(ValueError):
        AdmissionController(n_replicas=0)


# ---------------------------------------------------------------------------
# Async driver vs engine.run() — byte-identical streams
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_mesh():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


@pytest.fixture(scope="module")
def glm_params(tiny_mesh):
    import jax.numpy as jnp
    from repro.models import api
    cfg = get_config("glm4_9b", smoke=True)
    with jax.set_mesh(tiny_mesh):
        params_f32, _ = api.init_model(cfg, jax.random.key(0))
        params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params_f32)
    return cfg, params


def _engine(cfg, mesh, params, **kw):
    from repro.serving import InferenceEngine
    kw.setdefault("max_batch", 4)
    kw.setdefault("block_size", 16)
    kw.setdefault("max_len", 96)
    return InferenceEngine(cfg, mesh, params=params, debug_invariants=True,
                           **kw)


_SCHED_KEYS = ("steps", "tokens", "prefill_chunks", "prefill_tokens",
               "cache_hit_tokens", "preemptions", "cow_copies",
               "requests", "requests_done")


async def _stream_all(drv, reqs, arrivals):
    """Submit everything *before* the step thread starts, so the driver
    sees the same arrival picture engine.run() gets upfront — then the
    stream outputs AND the scheduling stats must match exactly."""
    streams = [await drv.submit(r, arrival_step=t)
               for r, t in zip(reqs, arrivals)]
    await drv.start()

    async def pull(s):
        return [ev async for ev in s]

    events = await asyncio.gather(*(pull(s) for s in streams))
    await drv.drain()
    return events


def test_stream_matches_engine_run(tiny_mesh, glm_params):
    """Staggered submissions with a full-prompt prefix-cache hit and a
    temperature request: token streams byte-identical to engine.run(),
    scheduling stats identical too (same virtual-clock admission)."""
    cfg, params = glm_params
    common = RNG.integers(0, cfg.vocab_size, 64).astype(np.int32)
    prompts = [common.copy(), common.copy(),           # full-prompt hit+COW
               RNG.integers(0, cfg.vocab_size, 32).astype(np.int32),
               RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)]
    temp = SamplingParams(temperature=0.9, top_k=16, seed=3)

    def make():
        return [Request(p.copy(), max_new=6,
                        sampling=temp if i == 3 else SamplingParams(),
                        rid=61000 + i)
                for i, p in enumerate(prompts)]

    arrivals = [0, 3, 3, 6]
    twin = _engine(cfg, tiny_mesh, params)
    want = twin.run(make(), arrival_steps=arrivals)

    eng = _engine(cfg, tiny_mesh, params)
    drv = AsyncEngineDriver(eng)
    reqs = make()
    events = asyncio.run(_stream_all(drv, reqs, arrivals))

    for r, evs in zip(reqs, events):
        np.testing.assert_array_equal([e.token for e in evs], want[r.rid])
        assert [e.index for e in evs] == list(range(len(evs)))
        assert [e.text for e in evs] == [f"{e.token} " for e in evs]
    assert eng.stats["cache_hit_tokens"] > 0        # the duplicate hit
    for k in _SCHED_KEYS:
        assert eng.stats[k] == twin.stats[k], k
    assert drv.admission.completed == 4 and drv.admission.shed == 0


def test_stream_preemption_equivalence(tiny_mesh, glm_params):
    """A recompute-preemption victim streams byte-identically: preempted
    tokens were already delivered (the engine replays, never re-emits)."""
    cfg, params = glm_params
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(2)]

    def make():
        return [Request(p.copy(), max_new=20) for p in prompts]

    twin = _engine(cfg, tiny_mesh, params, max_batch=2, num_blocks=8)
    want = list(twin.run(make()).values())
    assert twin.stats["preemptions"] >= 1

    eng = _engine(cfg, tiny_mesh, params, max_batch=2, num_blocks=8)
    drv = AsyncEngineDriver(eng)
    reqs = make()
    events = asyncio.run(_stream_all(drv, reqs, [0, 0]))
    assert eng.stats["preemptions"] >= 1
    for w, evs in zip(want, events):
        np.testing.assert_array_equal([e.token for e in evs], w)
    for k in _SCHED_KEYS:
        assert eng.stats[k] == twin.stats[k], k


def test_stream_speculative_k2_equivalence(tiny_mesh):
    """Speculative draft-and-verify (k=2, self-draft) behind the driver:
    streams match engine.run() and the spec counters agree."""
    import jax.numpy as jnp
    from repro.models import api
    from repro.serving import InferenceEngine, SpeculativeRunner
    cfg = get_config("starcoder2_3b", smoke=True)
    with jax.set_mesh(tiny_mesh):
        params_f32, _ = api.init_model(cfg, jax.random.key(0))
        params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params_f32)
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(2)]

    def make():
        return [Request(p.copy(), max_new=8) for p in prompts]

    def spec_engine():
        return InferenceEngine(cfg, tiny_mesh, max_batch=2, block_size=16,
                               max_len=96, params=params,
                               num_speculative_tokens=2, draft_params=params,
                               debug_invariants=True)

    twin = spec_engine()
    want = list(twin.run(make(), arrival_steps=[0, 2]).values())
    eng = spec_engine()
    assert isinstance(eng.runner, SpeculativeRunner)
    drv = AsyncEngineDriver(eng)
    reqs = make()
    events = asyncio.run(_stream_all(drv, reqs, [0, 2]))
    for w, evs in zip(want, events):
        np.testing.assert_array_equal([e.token for e in evs], w)
    assert eng.stats["spec_decodes"] >= 1
    assert eng.stats["spec_decodes"] == twin.stats["spec_decodes"]
    assert eng.stats["spec_emitted"] == twin.stats["spec_emitted"]
    assert eng.mean_accept_len > 1.0        # self-draft: full acceptance


# ---------------------------------------------------------------------------
# Admission over the driver: saturation, FCFS, SLO shed, graceful drain
# ---------------------------------------------------------------------------


def test_queue_saturation_sheds_with_retry_signal(tiny_mesh, glm_params):
    cfg, params = glm_params
    prompts = [RNG.integers(0, cfg.vocab_size, 16).astype(np.int32)
               for _ in range(3)]
    eng = _engine(cfg, tiny_mesh, params, max_batch=1)
    adm = AdmissionController(max_queue=2)
    drv = AsyncEngineDriver(eng, admission=adm)

    async def go():
        s0 = await drv.submit(Request(prompts[0], max_new=4))
        s1 = await drv.submit(Request(prompts[1], max_new=4))
        with pytest.raises(ShedError) as ei:
            await drv.submit(Request(prompts[2], max_new=4))
        assert ei.value.reason == "queue_full"
        assert ei.value.retry_after_s > 0
        assert drv.queue_depth == 2          # shed request never queued
        await drv.start()

        done_order = []

        async def pull(s):
            toks = [ev.token async for ev in s]
            done_order.append(s.request.rid)
            return toks

        outs = await asyncio.gather(pull(s0), pull(s1))
        await drv.aclose()
        return (s0, s1), done_order, outs

    (s0, s1), done_order, outs = asyncio.run(go())
    # max_batch=1: strict FCFS — first submitted finishes (and first-tokens)
    # first
    assert done_order == [s0.request.rid, s1.request.rid]
    assert s0.first_token_wall <= s1.first_token_wall
    assert all(len(t) == 4 for t in outs)
    assert (adm.submitted, adm.shed, adm.completed) == (2, 1, 2)
    assert adm.queue_peak == 2
    # a TTFT sample per request reached the controller and the histograms
    assert len(adm._ttft) == 2
    assert eng.hist["ttft_seconds"].count == 2


def test_slo_shed_carries_projection(tiny_mesh, glm_params):
    """With a hot TTFT window above target, submit sheds with the
    projected p95 and a retry hint; drain-before-start aborts queued
    streams and further submits shed as draining."""
    cfg, params = glm_params
    eng = _engine(cfg, tiny_mesh, params)
    adm = AdmissionController(ttft_slo_p95_s=2.5)
    for _ in range(3):
        adm.note_ttft(2.0)
    for t in (5.0, 6.0, 7.0):                # 1.0s per admission
        adm.note_admit(t)
    drv = AsyncEngineDriver(eng, admission=adm)
    prompt = RNG.integers(0, cfg.vocab_size, 16).astype(np.int32)

    async def go():
        s0 = await drv.submit(Request(prompt.copy(), max_new=4))
        with pytest.raises(ShedError) as ei:   # depth 1 -> projected 3.0
            await drv.submit(Request(prompt.copy(), max_new=4))
        assert ei.value.reason == "ttft_slo"
        assert ei.value.projected_ttft_s == pytest.approx(3.0)
        assert ei.value.retry_after_s == pytest.approx(0.5)
        await drv.drain()                      # never started
        with pytest.raises(RuntimeError, match="drained before start"):
            await s0.__anext__()
        with pytest.raises(ShedError) as ei2:
            await drv.submit(Request(prompt.copy(), max_new=4))
        assert ei2.value.reason == "draining"

    asyncio.run(go())
    assert adm.shed == 1                      # draining sheds don't count


def test_graceful_drain_retires_all_admitted(tiny_mesh, glm_params):
    """drain() immediately after submission: every admitted request still
    retires with its full output buffered in a closed stream, and the
    engine remains usable as a batch driver after aclose()."""
    cfg, params = glm_params
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(3)]
    eng = _engine(cfg, tiny_mesh, params, max_batch=2)
    drv = AsyncEngineDriver(eng)

    async def go():
        await drv.start()
        streams = [await drv.submit(Request(p, max_new=5)) for p in prompts]
        await drv.drain()                     # before consuming anything
        assert drv.queue_depth == 0
        with pytest.raises(ShedError):
            await drv.submit(Request(prompts[0], max_new=1))
        assert eng.sched.draining             # refuses direct adds too
        outs = []
        for s in streams:
            outs.append([ev.token async for ev in s])
        assert all(s.finished for s in streams)
        await drv.aclose()
        return outs

    outs = asyncio.run(go())
    assert all(len(t) == 5 for t in outs)
    assert eng.stats["requests_done"] == 3
    assert drv.admission.completed == 3
    # aclose() detached the hooks and cleared the drain flag: the same
    # warm engine serves the batch path again (the bench reuse pattern)
    assert not eng.sched.draining and eng.on_token is None
    out = eng.run([Request(prompts[0].copy(), max_new=3)])
    assert len(next(iter(out.values()))) == 3


# ---------------------------------------------------------------------------
# HTTP surface (stdlib client over asyncio.open_connection)
# ---------------------------------------------------------------------------


async def _http(port, raw: bytes):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write(raw)
    await writer.drain()
    data = await reader.read()               # Connection: close -> EOF
    writer.close()
    await writer.wait_closed()
    head, _, body = data.partition(b"\r\n\r\n")
    status = int(head.split(b" ", 2)[1])
    headers = {k.strip().lower(): v.strip() for k, v in
               (ln.split(":", 1) for ln in
                head.decode().split("\r\n")[1:] if ":" in ln)}
    return status, headers, body


def _post(path: str, payload) -> bytes:
    body = (payload if isinstance(payload, bytes)
            else json.dumps(payload).encode())
    return (f"POST {path} HTTP/1.1\r\nHost: t\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\n\r\n").encode() + body


def _get(path: str) -> bytes:
    return f"GET {path} HTTP/1.1\r\nHost: t\r\n\r\n".encode()


def _sse_events(body: bytes):
    return [json.loads(ln[len("data: "):]) for ln in body.decode().split("\n")
            if ln.startswith("data: ") and ln != "data: [DONE]"]


def _assert_prometheus_valid(text: str):
    """Every sample line parses; histogram buckets are cumulative and the
    +Inf bucket equals _count."""
    buckets: dict[str, list[float]] = {}
    counts: dict[str, float] = {}
    for ln in text.strip().split("\n"):
        if ln.startswith("#"):
            assert ln.startswith(("# HELP ", "# TYPE ")), ln
            continue
        name, val = ln.rsplit(" ", 1)
        v = float(val)                       # every sample parses
        if "_bucket{" in name:
            buckets.setdefault(name.split("_bucket{")[0], []).append(v)
        elif name.endswith("_count"):
            counts[name[:-len("_count")]] = v
    assert buckets, "no histograms rendered"
    for base, cum in buckets.items():
        assert cum == sorted(cum), f"{base} buckets not cumulative"
        assert cum[-1] == counts[base], f"{base} +Inf != _count"


def test_http_sse_health_metrics(tiny_mesh, glm_params):
    cfg, params = glm_params
    prompt = [int(t) for t in
              RNG.integers(0, cfg.vocab_size, 24)]
    twin = _engine(cfg, tiny_mesh, params)
    want = next(iter(twin.run(
        [Request(np.asarray(prompt, np.int32), max_new=5)]).values()))

    eng = _engine(cfg, tiny_mesh, params)
    drv = AsyncEngineDriver(eng)

    async def go():
        async with drv:
            srv = FrontendServer(drv, port=0)
            await srv.start()
            p = srv.port
            st, hdr, body = await _http(p, _get("/health"))
            assert st == 200 and json.loads(body)["status"] == "ok"

            st, hdr, body = await _http(
                p, _post("/generate", {"prompt": prompt, "max_new": 5}))
            assert st == 200
            assert hdr["content-type"].startswith("text/event-stream")
            events = _sse_events(body)
            toks = [e["token"] for e in events if "token" in e]
            done = [e for e in events if e.get("done")]
            assert len(done) == 1 and done[0]["n_tokens"] == 5

            st, _, body = await _http(p, _get("/metrics"))
            assert st == 200
            text = body.decode()
            _assert_prometheus_valid(text)
            assert "repro_engine_tokens_total 5" in text
            assert "repro_engine_requests_done_total 1" in text
            assert "repro_engine_ttft_seconds_count 1" in text
            assert "repro_frontend_requests_submitted_total 1" in text
            assert "repro_frontend_requests_shed_total 0" in text
            assert "repro_frontend_queue_depth 0" in text

            st, _, body = await _http(p, _get("/nope"))
            assert st == 404
            st, _, body = await _http(p, _post("/generate", b"not json"))
            assert st == 400 and b"invalid JSON" in body
            st, _, body = await _http(
                p, _post("/generate", {"prompt": []}))
            assert st == 400 and b"prompt" in body
            st, _, body = await _http(
                p, _post("/generate", {"prompt": prompt, "max_new": 0}))
            assert st == 400
            await srv.aclose()
            return toks

    toks = asyncio.run(go())
    np.testing.assert_array_equal(toks, want)   # greedy: rid-independent


def test_http_shed_maps_to_429(tiny_mesh, glm_params):
    cfg, params = glm_params
    eng = _engine(cfg, tiny_mesh, params)
    drv = AsyncEngineDriver(eng, admission=AdmissionController(max_queue=0))
    prompt = [1] * 8

    async def go():
        # no driver start: nothing admits, the queue bound sheds instantly
        srv = FrontendServer(drv, port=0)
        await srv.start()
        st, hdr, body = await _http(
            srv.port, _post("/generate", {"prompt": prompt}))
        assert st == 429
        assert int(hdr["retry-after"]) >= 1
        err = json.loads(body)
        assert err["reason"] == "queue_full" and err["retry_after_s"] > 0
        await srv.aclose()

    asyncio.run(go())
    assert drv.admission.shed == 1


def test_render_metrics_without_driver(tiny_mesh, glm_params):
    """The metrics renderer also works bare (no front-end attached)."""
    cfg, params = glm_params
    eng = _engine(cfg, tiny_mesh, params)
    text = render_metrics(eng)
    _assert_prometheus_valid(text)
    assert "repro_engine_cache_hit_rate 0" in text      # div-zero guarded
    assert 'repro_engine_kv_dtype{kv_dtype="bf16"} 1' in text
    assert "repro_engine_swap_space_mib 0" in text      # tiering off
    assert "repro_engine_swap_preemptions_total 0" in text
    assert "repro_frontend" not in text


# ---------------------------------------------------------------------------
# Per-request cancellation: driver abort path + disconnect-triggered abort
# ---------------------------------------------------------------------------


def test_driver_abort_cancels_pending_and_running(tiny_mesh, glm_params):
    """abort() kills a request that is still queued (never reaches the
    engine) and one that is mid-generation (engine abort between steps);
    the survivor's stream is untouched and every block is released."""
    cfg, params = glm_params
    prompts = [RNG.integers(0, cfg.vocab_size, 16).astype(np.int32)
               for _ in range(3)]
    eng = _engine(cfg, tiny_mesh, params, max_batch=1)
    drv = AsyncEngineDriver(eng)

    async def go():
        s0 = await drv.submit(Request(prompts[0].copy(), max_new=24))
        s1 = await drv.submit(Request(prompts[1].copy(), max_new=4))
        s2 = await drv.submit(Request(prompts[2].copy(), max_new=4))
        drv.abort(s2.request.rid)          # aborted before the loop starts
        await drv.start()
        toks0 = []
        async for ev in s0:
            toks0.append(ev.token)
            if len(toks0) == 2:
                drv.abort(s0.request.rid)  # mid-stream abort
        toks1 = [ev.token async for ev in s1]
        await drv.drain()
        return toks0, toks1

    toks0, toks1 = asyncio.run(go())
    assert len(toks1) == 4                  # survivor runs to completion
    assert 2 <= len(toks0) < 24             # victim's stream closed early
    assert drv.aborted == 2
    assert eng.stats["aborts"] >= 1         # s0 was live inside the engine
    assert eng.bm.stats().blocks_in_use == 0
    eng.bm.check()


def test_http_disconnect_aborts_request(tiny_mesh, glm_params):
    """A client that vanishes mid-SSE-stream cancels its request: the
    driver abort path fires, generation stops early, and both the
    dropped-stream and aborted counters land in /metrics."""
    cfg, params = glm_params
    prompt = [int(t) for t in RNG.integers(0, cfg.vocab_size, 16)]
    eng = _engine(cfg, tiny_mesh, params)
    drv = AsyncEngineDriver(eng)

    async def go():
        async with drv:
            srv = FrontendServer(drv, port=0)
            await srv.start()
            reader, writer = await asyncio.open_connection(
                "127.0.0.1", srv.port)
            writer.write(_post("/generate",
                               {"prompt": prompt, "max_new": 64}))
            await writer.drain()
            got = b""
            while got.count(b"data: ") < 2:    # two tokens, then vanish
                got += await reader.read(256)
            writer.close()
            await writer.wait_closed()
            for _ in range(200):               # wait for the abort to land
                if drv.aborted:
                    break
                await asyncio.sleep(0.05)
            st, _, body = await _http(srv.port, _get("/metrics"))
            assert st == 200
            await srv.aclose()
            return body.decode()

    text = asyncio.run(go())
    assert drv.dropped_streams == 1
    assert drv.aborted == 1
    assert eng.stats["aborts"] == 1
    assert eng.stats["tokens"] < 64            # stopped well before max_new
    assert "repro_frontend_aborted_requests_total 1" in text
    assert "repro_frontend_dropped_streams_total 1" in text
    assert "repro_engine_aborts_total 1" in text
    assert eng.bm.stats().blocks_in_use == 0


def test_http_sampling_fields_logprobs_and_stop(tiny_mesh, glm_params):
    """/generate accepts the full sampling surface: a logprobs request
    streams per-token logprob objects over SSE (greedy, so tokens are
    byte-identical to engine.run), a stop-sequence request retires early
    in-engine, the new counters land in /metrics, and malformed stop
    bodies are a 400."""
    cfg, params = glm_params
    prompt = [int(t) for t in RNG.integers(0, cfg.vocab_size, 24)]
    twin = _engine(cfg, tiny_mesh, params)
    want = next(iter(twin.run(
        [Request(np.asarray(prompt, np.int32), max_new=8)]).values()))
    stop = [int(want[2]), int(want[3])]      # matches at stream index 3

    eng = _engine(cfg, tiny_mesh, params)
    drv = AsyncEngineDriver(eng)

    async def go():
        async with drv:
            srv = FrontendServer(drv, port=0)
            await srv.start()
            p = srv.port
            st, _, body = await _http(p, _post(
                "/generate",
                {"prompt": prompt, "max_new": 8, "logprobs": 2}))
            assert st == 200
            events = [e for e in _sse_events(body) if "token" in e]
            assert [e["token"] for e in events] == list(want)
            for e in events:
                lp = e["logprobs"]
                assert lp["token_logprob"] <= 0.0
                assert len(lp["top"]) == 2
                assert lp["top"][0][1] >= lp["top"][1][1]

            st, _, body = await _http(p, _post(
                "/generate",
                {"prompt": prompt, "max_new": 8, "stop": [stop]}))
            assert st == 200
            events = _sse_events(body)
            toks = [e["token"] for e in events if "token" in e]
            assert toks == list(want[:4])     # retired at the stop match
            assert "logprobs" not in events[0]
            done = [e for e in events if e.get("done")]
            assert done[0]["n_tokens"] == 4

            st, _, body = await _http(p, _get("/metrics"))
            text = body.decode()
            assert "repro_engine_stop_hits_total 1" in text
            assert "repro_engine_full_sampling_steps_total" in text

            st, _, body = await _http(p, _post(
                "/generate", {"prompt": prompt, "stop": [["x"]]}))
            assert st == 400 and b"stop" in body
            st, _, body = await _http(p, _post(
                "/generate", {"prompt": prompt, "top_p": 0.0}))
            assert st == 400 and b"top_p" in body
            st, _, body = await _http(p, _post(
                "/generate", {"prompt": prompt, "max_new": 4,
                              "min_new": 9}))
            assert st == 400 and b"min_new" in body
            await srv.aclose()

    asyncio.run(go())


def test_stream_full_pipeline_equivalence(tiny_mesh, glm_params):
    """A top-p + penalties request streamed through the driver is
    byte-identical to the same request through engine.run() — the full
    sampling executables behave identically under the async front-end."""
    cfg, params = glm_params
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(2)]
    sp = SamplingParams(temperature=0.9, top_p=0.85, repetition_penalty=1.3,
                        seed=4)

    def make():
        return [Request(p.copy(), max_new=10, sampling=sp, rid=62000 + i)
                for i, p in enumerate(prompts)]

    twin = _engine(cfg, tiny_mesh, params)
    want = twin.run(make())
    assert twin.stats["full_sampling_steps"] > 0

    eng = _engine(cfg, tiny_mesh, params)
    drv = AsyncEngineDriver(eng)
    reqs = make()
    events = asyncio.run(_stream_all(drv, reqs, [0, 0]))
    for r, evs in zip(reqs, events):
        np.testing.assert_array_equal([e.token for e in evs], want[r.rid])
