"""MoE block correctness: the capacity-dispatch shard_map implementation vs
a dense reference that evaluates the routed experts directly."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.models import moe as moe_mod

RNG = np.random.default_rng(11)


def dense_moe_reference(params, x, cfg):
    """Evaluate top-k experts per token exactly (no capacity, no drops)."""
    mo = cfg.moe
    T, d = x.shape
    w, idx, probs = moe_mod._route(
        jnp.asarray(x), params["router"].astype(jnp.float32),
        mo.experts_per_token)
    wg = np.asarray(params["w_gate"], np.float32)
    wi = np.asarray(params["w_in"], np.float32)
    wo = np.asarray(params["w_out"], np.float32)
    act = jax.nn.silu if cfg.mlp_activation == "silu" else (
        lambda v: jax.nn.gelu(v, approximate=True))
    y = np.zeros((T, d), np.float32)
    for t in range(T):
        for j in range(mo.experts_per_token):
            e = int(idx[t, j])
            h = np.asarray(act(jnp.asarray(x[t] @ wg[e]))) * (x[t] @ wi[e])
            y[t] += float(w[t, j]) * (h @ wo[e])
    return y


@pytest.mark.parametrize("arch", ["qwen3_moe_30b_a3b", "grok1_314b"])
def test_moe_block_matches_dense_reference(arch, tiny_mesh):
    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    B, S = 2, 8
    x = RNG.normal(0, 0.5, (B, S, cfg.d_model)).astype(np.float32)
    with jax.set_mesh(tiny_mesh):
        params, _ = moe_mod.init_moe(cfg, jax.random.key(0))
        y, aux = moe_mod.moe_block(params, jnp.asarray(x), cfg)
    ref = dense_moe_reference(params, x.reshape(-1, cfg.d_model), cfg)
    np.testing.assert_allclose(np.asarray(y).reshape(-1, cfg.d_model), ref,
                               atol=2e-3, rtol=1e-2)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens(tiny_mesh):
    """With capacity_factor -> tiny, overflowing tokens contribute zeros."""
    cfg = get_config("qwen3_moe_30b_a3b", smoke=True)
    tiny = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.01))
    x = jnp.asarray(RNG.normal(0, 0.5, (1, 64, cfg.d_model)), jnp.float32)
    with jax.set_mesh(tiny_mesh):
        params, _ = moe_mod.init_moe(cfg, jax.random.key(0))
        y_tiny, _ = moe_mod.moe_block(params, x, tiny)
        y_full, _ = moe_mod.moe_block(params, x, cfg)
    # dropped rows -> strictly smaller output norm
    assert float(jnp.linalg.norm(y_tiny)) < float(jnp.linalg.norm(y_full))


def test_moe_grads_flow_to_experts_and_router(tiny_mesh):
    cfg = get_config("qwen3_moe_30b_a3b", smoke=True)
    x = jnp.asarray(RNG.normal(0, 0.5, (1, 16, cfg.d_model)), jnp.float32)
    with jax.set_mesh(tiny_mesh):
        params, _ = moe_mod.init_moe(cfg, jax.random.key(0))

        def f(p):
            y, aux = moe_mod.moe_block(p, x, cfg)
            return jnp.sum(y * y) + 0.01 * aux

        grads = jax.grad(f)(params)
    for name in ("router", "w_gate", "w_in", "w_out"):
        assert float(jnp.max(jnp.abs(grads[name]))) > 0, name
        assert bool(jnp.all(jnp.isfinite(grads[name]))), name
