"""Quantized KV-page tests: quantize→dequantize error bounds per dtype,
and the fused-dequant attention paths — a quantized pool + scale sidecar
fed to the op must be *bit-identical* to dequantizing the pool by hand
and calling the same op, because every path round-trips through the one
``dequantize_kv`` convention before the attention math."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.quant import (KV_DTYPES, QMAX, dequantize_kv,
                                is_quantized, kv_dtype_bytes, kv_dtype_name,
                                quantize_kv)

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# Round-trip error bounds
# ---------------------------------------------------------------------------


# int8: half-step rounding error <= amax/254, plus bf16 output rounding
# (~2^-8 relative). fp8 e4m3: 3 mantissa bits, half-ulp relative error
# 2^-4 of the element, <= amax elementwise.
@pytest.mark.parametrize("name,err_frac", [("int8", 0.01), ("fp8", 0.07)])
def test_roundtrip_error_bound(name, err_frac):
    x = jnp.asarray(RNG.normal(0, 3, (5, 7, 2, 32)),
                    jnp.float32).astype(jnp.bfloat16)
    q, s = quantize_kv(x, name)
    assert q.dtype == KV_DTYPES[name]
    assert s.dtype == jnp.float32 and s.shape == x.shape[:-1] + (1,)
    deq = dequantize_kv(q, s)
    assert deq.dtype == jnp.bfloat16
    xf = np.asarray(x, np.float32)
    amax = np.max(np.abs(xf), -1, keepdims=True)
    err = np.abs(np.asarray(deq, np.float32) - xf)
    assert np.all(err <= err_frac * amax + 1e-6), float(np.max(err / amax))


def test_roundtrip_zero_rows_exact():
    q, s = quantize_kv(jnp.zeros((3, 4, 8), jnp.bfloat16), "int8")
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.asarray(s) > 0)          # eps-guarded, never 0
    assert np.all(np.asarray(dequantize_kv(q, s)) == 0)


def test_int8_symmetric_extremes_hit_qmax():
    q, s = quantize_kv(jnp.asarray([[1.0, -1.0, 0.5, -0.25]],
                                   jnp.bfloat16), "int8")
    qn = np.asarray(q, np.int32)
    assert qn[0, 0] == 127 and qn[0, 1] == -127     # symmetric full range
    np.testing.assert_allclose(np.asarray(s)[0, 0], 1.0 / 127.0, rtol=1e-6)


def test_dtype_helpers_roundtrip():
    for name, dt in KV_DTYPES.items():
        assert kv_dtype_name(dt) == name
        assert kv_dtype_bytes(name) == jnp.dtype(dt).itemsize
        assert is_quantized(name) == (name in QMAX)
    with pytest.raises(ValueError):
        kv_dtype_name(jnp.float64)


# ---------------------------------------------------------------------------
# Fused dequant in the attention ops
# ---------------------------------------------------------------------------


def _quant_case(name, B, K, hd, bs, nblk):
    N = 1 + B * nblk
    kp = jnp.asarray(RNG.normal(0, 1, (N, bs, K, hd)),
                     jnp.float32).astype(jnp.bfloat16)
    vp = jnp.asarray(RNG.normal(0, 1, (N, bs, K, hd)),
                     jnp.float32).astype(jnp.bfloat16)
    qk, sk = quantize_kv(kp, name)
    qv, sv = quantize_kv(vp, name)
    perm = RNG.permutation(np.arange(1, N))[:B * nblk].reshape(B, nblk)
    bt = jnp.asarray(perm, jnp.int32)
    ctx = jnp.asarray(RNG.integers(1, nblk * bs + 1, (B,)), jnp.int32)
    return (dequantize_kv(qk, sk), dequantize_kv(qv, sv),
            qk, sk, qv, sv, bt, ctx)


@pytest.mark.parametrize("name", ["int8", "fp8"])
def test_paged_decode_fused_dequant_bit_identical(name):
    from repro.kernels.paged_attention import paged_attention
    from repro.kernels.ref import paged_attention_ref
    B, H, K, hd, bs, nblk = 3, 4, 2, 16, 8, 4
    dk, dv, qk, sk, qv, sv, bt, ctx = _quant_case(name, B, K, hd, bs, nblk)
    q = jnp.asarray(RNG.normal(0, 1, (B, H, hd)),
                    jnp.float32).astype(jnp.bfloat16)
    o_pre = paged_attention_ref(q, dk, dv, bt, ctx)
    o_fused = paged_attention_ref(q, qk, qv, bt, ctx,
                                  k_scale=sk, v_scale=sv)
    np.testing.assert_array_equal(np.asarray(o_fused, np.float32),
                                  np.asarray(o_pre, np.float32))
    # the interpret-mode kernel fuses the same dequant convention
    o_k = paged_attention(q, qk, qv, bt, ctx, k_scale=sk, v_scale=sv,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_pre, np.float32), atol=2e-2)


@pytest.mark.parametrize("name", ["int8", "fp8"])
def test_paged_chunk_fused_dequant_bit_identical(name):
    from repro.kernels import ops as kops
    B, H, K, hd, bs, nblk, C = 2, 4, 2, 16, 8, 4, 8
    dk, dv, qk, sk, qv, sv, bt, _ = _quant_case(name, B, K, hd, bs, nblk)
    q = jnp.asarray(RNG.normal(0, 1, (B, C, H, hd)),
                    jnp.float32).astype(jnp.bfloat16)
    qlen = jnp.asarray([C, C - 3], jnp.int32)
    ctx = jnp.asarray([C + 5, C], jnp.int32)
    o_pre = kops.paged_prefill_attention(q, dk, dv, bt, ctx, qlen)
    o_fused = kops.paged_prefill_attention(q, qk, qv, bt, ctx, qlen,
                                           k_scale=sk, v_scale=sv)
    np.testing.assert_array_equal(np.asarray(o_fused, np.float32),
                                  np.asarray(o_pre, np.float32))
