"""Replica-equivalence harness for the data-parallel router
(docs/multi-host.md): requests routed across dp∈{1,2,3} engine replicas —
including cross-replica prefix-cache hits through the SharedPrefixIndex,
preemption on one replica, speculative k=2, and full-sampling rows — must
produce byte-identical per-request token streams to a single engine on
the same workload. Disaggregated prefill/decode hands KV off as hashed
blocks and must match too. Plus a Hypothesis random walk over the shared
index's publish/adopt/evict state machine against two BlockManagers."""

import numpy as np
import pytest

from repro.config import get_config
from repro.serving import ReplicaRouter, SharedPrefixIndex
from repro.serving.kv_cache import BlockManager
from repro.serving.scheduler import Request, SamplingParams

RNG = np.random.default_rng(11)


# ---------------------------------------------------------------------------
# SharedPrefixIndex — deterministic unit coverage
# ---------------------------------------------------------------------------


def _chain(tag: bytes, n: int) -> list[bytes]:
    return [tag + bytes([i]) for i in range(n)]


def test_shared_index_publish_adopt_cycle():
    idx = SharedPrefixIndex(num_slots=4)
    hs = _chain(b"a", 3)
    slots = []
    for h in hs:
        s = idx.reserve(h)
        assert s is not None and not idx.contains(h)   # invisible until commit
        idx.commit(s, h)
        assert idx.contains(h)
        slots.append(s)
    assert idx.reserve(hs[0]) is None                  # already committed
    pairs = idx.acquire(hs + [b"missing"])
    assert [h for _, h in pairs] == hs                 # longest prefix only
    assert [s for s, _ in pairs] == slots
    st = idx.stats()
    assert (st["published_blocks"], st["adopted_blocks"]) == (3, 3)
    # all 4 slots pinned-or-committed with 3 pins: one publish still fits,
    # a second finds nothing evictable
    s4 = idx.reserve(b"x1")
    assert s4 is not None
    assert idx.reserve(b"x2") is None                  # everything pinned
    idx.abandon(s4)
    idx.release([s for s, _ in pairs])
    idx.check()


def test_shared_index_racing_publishers_first_commit_wins():
    """Two replicas can reserve the same hash before either commits (the
    register-time dedup is advisory): the second commit must drop its
    copy, not orphan a slot or shadow the first."""
    idx = SharedPrefixIndex(num_slots=4)
    s_a = idx.reserve(b"h")
    s_b = idx.reserve(b"h")                  # raced: not committed yet
    assert s_a is not None and s_b is not None and s_a != s_b
    idx.commit(s_a, b"h")
    idx.commit(s_b, b"h")                    # loser: slot returns to free
    assert idx.stats()["published_blocks"] == 1
    assert [s for s, _ in idx.acquire([b"h"])] == [s_a]
    assert idx.reserve(b"x") == s_b          # the freed slot is reusable
    idx.check()


def test_shared_index_lru_eviction_and_pin_protection():
    idx = SharedPrefixIndex(num_slots=2)
    for h in (b"h1", b"h2"):
        idx.commit(idx.reserve(h), h)
    pinned = idx.acquire([b"h1"])                      # pin h1
    s3 = idx.reserve(b"h3")                            # must evict h2, not h1
    assert s3 is not None
    idx.commit(s3, b"h3")
    assert idx.contains(b"h1") and not idx.contains(b"h2")
    assert idx.stats()["evicted_blocks"] == 1
    pinned += idx.acquire([b"h3"])                     # pin h3 as well
    assert idx.reserve(b"h4") is None                  # everything pinned
    idx.release([s for s, _ in pinned])
    assert idx.reserve(b"h4") is not None              # evictable again
    idx.check()


def test_shared_index_pool_layout_must_match():
    idx = SharedPrefixIndex(num_slots=2)
    idx.attach_pool([((4, 8), np.dtype(np.float32))])
    idx.attach_pool([((4, 8), np.dtype(np.float32))])  # same layout: ok
    with pytest.raises(ValueError):
        idx.attach_pool([((4, 9), np.dtype(np.float32))])


# ---------------------------------------------------------------------------
# Hypothesis random walk: two BlockManagers against one shared index
# ---------------------------------------------------------------------------


_WALK_CHAINS = [_chain(bytes([t]), 4) for t in range(6)]
_WALK_BS = 4


def _shared_index_walk(rng):
    """One random publish/adopt/retire/evict/swap interleaving over two
    BlockManagers and a shared index; ``rng`` is any ``random.Random``-
    compatible source (a Hypothesis-controlled one when available)."""
    BS = _WALK_BS
    shared = SharedPrefixIndex(num_slots=6)
    bms = [BlockManager(8, BS, num_host_blocks=3, shared_index=shared)
           for _ in range(2)]
    live: list[dict] = [{}, {}]          # per-bm rid -> n_blocks
    pins: list[tuple[list, list]] = []   # held acquires: (slots, hashes)
    next_rid = [1000, 2000]

    def check_all():
        shared.check()
        for bm in bms:
            bm.check()
        # no adopted block outlives its payload: a pinned slot keeps its
        # committed hash until released, evictions notwithstanding
        for slots, hashes in pins:
            for s, h in zip(slots, hashes):
                assert shared._hash_of.get(s) == h

    for _ in range(rng.randint(10, 30)):
        op = rng.choice(("alloc", "publish", "adopt", "release",
                         "retire", "truncate", "swap"))
        i = rng.randint(0, 1)
        bm = bms[i]
        if op == "alloc":
            chain = rng.choice(_WALK_CHAINS)
            n = rng.randint(1, 4)
            if bm.num_free >= n:
                rid = next_rid[i] = next_rid[i] + 1
                blocks = bm.allocate(rid, n * BS)
                for b, h in zip(blocks, chain):
                    bm.register(b, h)
                live[i][rid] = n
        elif op == "publish":
            for b, h in bm.drain_publishable():
                s = shared.reserve(h)
                if s is None:
                    continue
                if rng.random() < 0.2:
                    shared.abandon(s)       # e.g. a raced/failed d2h copy
                else:
                    shared.commit(s, h)
        elif op == "adopt":
            chain = rng.choice(_WALK_CHAINS)
            pairs = shared.acquire(chain, limit=bm.num_free)
            if pairs:
                rid = next_rid[i] = next_rid[i] + 1
                bm.host_copy_in(rid, [s for s, _ in pairs],
                                [h for _, h in pairs])
                live[i][rid] = len(pairs)
                pins.append(([s for s, _ in pairs],
                             [h for _, h in pairs]))
        elif op == "release" and pins:
            slots, _ = pins.pop(rng.randrange(len(pins)))
            shared.release(slots)
        elif op == "retire" and live[i]:
            rid = rng.choice(sorted(live[i]))
            if not bm.is_swapped(rid):
                bm.free(rid)
                del live[i][rid]
        elif op == "truncate" and live[i]:
            rid = rng.choice(sorted(live[i]))
            if not bm.is_swapped(rid):
                bm.truncate(rid, BS)
                live[i][rid] = 1
        elif op == "swap" and live[i]:
            rid = rng.choice(sorted(live[i]))
            if not bm.is_swapped(rid) and bm.can_swap_out(rid):
                bm.swap_out(rid)
                if bm.can_swap_in(rid) and rng.random() < 0.5:
                    bm.swap_in(rid)
                elif bm.is_swapped(rid):
                    bm.swap_discard(rid)
                    del live[i][rid]
        check_all()
    for slots, _ in pins:
        shared.release(slots)
    check_all()


def test_shared_index_random_walk_two_managers():
    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        # hypothesis isn't in the image: fall back to fixed-seed walks so
        # the property still runs (same interleavings every time)
        import random
        for seed in range(60):
            _shared_index_walk(random.Random(seed))
        return

    @settings(max_examples=60, deadline=None)
    @given(st.randoms(use_true_random=False))
    def prop(rng):
        _shared_index_walk(rng)

    prop()


# ---------------------------------------------------------------------------
# Router vs single engine — byte-identical per-request streams
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def glm_params(tiny_mesh):
    import jax
    import jax.numpy as jnp
    from repro.models import api
    cfg = get_config("glm4_9b", smoke=True)
    with jax.set_mesh(tiny_mesh):
        params_f32, _ = api.init_model(cfg, jax.random.key(0))
        params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params_f32)
    return cfg, params


def _engine(cfg, mesh, params, shared=None, **kw):
    from repro.serving import InferenceEngine
    kw.setdefault("max_batch", 4)
    kw.setdefault("block_size", 16)
    kw.setdefault("max_len", 96)
    return InferenceEngine(cfg, mesh, params=params, shared_index=shared,
                           debug_invariants=True, **kw)


def _fleet(cfg, mesh, params, dp, *, shared_slots=64, router_kw=None, **kw):
    shared = SharedPrefixIndex(num_slots=shared_slots)
    engines = [_engine(cfg, mesh, params, shared=shared, **kw)
               for _ in range(dp)]
    return ReplicaRouter(engines, **(router_kw or {})), engines


FULL = SamplingParams(temperature=0.8, top_p=0.9, min_p=0.02,
                      repetition_penalty=1.1, presence_penalty=0.2,
                      frequency_penalty=0.1, top_k=0, logprobs=2, seed=5)
TEMP = SamplingParams(temperature=0.9, top_k=16, seed=3)


def _workload(cfg, n=6):
    """Duplicate prompts (prefix sharing), a temperature row, and a
    full-sampling-pipeline row; rids fixed so sampling streams are
    placement-independent."""
    common = RNG.integers(0, cfg.vocab_size, 64).astype(np.int32)
    prompts = [common.copy(), common.copy()] + [
        RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
        for _ in range(n - 2)]
    sampling = {n - 1: FULL, n - 2: TEMP}

    def make():
        return [Request(p.copy(), max_new=6,
                        sampling=sampling.get(i, SamplingParams()),
                        rid=71000 + i)
                for i, p in enumerate(prompts)]
    return make


@pytest.mark.parametrize("dp", [1, 2, 3])
def test_dp_byte_identity(tiny_mesh, glm_params, dp):
    """The headline pin: dp∈{1,2,3} routed outputs byte-identical per
    request to one engine — duplicate prompts, temperature and
    full-sampling rows, staggered arrivals."""
    cfg, params = glm_params
    make = _workload(cfg, n=6)
    arrivals = [0, 2, 3, 3, 5, 6]
    single = _engine(cfg, tiny_mesh, params)
    want = single.run(make(), arrival_steps=arrivals)

    router, engines = _fleet(cfg, tiny_mesh, params, dp)
    got = router.run(make(), arrival_steps=arrivals)
    assert sorted(got) == sorted(want)
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid],
                                      err_msg=f"rid {rid} dp={dp}")
    assert sum(router.routed) == 6
    if dp > 1:
        assert all(n > 0 for n in router.routed)    # spread, not pile-up
    assert (sum(e.stats["tokens"] for e in engines)
            == single.stats["tokens"])
    assert single.stats["full_sampling_steps"] > 0   # FULL row exercised


def test_dp2_cross_replica_prefix_hit(tiny_mesh, glm_params):
    """A prompt served (and retired) on replica 0 is adopted on replica 1
    through the shared index: second batch routes its duplicate to the
    other replica, which admits with shared-index hits and still matches
    the single engine byte for byte."""
    cfg, params = glm_params
    common = RNG.integers(0, cfg.vocab_size, 64).astype(np.int32)
    short = RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)

    def batch1():
        return [Request(common.copy(), max_new=6, rid=72000)]

    def batch2():
        # least-outstanding routing: 72001 -> replica 0, 72002 -> replica 1;
        # 72002 duplicates batch1's prompt, served by replica 0
        return [Request(common.copy(), max_new=6, rid=72001),
                Request(common.copy(), max_new=6, rid=72002),
                Request(short.copy(), max_new=6, rid=72003)]

    single = _engine(cfg, tiny_mesh, params)
    want = {**single.run(batch1()), **single.run(batch2())}

    router, engines = _fleet(cfg, tiny_mesh, params, 2)
    got = router.run(batch1())
    assert router.routed == [1, 0]
    got.update(router.run(batch2()))
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid],
                                      err_msg=f"rid {rid}")
    # replica 1 never computed the common prompt locally: its copy came
    # from the shared index (4 full 16-token blocks of the 64 prompt)
    assert engines[1].stats["shared_hit_blocks"] == 4
    assert engines[0].stats["shared_published_blocks"] >= 4
    assert router.shared_stats()["adopted_blocks"] >= 4


def test_dp2_preemption_on_one_replica(tiny_mesh, glm_params):
    """Block pressure preempts on one replica while the other cruises:
    preemption replay is placement-invariant, so outputs still match an
    uncontended single engine."""
    cfg, params = glm_params
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(3)]

    def make():
        return [Request(p.copy(), max_new=20, rid=73000 + i)
                for i, p in enumerate(prompts)]

    single = _engine(cfg, tiny_mesh, params, max_batch=4, max_len=128)
    want = single.run(make())

    # equal costs tie-break to replica 0 twice: it runs 2 requests on a
    # starved pool (the preemption shape test_frontend pins for dp=1)
    router, engines = _fleet(cfg, tiny_mesh, params, 2,
                             max_batch=2, num_blocks=8, max_len=128)
    got = router.run(make())
    assert router.routed == [2, 1]
    assert engines[0].stats["preemptions"] >= 1
    assert engines[1].stats["preemptions"] == 0
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid],
                                      err_msg=f"rid {rid}")


def test_dp2_speculative_k2(tiny_mesh):
    """Draft-and-verify replicas behind the router: acceptance windows and
    realigned replay are per-request state, so dp=2 spec output matches
    the single spec engine."""
    import jax
    import jax.numpy as jnp
    from repro.models import api
    from repro.serving import InferenceEngine
    cfg = get_config("starcoder2_3b", smoke=True)
    with jax.set_mesh(tiny_mesh):
        params_f32, _ = api.init_model(cfg, jax.random.key(0))
        params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params_f32)
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(3)]

    def make():
        return [Request(p.copy(), max_new=8, rid=74000 + i)
                for i, p in enumerate(prompts)]

    def spec_engine():
        return InferenceEngine(cfg, tiny_mesh, max_batch=2, block_size=16,
                               max_len=96, params=params,
                               num_speculative_tokens=2, draft_params=params,
                               debug_invariants=True)

    single = spec_engine()
    want = single.run(make())
    assert single.stats["spec_decodes"] > 0

    router = ReplicaRouter([spec_engine(), spec_engine()])
    got = router.run(make())
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid],
                                      err_msg=f"rid {rid}")
    assert sum(e.stats["spec_decodes"] for e in router.engines) > 0


# ---------------------------------------------------------------------------
# Disaggregated prefill/decode
# ---------------------------------------------------------------------------


def test_disagg_handoff_byte_identity(tiny_mesh, glm_params):
    """Prefill-role probe + decode-role continuation, KV handed off as
    published hashed blocks: the stitched streams equal the colocated
    single engine, every request hands off, and the decode replica admits
    from the shared index (no prefill recompute)."""
    cfg, params = glm_params
    make = _workload(cfg, n=4)
    arrivals = [0, 3, 3, 6]
    single = _engine(cfg, tiny_mesh, params)
    want = single.run(make(), arrival_steps=arrivals)

    router, engines = _fleet(cfg, tiny_mesh, params, 2,
                             router_kw=dict(disaggregate=True))
    got = router.run(make(), arrival_steps=arrivals)
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid],
                                      err_msg=f"rid {rid}")
    assert router.handoffs == 4                      # every request split
    assert router.routed == [4, 0]                   # probes all prefill-side
    assert engines[1].stats["shared_hit_blocks"] > 0
    assert engines[0].stats["shared_published_blocks"] > 0
    # the decode replica adopted, not recomputed, the prompt prefixes
    assert engines[1].stats["cache_hit_tokens"] > 0


def test_disagg_stop_and_min_new(tiny_mesh, glm_params):
    """Host-side stop semantics across the handoff: a token-1 stop match
    retires during the probe (no handoff); min_new >= 2 defers the stop
    check past the probe exactly like the colocated engine."""
    cfg, params = glm_params
    prompt = RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
    probe = _engine(cfg, tiny_mesh, params)
    t = probe.run([Request(prompt.copy(), max_new=4, rid=75000)])[75000]
    stop = ((int(t[0]),),)                           # matches at token 1

    def make():
        sp = SamplingParams(stop=stop)
        return [Request(prompt.copy(), max_new=6, sampling=sp, rid=75001),
                Request(prompt.copy(), max_new=6, sampling=sp, rid=75002,
                        min_new=3)]

    single = _engine(cfg, tiny_mesh, params)
    want = single.run(make())
    assert len(want[75001]) == 1                     # stop hit at token 1
    assert len(want[75002]) >= 3                     # min_new defers it

    router, _ = _fleet(cfg, tiny_mesh, params, 2,
                       router_kw=dict(disaggregate=True))
    got = router.run(make())
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid],
                                      err_msg=f"rid {rid}")
    assert router.handoffs == 1                      # 75001 never left prefill


def test_router_validation():
    class _Dummy:
        shared_index = None

    with pytest.raises(ValueError):
        ReplicaRouter([])
    with pytest.raises(ValueError):
        ReplicaRouter([_Dummy()], disaggregate=True)           # dp < 2
    with pytest.raises(ValueError):
        ReplicaRouter([_Dummy(), _Dummy()], disaggregate=True,
                      n_prefill=2)                             # no decoders
    with pytest.raises(ValueError):
        ReplicaRouter([_Dummy(), _Dummy()], disaggregate=True)  # no index
