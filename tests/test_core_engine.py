"""Core dataflow engine tests (paper §3-4): graph, autodiff, variables,
queues, Switch/Merge, placement/partition with Send/Recv, sparse embedding
Part/Gather/Stitch, concurrent steps."""

import threading

import numpy as np
import pytest

import repro.core.ops          # noqa: F401
import repro.core.partition    # noqa: F401
import repro.core.queues       # noqa: F401
import repro.core.variables    # noqa: F401
from repro.core.cluster import Cluster
from repro.core.control_flow import cond
from repro.core.gradients import gradients
from repro.core.graph import Graph
from repro.core.session import Session


@pytest.fixture()
def sess():
    g = Graph()
    cl = Cluster(ps=2, worker=2)
    return g, Session(g, cl, default_device="worker:0")


def test_autodiff_matmul_mean(sess):
    g, s = sess
    x = g.placeholder("x")
    w = g.apply("Variable", var_name="w",
                initial=np.array([[1., 2.], [3., 4.]], np.float32),
                device="ps:0")
    wv = g.apply("Read", w)
    loss = g.apply("ReduceMean", g.apply("Square", g.apply("MatMul", x, wv)))
    (gw,) = gradients(loss, [wv])
    xv = np.eye(2, dtype=np.float32)
    lv, gv = s.run([loss, gw], {x: xv})
    assert np.isclose(lv, 7.5)
    np.testing.assert_allclose(gv, np.array([[.5, 1.], [1.5, 2.]]))


def test_variable_update_cross_device(sess):
    g, s = sess
    w = g.apply("Variable", var_name="w", initial=np.ones(3, np.float32),
                device="ps:1")
    wv = g.apply("Read", w)
    upd = g.apply("AssignAdd", w, g.constant(np.float32(2.0)))
    s.run(upd)
    np.testing.assert_allclose(s.run(wv), 3.0 * np.ones(3))


def test_scatter_add_sparse_update(sess):
    g, s = sess
    w = g.apply("Variable", var_name="emb",
                initial=np.zeros((4, 2), np.float32), device="ps:0")
    ids = g.placeholder("ids")
    rows = g.placeholder("rows")
    upd = g.apply("ScatterAdd", w, ids, rows)
    s.run(upd, {ids: np.array([1, 1, 3]),
                rows: np.ones((3, 2), np.float32)})
    out = s.run(g.apply("Read", w))
    np.testing.assert_allclose(out, [[0, 0], [2, 2], [0, 0], [1, 1]])


def test_queue_blocking_backpressure(sess):
    g, s = sess
    q = g.apply("FIFOQueue", queue_name="q", capacity=2, device="worker:1")
    item = g.placeholder("item")
    enq = g.apply("Enqueue", q, item)
    deq = g.apply("Dequeue", q)
    s.run(enq, {item: np.array(1.0)})
    s.run(enq, {item: np.array(2.0)})
    # third enqueue blocks until a consumer dequeues (backpressure)
    done = threading.Event()

    def producer():
        s.run(enq, {item: np.array(3.0)})
        done.set()

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    assert not done.wait(0.3), "enqueue should block on a full queue"
    assert s.run(deq) == 1.0
    assert done.wait(2.0), "enqueue should complete after dequeue"
    assert s.run(deq) == 2.0
    assert s.run(deq) == 3.0


def test_switch_merge_cond(sess):
    g, s = sess
    p = g.placeholder("p")
    a = g.placeholder("a")
    r = cond(p, lambda t: t * g.constant(2.0),
             lambda f: f + g.constant(100.0), [a])
    assert s.run(r, {p: np.array(True), a: np.array(3.0)}) == 6.0
    assert s.run(r, {p: np.array(False), a: np.array(3.0)}) == 103.0


def test_sharded_embedding_part_gather_stitch(sess):
    """Figure 3: two-way sharded embedding lookup, gradients included."""
    g, s = sess
    e0 = g.apply("Variable", var_name="e0",
                 initial=np.arange(8.).reshape(4, 2).astype(np.float32),
                 device="ps:0")
    e1 = g.apply("Variable", var_name="e1",
                 initial=(np.arange(8.) + 100).reshape(4, 2).astype(
                     np.float32), device="ps:1")
    ids = g.placeholder("ids")
    shard = g.apply("FloorDiv", ids, g.constant(4))
    l0, l1 = g.apply("DynamicPartition", ids, shard, num_partitions=2)
    i0, i1 = g.apply("DynamicPartitionIndices", shard, num_partitions=2)
    r0 = g.apply("Read", e0)
    r1 = g.apply("Read", e1)
    g0 = g.apply("Gather", r0, l0)
    g1 = g.apply("Gather", r1, g.apply("Sub", l1, g.constant(4)))
    emb = g.apply("DynamicStitch", i0, i1, g0, g1, n=2)
    loss = g.apply("ReduceSum", emb)
    (d0, d1) = gradients(loss, [r0, r1])
    idv = np.array([0, 5, 3, 4])
    out, gv0, gv1 = s.run([emb, d0, d1], {ids: idv})
    np.testing.assert_allclose(out[0], [0, 1])
    np.testing.assert_allclose(out[1], [102, 103])
    np.testing.assert_allclose(out[2], [6, 7])
    np.testing.assert_allclose(out[3], [100, 101])
    # gradient lands only on touched rows
    np.testing.assert_allclose(gv0.sum(axis=1), [2, 0, 0, 2])
    np.testing.assert_allclose(gv1.sum(axis=1), [2, 2, 0, 0])


def test_placement_round_robin_and_colocation(sess):
    g, s = sess
    handles = [g.apply("Variable", var_name=f"v{i}",
                       initial=np.zeros(1, np.float32), device="ps:*")
               for i in range(4)]
    reads = [g.apply("Read", h) for h in handles]
    s.run(reads)
    devs = [h.op.assigned_device for h in handles]
    assert set(devs) == {"ps:0", "ps:1"}, devs
    # Read colocates with its Variable
    for h, r in zip(handles, reads):
        assert r.op.assigned_device == h.op.assigned_device


def test_send_recv_inserted_for_cross_device_edges(sess):
    g, s = sess
    a = g.apply("Variable", var_name="a",
                initial=np.array([2.0], np.float32), device="ps:0")
    b = g.apply("Read", a)
    c = g.apply("Mul", b, g.constant(np.float32(3.0)))
    c.op.device = "worker:1"
    out = s.run(c)
    np.testing.assert_allclose(out, [6.0])
    sends = [op for op in g.ops.values() if op.type == "Send"]
    recvs = [op for op in g.ops.values() if op.type == "Recv"]
    assert sends and recvs


def test_concurrent_steps_shared_state(sess):
    g, s = sess
    w = g.apply("Variable", var_name="ctr",
                initial=np.zeros(1, np.float32), device="ps:0")
    inc = g.apply("AssignAdd", w, g.constant(np.float32(1.0)))
    threads = [threading.Thread(target=lambda: s.run(inc), daemon=True)
               for _ in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert float(s.run(g.apply("Read", w))[0]) == 16.0


def test_step_cache_reused(sess):
    g, s = sess
    x = g.placeholder("x")
    y = g.apply("Mul", x, g.constant(2.0))
    s.run(y, {x: np.array(1.0)})
    n_plans = len(s._plan_cache)
    s.run(y, {x: np.array(2.0)})
    assert len(s._plan_cache) == n_plans  # same plan reused
