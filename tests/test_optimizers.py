"""Optimizer tests (§4.1): each update rule vs a hand-written numpy
reference, schedules, clipping, mixed-precision master updates, and a
hypothesis property for Adam's bias correction."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.config import OptimizerConfig
from repro.optim import optimizers as opt


def _tree():
    return {"a": jnp.asarray([1.0, -2.0, 3.0]),
            "b": {"c": jnp.asarray([[0.5, -0.5]])}}


def _grads():
    return {"a": jnp.asarray([0.1, 0.2, -0.3]),
            "b": {"c": jnp.asarray([[1.0, -1.0]])}}


def _cfg(name, **kw):
    base = dict(name=name, lr=0.1, warmup_steps=0, schedule="constant",
                weight_decay=0.0, grad_clip=0.0)
    base.update(kw)
    return OptimizerConfig(**base)


def _np(t):
    return np.asarray(t["a"]), np.asarray(t["b"]["c"])


@pytest.mark.parametrize("name", ["sgd", "momentum", "adagrad", "rmsprop",
                                  "adadelta", "adam", "adamw"])
def test_optimizers_match_numpy_reference(name):
    ocfg = _cfg(name)
    params, grads = _tree(), _grads()
    state = opt.init_opt_state(ocfg, params)
    p1, s1 = opt.apply_updates(ocfg, params, grads, state, 0)
    p2, s2 = opt.apply_updates(ocfg, p1, grads, s1, 1)

    # numpy reference, two steps
    pa, pc = _np(params)
    ga, gc = _np(grads)
    lr, b1, b2, eps = 0.1, ocfg.beta1, ocfg.beta2, ocfg.eps

    def two_steps(p, g):
        if name == "sgd":
            return p - lr * g - lr * g
        if name == "momentum":
            v = b1 * 0 + g
            p = p - lr * v
            v = b1 * v + g
            return p - lr * v
        if name == "adagrad":
            a = g * g
            p = p - lr * g / (np.sqrt(a) + eps)
            a = a + g * g
            return p - lr * g / (np.sqrt(a) + eps)
        if name == "rmsprop":
            a = (1 - b2) * g * g
            p = p - lr * g / (np.sqrt(a) + eps)
            a = b2 * a + (1 - b2) * g * g
            return p - lr * g / (np.sqrt(a) + eps)
        if name == "adadelta":
            rho = b2
            ag = (1 - rho) * g * g
            ax = np.zeros_like(g)
            u = g * np.sqrt(ax + eps) / np.sqrt(ag + eps)
            p = p - lr * u
            ax = rho * ax + (1 - rho) * u * u
            ag = rho * ag + (1 - rho) * g * g
            u = g * np.sqrt(ax + eps) / np.sqrt(ag + eps)
            return p - lr * u
        if name in ("adam", "adamw"):
            m = v = np.zeros_like(g)
            for t in range(2):
                m = b1 * m + (1 - b1) * g
                v = b2 * v + (1 - b2) * g * g
                mh = m / (1 - b1 ** (t + 1))
                vh = v / (1 - b2 ** (t + 1))
                p = p - lr * mh / (np.sqrt(vh) + eps)
            return p
        raise ValueError(name)

    ra, rc = two_steps(pa, ga), two_steps(pc, gc)
    np.testing.assert_allclose(np.asarray(p2["a"]), ra, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(p2["b"]["c"]), rc, rtol=1e-5)


def test_master_update_mixed_precision():
    ocfg = _cfg("adamw", weight_decay=0.01)
    params_f32 = _tree()
    state = opt.init_train_state(ocfg, params_f32)
    bf = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params_f32)
    grads = jax.tree.map(lambda x: x.astype(jnp.bfloat16), _grads())
    new_bf, new_state = opt.apply_updates_master(ocfg, state, grads, 0)
    assert new_bf["a"].dtype == jnp.bfloat16
    assert new_state["master"]["a"].dtype == jnp.float32
    # master moved in fp32 precision
    assert float(jnp.max(jnp.abs(new_state["master"]["a"]
                                 - params_f32["a"]))) > 0


def test_schedule_shapes():
    ocfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                           schedule="cosine")
    lrs = [float(opt.schedule(ocfg, s)) for s in range(101)]
    assert abs(lrs[0] - 0.1) < 1e-6          # (0+1)/10 warmup fraction
    assert abs(lrs[10] - 1.0 * 0.5 * (1 + np.cos(np.pi * 0.1))) < 1e-6
    assert lrs[100] < 1e-6
    assert max(lrs) <= 1.0


def test_clip_by_global_norm():
    grads = {"a": jnp.asarray([3.0, 4.0])}       # norm 5
    clipped, gn = opt.clip_by_global_norm(grads, 1.0)
    assert abs(float(gn) - 5.0) < 1e-6
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8],
                               rtol=1e-5)


@given(st.floats(1e-5, 1e-1), st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_adam_step_bounded_by_lr(g, step):
    """Property: |Adam update| <= ~lr per element (bias-corrected)."""
    ocfg = _cfg("adam", lr=0.01)
    params = {"w": jnp.asarray([1.0])}
    grads = {"w": jnp.asarray([g])}
    state = opt.init_opt_state(ocfg, params)
    for t in range(3):
        params2, state = opt.apply_updates(ocfg, params, grads, state,
                                           step + t)
        delta = abs(float(params2["w"][0] - params["w"][0]))
        assert delta <= 0.011 * 1.2
        params = params2
