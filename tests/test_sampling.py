"""Distribution-oracle tests for the full sampling pipeline.

A dense-numpy reference implements penalties + temperature + top-k/top-p/
min-p truncation exactly, and the in-jit pipeline is held to it three
ways: exact mask equality for every truncation combination (including the
degenerate p=1.0 / k=V / all-masked-fallback corners), chi-square and
TV-distance agreement of many-draw samples with the reference
distribution, and a speculative-verify property test showing rejection
sampling preserves the *transformed* target distribution under every new
knob (miscalibrated draft, many independent rids). The plain path's
(seed, rid, counter)+tag key streams are pinned by a golden regression so
sampling refactors cannot silently break preemption replay.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.sampling import (NEG, SP_KEYS, SamplingBuffer, _penalize,
                                    _prep_logits, _prep_logits_full,
                                    _truncate, propose_tokens,
                                    propose_tokens_full, sample_tokens,
                                    sample_tokens_full, speculative_verify,
                                    speculative_verify_full)

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# dense-numpy reference sampler
# ---------------------------------------------------------------------------


def _softmax(x):
    x = np.asarray(x, np.float32)
    e = np.exp(x - x.max())
    return e / e.sum()


def ref_penalize(lg, pmask, ocounts, rep, pres, freq):
    """Reference penalties, float32 like the kernel: repetition divides
    positive / multiplies negative logits of prompt-or-output tokens,
    frequency subtracts per occurrence, presence once per distinct."""
    lg = np.asarray(lg, np.float32).copy()
    seen = np.asarray(pmask, bool) | (np.asarray(ocounts) > 0)
    rep = np.float32(rep)
    lg[seen] = np.where(lg[seen] > 0, lg[seen] / rep, lg[seen] * rep)
    lg = lg - np.float32(freq) * np.asarray(ocounts, np.float32)
    lg = lg - np.float32(pres) * (np.asarray(ocounts) > 0).astype(np.float32)
    return lg


def ref_keep_mask(lg, k, top_p, min_p):
    """Reference keep-mask over one (V,) temperature-scaled row: the
    intersection of top-k, nucleus (ranks whose mass *before* them is
    < top_p, at least one kept) and min-p (>= max_prob * min_p); if
    everything is masked, keep the argmax."""
    lg = np.asarray(lg, np.float32)
    V = lg.shape[-1]
    srt = np.sort(lg)
    keep = np.ones(V, bool)
    if k > 0:
        keep &= ~(lg < srt[V - min(max(k, 1), V)])
    if top_p < 1.0:
        desc = srt[::-1]
        probs = _softmax(desc)
        before = np.cumsum(probs) - probs
        n_keep = max(int((before < np.float32(top_p)).sum()), 1)
        keep &= ~(lg < desc[n_keep - 1])
    if min_p > 0.0:
        keep &= ~(lg < srt[-1] + np.log(np.float32(min_p)))
    if not keep.any():
        keep = np.zeros(V, bool)
        keep[int(np.argmax(lg))] = True
    return keep


def ref_full_probs(lg, pmask, ocounts, t, k, top_p, min_p, rep, pres, freq):
    """Reference sampling distribution of the full pipeline on one row."""
    pen = ref_penalize(lg, pmask, ocounts, rep, pres, freq)
    scaled = pen / max(np.float32(t), np.float32(1e-6))
    keep = ref_keep_mask(scaled, k, top_p, min_p)
    probs = np.where(keep, _softmax(np.where(keep, scaled, NEG)), 0.0)
    return probs / probs.sum()


def make_sp(n, V, **over):
    """Default full-path param arrays for n rows; override per test."""
    sp = {"temps": np.ones(n, np.float32),
          "top_ks": np.zeros(n, np.int32),
          "top_ps": np.ones(n, np.float32),
          "min_ps": np.zeros(n, np.float32),
          "rep_pens": np.ones(n, np.float32),
          "pres_pens": np.zeros(n, np.float32),
          "freq_pens": np.zeros(n, np.float32),
          "seeds": np.zeros(n, np.int32),
          "rids": np.arange(n, dtype=np.int32),
          "counters": np.zeros(n, np.int32),
          "pmask": np.zeros((n, V), bool),
          "ocounts": np.zeros((n, V), np.int32)}
    sp.update(over)
    assert set(sp) == set(SP_KEYS)
    return {k: jnp.asarray(v) for k, v in sp.items()}


# ---------------------------------------------------------------------------
# exact mask equality, every truncation combination
# ---------------------------------------------------------------------------


TRUNC_GRID = [(k, tp, mp)
              for k in (0, 1, 3, 32)            # off / degenerate / mid / =V
              for tp in (1.0, 0.75, 0.4)        # off / mid / tight
              for mp in (0.0, 0.05, 0.3)]       # off / loose / tight


@pytest.mark.parametrize("k,top_p,min_p", TRUNC_GRID)
def test_truncation_mask_matches_reference(k, top_p, min_p):
    V = 32
    for row in range(8):
        lg = RNG.normal(0, 2, V).astype(np.float32)
        out = np.asarray(_truncate(jnp.asarray(lg), jnp.int32(k),
                                   jnp.float32(top_p), jnp.float32(min_p)))
        keep = ref_keep_mask(lg, k, top_p, min_p)
        np.testing.assert_array_equal(out != NEG, keep,
                                      err_msg=f"row {row} mask mismatch")
        np.testing.assert_array_equal(out[keep], lg[keep])


def test_truncation_defaults_bitwise_prep_logits():
    """k>0 with top_p=1, min_p=0 is bitwise the plain `_prep_logits`
    truncation (and so are the full defaults) — the property the
    mixed-batch byte-identity guarantee rests on."""
    V = 64
    lg = jnp.asarray(RNG.normal(0, 2, V), jnp.float32)
    for k in (0, 5, V):
        plain = _prep_logits(lg, jnp.float32(1.0), jnp.int32(k))
        full = _truncate(lg, jnp.int32(k), jnp.float32(1.0),
                         jnp.float32(0.0))
        np.testing.assert_array_equal(np.asarray(plain), np.asarray(full))


def test_truncation_all_masked_falls_back_to_argmax():
    """min_p > 1 masks every position including the max (threshold above
    the row max): the fallback must keep exactly the argmax."""
    lg = jnp.asarray(RNG.normal(0, 2, 16), jnp.float32)
    out = np.asarray(_truncate(lg, jnp.int32(0), jnp.float32(1.0),
                               jnp.float32(2.0)))
    keep = out != NEG
    assert keep.sum() == 1 and int(np.argmax(np.asarray(lg))) == \
        int(np.argmax(keep))
    assert out[keep][0] == np.asarray(lg)[keep][0]


def test_truncation_degenerate_composition_keeps_one():
    """Tightest legal settings (k=1, tiny top_p, min_p=1.0) keep exactly
    the argmax; no parameter combination ever empties the row."""
    for _ in range(8):
        lg = RNG.normal(0, 2, 24).astype(np.float32)
        out = np.asarray(_truncate(jnp.asarray(lg), jnp.int32(1),
                                   jnp.float32(1e-9), jnp.float32(1.0)))
        keep = out != NEG
        assert keep.sum() == 1 and int(np.argmax(lg)) == int(np.argmax(keep))


def test_penalties_match_reference_exactly():
    V = 32
    lg = RNG.normal(0, 2, V).astype(np.float32)
    pmask = RNG.random(V) < 0.3
    oc = RNG.integers(0, 4, V).astype(np.int32)
    for rep, pres, freq in [(1.0, 0.0, 0.0), (1.7, 0.0, 0.0),
                            (0.8, 0.5, 0.0), (1.3, 0.2, 0.4)]:
        got = np.asarray(_penalize(
            jnp.asarray(lg), jnp.asarray(pmask), jnp.asarray(oc),
            jnp.float32(rep), jnp.float32(pres), jnp.float32(freq)))
        want = ref_penalize(lg, pmask, oc, rep, pres, freq)
        np.testing.assert_array_equal(got, want)
    # defaults are a bitwise identity
    got = np.asarray(_penalize(jnp.asarray(lg), jnp.asarray(pmask),
                               jnp.asarray(oc), jnp.float32(1.0),
                               jnp.float32(0.0), jnp.float32(0.0)))
    np.testing.assert_array_equal(got, lg)


def test_full_prep_defaults_bitwise_plain():
    """`_prep_logits_full` at default penalties/top-p/min-p is bitwise
    `_prep_logits` for any (t, k) — even with non-trivial count state."""
    V = 48
    lg = jnp.asarray(RNG.normal(0, 2, V), jnp.float32)
    pmask = jnp.asarray(RNG.random(V) < 0.3)
    oc = jnp.asarray(RNG.integers(0, 3, V), jnp.int32)
    for t, k in [(1.0, 0), (0.7, 8), (1.5, V)]:
        plain = _prep_logits(lg, jnp.float32(t), jnp.int32(k))
        full = _prep_logits_full(
            lg, pmask, oc, jnp.float32(t), jnp.int32(k), jnp.float32(1.0),
            jnp.float32(0.0), jnp.float32(1.0), jnp.float32(0.0),
            jnp.float32(0.0))
        np.testing.assert_array_equal(np.asarray(plain), np.asarray(full))


def test_sample_tokens_full_defaults_match_plain_tokens():
    """Same streams, identity transform: the full path draws the exact
    tokens the plain path draws at default params, greedy rows included —
    a plain-param request in a full-pipeline batch loses nothing."""
    B, V = 16, 64
    logits = jnp.asarray(RNG.normal(0, 2, (B, V)), jnp.float32)
    temps = jnp.asarray(RNG.choice([0.0, 0.7, 1.0, 1.4], B), jnp.float32)
    top_ks = jnp.asarray(RNG.choice([0, 4, V], B), jnp.int32)
    seeds = jnp.asarray(RNG.integers(0, 5, B), jnp.int32)
    rids = jnp.asarray(RNG.integers(0, 1000, B), jnp.int32)
    cnts = jnp.asarray(RNG.integers(0, 30, B), jnp.int32)
    plain = sample_tokens(logits, temps, top_ks, seeds, rids, cnts)
    sp = make_sp(B, V, temps=temps, top_ks=top_ks, seeds=seeds,
                 rids=rids, counters=cnts)
    full, lp = sample_tokens_full(logits, sp)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(full))
    assert lp["top_lp"].shape == (B, min(8, V))


def test_greedy_is_penalty_aware():
    """t=0 rows argmax the *transformed* row: a strong repetition
    penalty on the raw argmax moves greedy to the runner-up."""
    V = 16
    lg = np.zeros(V, np.float32)
    lg[3], lg[7] = 4.0, 3.0
    oc = np.zeros(V, np.int32)
    oc[3] = 1
    sp = make_sp(1, V, temps=np.zeros(1, np.float32),
                 rep_pens=np.full(1, 10.0, np.float32),
                 ocounts=oc[None])
    tok, _ = sample_tokens_full(jnp.asarray(lg[None]), sp)
    assert int(tok[0]) == 7


# ---------------------------------------------------------------------------
# distribution agreement: chi-square + TV distance over many rids
# ---------------------------------------------------------------------------


def _draw_marginal(lg_row, n, **over):
    """Sample the same row across n independent rids (one draw each) —
    the i.i.d. many-draw estimate of the pipeline's distribution."""
    V = lg_row.shape[-1]
    sp = make_sp(n, V, **over)
    rows = jnp.broadcast_to(jnp.asarray(lg_row, jnp.float32), (n, V))
    toks, _ = sample_tokens_full(rows, sp)
    return np.bincount(np.asarray(toks), minlength=V) / n


def _check_dist(obs_freq, want, n):
    """TV-distance + chi-square agreement of an observed histogram with
    the reference distribution."""
    tv = 0.5 * np.abs(obs_freq - want).sum()
    assert tv < 0.03, f"TV distance {tv:.4f}"
    support = want > 1e-9
    exp = want[support] * n
    chi2 = ((obs_freq[support] * n - exp) ** 2 / exp).sum()
    df = int(support.sum()) - 1
    # generous ~99.99th-percentile bound: far tighter than a wrong
    # distribution, far looser than seed-to-seed noise
    assert chi2 < df + 5 * np.sqrt(2 * df) + 10, \
        f"chi2 {chi2:.1f} over df {df}"
    # nothing outside the truncated support is ever drawn
    assert obs_freq[~support].sum() == 0.0


DIST_CASES = [
    dict(),                                       # plain temperature
    dict(top_ps=0.7),
    dict(min_ps=0.2),
    dict(top_ks=5, top_ps=0.8),
    dict(top_ps=0.85, min_ps=0.05, top_ks=9),
    dict(rep_pens=1.6, freq_pens=0.3, pres_pens=0.4),
    dict(top_ps=0.75, rep_pens=1.4),
]


@pytest.mark.parametrize("over", DIST_CASES)
def test_sampled_distribution_matches_reference(over):
    V, N = 12, 4000
    rng = np.random.default_rng(7)
    lg = rng.normal(0, 1.5, V).astype(np.float32)
    pmask = np.zeros(V, bool)
    pmask[[0, 4]] = True
    oc = np.zeros(V, np.int32)
    oc[[1, 4, 4]] += 1                            # token 4 counted twice? no
    oc[1], oc[4] = 1, 2
    t = 0.9
    full = {k: (np.full(N, v, np.float32) if k in
                ("top_ps", "min_ps", "rep_pens", "pres_pens", "freq_pens")
                else np.full(N, v, np.int32))
            for k, v in over.items()}
    obs = _draw_marginal(lg, N, temps=np.full(N, t, np.float32),
                         pmask=np.broadcast_to(pmask, (N, V)),
                         ocounts=np.broadcast_to(oc, (N, V)), **full)
    want = ref_full_probs(
        lg, pmask, oc, t, int(over.get("top_ks", 0)),
        float(over.get("top_ps", 1.0)), float(over.get("min_ps", 0.0)),
        float(over.get("rep_pens", 1.0)), float(over.get("pres_pens", 0.0)),
        float(over.get("freq_pens", 0.0)))
    _check_dist(obs, want, N)


# ---------------------------------------------------------------------------
# speculative verify: distribution preserved under every transform
# ---------------------------------------------------------------------------


SPEC_CASES = [
    dict(),
    dict(top_ps=0.8),
    dict(top_ps=0.8, rep_pens=1.4),
    dict(min_ps=0.1, freq_pens=0.3),
]


@pytest.mark.parametrize("over", SPEC_CASES)
@pytest.mark.parametrize("K", [1, 2])
def test_speculative_verify_full_preserves_target_distribution(over, K):
    """Rejection sampling leaves the realized first-token marginal equal
    to the *transformed* target distribution even when the draft is badly
    miscalibrated, for every new logits transform — the property that
    makes speculative decoding compose with the full pipeline."""
    V, N = 8, 4000
    p_lg = np.asarray([0.0, 1.0, -1.0, 0.5, 0.2, -0.4, 1.3, -2.0],
                      np.float32)
    q_lg = np.asarray([2.0, -2.0, 0.0, 0.0, -1.0, 1.0, -0.5, 0.5],
                      np.float32)
    pmask = np.zeros(V, bool)
    pmask[0] = True
    oc0 = np.zeros(V, np.int32)
    oc0[6] = 1
    full = {k: np.full(N, v, np.float32) for k, v in over.items()}
    sp = {k: np.asarray(v)
          for k, v in make_sp(N, V, pmask=np.broadcast_to(pmask, (N, V)),
                              ocounts=np.broadcast_to(oc0, (N, V)),
                              **full).items()}
    q_rows = jnp.broadcast_to(jnp.asarray(q_lg), (N, V))
    # propose exactly as the speculative runner does: oc accumulates the
    # one-hots of earlier proposals so proposal i and verify row i agree
    oc = jnp.asarray(sp["ocounts"])
    drafts, d_lgs = [], []
    for i in range(K):
        nt = propose_tokens_full(
            q_rows, dict(sp, ocounts=oc,
                         counters=sp["counters"] + np.int32(i)))
        drafts.append(nt)
        d_lgs.append(q_rows)
        oc = oc + jax.nn.one_hot(nt, V, dtype=oc.dtype)
    out, n_acc, lp = speculative_verify_full(
        jnp.stack(drafts, 1), jnp.stack(d_lgs, 1),
        jnp.broadcast_to(jnp.asarray(p_lg), (N, K + 1, V)), sp)
    first = np.asarray(out[:, 0])
    want = ref_full_probs(
        p_lg, pmask, oc0, 1.0, 0, float(over.get("top_ps", 1.0)),
        float(over.get("min_ps", 0.0)), float(over.get("rep_pens", 1.0)),
        0.0, float(over.get("freq_pens", 0.0)))
    got = np.bincount(first, minlength=V) / N
    _check_dist(got, want, N)
    # proposals themselves follow transformed q, not p
    got_q = np.bincount(np.asarray(drafts[0]), minlength=V) / N
    want_q = ref_full_probs(
        q_lg, pmask, oc0, 1.0, 0, float(over.get("top_ps", 1.0)),
        float(over.get("min_ps", 0.0)), float(over.get("rep_pens", 1.0)),
        0.0, float(over.get("freq_pens", 0.0)))
    assert 0.5 * np.abs(got_q - want_q).sum() < 0.03
    assert lp["chosen"].shape == (N, K + 1)


def test_speculative_verify_full_defaults_bitwise_plain():
    """At default params the full verifier reproduces the plain one's
    tokens and accept counts exactly (same streams, identity transform)."""
    B, K, V = 8, 2, 16
    d_toks = jnp.asarray(RNG.integers(0, V, (B, K)), jnp.int32)
    d_lg = jnp.asarray(RNG.normal(0, 1, (B, K, V)), jnp.float32)
    t_lg = jnp.asarray(RNG.normal(0, 1, (B, K + 1, V)), jnp.float32)
    temps = jnp.asarray(RNG.choice([0.0, 0.8, 1.2], B), jnp.float32)
    top_ks = jnp.asarray(RNG.choice([0, 6], B), jnp.int32)
    seeds = jnp.zeros(B, jnp.int32)
    rids = jnp.arange(B, dtype=jnp.int32)
    cnts = jnp.asarray(RNG.integers(0, 9, B), jnp.int32)
    want_out, want_acc = speculative_verify(
        d_toks, d_lg, t_lg, temps, top_ks, seeds, rids, cnts)
    sp = make_sp(B, V, temps=temps, top_ks=top_ks, seeds=seeds,
                 rids=rids, counters=cnts)
    got_out, got_acc, _ = speculative_verify_full(d_toks, d_lg, t_lg, sp)
    np.testing.assert_array_equal(np.asarray(want_out), np.asarray(got_out))
    np.testing.assert_array_equal(np.asarray(want_acc), np.asarray(got_acc))


# ---------------------------------------------------------------------------
# logprobs reporting
# ---------------------------------------------------------------------------


def test_logprobs_match_penalized_distribution():
    """Reported logprobs are the log-softmax of the penalized,
    pre-truncation logits: sampled rows at their temperature, greedy rows
    unscaled; top-L is sorted descending and contains the true top-L."""
    V = 20
    lg = RNG.normal(0, 2, V).astype(np.float32)
    oc = np.zeros(V, np.int32)
    oc[2] = 3
    for t in (0.0, 0.8):
        sp = make_sp(1, V, temps=np.full(1, t, np.float32),
                     rep_pens=np.full(1, 1.5, np.float32),
                     freq_pens=np.full(1, 0.2, np.float32),
                     ocounts=oc[None], top_ps=np.full(1, 0.6, np.float32))
        tok, lp = sample_tokens_full(jnp.asarray(lg[None]), sp,
                                     max_logprobs=5)
        pen = ref_penalize(lg, np.zeros(V, bool), oc, 1.5, 0.0, 0.2)
        scale = t if t > 0 else 1.0
        want = pen / np.float32(scale)
        want = want - (np.max(want) + np.log(np.exp(want - np.max(want))
                                             .sum()))
        np.testing.assert_allclose(float(lp["chosen"][0]),
                                   want[int(tok[0])], rtol=1e-5)
        ids = np.asarray(lp["top_ids"][0])
        np.testing.assert_allclose(np.asarray(lp["top_lp"][0]), want[ids],
                                   rtol=1e-5)
        assert set(ids) == set(np.argsort(want)[::-1][:5])


# ---------------------------------------------------------------------------
# golden key-stream regression (preemption replay depends on these)
# ---------------------------------------------------------------------------


def test_key_stream_golden_regression():
    """Pins the (seed, rid, counter)+tag streams: fold order is PRNGKey(
    seed) -> rid -> counter, with the tag folded last. Any refactor that
    changes these values breaks preemption replay for every deployed
    request — the expected tokens were generated once and are frozen."""
    rng = np.random.default_rng(42)
    logits = jnp.asarray(rng.normal(0, 2, (6, 16)), jnp.float32)
    temps = jnp.asarray([1.0, 0.7, 1.3, 1.0, 0.0, 1.0], jnp.float32)
    top_ks = jnp.asarray([0, 4, 8, 0, 0, 2], jnp.int32)
    seeds = jnp.asarray([0, 0, 7, 7, 3, 3], jnp.int32)
    rids = jnp.asarray([100, 101, 100, 5, 6, 7], jnp.int32)
    cnts = jnp.asarray([0, 1, 2, 3, 4, 5], jnp.int32)
    plain = sample_tokens(logits, temps, top_ks, seeds, rids, cnts)
    np.testing.assert_array_equal(np.asarray(plain),
                                  [13, 14, 3, 9, 4, 10])
    draft = propose_tokens(logits, temps, top_ks, seeds, rids, cnts)
    np.testing.assert_array_equal(np.asarray(draft),
                                  [6, 14, 15, 9, 4, 5])
    # greedy rows (t=0) ignore the tag entirely: no randomness consumed
    assert int(plain[4]) == int(draft[4]) == int(jnp.argmax(logits[4]))
    # the _ACCEPT uniforms of rejection sampling, same derivation
    from repro.serving.sampling import _ACCEPT, _base_key
    u = [float(jax.random.uniform(jax.random.fold_in(
        _base_key(0, 100, c), _ACCEPT))) for c in range(3)]
    np.testing.assert_allclose(
        u, [0.95220649, 0.18331921, 0.01607811], atol=1e-7)
    # full path at default params rides the identical streams
    sp = make_sp(6, 16, temps=temps, top_ks=top_ks, seeds=seeds,
                 rids=rids, counters=cnts)
    full, _ = sample_tokens_full(logits, sp)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(plain))


# ---------------------------------------------------------------------------
# SamplingBuffer: dense per-slot state, replay-by-rebind
# ---------------------------------------------------------------------------


class _Req:
    def __init__(self, rid, prompt, out=(), **kw):
        self.rid = rid
        self.prompt = np.asarray(prompt, np.int32)
        self.out = list(out)
        self.max_new = kw.get("max_new", 16)
        self.min_new = kw.get("min_new", 0)
        from repro.serving.scheduler import SamplingParams
        self.sampling = kw.get("sampling", SamplingParams())


def test_sampling_buffer_bind_commit_ring():
    buf = SamplingBuffer(4, 16, max_stop_len=3)
    req = _Req(5, [1, 2, 2, 15])
    buf.bind(req, 2)
    pm, oc = buf.row(5)
    assert pm[[1, 2, 15]].all() and pm.sum() == 3
    assert oc.sum() == 0
    for tok in (7, 7, 3, 9):
        buf.commit(5, tok)
    pm, oc = buf.row(5)
    assert oc[7] == 2 and oc[3] == 1 and oc[9] == 1
    # ring holds only the last max_stop_len tokens
    assert buf.check_stop(5, [(7, 3, 9)]) == (7, 3, 9)
    assert buf.check_stop(5, [(9,)]) == (9,)
    assert buf.check_stop(5, [(7, 7)]) is None          # shifted out
    assert buf.check_stop(5, [(3, 9, 1)]) is None
    buf.free(5)
    assert buf.pmask[2].sum() == 0 and buf.ocounts[2].sum() == 0
    buf.free(5)                                         # double-free: no-op


def test_sampling_buffer_rebind_replays_state():
    """Rebinding from (prompt, out) reproduces the incrementally
    committed state exactly — the property that makes preemption-
    recompute / swap-in / rollback replay free."""
    buf = SamplingBuffer(2, 32, max_stop_len=4)
    prompt = [3, 9, 9]
    req = _Req(1, prompt)
    buf.bind(req, 0)
    toks = [4, 9, 4, 31, 2, 4]
    for t in toks:
        buf.commit(1, t)
        req.out.append(t)
    pm0, oc0 = (a.copy() for a in buf.row(1))
    ring0 = buf.rings[0].copy()
    # preempt: free the row, re-admit into a different slot
    buf.free(1)
    buf.bind(req, 1)
    pm1, oc1 = buf.row(1)
    np.testing.assert_array_equal(pm0, pm1)
    np.testing.assert_array_equal(oc0, oc1)
    np.testing.assert_array_equal(ring0, buf.rings[1])


def test_sampling_buffer_validate():
    from repro.serving.scheduler import SamplingParams
    buf = SamplingBuffer(2, 16, max_stop_len=2, max_logprobs=4)
    buf.validate(_Req(0, [1], sampling=SamplingParams(
        top_p=0.5, min_p=0.1, repetition_penalty=1.2, logprobs=4,
        stop=((1, 2),))))
    with pytest.raises(ValueError, match="top_p"):
        buf.validate(_Req(0, [1], sampling=SamplingParams(top_p=0.0)))
    with pytest.raises(ValueError, match="min_p"):
        buf.validate(_Req(0, [1], sampling=SamplingParams(min_p=1.5)))
    with pytest.raises(ValueError, match="repetition"):
        buf.validate(_Req(0, [1], sampling=SamplingParams(
            repetition_penalty=0.0)))
    with pytest.raises(ValueError, match="logprobs"):
        buf.validate(_Req(0, [1], sampling=SamplingParams(logprobs=5)))
    with pytest.raises(ValueError, match="stop"):
        buf.validate(_Req(0, [1], sampling=SamplingParams(
            stop=((1, 2, 3),))))
    with pytest.raises(ValueError, match="min_new"):
        buf.validate(_Req(0, [1], min_new=20, max_new=8))


def test_needs_pipeline_flags():
    from repro.serving.scheduler import SamplingParams
    assert not SamplingParams().needs_pipeline
    assert not SamplingParams(temperature=1.0, top_k=5,
                              stop=((3,),)).needs_pipeline
    for kw in (dict(top_p=0.9), dict(min_p=0.1),
               dict(repetition_penalty=1.1), dict(presence_penalty=0.1),
               dict(frequency_penalty=0.1), dict(logprobs=1)):
        assert SamplingParams(**kw).needs_pipeline, kw
