"""Tensor-parallel paged serving tests (docs/multi-host.md).

Three layers of proof that sharding the serving engine over the mesh
"model" axis is a pure placement change:

* **Stitch math** — the partial-softmax / LSE-stitch path of the paged
  kernels (``block_mask`` + ``return_lse``) reproduces the dense
  reference for every shard count and head-count shape, including the
  Pallas kernels in interpret mode, plus the explicit error path when
  kv heads don't divide the mesh.
* **Host metadata mesh-invariance** — the BlockManager / SlotStateCache
  random walks re-run under different mesh-model parameters and their
  full state traces must be identical (the managers never see the mesh;
  only per-shard byte accounting divides).
* **Engine byte-identity** — subprocess tests on a forced 4-device host:
  greedy engine outputs (prefix-cache hits + COW, preemption-recompute,
  speculative k=2, hybrid SSM and enc-dec runners) on model=2 and
  model=4 meshes are byte-identical to the single-device engine, with
  identical scheduling stats.
"""

import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import run_with_devices
from repro.config import get_config
from repro.kernels.paged_attention import (paged_attention,
                                           paged_prefill_attention)
from repro.kernels.ref import (paged_attention_partial_ref,
                               paged_attention_ref,
                               paged_prefill_attention_ref,
                               paged_shard_attention_ref)
from repro.models.attention import paged_shard_attention, \
    stitch_paged_partials
from repro.serving.kv_cache import BlockManager, block_bytes
from repro.spmd.sharding import (paged_pool_pspec, serving_cache_pspec,
                                 serving_cache_shardings, serving_tp)

RNG = np.random.default_rng(7)


def _case(B, H, K, hd, bs, nblk, dtype=jnp.float32):
    N = 1 + B * nblk
    q = jnp.asarray(RNG.normal(0, 1, (B, H, hd)), jnp.float32).astype(dtype)
    kp = jnp.asarray(RNG.normal(0, 1, (N, bs, K, hd)),
                     jnp.float32).astype(dtype)
    vp = jnp.asarray(RNG.normal(0, 1, (N, bs, K, hd)),
                     jnp.float32).astype(dtype)
    perm = RNG.permutation(np.arange(1, N))[:B * nblk].reshape(B, nblk)
    bt = jnp.asarray(perm, jnp.int32)
    ctx = jnp.asarray(RNG.integers(1, nblk * bs + 1, (B,)), jnp.int32)
    return q, kp, vp, bt, ctx


# ---------------------------------------------------------------------------
# Partial-softmax / LSE-stitch math
# ---------------------------------------------------------------------------


# head-count shapes: GQA, MHA (G=1), MQA (K=1), deeper GQA
HEAD_CASES = [
    # B, H, K, hd, block_size, blocks_per_seq, window, cap
    (3, 4, 2, 16, 8, 4, None, None),
    (2, 6, 6, 16, 8, 5, 12, None),        # MHA + sliding window
    (2, 8, 1, 32, 8, 4, None, 50.0),      # MQA + softcap
    (2, 8, 2, 16, 16, 3, None, None),
]


def test_partial_ref_full_mask_is_exact():
    """A full mask makes the partial oracle the plain oracle bit for bit
    (same op order) — the stitch path is a strict generalization."""
    q, kp, vp, bt, ctx = _case(3, 4, 2, 16, 8, 4)
    o, lse = paged_attention_partial_ref(
        q, kp, vp, bt, ctx, jnp.ones(bt.shape, bool))
    o_r = paged_attention_ref(q, kp, vp, bt, ctx)
    np.testing.assert_array_equal(np.asarray(o), np.asarray(o_r))
    assert np.all(np.asarray(lse) > -1e29)     # every row attended something


@pytest.mark.parametrize("case", HEAD_CASES)
@pytest.mark.parametrize("n_shards", [1, 2, 3, 4])
def test_shard_oracle_matches_plain_ref(case, n_shards):
    B, H, K, hd, bs, nblk, window, cap = case
    q, kp, vp, bt, ctx = _case(B, H, K, hd, bs, nblk)
    o_s = paged_shard_attention_ref(q, kp, vp, bt, ctx, n_shards,
                                    window=window, cap=cap)
    o_r = paged_attention_ref(q, kp, vp, bt, ctx, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(o_s), np.asarray(o_r), atol=1e-5)


@pytest.mark.parametrize("case", HEAD_CASES)
def test_production_stitch_matches_oracle(case):
    """kops partial kernel + ``stitch_paged_partials`` == the ref oracle
    == the plain path (the production blocks-axis-sharded route)."""
    B, H, K, hd, bs, nblk, window, cap = case
    q, kp, vp, bt, ctx = _case(B, H, K, hd, bs, nblk)
    o_p = paged_shard_attention(q, kp, vp, bt, ctx, 3, window=window,
                                cap=cap)
    o_r = paged_attention_ref(q, kp, vp, bt, ctx, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_r), atol=1e-5)


def test_partials_stay_fp32_for_bf16_pools():
    """Regression: partial outputs must come back fp32 even when the
    pools/queries are bf16 — rounding each shard's o to bf16 before the
    stitch would make the stitched result depend on the shard count."""
    q, kp, vp, bt, ctx = _case(2, 4, 2, 16, 8, 4, dtype=jnp.bfloat16)
    o, lse = paged_attention(q, kp, vp, bt, ctx, interpret=True,
                             block_mask=jnp.ones(bt.shape, jnp.int32),
                             return_lse=True)
    assert o.dtype == jnp.float32 and lse.dtype == jnp.float32
    from repro.kernels import ops as kops
    o2, lse2 = kops.paged_attention_partial(
        q, kp, vp, bt, ctx, jnp.ones(bt.shape, bool))
    assert o2.dtype == jnp.float32 and lse2.dtype == jnp.float32
    # a 1-shard "stitch" is exactly the plain path (w = 1, den = 1)
    o_plain = paged_attention_ref(q, kp, vp, bt, ctx)
    np.testing.assert_array_equal(
        np.asarray(paged_shard_attention(q, kp, vp, bt, ctx, 1)),
        np.asarray(o_plain))
    # multi-shard stitches agree with the plain bf16 path to bf16 ulp
    for s in (2, 3):
        np.testing.assert_allclose(
            np.asarray(paged_shard_attention(q, kp, vp, bt, ctx, s),
                       np.float32),
            np.asarray(o_plain, np.float32), atol=2e-2)


def test_pallas_partial_matches_partial_ref():
    """Interpret-mode Pallas decode kernel with a shard-local mask returns
    the same (o, lse) as the oracle; skipped entries are never read."""
    q, kp, vp, bt, ctx = _case(3, 4, 2, 16, 8, 4)
    for seed in range(4):
        mask = jnp.asarray(
            np.random.default_rng(seed).integers(0, 2, bt.shape), jnp.int32)
        o_k, lse_k = paged_attention(q, kp, vp, bt, ctx, block_mask=mask,
                                     return_lse=True, interpret=True)
        o_r, lse_r = paged_attention_partial_ref(q, kp, vp, bt, ctx, mask)
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                                   atol=1e-5)
        lk, lr = np.asarray(lse_k), np.asarray(lse_r)
        live = lr > -1e29
        np.testing.assert_allclose(lk[live], lr[live], atol=1e-5)
        assert np.all(lk[~live] < -1e29)       # empty rows: zero weight


def test_pallas_partial_random_partition_stitches_exact():
    """Property: ANY partition of the table entries over shards stitches
    back to the plain answer (not just round-robin) — seeded sweep."""
    B, H, K, hd, bs, nblk = 2, 4, 2, 16, 8, 5
    q, kp, vp, bt, ctx = _case(B, H, K, hd, bs, nblk)
    o_full = np.asarray(paged_attention_ref(q, kp, vp, bt, ctx))
    for seed in range(6):
        rng = np.random.default_rng(100 + seed)
        n_shards = int(rng.integers(2, 5))
        owner = rng.integers(0, n_shards, (B, nblk))
        parts = [paged_attention_partial_ref(
            q, kp, vp, bt, ctx, jnp.asarray(owner == s))
            for s in range(n_shards)]
        o = stitch_paged_partials(jnp.stack([p[0] for p in parts]),
                                  jnp.stack([p[1] for p in parts]))
        np.testing.assert_allclose(np.asarray(o), o_full, atol=1e-5)


def test_chunk_kernel_partial_path():
    """The multi-query kernel's partial path: a full mask reproduces the
    plain chunk kernel exactly; a 2-way split of the *context-only* blocks
    stitches back to it (the chunk's own keys live in unmasked blocks)."""
    B, H, K, hd, bs, nblk, C = 2, 4, 2, 16, 8, 4, 8
    q = jnp.asarray(RNG.normal(0, 1, (B, C, H, hd)), jnp.float32)
    _, kp, vp, bt, _ = _case(B, H, K, hd, bs, nblk)
    qlen = jnp.asarray([C, 3], jnp.int32)
    ctx = jnp.asarray([24, 11], jnp.int32)
    o_plain = paged_prefill_attention(q, kp, vp, bt, ctx, qlen,
                                      interpret=True)
    o_f, lse_f = paged_prefill_attention(q, kp, vp, bt, ctx, qlen,
                                         block_mask=jnp.ones(bt.shape,
                                                             jnp.int32),
                                         return_lse=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(o_f), np.asarray(o_plain))
    lse = np.asarray(lse_f)
    assert np.all(lse[0] > -1e29)              # full row attended
    assert np.all(lse[1, 3:] < -1e29)          # padding rows: empty
    entry = np.arange(nblk)[None, :]
    parts = [paged_prefill_attention(
        q, kp, vp, bt, ctx, qlen,
        block_mask=jnp.asarray(entry % 2 == s), return_lse=True,
        interpret=True) for s in range(2)]
    o = stitch_paged_partials(
        jnp.stack([p[0].astype(jnp.float32) for p in parts]),
        jnp.stack([p[1] for p in parts]))
    valid = np.asarray(jnp.arange(C)[None] < qlen[:, None])
    np.testing.assert_allclose(np.asarray(o)[valid],
                               np.asarray(o_plain)[valid], atol=1e-5)


def test_chunk_ref_unchanged_by_full_mask_path():
    """Plain multi-query ref still matches the kernel after the partial
    plumbing (regression guard for the added scalar-prefetch arg)."""
    B, H, K, hd, bs, nblk, C = 2, 6, 2, 16, 8, 5, 20
    q = jnp.asarray(RNG.normal(0, 1, (B, C, H, hd)), jnp.float32)
    _, kp, vp, bt, _ = _case(B, H, K, hd, bs, nblk)
    qlen = jnp.asarray([C, 7], jnp.int32)
    ctx = jnp.asarray([32, 20], jnp.int32)
    o_k = paged_prefill_attention(q, kp, vp, bt, ctx, qlen, interpret=True)
    o_r = paged_prefill_attention_ref(q, kp, vp, bt, ctx, qlen)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-5)


# ---------------------------------------------------------------------------
# Sharding specs: the kv-head layout and its error path
# ---------------------------------------------------------------------------


def test_paged_pool_pspec_and_error_path():
    from jax.sharding import PartitionSpec as P
    assert paged_pool_pspec(4, 1) == P(None, None, None, None, None)
    assert paged_pool_pspec(4, 2) == P(None, None, None, "model", None)
    assert paged_pool_pspec(4, 4) == P(None, None, None, "model", None)
    for K, tp in ((2, 4), (3, 2), (1, 2), (6, 4)):
        with pytest.raises(ValueError, match="not divisible"):
            paged_pool_pspec(K, tp)


def test_shard_oracle_rejects_bad_shard_count():
    q, kp, vp, bt, ctx = _case(2, 4, 2, 16, 8, 3)
    with pytest.raises(ValueError, match="n_shards"):
        paged_shard_attention_ref(q, kp, vp, bt, ctx, 0)
    with pytest.raises(ValueError, match="n_shards"):
        paged_shard_attention(q, kp, vp, bt, ctx, -1)


def test_serving_cache_pspec_by_leaf_kind():
    """Pool / encoder leaves shard by kv head; indivisible head counts
    fall back to replicated storage (the hard error for paged kinds lives
    in paged_pool_pspec / engine construction); Mamba slot-state tuples
    stay replicated — storing recurrent state sharded lets GSPMD
    repartition the SSD scan's contractions, which would cost the engine
    its bitwise mesh-invariance (see serving_cache_pspec docstring)."""
    from jax.sharding import PartitionSpec as P
    from jax.tree_util import DictKey, SequenceKey
    pool = jnp.zeros((2, 9, 8, 4, 16))
    enc = jnp.zeros((2, 4, 15, 4, 16))
    state = jnp.zeros((2, 4, 8, 16, 8))
    tail = jnp.zeros((2, 4, 3, 24))
    kpath = (DictKey("sub0"), DictKey("k"))
    assert serving_cache_pspec(kpath, pool, 2) \
        == P(None, None, None, "model", None)
    assert serving_cache_pspec((DictKey("cross"), DictKey("xk")), enc, 2) \
        == P(None, None, None, "model", None)
    # kv heads (4) don't divide tp=3: replicated storage
    assert serving_cache_pspec(kpath, pool, 3) == P(None, None, None,
                                                    None, None)
    assert serving_cache_pspec((DictKey("sub1"), SequenceKey(1)),
                               state, 2) == P()
    assert serving_cache_pspec((DictKey("sub1"), SequenceKey(0)), tail, 2) \
        == P()
    assert serving_cache_pspec(kpath, pool, 1) == P()


def test_serving_tp_and_cache_shardings_on_host_mesh(tiny_mesh):
    """On the 1x1 host mesh everything resolves to replicated and the
    shardings tree is well-formed for a real runner cache."""
    from repro.config import ParallelConfig
    from repro.serving.runners import make_runner
    assert serving_tp(tiny_mesh) == 1
    assert serving_tp(None) == 1
    cfg = get_config("zamba2_2p7b", smoke=True)
    runner = make_runner(cfg, ParallelConfig(remat="none"))
    with jax.set_mesh(tiny_mesh):
        cache = runner.init_cache(9, 16, 2)
    sh = serving_cache_shardings(cache, tiny_mesh)
    assert jax.tree.structure(sh) == jax.tree.structure(cache)


# ---------------------------------------------------------------------------
# Host-side metadata is mesh-invariant (random walks x mesh shape)
# ---------------------------------------------------------------------------


def _bm_walk_trace(seed: int, mesh_model: int) -> list:
    """Run a seeded BlockManager walk and capture the full host-visible
    state after every op. ``mesh_model`` enters exactly the way it does in
    the engine — per-shard byte accounting and pool specs — and must not
    perturb one bit of the manager's state: block ids are global (pools
    shard by kv head, not by block), so tables/refcounts/hashes/free
    lists are identical on every mesh. The trace equality across
    mesh_model values pins that, and would catch anyone threading the
    mesh into the manager."""
    cfg = dataclasses.replace(get_config("glm4_9b", smoke=True),
                              num_kv_heads=4)
    # mesh-parametric accounting: a block's bytes divide exactly over
    # shards, and the pool spec resolves (4 kv heads, model in {1,2,4})
    assert block_bytes(cfg, 16) == mesh_model * block_bytes(
        cfg, 16, tp=mesh_model)
    paged_pool_pspec(cfg.num_kv_heads, mesh_model)
    rng = random.Random(seed)
    NB, BS = 9, 4
    bm = BlockManager(num_blocks=NB, block_size=BS)
    live: set[int] = set()
    next_rid, next_hash = [0], [0]
    trace = []

    def snap():
        trace.append((
            {rid: tuple(bm.table(rid)) for rid in sorted(live)},
            tuple(sorted(bm._ref.items())),
            tuple(bm._free),
            tuple(sorted((b, h) for b, h in bm._hash_of.items())),
        ))

    for _ in range(150):
        op = rng.randrange(8)
        rids = sorted(live)
        if op == 0 or not rids:
            next_rid[0] += 1
            try:
                bm.allocate(next_rid[0], rng.randrange(3 * BS + 1))
                live.add(next_rid[0])
            except MemoryError:
                pass
        elif op == 1:
            rid = rids[rng.randrange(len(rids))]
            bm.ensure(rid, len(bm.table(rid)) * BS + rng.randrange(BS) + 1)
        elif op == 2:
            next_rid[0] += 1
            bm.fork(rids[rng.randrange(len(rids))], next_rid[0])
            live.add(next_rid[0])
        elif op == 3:
            rid = rids[rng.randrange(len(rids))]
            t = bm.table(rid)
            if t:
                try:
                    bm.cow(rid, rng.randrange(len(t)))
                except MemoryError:
                    pass
        elif op == 4:
            rid = rids[rng.randrange(len(rids))]
            bm.free(rid)
            live.discard(rid)
        elif op == 5:
            rid = rids[rng.randrange(len(rids))]
            t = bm.table(rid)
            if t:
                next_hash[0] += 1
                bm.register(t[rng.randrange(len(t))], next_hash[0])
        elif op == 6:
            rid = rids[rng.randrange(len(rids))]
            cover = len(bm.table(rid)) * BS
            bm.truncate(rid, rng.randrange(cover + 1) if cover else 0)
        else:
            if next_hash[0]:
                blocks = bm.match([rng.randrange(next_hash[0]) + 1])
                if blocks:
                    next_rid[0] += 1
                    bm.adopt(next_rid[0], blocks)
                    live.add(next_rid[0])
        bm.check()
        snap()
    return trace


@pytest.mark.parametrize("mesh_model", [2, 4])
def test_block_manager_walk_mesh_invariant(mesh_model):
    for seed in range(4):
        ref = _bm_walk_trace(seed, 1)
        got = _bm_walk_trace(seed, mesh_model)
        assert got == ref


def _slot_walk_trace(seed: int, mesh_model: int) -> list:
    """SlotStateCache walk under a mesh parameter: the rid<->slot binding
    never sees the mesh (slot state shards on the ssm-head axis, slots
    stay global), so the binding trace is mesh-invariant."""
    from repro.serving import SlotStateCache
    cfg = get_config("mamba2_370m", smoke=True)
    nh = cfg.ssm.n_heads(cfg.d_model)
    # the mesh-parametric piece: the state spec resolves (replicated —
    # see serving_cache_pspec) without ever touching the slot binding
    from jax.tree_util import DictKey, SequenceKey
    state = jnp.zeros((1, 4, nh, cfg.ssm.head_dim, cfg.ssm.state_dim))
    serving_cache_pspec((DictKey("sub0"), SequenceKey(1)), state,
                        mesh_model)
    rng = random.Random(seed)
    sc = SlotStateCache(4)
    bound: dict[int, int] = {}
    next_rid = [0]
    trace = []
    for _ in range(150):
        op = rng.randrange(3)
        rids = sorted(bound)
        if op == 0 or not rids:
            next_rid[0] += 1
            try:
                bound[next_rid[0]] = sc.allocate(next_rid[0])
            except MemoryError:
                pass
        elif op == 1:
            rid = rids[rng.randrange(len(rids))]
            sc.free(rid)
            del bound[rid]
        else:                                   # preempt + readmit
            rid = rids[rng.randrange(len(rids))]
            sc.free(rid)
            del bound[rid]
            next_rid[0] += 1
            bound[next_rid[0]] = sc.allocate(next_rid[0])
        sc.check()
        trace.append(tuple(sorted(sc._slot_of.items())))
    return trace


@pytest.mark.parametrize("mesh_model", [2, 4])
def test_slot_cache_walk_mesh_invariant(mesh_model):
    for seed in range(4):
        assert _slot_walk_trace(seed, mesh_model) \
            == _slot_walk_trace(seed, 1)


# ---------------------------------------------------------------------------
# Engine byte-identity across mesh shapes (subprocess, 4 virtual devices)
# ---------------------------------------------------------------------------


TP_CODE = """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
import repro.compat
from repro.config import get_config
from repro.models import api
from repro.serving import InferenceEngine, Request

def mesh_of(model):
    return jax.make_mesh((1, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

def params_for(cfg, seed=0):
    with jax.set_mesh(mesh_of(1)):
        pf32, _ = api.init_model(cfg, jax.random.key(seed))
        return jax.tree.map(
            lambda x: np.asarray(x.astype(jnp.bfloat16)), pf32)

def check(run, stat_keys):
    outs1, stats1 = run(1)
    for tp in (2, 4):
        outs, stats = run(tp)
        assert stats == stats1, (tp, stats, stats1)
        for a, b in zip(outs1, outs):
            np.testing.assert_array_equal(a, b)
    return stats1

rng = np.random.default_rng(0)
cfg = dataclasses.replace(get_config("glm4_9b", smoke=True),
                          num_kv_heads=4)
params = params_for(cfg)

# -- scenario A: shared prefix (cache hits + boundary COW), staggered ----
common = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
pa = [np.concatenate([common,
                      rng.integers(0, cfg.vocab_size, 8).astype(np.int32)])
      for _ in range(3)] + [common.copy()]          # full-prompt hit too

def run_prefix(model):
    eng = InferenceEngine(cfg, mesh_of(model), max_batch=2, block_size=16,
                          max_len=96, params=params, debug_invariants=True)
    reqs = [Request(p.copy(), max_new=8) for p in pa]
    outs = eng.run(reqs, arrival_steps=[0, 0, 2, 5])
    return [outs[r.rid] for r in reqs], {
        k: eng.stats[k] for k in ("steps", "tokens", "cache_hit_tokens",
                                  "cow_copies", "preemptions")}

s = check(run_prefix, None)
# two suffix requests hit the full 32-token common prefix; the duplicate
# full-prompt request hits all but its recomputed last token (31)
assert s["cache_hit_tokens"] >= 2 * 32 + 31, s
assert s["cow_copies"] >= 1, s
print("PREFIX-OK", s)

# -- scenario B: preemption-recompute under a tight pool -----------------
pb = [rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
      for _ in range(2)]

def run_tight(model):
    eng = InferenceEngine(cfg, mesh_of(model), max_batch=2, block_size=16,
                          max_len=96, num_blocks=8, params=params,
                          debug_invariants=True)
    reqs = [Request(p.copy(), max_new=20) for p in pb]
    outs = eng.run(reqs)
    return [outs[r.rid] for r in reqs], {
        k: eng.stats[k] for k in ("steps", "tokens", "preemptions")}

s = check(run_tight, None)
assert s["preemptions"] >= 1, s
print("PREEMPT-OK", s)

# -- scenario C: speculative k=2 (self-draft params: accept > 1) ---------
scfg = dataclasses.replace(get_config("starcoder2_3b", smoke=True),
                           num_heads=8, num_kv_heads=4)
sparams = params_for(scfg)
pc = [rng.integers(0, scfg.vocab_size, 32).astype(np.int32)
      for _ in range(3)]

def run_spec(model):
    eng = InferenceEngine(scfg, mesh_of(model), max_batch=2, block_size=16,
                          max_len=96, params=sparams, draft_params=sparams,
                          num_speculative_tokens=2, debug_invariants=True)
    reqs = [Request(p.copy(), max_new=8) for p in pc]
    outs = eng.run(reqs, arrival_steps=[0, 0, 2])
    return [outs[r.rid] for r in reqs], {
        k: eng.stats[k] for k in ("steps", "tokens", "spec_decodes",
                                  "spec_emitted", "mean_accept_len")}

s = check(run_spec, None)
assert s["mean_accept_len"] > 1.0, s
print("SPEC-OK", s)

# -- error path: kv heads must divide the model axis ---------------------
try:
    InferenceEngine(get_config("glm4_9b", smoke=True),   # K = 2
                    mesh_of(4), max_batch=2, block_size=16, max_len=96)
    raise AssertionError("expected ValueError for K=2 on model=4")
except ValueError as e:
    assert "not divisible" in str(e)
print("ERRPATH-OK")
"""


def test_engine_tp_byte_identical_subprocess():
    """model=2 and model=4 engines are byte-identical to single-device —
    greedy outputs AND scheduling stats — across prefix-cache hits with
    boundary COW, preemption-recompute, and speculative k=2; and an
    indivisible kv-head count is refused at construction."""
    out = run_with_devices(TP_CODE, n_devices=4, timeout=1800)
    for tag in ("PREFIX-OK", "PREEMPT-OK", "SPEC-OK", "ERRPATH-OK"):
        assert tag in out, out


TP_FAMILY_CODE = """
import jax, jax.numpy as jnp, numpy as np
import repro.compat
from repro.config import get_config
from repro.models import api
from repro.serving import InferenceEngine, Request

def mesh_of(model):
    return jax.make_mesh((1, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

def params_for(cfg):
    with jax.set_mesh(mesh_of(1)):
        pf32, _ = api.init_model(cfg, jax.random.key(0))
        return jax.tree.map(
            lambda x: np.asarray(x.astype(jnp.bfloat16)), pf32)

rng = np.random.default_rng(3)

# hybrid: mamba slot state (replicated — see serving_cache_pspec) +
# paged shared-attention KV sharded by kv head
zcfg = get_config("zamba2_2p7b", smoke=True)
zp = params_for(zcfg)
zprompts = [rng.integers(0, zcfg.vocab_size, 24).astype(np.int32)
            for _ in range(3)]

def run_z(model):
    eng = InferenceEngine(zcfg, mesh_of(model), max_batch=2, block_size=16,
                          max_len=96, max_num_batched_tokens=2 + 16,
                          params=zp, debug_invariants=True)
    outs = eng.run([Request(p.copy(), max_new=8) for p in zprompts],
                   arrival_steps=[0, 0, 3])
    return [outs[r] for r in sorted(outs)]

z1 = run_z(1)
for a, b in zip(z1, run_z(2)):
    np.testing.assert_array_equal(a, b)
print("HYBRID-OK")

# enc-dec: paged self-KV + per-slot cross K/V, both sharded by kv head
wcfg = get_config("whisper_large_v3", smoke=True)
wp = params_for(wcfg)
wprompts = [rng.integers(0, wcfg.vocab_size, 8).astype(np.int32)
            for _ in range(2)]
wframes = [rng.normal(0, 1, (wcfg.encoder_seq_len, wcfg.d_model)
                      ).astype(np.float32) for _ in range(2)]

def run_w(model):
    eng = InferenceEngine(wcfg, mesh_of(model), max_batch=2, block_size=16,
                          max_len=64, params=wp, debug_invariants=True)
    outs = eng.run([Request(p.copy(), max_new=6, frames=f)
                    for p, f in zip(wprompts, wframes)])
    return [outs[r] for r in sorted(outs)]

w1 = run_w(1)
for a, b in zip(w1, run_w(2)):
    np.testing.assert_array_equal(a, b)
print("ENCDEC-OK")
"""


def test_engine_tp_hybrid_and_encdec_subprocess():
    """The other cache kinds stay byte-identical under TP too: zamba2
    (hybrid: replicated slot state + sharded shared-attention pools) and
    whisper (enc-dec: sharded self-KV pools + sharded per-slot cross
    K/V) on a model=2 mesh match single-device byte for byte."""
    out = run_with_devices(TP_FAMILY_CODE, n_devices=4, timeout=1800)
    assert "HYBRID-OK" in out and "ENCDEC-OK" in out, out
