"""Docs hygiene: every relative markdown link in the repo must resolve.

The same check runs as a CI step (``python tools/check_links.py``); having
it under tier-1 means a dead link shows up in the local test run too, not
only after pushing.
"""

import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "tools"))

from check_links import dead_links, markdown_files  # noqa: E402


def test_docs_tree_exists():
    for page in ("architecture.md", "kv-cache.md", "kernels.md",
                 "speculative.md"):
        assert (REPO / "docs" / page).is_file(), f"docs/{page} missing"
    assert (REPO / "README.md").is_file()


def test_no_dead_relative_links():
    assert len(list(markdown_files(REPO))) >= 5
    bad = dead_links(REPO)
    assert not bad, "dead relative links:\n" + "\n".join(
        f"{md}: {target}" for md, target in bad)
