"""int8 error-feedback compressed all-reduce tests (multi-device via
subprocess shard_map)."""

import numpy as np

from helpers import run_with_devices

CODE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.spmd.compression import compressed_psum_mean, init_error_state

mesh = jax.make_mesh((4,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.normal(0, 1, (4, 37)), jnp.float32)   # per-rank grads

def body(g, e):
    out, new_err = compressed_psum_mean(g[0], e[0], "data")
    return out, new_err[None]   # keep the (ranks, n) global layout


with jax.set_mesh(mesh):
    err = jnp.zeros((4, 37), jnp.float32)
    out, new_err = jax.jit(jax.shard_map(
        body, mesh=mesh,
        in_specs=(P("data", None), P("data", None)),
        out_specs=(P(None), P("data", None)), check_vma=False))(g, err)
true = np.asarray(g).mean(axis=0)
got = np.asarray(out)
rel = np.abs(got - true).max() / (np.abs(true).max() + 1e-9)
print("one-shot rel err:", rel)
assert rel < 0.05, rel

# error feedback: repeated reduction of the SAME gradient converges so that
# the accumulated applied update matches the true mean (EF property)
applied = np.zeros(37, np.float32)
err = jnp.zeros((4, 37), jnp.float32)
for i in range(20):
    with jax.set_mesh(mesh):
        out, err = jax.jit(jax.shard_map(
            body, mesh=mesh,
            in_specs=(P("data", None), P("data", None)),
            out_specs=(P(None), P("data", None)), check_vma=False))(g, err)
    applied += np.asarray(out)
drift = np.abs(applied / 20 - true).max()
print("EF 20-step mean drift:", drift)
assert drift < 0.02, drift
print("COMPRESSION OK")
"""


def test_compressed_psum_mean_and_error_feedback():
    out = run_with_devices(CODE, n_devices=4)
    assert "COMPRESSION OK" in out
