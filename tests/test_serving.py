"""Serving subsystem tests: paged-attention kernel vs dense oracle,
block-manager/scheduler invariants, and engine-vs-static-Server greedy
equivalence (the continuous-batching path must be a pure latency/memory
optimization — never a numerics change)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import attention_ref, paged_attention_ref
from repro.serving.kv_cache import TRASH_BLOCK, BlockManager
from repro.serving.scheduler import Request, SamplingParams, Scheduler

RNG = np.random.default_rng(0)


def _paged_case(B, H, K, hd, bs, nblk, dtype):
    """Random page pools + disjoint per-seq block tables + ctx lens."""
    N = 1 + B * nblk
    q = jnp.asarray(RNG.normal(0, 1, (B, H, hd)), jnp.float32).astype(dtype)
    kp = jnp.asarray(RNG.normal(0, 1, (N, bs, K, hd)),
                     jnp.float32).astype(dtype)
    vp = jnp.asarray(RNG.normal(0, 1, (N, bs, K, hd)),
                     jnp.float32).astype(dtype)
    perm = RNG.permutation(np.arange(1, N))[:B * nblk].reshape(B, nblk)
    bt = jnp.asarray(perm, jnp.int32)
    ctx = jnp.asarray(RNG.integers(1, nblk * bs + 1, (B,)), jnp.int32)
    return q, kp, vp, bt, ctx


PAGED_CASES = [
    # B, H, K, hd, block_size, blocks_per_seq, window, cap, dtype
    (3, 4, 2, 16, 8, 4, None, None, jnp.float32),
    (2, 8, 2, 32, 16, 3, None, 50.0, jnp.bfloat16),
    (2, 6, 6, 16, 8, 5, 12, None, jnp.float32),     # MHA (G=1) + window
    (1, 8, 1, 64, 8, 4, None, None, jnp.bfloat16),  # MQA (K=1)
    (2, 4, 2, 64, 16, 2, 8, 30.0, jnp.bfloat16),
]


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_kernel_vs_ref(case):
    B, H, K, hd, bs, nblk, window, cap, dt = case
    q, kp, vp, bt, ctx = _paged_case(B, H, K, hd, bs, nblk, dt)
    o_k = paged_attention(q, kp, vp, bt, ctx, window=window, cap=cap,
                          interpret=True)
    o_r = paged_attention_ref(q, kp, vp, bt, ctx, window=window, cap=cap)
    tol = 1e-2 if dt == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), atol=tol)


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_ref_vs_dense_oracle(case):
    """Densify the pages by hand and compare against the plain attention
    oracle at q_offset = ctx-1 (GQA g-major grouping included)."""
    B, H, K, hd, bs, nblk, window, cap, dt = case
    q, kp, vp, bt, ctx = _paged_case(B, H, K, hd, bs, nblk, dt)
    o_p = np.asarray(paged_attention_ref(q, kp, vp, bt, ctx, window=window,
                                         cap=cap), np.float32)
    for b in range(B):
        S = int(ctx[b])
        k = np.asarray(kp, np.float32)[np.asarray(bt[b])].reshape(
            -1, K, hd)[:S]
        v = np.asarray(vp, np.float32)[np.asarray(bt[b])].reshape(
            -1, K, hd)[:S]
        o_d = attention_ref(
            jnp.asarray(q[b:b + 1, None], jnp.float32),
            jnp.asarray(k[None]), jnp.asarray(v[None]),
            causal=True, window=window, cap=cap, q_offset=S - 1)
        tol = 2e-2 if dt == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(o_p[b], np.asarray(o_d)[0, 0], atol=tol)


def test_paged_inactive_slot_is_zero():
    q, kp, vp, bt, _ = _paged_case(2, 4, 2, 16, 8, 3, jnp.float32)
    ctx = jnp.asarray([0, 5], jnp.int32)
    for fn in (lambda: paged_attention(q, kp, vp, bt, ctx, interpret=True),
               lambda: paged_attention_ref(q, kp, vp, bt, ctx)):
        o = np.asarray(fn())
        assert np.all(o[0] == 0)
        assert np.all(np.isfinite(o))


# ---------------------------------------------------------------------------
# Block manager
# ---------------------------------------------------------------------------


def test_block_manager_alloc_free_invariants():
    bm = BlockManager(num_blocks=9, block_size=4)
    t1 = bm.allocate(1, 9)          # 3 blocks
    t2 = bm.allocate(2, 4)          # 1 block
    bm.check()
    assert TRASH_BLOCK not in t1 + t2
    assert len(set(t1) | set(t2)) == 4
    assert bm.stats().blocks_in_use == 4
    assert bm.ensure(1, 12) and len(bm.table(1)) == 3      # no growth
    assert bm.ensure(1, 13) and len(bm.table(1)) == 4
    bm.check()
    with pytest.raises(KeyError):
        bm.allocate(1, 1)           # double alloc
    assert bm.num_free == 3
    assert not bm.ensure(2, 100)    # OOM -> False, table unchanged
    assert len(bm.table(2)) == 1
    bm.free(1)
    bm.check()
    assert bm.num_free == 7
    assert bm.stats().utilization == pytest.approx(1 / 8)


def test_block_manager_exhaustion_and_reuse():
    bm = BlockManager(num_blocks=5, block_size=2)
    bm.allocate(1, 8)               # all 4 allocatable blocks
    assert not bm.can_allocate(1)
    with pytest.raises(MemoryError):
        bm.allocate(2, 2)
    bm.free(1)
    assert sorted(bm.allocate(3, 8)) == [1, 2, 3, 4]
    bm.check()


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------


def _req(n_prompt=8, max_new=4, **kw):
    return Request(np.arange(n_prompt, dtype=np.int32), max_new=max_new,
                   **kw)


def test_scheduler_fcfs_admission_and_retire():
    bm = BlockManager(num_blocks=9, block_size=4)
    s = Scheduler(bm, max_batch=2, max_blocks_per_seq=4)
    reqs = [_req() for _ in range(3)]
    for r in reqs:
        s.add(r)
    joins = s.admit()
    assert [r.rid for _, r in joins] == [reqs[0].rid, reqs[1].rid]
    assert len(s.waiting) == 1          # no free slot for the third
    assert s.admit() == []
    s.retire(joins[0][0])
    bm.check()
    joins2 = s.admit()                  # freed slot -> FCFS next
    assert [r.rid for _, r in joins2] == [reqs[2].rid]


def test_scheduler_preempts_newest_and_requeues_front():
    # 6 allocatable blocks of 2 tokens; two requests of prompt 4 (2 blocks
    # + 1 decode block each) fill the pool; growth must evict the newest.
    bm = BlockManager(num_blocks=7, block_size=2)
    s = Scheduler(bm, max_batch=2, max_blocks_per_seq=6)
    a, b = _req(n_prompt=4), _req(n_prompt=4)
    s.add(a), s.add(b)
    joins = s.admit()
    assert len(joins) == 2 and bm.num_free == 0
    for _, r in joins:
        r.out.append(7)                 # first sampled token -> ctx 5
    a.out.append(8)                     # a at ctx 6: needs a 4th block
    preempted = s.ensure_decode_capacity()
    assert [r.rid for r in preempted] == [b.rid]
    assert s.waiting[0].rid == b.rid    # requeued at the FRONT
    assert b.n_preempted == 1 and s.n_preemptions == 1
    assert b.out == [7]                 # keeps generated tokens (recompute)
    assert np.array_equal(b.prefill_tokens(),
                          np.concatenate([b.prompt, [7]]))
    bm.check()


def test_scheduler_rejects_horizon_past_capacity():
    # regression: max_new that would grow the table past max_blocks_per_seq
    # must be rejected at submission, not crash the decode loop later
    bm = BlockManager(num_blocks=99, block_size=4)
    s = Scheduler(bm, max_batch=1, max_blocks_per_seq=4)   # 16-token cap
    with pytest.raises(ValueError, match="exceeds max_len capacity"):
        s.add(_req(n_prompt=8, max_new=9))
    s.add(_req(n_prompt=8, max_new=8))                     # exactly fits


def test_request_eos_and_maxnew_done():
    r = _req(max_new=3, eos_id=42)
    assert not r.done
    r.out.append(1)
    assert not r.done
    r.out.append(42)
    assert r.done                       # EOS before max_new
    r2 = _req(max_new=2)
    r2.out += [1, 2]
    assert r2.done                      # max_new without EOS


# ---------------------------------------------------------------------------
# Engine end-to-end (smoke model on the host mesh)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def glm_smoke(tiny_mesh_module):
    from repro.launch.serve import Server
    cfg = get_config("glm4_9b", smoke=True)
    server = Server(cfg, tiny_mesh_module, max_batch=4, prompt_len=32,
                    max_len=96)
    return cfg, tiny_mesh_module, server


@pytest.fixture(scope="module")
def tiny_mesh_module():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def test_engine_matches_static_server_greedy(glm_smoke):
    from repro.launch.serve import Request as SRequest
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(4)]
    legacy = server.serve_batch([SRequest(p, max_new=8) for p in prompts])
    eng = InferenceEngine(cfg, mesh, max_batch=2, block_size=16, max_len=96,
                          params=server.params)
    reqs = [Request(p, max_new=8) for p in prompts]
    outs = eng.run(reqs, arrival_steps=[0, 0, 2, 5])
    for i, r in enumerate(reqs):
        # max_batch=2 < 4 requests + staggered arrivals: identical greedy
        # tokens regardless of batch composition over time
        np.testing.assert_array_equal(outs[r.rid], legacy[i])


def test_engine_eos_early_stop_frees_slot(glm_smoke):
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(2)]
    # probe: discover the token request 0 greedily emits at step 3
    eng = InferenceEngine(cfg, mesh, max_batch=1, block_size=16, max_len=96,
                          params=server.params)
    probe = Request(prompts[0], max_new=6)
    eos = int(eng.run([probe])[probe.rid][3])

    eng = InferenceEngine(cfg, mesh, max_batch=1, block_size=16, max_len=96,
                          params=server.params)
    r0 = Request(prompts[0], max_new=32, eos_id=eos)
    r1 = Request(prompts[1], max_new=4)
    outs = eng.run([r0, r1])
    assert outs[r0.rid][-1] == eos and len(outs[r0.rid]) == 4
    assert len(outs[r1.rid]) == 4
    # retired-at-EOS request stopped consuming decode steps: with one slot,
    # total decode steps is (4-1) + (4-1), nowhere near r0's max_new=32
    assert eng.stats["decode_steps"] == 6
    assert eng.bm.stats().blocks_in_use == 0       # everything freed


def test_engine_preemption_preserves_greedy_output(glm_smoke):
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(2)]
    base = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                           max_len=96, params=server.params)
    want = base.run([Request(p, max_new=20) for p in prompts])
    want = list(want.values())

    # 7 allocatable blocks of 16: two ctx-33 joins take 3 blocks each;
    # growth past 48 tokens (ctx 32+16) forces preempting the newer one.
    tight = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                            max_len=96, num_blocks=8, params=server.params)
    reqs = [Request(p, max_new=20) for p in prompts]
    got = tight.run(reqs)
    assert tight.stats["preemptions"] >= 1
    for w, r in zip(want, reqs):
        np.testing.assert_array_equal(got[r.rid], w)


def test_engine_rejects_unpageable_archs(glm_smoke):
    from repro.serving import InferenceEngine
    _, mesh, _ = glm_smoke
    with pytest.raises(ValueError, match="SSM"):
        InferenceEngine(get_config("mamba2_370m", smoke=True), mesh)
    with pytest.raises(ValueError, match="cross caches"):
        InferenceEngine(get_config("whisper_large_v3", smoke=True), mesh)
