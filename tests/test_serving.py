"""Serving subsystem tests: paged-attention kernels (decode + chunked
prefill) vs densifying oracles, refcounted block-manager / prefix-cache /
COW invariants, budgeted-scheduler behaviour, and engine-vs-static-Server
greedy equivalence (the continuous-batching path must be a pure
latency/memory optimization — never a numerics change)."""

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.kernels.paged_attention import (paged_attention,
                                           paged_prefill_attention)
from repro.kernels.ref import (attention_ref, paged_attention_ref,
                               paged_prefill_attention_ref)
from repro.serving.kv_cache import (TRASH_BLOCK, BlockManager,
                                    chain_block_hashes)
from repro.serving.scheduler import Request, SamplingParams, Scheduler

RNG = np.random.default_rng(0)


def _paged_case(B, H, K, hd, bs, nblk, dtype):
    """Random page pools + disjoint per-seq block tables + ctx lens."""
    N = 1 + B * nblk
    q = jnp.asarray(RNG.normal(0, 1, (B, H, hd)), jnp.float32).astype(dtype)
    kp = jnp.asarray(RNG.normal(0, 1, (N, bs, K, hd)),
                     jnp.float32).astype(dtype)
    vp = jnp.asarray(RNG.normal(0, 1, (N, bs, K, hd)),
                     jnp.float32).astype(dtype)
    perm = RNG.permutation(np.arange(1, N))[:B * nblk].reshape(B, nblk)
    bt = jnp.asarray(perm, jnp.int32)
    ctx = jnp.asarray(RNG.integers(1, nblk * bs + 1, (B,)), jnp.int32)
    return q, kp, vp, bt, ctx


PAGED_CASES = [
    # B, H, K, hd, block_size, blocks_per_seq, window, cap, dtype
    (3, 4, 2, 16, 8, 4, None, None, jnp.float32),
    (2, 8, 2, 32, 16, 3, None, 50.0, jnp.bfloat16),
    (2, 6, 6, 16, 8, 5, 12, None, jnp.float32),     # MHA (G=1) + window
    (1, 8, 1, 64, 8, 4, None, None, jnp.bfloat16),  # MQA (K=1)
    (2, 4, 2, 64, 16, 2, 8, 30.0, jnp.bfloat16),
]


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_kernel_vs_ref(case):
    B, H, K, hd, bs, nblk, window, cap, dt = case
    q, kp, vp, bt, ctx = _paged_case(B, H, K, hd, bs, nblk, dt)
    o_k = paged_attention(q, kp, vp, bt, ctx, window=window, cap=cap,
                          interpret=True)
    o_r = paged_attention_ref(q, kp, vp, bt, ctx, window=window, cap=cap)
    tol = 1e-2 if dt == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), atol=tol)


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_ref_vs_dense_oracle(case):
    """Densify the pages by hand and compare against the plain attention
    oracle at q_offset = ctx-1 (GQA g-major grouping included)."""
    B, H, K, hd, bs, nblk, window, cap, dt = case
    q, kp, vp, bt, ctx = _paged_case(B, H, K, hd, bs, nblk, dt)
    o_p = np.asarray(paged_attention_ref(q, kp, vp, bt, ctx, window=window,
                                         cap=cap), np.float32)
    for b in range(B):
        S = int(ctx[b])
        k = np.asarray(kp, np.float32)[np.asarray(bt[b])].reshape(
            -1, K, hd)[:S]
        v = np.asarray(vp, np.float32)[np.asarray(bt[b])].reshape(
            -1, K, hd)[:S]
        o_d = attention_ref(
            jnp.asarray(q[b:b + 1, None], jnp.float32),
            jnp.asarray(k[None]), jnp.asarray(v[None]),
            causal=True, window=window, cap=cap, q_offset=S - 1)
        tol = 2e-2 if dt == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(o_p[b], np.asarray(o_d)[0, 0], atol=tol)


def test_paged_inactive_slot_is_zero():
    q, kp, vp, bt, _ = _paged_case(2, 4, 2, 16, 8, 3, jnp.float32)
    ctx = jnp.asarray([0, 5], jnp.int32)
    for fn in (lambda: paged_attention(q, kp, vp, bt, ctx, interpret=True),
               lambda: paged_attention_ref(q, kp, vp, bt, ctx)):
        o = np.asarray(fn())
        assert np.all(o[0] == 0)
        assert np.all(np.isfinite(o))


# ---------------------------------------------------------------------------
# Multi-query (chunked prefill) kernel
# ---------------------------------------------------------------------------


def _chunk_case(B, H, K, hd, bs, nblk, C, dtype):
    N = 1 + B * nblk
    q = jnp.asarray(RNG.normal(0, 1, (B, C, H, hd)),
                    jnp.float32).astype(dtype)
    kp = jnp.asarray(RNG.normal(0, 1, (N, bs, K, hd)),
                     jnp.float32).astype(dtype)
    vp = jnp.asarray(RNG.normal(0, 1, (N, bs, K, hd)),
                     jnp.float32).astype(dtype)
    perm = RNG.permutation(np.arange(1, N))[:B * nblk].reshape(B, nblk)
    bt = jnp.asarray(perm, jnp.int32)
    qlen = RNG.integers(0, C + 1, (B,))
    qlen[0] = C                     # always one full chunk in the batch
    ctx = np.array([RNG.integers(ql, nblk * bs + 1) if ql else 0
                    for ql in qlen])
    return (q, kp, vp, bt, jnp.asarray(ctx, jnp.int32),
            jnp.asarray(qlen, jnp.int32))


# acceptance: chunk lengths {1, block_size, 2.5 blocks} with causal masking
CHUNK_CASES = [
    # B, H, K, hd, block_size, blocks_per_seq, C, window, cap, dtype
    (3, 4, 2, 16, 8, 4, 1, None, None, jnp.float32),
    (2, 8, 2, 32, 16, 3, 16, None, 50.0, jnp.bfloat16),  # C == block_size
    (2, 6, 6, 16, 8, 5, 20, None, None, jnp.float32),    # C == 2.5 blocks
    (2, 6, 2, 16, 8, 5, 20, 12, None, jnp.float32),      # + sliding window
    (1, 8, 1, 64, 8, 4, 20, None, None, jnp.bfloat16),   # MQA, 2.5 blocks
]


@pytest.mark.parametrize("case", CHUNK_CASES)
def test_chunk_kernel_vs_ref(case):
    B, H, K, hd, bs, nblk, C, window, cap, dt = case
    q, kp, vp, bt, ctx, qlen = _chunk_case(B, H, K, hd, bs, nblk, C, dt)
    o_k = paged_prefill_attention(q, kp, vp, bt, ctx, qlen, window=window,
                                  cap=cap, interpret=True)
    o_r = paged_prefill_attention_ref(q, kp, vp, bt, ctx, qlen,
                                      window=window, cap=cap)
    tol = 1e-2 if dt == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), atol=tol)


def test_chunk_kernel_qlen1_matches_decode_kernel():
    """A 1-token chunk is exactly a decode step."""
    B, H, K, hd, bs, nblk = 3, 4, 2, 16, 8, 4
    q, kp, vp, bt, ctx = _paged_case(B, H, K, hd, bs, nblk, jnp.float32)
    o_d = paged_attention(q, kp, vp, bt, ctx, interpret=True)
    o_c = paged_prefill_attention(q[:, None], kp, vp, bt, ctx,
                                  jnp.ones(B, jnp.int32), interpret=True)
    np.testing.assert_array_equal(np.asarray(o_c)[:, 0], np.asarray(o_d))


def test_chunk_ref_vs_dense_oracle():
    """Densify by hand, run the plain oracle over the chunk's query span."""
    B, H, K, hd, bs, nblk, C = 2, 4, 2, 16, 8, 4, 12
    q, kp, vp, bt, ctx, qlen = _chunk_case(B, H, K, hd, bs, nblk, C,
                                           jnp.float32)
    o_p = np.asarray(paged_prefill_attention_ref(q, kp, vp, bt, ctx, qlen),
                     np.float32)
    for b in range(B):
        n, S = int(qlen[b]), int(ctx[b])
        if n == 0:
            assert np.all(o_p[b] == 0)
            continue
        k = np.asarray(kp, np.float32)[np.asarray(bt[b])].reshape(
            -1, K, hd)[:S]
        v = np.asarray(vp, np.float32)[np.asarray(bt[b])].reshape(
            -1, K, hd)[:S]
        o_d = attention_ref(
            jnp.asarray(q[b:b + 1, :n], jnp.float32),
            jnp.asarray(k[None]), jnp.asarray(v[None]),
            causal=True, q_offset=S - n)
        np.testing.assert_allclose(o_p[b, :n], np.asarray(o_d)[0],
                                   atol=1e-5)


def test_chunk_padding_rows_are_zero():
    q, kp, vp, bt, ctx, _ = _chunk_case(2, 4, 2, 16, 8, 3, 8, jnp.float32)
    qlen = jnp.asarray([3, 0], jnp.int32)
    ctx = jnp.asarray([10, 0], jnp.int32)
    for fn in (lambda: paged_prefill_attention(q, kp, vp, bt, ctx, qlen,
                                               interpret=True),
               lambda: paged_prefill_attention_ref(q, kp, vp, bt, ctx,
                                                   qlen)):
        o = np.asarray(fn())
        assert np.all(o[0, 3:] == 0)
        assert np.all(o[1] == 0)
        assert np.all(np.isfinite(o))


# ---------------------------------------------------------------------------
# Block manager
# ---------------------------------------------------------------------------


def test_block_manager_alloc_free_invariants():
    bm = BlockManager(num_blocks=9, block_size=4)
    t1 = bm.allocate(1, 9)          # 3 blocks
    t2 = bm.allocate(2, 4)          # 1 block
    bm.check()
    assert TRASH_BLOCK not in t1 + t2
    assert len(set(t1) | set(t2)) == 4
    assert bm.stats().blocks_in_use == 4
    assert bm.ensure(1, 12) and len(bm.table(1)) == 3      # no growth
    assert bm.ensure(1, 13) and len(bm.table(1)) == 4
    bm.check()
    with pytest.raises(KeyError):
        bm.allocate(1, 1)           # double alloc
    assert bm.num_free == 3
    assert not bm.ensure(2, 100)    # OOM -> False, table unchanged
    assert len(bm.table(2)) == 1
    bm.free(1)
    bm.check()
    assert bm.num_free == 7
    assert bm.stats().utilization == pytest.approx(1 / 8)


def test_block_manager_exhaustion_and_reuse():
    bm = BlockManager(num_blocks=5, block_size=2)
    bm.allocate(1, 8)               # all 4 allocatable blocks
    assert not bm.can_allocate(1)
    with pytest.raises(MemoryError):
        bm.allocate(2, 2)
    bm.free(1)
    assert sorted(bm.allocate(3, 8)) == [1, 2, 3, 4]
    bm.check()


def test_block_manager_fork_refcount_and_cow():
    bm = BlockManager(num_blocks=8, block_size=4)
    t1 = bm.allocate(1, 8)          # 2 blocks
    bm.fork(1, 2)
    bm.check()
    assert bm.table(2) == t1
    assert all(bm.refcount(b) == 2 for b in t1)
    assert bm.stats().blocks_in_use == 2       # shared, counted once
    assert bm.stats().shared_blocks == 2
    # COW the second block for writer 2
    new = bm.cow(2, 1)
    assert new is not None and new != t1[1]
    assert bm.refcount(t1[1]) == 1 and bm.refcount(new) == 1
    assert bm.table(1) == t1 and bm.table(2) == [t1[0], new]
    assert bm.cow(2, 1) is None                # already exclusive: in place
    bm.check()
    # freeing one sharer keeps the shared block alive
    bm.free(2)
    bm.check()
    assert bm.refcount(t1[0]) == 1
    assert bm.table(1) == t1
    bm.free(1)
    bm.check()
    assert bm.num_free == 7


def test_block_manager_cow_oom():
    bm = BlockManager(num_blocks=3, block_size=2)
    bm.allocate(1, 4)               # both allocatable blocks
    bm.fork(1, 2)
    with pytest.raises(MemoryError):
        bm.cow(2, 0)


def test_prefix_hash_register_match_and_revival():
    bm = BlockManager(num_blocks=9, block_size=4)
    toks = np.arange(14, dtype=np.int32)
    hashes = chain_block_hashes(toks, 4)
    assert len(hashes) == 3                    # full blocks only
    # chained: a different first block changes every downstream hash
    other = chain_block_hashes(np.concatenate([[99], toks[1:]]), 4)
    assert all(a != b for a, b in zip(hashes, other))
    t1 = bm.allocate(1, 14)
    for b, h in zip(t1, hashes):
        bm.register(b, h)
    bm.check()
    assert bm.match(hashes) == t1[:3]
    assert bm.match(other) == []
    assert bm.match(hashes[:2] + [12345]) == t1[:2]    # longest prefix
    # adopt shares the matched blocks
    t2 = bm.adopt(2, bm.match(hashes))
    assert t2 == t1[:3] and all(bm.refcount(b) == 2 for b in t2)
    bm.check()
    # freeing the original keeps the cached blocks matchable (revival)
    bm.free(2)
    bm.free(1)
    bm.check()
    assert bm.num_free == 8
    assert bm.match(hashes) == t1[:3]          # still cached while free
    t3 = bm.adopt(3, bm.match(hashes))
    assert t3 == t1[:3]
    assert bm.num_free == 5                    # revived out of the free list
    bm.check()


def test_block_manager_truncate_rewind():
    """Speculative rollback: truncate frees tail blocks (newest first),
    respects sharing via refcounts, and keeps content hashes on freed
    blocks so prefix entries survive a rewind."""
    bm = BlockManager(num_blocks=9, block_size=4)
    t = bm.allocate(1, 16)              # 4 blocks
    assert bm.truncate(1, 9) == [t[3]]  # keep ceil(9/4) = 3
    assert bm.table(1) == t[:3] and bm.num_free == 5
    bm.check()
    bm.fork(1, 2)
    bm.truncate(2, 4)                   # rid 2 keeps 1 block
    assert bm.table(2) == t[:1]
    assert bm.refcount(t[1]) == 1 and bm.table(1) == t[:3]
    bm.check()
    bm.register(t[2], b"spec")
    bm.truncate(1, 5)                   # drops the hashed tail block
    assert bm.match([b"spec"]) == [t[2]]     # cached-free, revivable
    assert bm.truncate(1, 0) == [t[1], t[0]]
    assert bm.truncate(1, 0) == []           # idempotent on empty
    bm.check()


def test_prefix_cache_eviction_prefers_unhashed():
    bm = BlockManager(num_blocks=5, block_size=2)
    t = bm.allocate(1, 8)
    bm.register(t[0], 111)
    bm.free(1)
    # allocating 2 blocks must prefer the 3 unhashed ones
    t2 = bm.allocate(2, 4)
    assert t[0] not in t2
    assert bm.match([111]) == [t[0]]
    # allocating past the unhashed supply evicts the cached block
    bm.ensure(2, 8)
    assert bm.match([111]) == []
    bm.check()


# ---------------------------------------------------------------------------
# Property test: random walks over the block manager
# ---------------------------------------------------------------------------


def _bm_random_walk(tape):
    """Interpret ``tape`` (an iterator of ints) as add/grow/fork/free/COW/
    register/adopt/truncate/swap ops against a BlockManager with a host
    tier, asserting the full invariant set and exact free-block accounting
    on both tiers after every op (truncate is the speculative draft/target
    rewind path; swap-out/swap-in/swap-discard are the host-residency
    preemption/abort paths)."""
    NB, BS, NH = 9, 4, 6
    bm = BlockManager(num_blocks=NB, block_size=BS, num_host_blocks=NH)
    tokens: dict[int, int] = {}       # rid -> tokens covered
    swapped: dict[int, int] = {}      # rid -> host slots owned
    next_rid = [0]
    next_hash = [0]

    def draw(n):
        return next(tape) % n

    def new_rid():
        next_rid[0] += 1
        return next_rid[0]

    def check_accounting():
        bm.check()
        in_use = {b for rid in tokens for b in bm.table(rid)}
        assert bm.num_free == (NB - 1) - len(in_use)
        assert bm.stats().blocks_in_use == len(in_use)
        assert bm.num_host_free == NH - sum(swapped.values())
        for rid in swapped:
            assert bm.is_swapped(rid)

    for _ in range(160):
        op = draw(11)
        rids = list(tokens)
        if op == 0 or (not rids and op < 8):          # allocate
            rid = new_rid()
            try:
                bm.allocate(rid, draw(3 * BS + 1))
                tokens[rid] = 0
            except MemoryError:
                pass
        elif op == 1:                                 # grow
            rid = rids[draw(len(rids))]
            want = len(bm.table(rid)) * BS + draw(2 * BS) + 1
            if bm.ensure(rid, want):
                tokens[rid] = want
        elif op == 2:                                 # fork
            rid = new_rid()
            src = rids[draw(len(rids))]
            bm.fork(src, rid)
            tokens[rid] = tokens[src]
        elif op == 3:                                 # cow
            rid = rids[draw(len(rids))]
            t = bm.table(rid)
            if t:
                try:
                    bm.cow(rid, draw(len(t)))
                except MemoryError:
                    pass
        elif op == 4:                                 # free
            rid = rids[draw(len(rids))]
            bm.free(rid)
            del tokens[rid]
        elif op == 5:                                 # register a block
            rid = rids[draw(len(rids))]
            t = bm.table(rid)
            if t:
                next_hash[0] += 1
                bm.register(t[draw(len(t))], next_hash[0])
        elif op == 7:                                 # truncate (spec rewind)
            rid = rids[draw(len(rids))]
            cover = len(bm.table(rid)) * BS
            n = draw(cover + 1) if cover else 0
            bm.truncate(rid, n)
            tokens[rid] = min(tokens[rid], n)
        elif op == 8:                                 # swap out (preempt)
            if rids:
                rid = rids[draw(len(rids))]
                if bm.can_swap_out(rid):
                    n = len(bm.table(rid))
                    pairs = bm.swap_out(rid)
                    assert len(pairs) == n
                    swapped[rid] = n
                    del tokens[rid]
        elif op == 9:                                 # swap in (re-admit)
            srids = list(swapped)
            if srids:
                rid = srids[draw(len(srids))]
                if bm.can_swap_in(rid):
                    t, pairs = bm.swap_in(rid)
                    assert len(t) == swapped.pop(rid)
                    assert len(pairs) <= len(t)   # revivals copy nothing
                    tokens[rid] = 0
        elif op == 10:                                # swap discard (abort)
            srids = list(swapped)
            if srids:
                rid = srids[draw(len(srids))]
                bm.swap_discard(rid)
                del swapped[rid]
        else:                                         # adopt cached blocks
            if next_hash[0]:
                h = draw(next_hash[0]) + 1
                blocks = bm.match([h])
                if blocks:
                    rid = new_rid()
                    bm.adopt(rid, blocks)
                    tokens[rid] = 0
        check_accounting()
    for rid in list(tokens):
        bm.free(rid)
        del tokens[rid]
        check_accounting()
    for rid in list(swapped):
        bm.swap_discard(rid)
        del swapped[rid]
        check_accounting()
    assert bm.num_free == NB - 1
    assert bm.num_host_free == NH


def test_block_manager_random_walk_seeded():
    for seed in range(8):
        rng = random.Random(seed)
        _bm_random_walk(iter(lambda: rng.randrange(1 << 20), None))


def test_block_manager_random_walk_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.lists(st.integers(0, (1 << 20) - 1), max_size=900))
    @hyp.settings(max_examples=60, deadline=None)
    def prop(tape):
        it = iter(tape)
        _bm_random_walk(iter(lambda: next(it, 0), None))

    prop()


# ---------------------------------------------------------------------------
# Host tier: swap-out / swap-in residency
# ---------------------------------------------------------------------------


def test_swap_roundtrip_revives_free_device_blocks():
    """Swap out then immediately swap in: hashed device blocks survived on
    the free list (pages are never written while free), so the table is
    rebuilt in place with zero h2d copies."""
    bm = BlockManager(num_blocks=6, block_size=4, num_host_blocks=4)
    bm.allocate(1, 8)
    t0 = bm.table(1)
    bm.register(t0[0], b"h0"), bm.register(t0[1], b"h1")
    pairs = bm.swap_out(1)
    assert [b for b, _ in pairs] == t0 and bm.is_swapped(1)
    assert bm.num_host_free == 2 and bm.num_free == 5
    assert bm.match_host([b"h0", b"h1"]) == [s for _, s in pairs]
    bm.check()
    t1, copies = bm.swap_in(1)
    assert t1 == t0 and copies == []              # pure revival
    assert bm.num_host_free == 4 and not bm.is_swapped(1)
    bm.check()
    bm.free(1)
    bm.check()


def test_swap_in_copies_after_device_eviction():
    """If the freed device twins get recycled while a request is swapped
    out, swap-in must allocate fresh blocks and return h2d copy pairs,
    re-registering the hashes on the new blocks."""
    bm = BlockManager(num_blocks=6, block_size=4, num_host_blocks=4)
    bm.allocate(1, 8)
    t0 = bm.table(1)
    bm.register(t0[0], b"h0"), bm.register(t0[1], b"h1")
    bm.swap_out(1)
    bm.allocate(2, 20)                 # recycles every free block
    assert bm.match([b"h0", b"h1"]) == []         # device hashes wiped
    assert not bm.can_swap_in(1)
    bm.free(2)
    t1, copies = bm.swap_in(1)
    assert len(t1) == 2 and len(copies) == 2      # no revival possible
    assert bm.match([b"h0", b"h1"]) == t1         # hashes re-registered
    bm.check()


def test_match_host_and_host_copy_in_shares_blocks():
    """A host prefix hit copies swapped slots into fresh device blocks
    without disturbing the swapped-out owner; a later swap-in of the
    owner dedups onto the re-registered blocks (refcount share)."""
    bm = BlockManager(num_blocks=6, block_size=4, num_host_blocks=4)
    bm.allocate(1, 8)
    h = chain_block_hashes(np.arange(8, dtype=np.int32), 4)
    for b, hb in zip(bm.table(1), h):
        bm.register(b, hb)
    bm.swap_out(1)
    bm.allocate(2, 20)                 # wipe the device-side hash index
    bm.free(2)
    assert bm.match(h) == []
    slots = bm.match_host(h)
    assert len(slots) == 2
    t3, copies = bm.host_copy_in(3, slots, h)
    assert len(t3) == 2 and [s for s, _ in copies] == slots
    assert bm.match(h) == t3           # host hit re-registered on device
    bm.check()
    t1, copies1 = bm.swap_in(1)        # owner dedups onto rid 3's blocks
    assert t1 == t3 and copies1 == []
    assert bm.refcount(t1[0]) == 2
    bm.check()
    bm.free(1), bm.free(3)
    bm.check()


def test_swap_discard_releases_host_slots():
    bm = BlockManager(num_blocks=6, block_size=4, num_host_blocks=4)
    bm.allocate(1, 8)
    bm.register(bm.table(1)[0], b"h0")
    bm.swap_out(1)
    assert bm.num_host_free == 2
    bm.swap_discard(1)
    assert bm.num_host_free == 4 and not bm.is_swapped(1)
    assert bm.match_host([b"h0"]) == []           # host hash died with slot
    bm.check()


def test_swap_cost_model_prefers_cheaper_side():
    from repro.serving.scheduler import SwapCostModel
    m = SwapCostModel(block_bytes=1 << 20)        # defaults: 4 GB/s, 20k t/s
    # 2 blocks: 4 MiB both ways / 4 GB/s ~ 1.0 ms < 100 tokens / 20k t/s
    assert m.prefer_swap(2, 100)
    assert not m.prefer_swap(64, 4)               # 128 MiB vs 0.2 ms
    assert SwapCostModel(block_bytes=1, policy="always").prefer_swap(9, 0)
    assert not SwapCostModel(block_bytes=1, policy="never").prefer_swap(0, 9)
    # EMA observations move the estimates toward the measured rates
    m.observe_swap(1 << 30, 1.0)                  # measured 1 GB/s
    assert m.bytes_per_s < 4e9
    m.observe_prefill(100_000, 1.0)               # measured 100k tok/s
    assert m.prefill_tok_s > 2e4


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------


def _req(n_prompt=8, max_new=4, **kw):
    return Request(np.arange(n_prompt, dtype=np.int32), max_new=max_new,
                   **kw)


def _sched(bm, max_batch=2, max_blocks_per_seq=4, budget=12, chunk=8, **kw):
    return Scheduler(bm, max_batch, max_blocks_per_seq, budget, chunk, **kw)


def _complete_chunk(plan):
    """Simulate the engine finishing the planned chunk (+ a sampled token
    when the prompt completes)."""
    slot, req, n = plan.chunk
    req.num_computed += n
    if req.num_computed == req.context_len:
        req.out.append(7)
    return slot, req


def test_scheduler_budget_and_fcfs_order():
    bm = BlockManager(num_blocks=17, block_size=4)
    s = _sched(bm, max_batch=2, budget=9, chunk=8)
    reqs = [_req(n_prompt=12) for _ in range(3)]
    for r in reqs:
        s.add(r)
    p1 = s.schedule()                       # admit first; chunk of 8
    assert p1.decodes == [] and p1.admitted == 1
    assert p1.chunk[1] is reqs[0] and p1.chunk[2] == 8
    assert p1.scheduled_tokens <= 9
    _complete_chunk(p1)
    p2 = s.schedule()                       # finish req0's prompt (4 left)
    assert p2.chunk[1] is reqs[0] and p2.chunk[2] == 4
    _complete_chunk(p2)                     # samples req0's first token
    assert reqs[0].decode_ready
    p3 = s.schedule()                       # req0 decodes, req1 admits
    assert [r.rid for _, r in p3.decodes] == [reqs[0].rid]
    assert p3.chunk[1] is reqs[1]
    assert p3.chunk[2] == 8                 # 9 budget - 1 decode
    assert len(s.waiting) == 1              # no slot for the third yet


def test_scheduler_admission_waits_for_free_slot():
    bm = BlockManager(num_blocks=33, block_size=4)
    s = _sched(bm, max_batch=1, budget=16, chunk=8)
    a, b = _req(), _req()
    s.add(a), s.add(b)
    p = s.schedule()
    _complete_chunk(p)
    assert a.decode_ready and len(s.waiting) == 1
    p2 = s.schedule()                       # slot busy: b keeps waiting
    assert p2.chunk is None and len(p2.decodes) == 1
    a.out.append(9)
    a.num_computed += 1
    s.retire(0)
    p3 = s.schedule()
    assert p3.chunk[1] is b


def test_scheduler_preempts_newest_and_requeues_front():
    # 6 allocatable blocks of 2 tokens; two requests of prompt 4 fill the
    # pool after their first sampled token; growth must evict the newest.
    bm = BlockManager(num_blocks=7, block_size=2)
    s = _sched(bm, max_batch=2, max_blocks_per_seq=6, budget=8, chunk=4,
               enable_prefix_caching=False)
    a, b, c = _req(n_prompt=4), _req(n_prompt=4), _req(n_prompt=4)
    s.add(a), s.add(b)
    _complete_chunk(s.schedule())           # a prefills, samples
    _complete_chunk(s.schedule())           # b prefills, samples
    assert a.decode_ready and b.decode_ready
    s.schedule()                            # both decode: 3 blocks each
    for r in (a, b):
        r.out.append(8)
        r.num_computed += 1
    s.add(c)                                # queued behind any preemption
    # a now at ctx 6 -> needs a 4th block; pool is dry -> b is evicted
    plan = s.schedule()
    assert [r.rid for _, r in plan.decodes] == [a.rid]
    assert b.n_preempted == 1 and s.n_preemptions == 1
    assert b.out == [7, 8]                  # keeps generated tokens
    # requeued at the FRONT: b re-admits ahead of c, recomputing
    # prompt + generated from scratch
    assert plan.chunk[1] is b and b.num_computed == 0
    assert s.waiting[0].rid == c.rid
    assert np.array_equal(b.prefill_tokens(),
                          np.concatenate([b.prompt, [7, 8]]))
    bm.check()


def _swap_preempt_setup():
    """The growth-pressure choreography of the preemption test above, but
    with a host tier and a policy="always" cost model: the evicted victim
    is swap-preempted instead of released."""
    from repro.serving.scheduler import SwapCostModel
    bm = BlockManager(num_blocks=7, block_size=2, num_host_blocks=8)
    s = _sched(bm, max_batch=2, max_blocks_per_seq=6, budget=8, chunk=4,
               enable_prefix_caching=False,
               swap_cost=SwapCostModel(block_bytes=64, policy="always"))
    a, b = _req(n_prompt=4), _req(n_prompt=4)
    s.add(a), s.add(b)
    _complete_chunk(s.schedule())           # a prefills, samples
    _complete_chunk(s.schedule())           # b prefills, samples
    s.schedule()                            # both decode: 3 blocks each
    for r in (a, b):
        r.out.append(8)
        r.num_computed += 1
    plan = s.schedule()         # a's growth evicts b -> swapped, not reset
    return bm, s, a, b, plan


def test_scheduler_swap_preemption_preserves_progress():
    bm, s, a, b, plan = _swap_preempt_setup()
    assert s.n_swap_preemptions == 1    # counted within n_preemptions
    assert len(plan.swap_outs) == 3         # b's whole table went to host
    assert bm.is_swapped(b.rid) and s.waiting[0] is b
    assert b.num_computed == 5              # progress survives the swap
    assert b.out == [7, 8]
    bm.check()
    # a finishes and retires; b swaps back in and resumes *decoding* —
    # no recompute chunk is scheduled for it
    slot_a = next(sl for sl, r in s.running.items() if r is a)
    s.retire(slot_a)
    plan2 = s.schedule()
    assert s.n_swap_ins == 1 and not bm.is_swapped(b.rid)
    assert len(plan2.swap_ins) == 3         # unhashed blocks: all copied
    assert plan2.chunk is None              # no recompute chunk for b
    assert b.num_computed == 5
    plan3 = s.schedule()                    # decodes are planned pre-admit
    assert [r.rid for _, r in plan3.decodes] == [b.rid]
    bm.check()


def test_scheduler_abort_swapped_request_discards_host_slots():
    bm, s, a, b, _ = _swap_preempt_setup()
    assert bm.num_host_free == 8 - 3
    assert s.abort(b.rid)
    assert s.n_aborts == 1
    assert bm.num_host_free == 8 and not bm.is_swapped(b.rid)
    assert not s.waiting
    bm.check()


def test_scheduler_abort_running_and_waiting():
    bm = BlockManager(num_blocks=17, block_size=4)
    s = _sched(bm, max_batch=1, budget=16, chunk=8)
    a, b = _req(), _req()
    s.add(a), s.add(b)
    _complete_chunk(s.schedule())           # a running, b waiting
    assert s.abort(b.rid)                   # waiting abort: just dequeues
    assert not s.waiting
    assert s.abort(a.rid)                   # running abort: frees the slot
    assert not s.running and not s.has_work
    assert bm.stats().blocks_in_use == 0
    assert not s.abort(999_999)             # unknown rid: no-op
    assert s.n_aborts == 2
    bm.check()


def test_scheduler_rejects_horizon_past_capacity():
    # the one place horizon validation lives: submission. Admission relies
    # on it instead of re-checking.
    bm = BlockManager(num_blocks=99, block_size=4)
    s = _sched(bm, max_batch=1, max_blocks_per_seq=4)   # 16-token cap
    with pytest.raises(ValueError, match="exceeds max_len capacity"):
        s.add(_req(n_prompt=8, max_new=9))
    s.add(_req(n_prompt=8, max_new=8))                     # exactly fits
    assert len(s.waiting) == 1


def test_admission_full_hit_cow_with_drained_free_list():
    """Regression: a full-prompt hit whose matched chain mixes a cached
    *free* block (revived by adoption) with a *live* shared block must
    drop the last hit when adoption drains the free list — the boundary
    COW would otherwise raise an uncaught MemoryError."""
    bm = BlockManager(num_blocks=5, block_size=2)
    s = _sched(bm, max_batch=2, max_blocks_per_seq=3, budget=8, chunk=4)
    toks = np.arange(4, dtype=np.int32)
    h0, h1 = chain_block_hashes(toks, 2)
    # stale cached-free copy of the first block (an earlier request's)
    x = bm.allocate(7777, 2)[0]
    bm.register(x, h0)
    bm.free(7777)
    # running request b computed its OWN copy of the prefix (h0 was taken
    # first, so only its second block registered) and holds all remaining
    # blocks; it is decode-ready and needs no growth
    b = Request(toks.copy(), max_new=4)
    b.out.append(8)
    b.num_computed = 4
    b.n_published = 2
    bm.allocate(b.rid, 6)                          # 3 blocks
    bm.register(bm.table(b.rid)[1], h1)
    s.running[0] = b
    s._join_order.append(0)
    assert bm.match([h0, h1]) == [x, bm.table(b.rid)[1]]
    assert bm.num_free == 1                        # exactly {x}
    c = Request(toks.copy(), max_new=2)
    s.add(c)
    plan = s.schedule()                            # must not raise
    assert plan.admitted == 1
    assert c.num_computed == 2                     # last hit dropped
    assert bm.table(c.rid) == [x]
    bm.check()


def test_admission_in_place_boundary_write_leaves_cache():
    """Regression: a full-prompt hit revived with refcount 1 recomputes
    its last token *in place*; until that write lands the block must leave
    the hash index, or an admission in the same pass adopts a block with a
    pending write (the decode would then write into a shared block)."""
    bm = BlockManager(num_blocks=9, block_size=2)
    s = _sched(bm, max_batch=2, max_blocks_per_seq=4, budget=8, chunk=4)
    stream = np.array([0, 1, 2, 7], np.int32)
    h0, h1 = chain_block_hashes(stream, 2)
    old = bm.allocate(4242, 4)
    bm.register(old[0], h0)
    bm.register(old[1], h1)
    bm.free(4242)                     # retired: both blocks cached-free
    # d: preempted recompute of prompt [0,1,2] + generated [7] — full hit,
    # immediately decode-ready, with a pending in-place write at pos 3
    d = Request(stream[:3].copy(), max_new=4)
    d.out.append(7)
    e = Request(stream.copy(), max_new=2)
    s.add(d)
    s.add(e)
    s.schedule()
    assert d.decode_ready and bm.table(d.rid) == old
    # e, admitted in the same pass, must NOT share d's pending-write block
    assert old[1] not in bm.table(e.rid)
    assert bm.refcount(old[1]) == 1
    assert bm.match([h0, h1]) == [old[0]]
    bm.check()


def test_scheduler_budget_must_exceed_max_batch():
    bm = BlockManager(num_blocks=9, block_size=4)
    with pytest.raises(ValueError, match="must exceed max_batch"):
        Scheduler(bm, 4, 4, 4, 1)


def test_request_eos_and_maxnew_done():
    r = _req(max_new=3, eos_id=42)
    assert not r.done
    r.out.append(1)
    assert not r.done
    r.out.append(42)
    assert r.done                       # EOS before max_new
    r2 = _req(max_new=2)
    r2.out += [1, 2]
    assert r2.done                      # max_new without EOS


# ---------------------------------------------------------------------------
# Engine end-to-end (smoke model on the host mesh)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def glm_smoke(tiny_mesh_module):
    from helpers import StaticServerOracle
    cfg = get_config("glm4_9b", smoke=True)
    server = StaticServerOracle(cfg, tiny_mesh_module, max_batch=4,
                                prompt_len=32, max_len=96)
    return cfg, tiny_mesh_module, server


@pytest.fixture(scope="module")
def tiny_mesh_module():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def test_engine_matches_static_server_greedy(glm_smoke):
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(4)]
    legacy = server.serve_batch(prompts, [8] * 4)
    eng = InferenceEngine(cfg, mesh, max_batch=2, block_size=16, max_len=96,
                          params=server.params, debug_invariants=True)
    reqs = [Request(p, max_new=8) for p in prompts]
    outs = eng.run(reqs, arrival_steps=[0, 0, 2, 5])
    for i, r in enumerate(reqs):
        # max_batch=2 < 4 requests + staggered arrivals: identical greedy
        # tokens regardless of batch composition over time
        np.testing.assert_array_equal(outs[r.rid], legacy[i])


def test_engine_chunked_prefill_matches_monolithic(glm_smoke):
    """A chunk budget smaller than the prompt streams the prefill over
    several steps — greedy outputs must not change."""
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(2)]
    legacy = server.serve_batch(prompts, [6] * 2)
    eng = InferenceEngine(cfg, mesh, max_batch=2, block_size=16, max_len=96,
                          max_num_batched_tokens=2 + 12,   # 12-token chunks
                          params=server.params, debug_invariants=True)
    reqs = [Request(p, max_new=6) for p in prompts]
    outs = eng.run(reqs)
    assert eng.stats["prefill_chunks"] >= 6     # ceil(32/12) = 3 per prompt
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(outs[r.rid], legacy[i])


def test_engine_no_decode_stall_during_long_prefill(glm_smoke):
    """While a long prompt streams in chunks, running decodes must make
    progress every step (the two-phase engine's full-batch prefill stall
    is gone)."""
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    short = Request(RNG.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new=24)
    long_r = Request(RNG.integers(0, cfg.vocab_size, 64).astype(np.int32),
                     max_new=4)
    eng = InferenceEngine(cfg, mesh, max_batch=2, block_size=16, max_len=96,
                          max_num_batched_tokens=2 + 8,    # 8-token chunks
                          params=server.params, debug_invariants=True)
    eng.sched.add(short)
    eng.step()                       # short's whole prompt is one chunk
    eng.step()                       # short decodes alone once
    eng.sched.add(long_r)
    decoded_during_prefill = 0
    while long_r.num_computed < long_r.context_len and not long_r.out:
        before = len(short.out)
        assert eng.step()
        assert len(short.out) == before + 1    # a decode token EVERY step
        decoded_during_prefill += 1
    assert decoded_during_prefill >= 8         # 64 tokens / 8-token chunks
    while eng.sched.has_work:
        eng.step()
    assert len(short.out) == 24 and len(long_r.out) == 4


def test_engine_eos_early_stop_frees_slot(glm_smoke):
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(2)]
    # probe: find a token request 0 greedily emits for the first time at
    # some early step — using it as EOS must stop generation right there
    eng = InferenceEngine(cfg, mesh, max_batch=1, block_size=16, max_len=96,
                          params=server.params, debug_invariants=True)
    probe = Request(prompts[0], max_new=6)
    pout = eng.run([probe])[probe.rid].tolist()
    idx = next((i for i in range(1, 6) if pout[i] not in pout[:i]), None)
    if idx is None:
        pytest.skip("probe emitted no first-occurrence token")
    eos = pout[idx]

    eng = InferenceEngine(cfg, mesh, max_batch=1, block_size=16, max_len=96,
                          params=server.params, debug_invariants=True)
    r0 = Request(prompts[0], max_new=32, eos_id=eos)
    r1 = Request(prompts[1], max_new=4)
    outs = eng.run([r0, r1])
    assert outs[r0.rid][-1] == eos and len(outs[r0.rid]) == idx + 1
    assert len(outs[r1.rid]) == 4
    # retired-at-EOS request stopped consuming steps: with one slot, each
    # request costs 1 prefill-chunk step plus one decode step per further
    # token — nowhere near r0's max_new=32
    assert eng.stats["steps"] == (1 + idx) + (1 + 3)
    assert eng.bm.stats().blocks_in_use == 0       # everything freed


def test_engine_preemption_preserves_greedy_output(glm_smoke):
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(2)]
    base = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                           max_len=96, params=server.params,
                           debug_invariants=True)
    want = base.run([Request(p, max_new=20) for p in prompts])
    want = list(want.values())

    # 7 allocatable blocks of 16: two ctx-33 requests take 3 blocks each;
    # growth past 48 tokens (ctx 32+16) forces preempting the newer one.
    tight = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                            max_len=96, num_blocks=8, params=server.params,
                            debug_invariants=True)
    reqs = [Request(p, max_new=20) for p in prompts]
    got = tight.run(reqs)
    assert tight.stats["preemptions"] >= 1
    # the victim's recompute hits its own just-freed cached blocks
    assert tight.stats["cache_hit_tokens"] > 0
    for w, r in zip(want, reqs):
        np.testing.assert_array_equal(got[r.rid], w)


def test_engine_swap_preemption_preserves_greedy_output(glm_smoke):
    """Swap-preemption is byte-identical to the unconstrained engine (and
    hence to recompute-preemption): swapped pages come back exact copies,
    and the host round-trip shows up in the swap counters."""
    from repro.serving import InferenceEngine, Request
    from repro.serving.kv_cache import block_bytes
    cfg, mesh, server = glm_smoke
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(2)]
    base = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                           max_len=96, params=server.params,
                           debug_invariants=True)
    want = list(base.run([Request(p, max_new=20) for p in prompts])
                .values())
    bb = block_bytes(cfg, 16)
    tight = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                            max_len=96, num_blocks=8, params=server.params,
                            swap_space_bytes=8 * bb, swap_policy="always",
                            debug_invariants=True)
    reqs = [Request(p, max_new=20) for p in prompts]
    got = tight.run(reqs)
    assert tight.stats["swap_preemptions"] >= 1
    assert tight.stats["swap_ins"] >= 1
    assert tight.stats["swapped_out_blocks"] > 0
    assert tight.stats["swapped_out_bytes"] \
        == tight.stats["swapped_out_blocks"] * bb
    for w, r in zip(want, reqs):
        np.testing.assert_array_equal(got[r.rid], w)
    assert tight.bm.stats().blocks_in_use == 0
    tight.bm.check()


def test_engine_abort_mid_run_releases_resources(glm_smoke):
    """Aborting a running and a waiting request mid-serve frees their
    slots/blocks, counts in stats, and leaves the survivors untouched."""
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(3)]
    eng = InferenceEngine(cfg, mesh, max_batch=2, block_size=16, max_len=96,
                          params=server.params, debug_invariants=True)
    reqs = [Request(p, max_new=24) for p in prompts]
    for r in reqs:
        eng.sched.add(r)
    for _ in range(6):
        eng.step()
    assert eng.abort(reqs[0].rid)          # running
    assert eng.abort(reqs[2].rid)          # still waiting (max_batch=2)
    assert not eng.abort(reqs[0].rid)      # already gone
    while eng.sched.has_work:
        eng.step()
    assert eng.stats["aborts"] == 2
    assert len(reqs[1].out) == 24          # survivor ran to completion
    assert 0 < len(reqs[0].out) < 24       # victim stopped where aborted
    assert len(reqs[2].out) <= 1           # never got a slot
    assert eng.bm.stats().blocks_in_use == 0
    eng.bm.check()


def test_engine_int8_cross_path_identity(glm_smoke):
    """One kv_dtype, every path: the int8 engine's greedy outputs are
    byte-identical across an unconstrained run, a prefix-cache re-run,
    recompute preemption and swap preemption — quantization is a pure
    elementwise function of the bf16 writes, so the repo's cross-path
    byte-identity story survives storage narrowing."""
    from repro.serving import InferenceEngine, Request
    from repro.serving.kv_cache import block_bytes
    cfg, mesh, server = glm_smoke
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(2)]
    kw = dict(max_batch=2, block_size=16, max_len=96, params=server.params,
              kv_dtype="int8", debug_invariants=True)
    base = InferenceEngine(cfg, mesh, **kw)
    assert base.stats["kv_dtype"] == "int8"
    want = list(base.run([Request(p, max_new=20) for p in prompts])
                .values())
    rerun = list(base.run([Request(p, max_new=20) for p in prompts])
                 .values())                # second pass: prefix-cache hits
    assert base.stats["cache_hit_tokens"] > 0
    for w, g in zip(want, rerun):
        np.testing.assert_array_equal(w, g)
    bb = block_bytes(cfg, 16, kv_dtype="int8")
    for swap_bytes in (0, 8 * bb):
        tight = InferenceEngine(cfg, mesh, num_blocks=8,
                                swap_space_bytes=swap_bytes,
                                swap_policy="always" if swap_bytes
                                else "auto", **kw)
        reqs = [Request(p, max_new=20) for p in prompts]
        got = tight.run(reqs)
        n_pre = (tight.stats["swap_preemptions"] if swap_bytes
                 else tight.stats["preemptions"])
        assert n_pre >= 1
        for w, r in zip(want, reqs):
            np.testing.assert_array_equal(got[r.rid], w)


def test_engine_quantized_tolerance_vs_bf16(glm_smoke):
    """Quantized engines are tolerance-equivalent to bf16 on greedy
    tokens: the prompt-prefill (first) token matches on nearly every
    request, and int8 (8-bit mantissa budget) tracks the full trajectory
    far more closely than the tiny-signal random-weight setup lets fp8
    (3-bit mantissa) — calibrated against the fixed fixture params."""
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(4)]
    kw = dict(max_batch=2, block_size=16, max_len=96, params=server.params,
              debug_invariants=True)
    outs = {}
    for dtype in ("bf16", "int8", "fp8"):
        eng = InferenceEngine(cfg, mesh, kv_dtype=dtype, **kw)
        reqs = [Request(p, max_new=12) for p in prompts]
        got = eng.run(reqs)
        outs[dtype] = [got[r.rid] for r in reqs]
    for dtype, min_first, min_total in (("int8", 3, 0.75), ("fp8", 2, 0.4)):
        first = sum(a[0] == b[0]
                    for a, b in zip(outs[dtype], outs["bf16"]))
        total = sum(int(np.sum(a == b))
                    for a, b in zip(outs[dtype], outs["bf16"]))
        assert first >= min_first, (dtype, first)
        assert total >= min_total * 4 * 12, (dtype, total)


def test_engine_int8_cache_layout_and_footprint(glm_smoke):
    """The int8 engine's paged pools really are int8 with fp32 (..., 1)
    scale leaves riding the same block axis, and the device footprint
    shrinks accordingly."""
    import jax
    from repro.serving import InferenceEngine
    cfg, mesh, server = glm_smoke
    kw = dict(max_batch=2, block_size=16, max_len=96, params=server.params,
              num_blocks=8)
    bf = InferenceEngine(cfg, mesh, **kw)
    i8 = InferenceEngine(cfg, mesh, kv_dtype="int8", **kw)
    dtypes = {str(p.dtype) for p in jax.tree.leaves(i8.cache)
              if p.ndim >= 2 and p.shape[1] == 8}
    assert "int8" in dtypes and "float32" in dtypes
    scales = [p for p in jax.tree.leaves(i8.cache)
              if p.ndim == 5 and p.shape[1] == 8 and p.shape[-1] == 1]
    assert scales and all(p.dtype == np.float32 for p in scales)
    assert i8.stats["kv_cache_mib"] < bf.stats["kv_cache_mib"]


def test_engine_shared_prefix_shares_blocks(glm_smoke):
    """N requests with a long common prefix: byte-identical outputs to the
    no-sharing engine, with measurably fewer blocks in use."""
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    common = RNG.integers(0, cfg.vocab_size, 64).astype(np.int32)
    prompts = [np.concatenate(
        [common, RNG.integers(0, cfg.vocab_size, 8).astype(np.int32)])
        for _ in range(6)]
    kw = dict(max_batch=4, block_size=16, max_len=96, params=server.params,
              debug_invariants=True)
    shared = InferenceEngine(cfg, mesh, **kw)
    o_s = shared.run([Request(p, max_new=6) for p in prompts])
    plain = InferenceEngine(cfg, mesh, enable_prefix_caching=False, **kw)
    o_p = plain.run([Request(p, max_new=6) for p in prompts])
    for a, b in zip(o_s.values(), o_p.values()):
        np.testing.assert_array_equal(a, b)
    # 4 shared 16-token blocks per request after the first
    assert shared.stats["cache_hit_tokens"] >= 5 * 64
    assert shared.stats["peak_blocks_in_use"] \
        < plain.stats["peak_blocks_in_use"]
    assert shared.stats["peak_block_utilization"] \
        < plain.stats["peak_block_utilization"]


def test_engine_full_prompt_cache_hit_cow(glm_smoke):
    """Identical block-aligned prompts: the whole prompt hits the cache,
    the recomputed last token's write lands in a shared block, and the
    copy-on-write keeps outputs byte-identical."""
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    prompt = RNG.integers(0, cfg.vocab_size, 64).astype(np.int32)
    kw = dict(max_batch=4, block_size=16, max_len=96, params=server.params,
              debug_invariants=True)
    shared = InferenceEngine(cfg, mesh, **kw)
    reqs = [Request(prompt.copy(), max_new=6) for _ in range(3)]
    o_s = shared.run(reqs, arrival_steps=[0, 3, 6])
    assert shared.stats["cow_copies"] >= 1
    assert shared.stats["cache_hit_tokens"] >= 2 * 63
    plain = InferenceEngine(cfg, mesh, enable_prefix_caching=False, **kw)
    reqs_p = [Request(prompt.copy(), max_new=6) for _ in range(3)]
    o_p = plain.run(reqs_p, arrival_steps=[0, 3, 6])
    for a, b in zip(o_s.values(), o_p.values()):
        np.testing.assert_array_equal(a, b)


def test_engine_latency_stats(glm_smoke):
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(3)]
    eng = InferenceEngine(cfg, mesh, max_batch=2, block_size=16, max_len=96,
                          params=server.params, debug_invariants=True)
    reqs = [Request(p, max_new=4) for p in prompts]
    eng.run(reqs, arrival_steps=[0, 0, 3])
    lat = eng.stats["latency"]
    assert set(lat) == {r.rid for r in reqs}
    for r in reqs:
        rec = lat[r.rid]
        assert rec["arrival_step"] <= rec["first_token_step"] \
            <= rec["done_step"]
        assert rec["arrival_wall"] <= rec["first_token_wall"] \
            <= rec["done_wall"]
        # 4 tokens = first + 3 decodes, plus any preemption stalls
        assert rec["done_step"] - rec["first_token_step"] >= 3


def test_engine_latency_retention_bounded(glm_smoke):
    """Per-request latency records are evicted past the cap, but the
    retirement-time histograms keep every observation — the serve loop's
    memory stays O(cap + buckets) over millions of requests."""
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    eng = InferenceEngine(cfg, mesh, max_batch=2, block_size=16, max_len=96,
                          params=server.params, latency_record_cap=4,
                          debug_invariants=True)
    reqs = [Request(RNG.integers(0, cfg.vocab_size, 16).astype(np.int32),
                    max_new=2) for _ in range(8)]
    eng.run(reqs)
    assert eng.stats["requests_done"] == 8
    assert len(eng.stats["latency"]) <= 4          # bounded retention
    for key in ("ttft_steps", "e2e_steps", "ttft_seconds", "e2e_seconds"):
        assert eng.hist[key].count == 8            # nothing lost
    # e2e dominates ttft observation-by-observation, so also in the mean
    assert eng.hist["e2e_steps"].mean >= eng.hist["ttft_steps"].mean


def test_engine_rate_accessors(glm_smoke):
    """cache_hit_rate / preemption_rate / mean_accept_len are div-zero
    guarded on a fresh engine and land in range after traffic — the one
    code path /metrics, the bench, and serve.py all report."""
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    eng = InferenceEngine(cfg, mesh, max_batch=2, block_size=16, max_len=96,
                          params=server.params, debug_invariants=True)
    assert eng.cache_hit_rate == 0.0
    assert eng.preemption_rate == 0.0
    assert eng.mean_accept_len == 0.0
    prompt = RNG.integers(0, cfg.vocab_size, 64).astype(np.int32)
    eng.run([Request(prompt.copy(), max_new=2) for _ in range(2)],
            arrival_steps=[0, 3])                  # duplicate: prefix hit
    assert 0.0 < eng.cache_hit_rate < 1.0
    assert 0.0 <= eng.preemption_rate <= 1.0
    assert eng.mean_accept_len == 0.0              # no speculation here


def test_runner_dispatch_and_vision_rejection(glm_smoke):
    from repro.config import ParallelConfig
    from repro.serving import (EncDecRunner, HybridRunner, InferenceEngine,
                               SSMRunner, TransformerRunner, make_runner)
    _, mesh, _ = glm_smoke
    pcfg = ParallelConfig(remat="none")
    pairs = [("glm4_9b", TransformerRunner), ("mamba2_370m", SSMRunner),
             ("zamba2_2p7b", HybridRunner),
             ("whisper_large_v3", EncDecRunner)]
    for arch, klass in pairs:
        assert type(make_runner(get_config(arch, smoke=True), pcfg)) is klass
    with pytest.raises(ValueError, match="frontend"):
        InferenceEngine(get_config("qwen2_vl_2b", smoke=True), mesh)


# ---------------------------------------------------------------------------
# SlotStateCache / EncoderCache
# ---------------------------------------------------------------------------


def test_slot_state_cache_basic():
    from repro.serving import SlotStateCache
    sc = SlotStateCache(2)
    assert sc.allocate(10) == 0 and sc.allocate(11, 1) == 1
    sc.check()
    assert sc.num_free == 0 and sc.owner(0) == 10 and sc.slot(11) == 1
    with pytest.raises(KeyError):
        sc.allocate(10)                     # double alloc
    with pytest.raises(MemoryError):
        sc.allocate(12)                     # no free slot
    assert sc.free(10) == 0
    sc.check()
    with pytest.raises(MemoryError):
        sc.allocate(12, 1)                  # requested slot taken
    assert sc.allocate(12) == 0
    sc.check()
    assert sc.stats().utilization == 1.0


def _slot_cache_random_walk(tape):
    """Interpret ``tape`` (an iterator of ints) as allocate/allocate-at/
    free/preempt-readmit ops against a SlotStateCache, asserting the
    bijection invariant and exact free-slot accounting after every op —
    mirroring the BlockManager walks."""
    from repro.serving import SlotStateCache
    NS = 4
    sc = SlotStateCache(NS)
    bound: dict[int, int] = {}            # rid -> slot (our shadow model)
    next_rid = [0]

    def draw(n):
        return next(tape) % n

    def new_rid():
        next_rid[0] += 1
        return next_rid[0]

    def check():
        sc.check()
        assert sc.num_free == NS - len(bound)
        assert sorted(sc.free_slots()) == sorted(
            set(range(NS)) - set(bound.values()))
        for rid, slot in bound.items():
            assert sc.slot(rid) == slot and sc.owner(slot) == rid

    for _ in range(150):
        op = draw(4)
        rids = list(bound)
        if op == 0 or not rids:                     # allocate lowest-free
            rid = new_rid()
            try:
                bound[rid] = sc.allocate(rid)
            except MemoryError:
                assert len(bound) == NS
        elif op == 1:                               # allocate a chosen slot
            rid, slot = new_rid(), draw(NS)
            try:
                assert sc.allocate(rid, slot) == slot
                bound[rid] = slot
            except MemoryError:
                assert slot in bound.values()
        elif op == 2:                               # retire
            rid = rids[draw(len(rids))]
            assert sc.free(rid) == bound.pop(rid)
        else:                                       # preempt + readmit
            rid = rids[draw(len(rids))]
            sc.free(rid)
            del bound[rid]
            check()
            rid2 = new_rid()                 # recompute joins as a fresh
            bound[rid2] = sc.allocate(rid2)  # binding, any free slot
        check()
    for rid in list(bound):
        sc.free(rid)
        del bound[rid]
        check()
    assert sc.num_free == NS


def test_slot_cache_random_walk_seeded():
    for seed in range(8):
        rng = random.Random(seed)
        _slot_cache_random_walk(iter(lambda: rng.randrange(1 << 20), None))


def test_slot_cache_random_walk_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.lists(st.integers(0, (1 << 20) - 1), max_size=900))
    @hyp.settings(max_examples=60, deadline=None)
    def prop(tape):
        it = iter(tape)
        _slot_cache_random_walk(iter(lambda: next(it, 0), None))

    prop()


# ---------------------------------------------------------------------------
# SSM / hybrid / enc-dec runners vs the static oracle
# ---------------------------------------------------------------------------


def _oracle(arch, mesh, prompt_len, max_len=96, max_batch=4):
    from helpers import StaticServerOracle
    cfg = get_config(arch, smoke=True)
    return cfg, StaticServerOracle(cfg, mesh, max_batch=max_batch,
                                   prompt_len=prompt_len, max_len=max_len)


def test_engine_matches_static_mamba2(tiny_mesh_module):
    """Pure SSM through the engine: slot-state cache, no block manager,
    greedy outputs byte-identical to the static oracle — including a
    chunked prefill whose boundaries land on SSD chunk multiples."""
    from repro.serving import InferenceEngine, Request
    mesh = tiny_mesh_module
    cfg, server = _oracle("mamba2_370m", mesh, prompt_len=24)
    prompts = [RNG.integers(0, cfg.vocab_size, 24).astype(np.int32)
               for _ in range(4)]
    legacy = server.serve_batch(prompts, [8] * 4)
    # chunk budget 16 < prompt 24: two chunks (16 then 8); the smoke SSD
    # chunk_size is 8, so the 16-token boundary is quantum-aligned
    eng = InferenceEngine(cfg, mesh, max_batch=2, block_size=16, max_len=96,
                          max_num_batched_tokens=2 + 16,
                          params=server.params, debug_invariants=True)
    assert eng.bm is None and eng.slot_cache is not None
    assert eng.sched.chunk_quantum == cfg.ssm.chunk_size == 8
    reqs = [Request(p, max_new=8) for p in prompts]
    outs = eng.run(reqs, arrival_steps=[0, 0, 3, 5])
    assert eng.stats["prefill_chunks"] >= 8        # 2 chunks per prompt
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(outs[r.rid], legacy[i])


def test_engine_ssm_quantized_chunk_lengths(tiny_mesh_module):
    """Non-final SSM chunks are quantized to the SSD chunk size even when
    the leftover step budget is not a multiple."""
    from repro.serving import InferenceEngine, Request
    mesh = tiny_mesh_module
    cfg, server = _oracle("mamba2_370m", mesh, prompt_len=24)
    prompts = [RNG.integers(0, cfg.vocab_size, 24).astype(np.int32)
               for _ in range(2)]
    legacy = server.serve_batch(prompts, [6] * 2)
    # budget leaves 13 tokens of chunk: quantized down to 8 until the
    # final chunk (24 = 8 + 8 + final 8; with a decode running, 13 -> 8)
    eng = InferenceEngine(cfg, mesh, max_batch=2, block_size=16, max_len=96,
                          max_num_batched_tokens=2 + 13,
                          params=server.params, debug_invariants=True)
    reqs = [Request(p, max_new=6) for p in prompts]
    outs = eng.run(reqs)
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(outs[r.rid], legacy[i])


def test_engine_matches_static_zamba2(tiny_mesh_module):
    """Hybrid runner: mamba slot state + paged shared-attention KV behind
    one block table; byte-identical to the static oracle under staggered
    arrivals and chunked prefill."""
    from repro.serving import InferenceEngine, Request
    mesh = tiny_mesh_module
    cfg, server = _oracle("zamba2_2p7b", mesh, prompt_len=24)
    prompts = [RNG.integers(0, cfg.vocab_size, 24).astype(np.int32)
               for _ in range(4)]
    legacy = server.serve_batch(prompts, [8] * 4)
    eng = InferenceEngine(cfg, mesh, max_batch=2, block_size=16, max_len=96,
                          max_num_batched_tokens=2 + 16,
                          params=server.params, debug_invariants=True)
    assert eng.bm is not None and eng.slot_cache is not None
    assert not eng.sched.enable_prefix_caching   # state is not shareable
    reqs = [Request(p, max_new=8) for p in prompts]
    outs = eng.run(reqs, arrival_steps=[0, 0, 2, 5])
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(outs[r.rid], legacy[i])


def test_engine_zamba2_preemption_resets_slot_state(tiny_mesh_module):
    """A hybrid victim of block-pool preemption recomputes from zeroed
    slot state: greedy outputs stay preemption-invariant."""
    from repro.serving import InferenceEngine, Request
    mesh = tiny_mesh_module
    cfg, server = _oracle("zamba2_2p7b", mesh, prompt_len=32)
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(2)]
    base = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                           max_len=96, params=server.params,
                           debug_invariants=True)
    want = list(base.run([Request(p, max_new=20) for p in prompts]).values())
    # 7 allocatable blocks of 16: two ctx-33 requests take 3 blocks each;
    # growth past 48 tokens forces preempting the newer one.
    tight = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                            max_len=96, num_blocks=8, params=server.params,
                            debug_invariants=True)
    reqs = [Request(p, max_new=20) for p in prompts]
    got = tight.run(reqs)
    assert tight.stats["preemptions"] >= 1
    for w, r in zip(want, reqs):
        np.testing.assert_array_equal(got[r.rid], w)


def test_engine_matches_static_whisper(tiny_mesh_module):
    """Enc-dec runner: paged decoder self-KV + per-slot read-only cross
    K/V written by the admission encode pass; byte-identical to the
    static oracle, with per-request (distinct) encoder inputs."""
    from repro.serving import InferenceEngine, Request
    mesh = tiny_mesh_module
    cfg, server = _oracle("whisper_large_v3", mesh, prompt_len=8,
                          max_len=64)
    prompts = [RNG.integers(0, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(3)]
    frames = [RNG.normal(0, 1, (cfg.encoder_seq_len, cfg.d_model)
                         ).astype(np.float32) for _ in range(3)]
    # oracle decodes one batch per request so each keeps its own frames
    legacy = [server.serve_batch([p], [6], frames=[f])[0]
              for p, f in zip(prompts, frames)]
    eng = InferenceEngine(cfg, mesh, max_batch=2, block_size=16, max_len=64,
                          params=server.params, debug_invariants=True)
    assert eng.encoder_cache is not None
    reqs = [Request(p, max_new=6, frames=f)
            for p, f in zip(prompts, frames)]
    outs = eng.run(reqs, arrival_steps=[0, 1, 4])
    assert eng.stats["encodes"] == 3
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(outs[r.rid], legacy[i])


def test_engine_whisper_preemption_reencodes(tiny_mesh_module):
    """An enc-dec victim of block-pool preemption re-runs its encode pass
    on readmission — cross K/V at the (possibly different) slot is its
    own, and greedy outputs stay preemption-invariant."""
    from repro.serving import InferenceEngine, Request
    mesh = tiny_mesh_module
    cfg, server = _oracle("whisper_large_v3", mesh, prompt_len=32,
                          max_len=96)
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(2)]
    frames = [RNG.normal(0, 1, (cfg.encoder_seq_len, cfg.d_model)
                         ).astype(np.float32) for _ in range(2)]

    def make():
        return [Request(p, max_new=20, frames=f)
                for p, f in zip(prompts, frames)]

    base = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                           max_len=96, params=server.params,
                           debug_invariants=True)
    want = list(base.run(make()).values())
    # 7 allocatable blocks of 16: growth past 48 tokens preempts the newer
    tight = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                            max_len=96, num_blocks=8, params=server.params,
                            debug_invariants=True)
    reqs = make()
    got = tight.run(reqs)
    assert tight.stats["preemptions"] >= 1
    # one encode per admission: initial 2 + one per readmission
    assert tight.stats["encodes"] >= 2 + tight.stats["preemptions"]
    for w, r in zip(want, reqs):
        np.testing.assert_array_equal(got[r.rid], w)


def test_engine_ssm_no_horizon_validation(tiny_mesh_module):
    """Slot caches have no block horizon: an SSM request whose
    prompt+max_new exceeds max_len capacity is accepted (the state is
    constant-size), while the paged transformer still rejects it."""
    from repro.serving import InferenceEngine, Request
    mesh = tiny_mesh_module
    cfg, server = _oracle("mamba2_370m", mesh, prompt_len=24, max_len=32)
    eng = InferenceEngine(cfg, mesh, max_batch=2, block_size=16, max_len=32,
                          max_num_batched_tokens=2 + 16,
                          params=server.params, debug_invariants=True)
    long_req = Request(RNG.integers(0, cfg.vocab_size, 24).astype(np.int32),
                       max_new=24)                 # 48 > 32-token "cap"
    outs = eng.run([long_req])
    assert len(outs[long_req.rid]) == 24


# ---------------------------------------------------------------------------
# Sampling determinism (rid + step folded into the key)
# ---------------------------------------------------------------------------


def test_sampling_reproducible_across_preemption(glm_smoke):
    """Temperature sampling is a pure function of (seed, rid, step):
    outputs are identical with and without recompute-preemption."""
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(2)]
    sp = SamplingParams(temperature=0.8, top_k=16, seed=3)

    def make():
        # pin rids: the sampling key folds (seed, rid, step), so replaying
        # the same logical requests must reuse their ids
        return [Request(p, max_new=20, sampling=sp, rid=77000 + i)
                for i, p in enumerate(prompts)]

    base = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                           max_len=96, params=server.params,
                           debug_invariants=True)
    want = list(base.run(make()).values())
    tight = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                            max_len=96, num_blocks=8, params=server.params,
                            debug_invariants=True)
    reqs = make()
    got = tight.run(reqs)
    assert tight.stats["preemptions"] >= 1
    for w, r in zip(want, reqs):
        np.testing.assert_array_equal(got[r.rid], w)


# ---------------------------------------------------------------------------
# Speculative decoding (draft-and-verify)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def star_params(tiny_mesh_module):
    """Shared target params for the speculative tests (starcoder2-class
    dense GQA config, per the acceptance bar for byte-equivalence)."""
    import jax.numpy as jnp
    from repro.models import api
    cfg = get_config("starcoder2_3b", smoke=True)
    with jax.set_mesh(tiny_mesh_module):
        params_f32, _ = api.init_model(cfg, jax.random.key(0))
        params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params_f32)
    return cfg, params


def _spec_engine(cfg, mesh, params, k, *, self_draft=False, **kw):
    from repro.serving import InferenceEngine
    return InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                           max_len=96, params=params,
                           num_speculative_tokens=k,
                           draft_params=params if self_draft else None,
                           debug_invariants=True, **kw)


@pytest.mark.parametrize("self_draft", [True, False])
def test_engine_speculative_greedy_matches_plain(tiny_mesh_module,
                                                 star_params, self_draft):
    """Greedy speculative decode is byte-identical to plain decode, both
    with a self-draft (full acceptance: every verify row agrees) and with
    an independently initialized draft (near-zero acceptance: every token
    is the target's correction) — acceptance only moves *throughput*."""
    from repro.serving import InferenceEngine, Request, SpeculativeRunner
    cfg, params = star_params
    mesh = tiny_mesh_module
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(4)]
    plain = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                            max_len=96, params=params,
                            debug_invariants=True)
    want = plain.run([Request(p, max_new=8) for p in prompts])
    want = list(want.values())
    spec = _spec_engine(cfg, mesh, params, 2, self_draft=self_draft)
    assert isinstance(spec.runner, SpeculativeRunner)
    reqs = [Request(p, max_new=8) for p in prompts]
    got = spec.run(reqs, arrival_steps=[0, 0, 2, 5])
    for w, r in zip(want, reqs):
        np.testing.assert_array_equal(got[r.rid], w)
    assert spec.stats["spec_decodes"] >= 1
    if self_draft:
        # identical draft == target logits: every draft token is accepted
        assert spec.stats["mean_accept_len"] > 1.0


def test_engine_int8_speculative_matches_plain_int8(tiny_mesh_module,
                                                    star_params):
    """Speculative decode (k=2, self-draft) over int8 pools is
    byte-identical to the plain int8 engine: draft and target quantize
    the same bf16 writes, so verify rows see the same dequantized KV."""
    from repro.serving import InferenceEngine, Request
    cfg, params = star_params
    mesh = tiny_mesh_module
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(4)]
    plain = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                            max_len=96, params=params, kv_dtype="int8",
                            debug_invariants=True)
    want = list(plain.run([Request(p, max_new=8) for p in prompts])
                .values())
    spec = _spec_engine(cfg, mesh, params, 2, self_draft=True,
                        kv_dtype="int8")
    reqs = [Request(p, max_new=8) for p in prompts]
    got = spec.run(reqs, arrival_steps=[0, 0, 2, 5])
    for w, r in zip(want, reqs):
        np.testing.assert_array_equal(got[r.rid], w)
    assert spec.stats["mean_accept_len"] > 1.0   # self-draft still accepts


def test_engine_speculative_prefix_cache_hit_cow(tiny_mesh_module,
                                                 star_params):
    """Full-prompt prefix-cache hits (boundary COW included) under
    speculation: cached blocks carry draft *and* target KV — outputs stay
    byte-identical to the non-speculative engine on the same workload."""
    from repro.serving import InferenceEngine, Request
    cfg, params = star_params
    mesh = tiny_mesh_module
    prompt = RNG.integers(0, cfg.vocab_size, 64).astype(np.int32)
    kw = dict(max_batch=4, block_size=16, max_len=96, params=params,
              debug_invariants=True)
    plain = InferenceEngine(cfg, mesh, **kw)
    reqs_p = [Request(prompt.copy(), max_new=6) for _ in range(3)]
    o_p = plain.run(reqs_p, arrival_steps=[0, 3, 6])
    spec = InferenceEngine(cfg, mesh, num_speculative_tokens=2,
                           draft_params=params, **kw)
    reqs_s = [Request(prompt.copy(), max_new=6) for _ in range(3)]
    o_s = spec.run(reqs_s, arrival_steps=[0, 3, 6])
    assert spec.stats["cow_copies"] >= 1
    assert spec.stats["cache_hit_tokens"] >= 2 * 63
    assert spec.stats["mean_accept_len"] > 1.0
    for a, b in zip(reqs_p, reqs_s):
        np.testing.assert_array_equal(o_p[a.rid], o_s[b.rid])


def test_engine_speculative_preemption_greedy(tiny_mesh_module, star_params):
    """Recompute-preemption under speculation (lookahead block pressure
    included): greedy outputs byte-identical to the unconstrained plain
    engine, and rejected lookahead blocks are rolled back (truncate) so
    the tight pool never leaks."""
    from repro.serving import InferenceEngine, Request
    cfg, params = star_params
    mesh = tiny_mesh_module
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(2)]
    plain = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                            max_len=96, params=params,
                            debug_invariants=True)
    want = list(plain.run([Request(p, max_new=20) for p in prompts])
                .values())
    tight = _spec_engine(cfg, mesh, params, 2, num_blocks=8)
    reqs = [Request(p, max_new=20) for p in prompts]
    got = tight.run(reqs)
    assert tight.stats["preemptions"] >= 1
    for w, r in zip(want, reqs):
        np.testing.assert_array_equal(got[r.rid], w)
    assert tight.bm.stats().blocks_in_use == 0


def test_engine_speculative_temperature_replays_across_preemption(
        tiny_mesh_module, star_params):
    """Temperature speculative sampling is a pure function of
    (seed, rid, counter): the draft/accept/residual streams key off the
    same rid-folded base keys as plain sampling, and preemption-recompute
    stops one token short so verify windows stay aligned — outputs replay
    identically under block-pool pressure."""
    cfg, params = star_params
    from repro.serving import Request
    mesh = tiny_mesh_module
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(2)]
    sp = SamplingParams(temperature=0.9, top_k=16, seed=3)

    def make():
        return [Request(p, max_new=20, sampling=sp, rid=88000 + i)
                for i, p in enumerate(prompts)]

    base = _spec_engine(cfg, mesh, params, 2)
    want = list(base.run(make()).values())
    tight = _spec_engine(cfg, mesh, params, 2, num_blocks=8)
    reqs = make()
    got = tight.run(reqs)
    assert tight.stats["preemptions"] >= 1
    for w, r in zip(want, reqs):
        np.testing.assert_array_equal(got[r.rid], w)


def test_engine_speculative_k0_degenerates_to_plain(tiny_mesh_module,
                                                    star_params):
    """k = 0 is the non-speculative path byte for byte, *including* the
    temperature RNG stream (the bonus sample uses the plain stream key)."""
    from repro.serving import InferenceEngine, Request
    cfg, params = star_params
    mesh = tiny_mesh_module
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(2)]
    sp = SamplingParams(temperature=0.9, top_k=16, seed=7)

    def make():
        return [Request(p, max_new=10, sampling=sp, rid=99000 + i)
                for i, p in enumerate(prompts)]

    plain = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                            max_len=96, params=params,
                            debug_invariants=True)
    want = list(plain.run(make()).values())
    k0 = _spec_engine(cfg, mesh, params, 0, draft_cfg=cfg)
    got = k0.run(make())
    for w, (rid, g) in zip(want, sorted(got.items())):
        np.testing.assert_array_equal(g, w)


def test_speculative_verify_preserves_target_distribution():
    """Rejection sampling must leave the realized first-token marginal
    equal to the target distribution p even when the draft q is badly
    miscalibrated (chi-square-ish bound over many independent rids)."""
    from repro.serving.sampling import propose_tokens, speculative_verify
    V, N = 4, 4000
    p_logits = jnp.asarray([0.0, 1.0, -1.0, 0.5], jnp.float32)
    q_logits = jnp.asarray([2.0, -2.0, 0.0, 0.0], jnp.float32)
    temps = jnp.ones((N,), jnp.float32)
    top_ks = jnp.zeros((N,), jnp.int32)
    seeds = jnp.zeros((N,), jnp.int32)
    rids = jnp.arange(N, dtype=jnp.int32)
    cnts = jnp.zeros((N,), jnp.int32)
    q_rows = jnp.broadcast_to(q_logits, (N, V))
    d_toks = propose_tokens(q_rows, temps, top_ks, seeds, rids, cnts)
    out, n_acc = speculative_verify(
        d_toks[:, None], q_rows[:, None],
        jnp.broadcast_to(p_logits, (N, 2, V)),
        temps, top_ks, seeds, rids, cnts)
    first = np.asarray(out[:, 0])
    want = np.asarray(jax.nn.softmax(p_logits))
    got = np.bincount(first, minlength=V) / N
    np.testing.assert_allclose(got, want, atol=0.03)
    # and the proposals themselves follow q, not p
    got_q = np.bincount(np.asarray(d_toks), minlength=V) / N
    np.testing.assert_allclose(got_q, np.asarray(jax.nn.softmax(q_logits)),
                               atol=0.03)


def test_speculative_runner_rejects_bad_pairs():
    from repro.config import ParallelConfig
    from repro.serving import make_runner
    pcfg = ParallelConfig(remat="none")
    star = get_config("starcoder2_3b", smoke=True)
    with pytest.raises(ValueError, match="paged-transformer"):
        make_runner(get_config("mamba2_370m", smoke=True), pcfg,
                    draft_cfg=star, num_speculative_tokens=2)
    with pytest.raises(ValueError, match="paged-transformer"):
        make_runner(star, pcfg,
                    draft_cfg=get_config("mamba2_370m", smoke=True),
                    num_speculative_tokens=2)
    # full-size configs: smoke vocabs all coincide at 256
    with pytest.raises(ValueError, match="vocab"):
        make_runner(get_config("starcoder2_3b"), pcfg,
                    draft_cfg=get_config("glm4_9b"),
                    num_speculative_tokens=2)


def test_sampling_same_seed_requests_decorrelated(glm_smoke):
    """Folding the rid into the key keeps two same-seed, same-prompt
    requests on distinct sampling streams."""
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    prompt = RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
    sp = SamplingParams(temperature=1.2, top_k=0, seed=7)
    eng = InferenceEngine(cfg, mesh, max_batch=2, block_size=16, max_len=96,
                          params=server.params, debug_invariants=True)
    a = Request(prompt.copy(), max_new=12, sampling=sp)
    b = Request(prompt.copy(), max_new=12, sampling=sp)
    outs = eng.run([a, b])
    assert not np.array_equal(outs[a.rid], outs[b.rid])


# ---------------------------------------------------------------------------
# Full sampling pipeline in the engine (top-p/min-p/penalties/stop/logprobs)
# ---------------------------------------------------------------------------


FULL_SP = dict(temperature=0.9, top_k=16, top_p=0.85,
               repetition_penalty=1.3, frequency_penalty=0.2,
               stop=((3, 1, 4),))


def test_engine_full_pipeline_replays_across_preemption(glm_smoke):
    """Preemption-recompute with penalties and stop sequences active:
    the SamplingBuffer rebinds from (prompt, out) on re-admission, so
    penalty counts and stop rings land back exactly where the
    uninterrupted run had them — streams stay byte-identical."""
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(2)]
    sp = SamplingParams(seed=3, **FULL_SP)

    def make():
        return [Request(p, max_new=20, sampling=sp, rid=66000 + i)
                for i, p in enumerate(prompts)]

    base = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                           max_len=96, params=server.params,
                           debug_invariants=True)
    want = list(base.run(make()).values())
    assert base.stats["full_sampling_steps"] > 0
    tight = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                            max_len=96, num_blocks=8, params=server.params,
                            debug_invariants=True)
    reqs = make()
    got = tight.run(reqs)
    assert tight.stats["preemptions"] >= 1
    for w, r in zip(want, reqs):
        np.testing.assert_array_equal(got[r.rid], w)


def test_engine_full_pipeline_replays_across_swap_in(glm_smoke):
    """Swap-preemption + swap-in with the full pipeline active: the
    sampling row is freed at swap-out and rebuilt at swap-in, and the
    streams are byte-identical to the unconstrained engine."""
    from repro.serving import InferenceEngine, Request
    from repro.serving.kv_cache import block_bytes
    cfg, mesh, server = glm_smoke
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(2)]
    sp = SamplingParams(seed=5, **FULL_SP)

    def make():
        return [Request(p, max_new=20, sampling=sp, rid=67000 + i)
                for i, p in enumerate(prompts)]

    base = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                           max_len=96, params=server.params,
                           debug_invariants=True)
    want = list(base.run(make()).values())
    bb = block_bytes(cfg, 16)
    tight = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                            max_len=96, num_blocks=8, params=server.params,
                            swap_space_bytes=8 * bb, swap_policy="always",
                            debug_invariants=True)
    reqs = make()
    got = tight.run(reqs)
    assert tight.stats["swap_preemptions"] >= 1
    assert tight.stats["swap_ins"] >= 1
    for w, r in zip(want, reqs):
        np.testing.assert_array_equal(got[r.rid], w)
    assert tight.bm.stats().blocks_in_use == 0


def test_engine_speculative_full_pipeline_replays(tiny_mesh_module,
                                                  star_params):
    """Speculative k=2 with top-p + penalties: proposal-side counts
    accumulate draft one-hots, the verifier derives the identical
    per-position counts, and rollback never commits rejected tokens —
    outputs replay byte-identically under block-pool pressure."""
    from repro.serving import Request
    cfg, params = star_params
    mesh = tiny_mesh_module
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(2)]
    sp = SamplingParams(seed=11, **FULL_SP)

    def make():
        return [Request(p, max_new=20, sampling=sp, rid=68000 + i)
                for i, p in enumerate(prompts)]

    base = _spec_engine(cfg, mesh, params, 2)
    want = list(base.run(make()).values())
    assert base.stats["full_sampling_steps"] > 0
    tight = _spec_engine(cfg, mesh, params, 2, num_blocks=8)
    reqs = make()
    got = tight.run(reqs)
    assert tight.stats["preemptions"] >= 1
    for w, r in zip(want, reqs):
        np.testing.assert_array_equal(got[r.rid], w)
    assert tight.bm.stats().blocks_in_use == 0


def test_engine_pure_greedy_skips_full_pipeline(glm_smoke):
    """The fast-path guard: an all-greedy workload never compiles or
    runs the full sampling executables (no sampling collectives traced),
    and its bytes still match the static-server oracle."""
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    prompts = [RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
               for _ in range(4)]
    legacy = server.serve_batch(prompts, [8] * 4)
    eng = InferenceEngine(cfg, mesh, max_batch=2, block_size=16, max_len=96,
                          params=server.params, debug_invariants=True)
    reqs = [Request(p, max_new=8) for p in prompts]
    outs = eng.run(reqs)
    assert eng._full_steps == {}            # full path never even traced
    assert eng.stats["full_sampling_steps"] == 0
    assert eng.stats["stop_hits"] == 0
    for r, want in zip(reqs, legacy):
        np.testing.assert_array_equal(outs[r.rid], want)


def test_engine_mixed_batch_full_path_preserves_plain_rows(glm_smoke):
    """A greedy request batched with a top-p batchmate rides the full
    executables (the batchmate needs them) yet emits bytes identical to
    its all-greedy solo run: every full-path transform is an exact
    identity at default params."""
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    prompt = RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
    solo = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                           max_len=96, params=server.params,
                           debug_invariants=True)
    g = Request(prompt.copy(), max_new=12, rid=70001)
    want = solo.run([g])[g.rid]
    mixed = InferenceEngine(cfg, mesh, max_batch=2, block_size=16,
                            max_len=96, params=server.params,
                            debug_invariants=True)
    g2 = Request(prompt.copy(), max_new=12, rid=70001)
    other = Request(
        RNG.integers(0, cfg.vocab_size, 32).astype(np.int32), max_new=12,
        sampling=SamplingParams(temperature=1.0, top_p=0.8, seed=9),
        rid=70002)
    outs = mixed.run([g2, other])
    assert mixed.stats["full_sampling_steps"] > 0
    np.testing.assert_array_equal(outs[g2.rid], want)


def test_engine_stop_sequence_retires_in_engine(glm_smoke):
    """A matched stop sequence retires the request inside the engine —
    shorter output, stop_hit set, counters bumped, blocks and the batch
    slot released — without consuming the remaining max_new steps."""
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    prompt = RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
    probe_eng = InferenceEngine(cfg, mesh, max_batch=1, block_size=16,
                                max_len=96, params=server.params,
                                debug_invariants=True)
    probe = Request(prompt.copy(), max_new=8)
    pout = probe_eng.run([probe])[probe.rid].tolist()
    # two-token stop ending at index 3 of the deterministic greedy stream
    stop = (int(pout[2]), int(pout[3]))

    eng = InferenceEngine(cfg, mesh, max_batch=1, block_size=16, max_len=96,
                          params=server.params, debug_invariants=True)
    r = Request(prompt.copy(), max_new=32,
                sampling=SamplingParams(stop=(stop,)))
    outs = eng.run([r])
    assert len(outs[r.rid]) == 4 and r.stop_hit
    assert tuple(outs[r.rid][-2:]) == stop
    assert eng.stats["stop_hits"] == 1
    assert not eng.sched.running                    # slot released
    assert eng.bm.stats().blocks_in_use == 0        # blocks released
    # stop sequences alone stay on the plain executables (host-side check)
    assert eng.stats["full_sampling_steps"] == 0


def test_engine_min_new_defers_eos_and_stop(glm_smoke):
    """min_new holds off EOS and stop retirement until the floor is
    reached; max_new still wins."""
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    prompt = RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
    probe_eng = InferenceEngine(cfg, mesh, max_batch=1, block_size=16,
                                max_len=96, params=server.params,
                                debug_invariants=True)
    probe = Request(prompt.copy(), max_new=20)
    pout = probe_eng.run([probe])[probe.rid].tolist()
    tok = int(pout[1])
    min_new = 6
    # expected: first re-occurrence at index >= min_new-1, else max_new
    exp = next((i + 1 for i in range(min_new - 1, 20) if pout[i] == tok), 20)

    eng = InferenceEngine(cfg, mesh, max_batch=1, block_size=16, max_len=96,
                          params=server.params, debug_invariants=True)
    r_eos = Request(prompt.copy(), max_new=20, eos_id=tok, min_new=min_new)
    assert len(eng.run([r_eos])[r_eos.rid]) == exp
    eng2 = InferenceEngine(cfg, mesh, max_batch=1, block_size=16, max_len=96,
                           params=server.params, debug_invariants=True)
    r_stop = Request(prompt.copy(), max_new=20, min_new=min_new,
                     sampling=SamplingParams(stop=((tok,),)))
    assert len(eng2.run([r_stop])[r_stop.rid]) == exp
    assert r_stop.stop_hit == (exp < 20)


def test_engine_logprobs_surface(glm_smoke):
    """logprobs route through on_token for every emitted token (chunk-
    final prefill tokens included), with the chosen token's logprob and
    a sorted top-n of the post-penalty distribution."""
    from repro.serving import InferenceEngine, Request
    cfg, mesh, server = glm_smoke
    eng = InferenceEngine(cfg, mesh, max_batch=2, block_size=16, max_len=96,
                          params=server.params, debug_invariants=True)
    got = {}
    eng.on_token = (lambda req, tok, lp=None:
                    got.setdefault(req.rid, []).append((int(tok), lp)))
    reqs = [Request(RNG.integers(0, cfg.vocab_size, 32).astype(np.int32),
                    max_new=6,
                    sampling=SamplingParams(temperature=0.8, seed=i,
                                            top_p=0.9, logprobs=3))
            for i in range(2)]
    outs = eng.run(reqs)
    for r in reqs:
        events = got[r.rid]
        assert len(events) == 6
        assert [t for t, _ in events] == list(outs[r.rid])
        for _, lp in events:
            assert lp is not None and len(lp["top"]) == 3
            assert all(isinstance(i, int) for i, _ in lp["top"])
            lps = [v for _, v in lp["top"]]
            assert lps == sorted(lps, reverse=True)
            assert lp["token_logprob"] <= 0.0
