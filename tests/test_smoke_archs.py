"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of each family runs one forward/train step on CPU; output shapes + no NaNs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (OptimizerConfig, ParallelConfig, ShapeConfig,
                          get_config)
from repro.models import api
from repro.optim import optimizers as opt
from repro.spmd import steps as steps_mod

from conftest import ALL_ARCHS

SHAPE = ShapeConfig("smoke_train", seq_len=16, global_batch=2, kind="train")


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_loss_finite(arch, tiny_mesh):
    cfg = get_config(arch, smoke=True)
    pcfg = ParallelConfig(remat="full")
    with jax.set_mesh(tiny_mesh):
        params, specs = api.init_model(cfg, jax.random.key(0))
        batch = api.make_batch(cfg, SHAPE)
        loss, metr = jax.jit(
            lambda p, b: api.loss_fn(p, b, cfg, pcfg))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    assert bool(jnp.isfinite(metr["ce"]))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step(arch, tiny_mesh):
    cfg = get_config(arch, smoke=True)
    pcfg = ParallelConfig(remat="full", microbatches=2)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    with jax.set_mesh(tiny_mesh):
        params_f32, _ = api.init_model(cfg, jax.random.key(0))
        opt_state = opt.init_train_state(ocfg, params_f32)
        params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params_f32)
        step = jax.jit(steps_mod.make_train_step(cfg, pcfg, ocfg))
        batch = api.make_batch(cfg, SHAPE)
        p2, o2, metr = step(params, opt_state, jnp.asarray(1), batch)
    # params changed, stayed finite, shapes preserved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), params, p2)
    assert max(jax.tree.leaves(moved)) > 0, f"{arch}: no update applied"
    for leaf in jax.tree.leaves(p2):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
    assert bool(jnp.isfinite(metr["loss"]))
    same_shape = jax.tree.map(lambda a, b: a.shape == b.shape, params, p2)
    assert all(jax.tree.leaves(same_shape))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode(arch, tiny_mesh):
    cfg = get_config(arch, smoke=True)
    pcfg = ParallelConfig(remat="none")
    pshape = ShapeConfig("p", seq_len=16, global_batch=2, kind="prefill")
    with jax.set_mesh(tiny_mesh):
        params, _ = api.init_model(cfg, jax.random.key(0))
        batch = api.make_batch(cfg, pshape)
        cache, tok = jax.jit(
            lambda p, b: api.prefill_fn(p, b, cfg, pcfg))(params, batch)
        assert tok.shape == (2,)
        assert int(tok.max()) < cfg.vocab_size
        dbatch = {"token": tok[:, None],
                  "pos": jnp.zeros((2,), jnp.int32)}
        zero_cache = jax.tree.map(
            lambda x: jnp.zeros(x.shape, x.dtype), cache)
        if api.is_encdec(cfg):
            zero_cache = dict(zero_cache)
            zero_cache["xk"], zero_cache["xv"] = cache["xk"], cache["xv"]
        tok2, cache2 = jax.jit(
            lambda p, c, b: api.decode_fn(p, c, b, cfg, pcfg))(
                params, zero_cache, dbatch)
        assert tok2.shape == (2,)
        assert int(tok2.max()) < cfg.vocab_size


def test_param_counts_match_analytic():
    """Analytic param_count within 2% of actual init (embedding padding)."""
    for arch in ALL_ARCHS:
        cfg = get_config(arch, smoke=True)
        params, _ = api.init_model(cfg, jax.random.key(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        analytic = cfg.param_count()
        pad = (cfg.padded_vocab_size - cfg.vocab_size) * cfg.d_model
        analytic += pad * (1 if cfg.tie_embeddings else 2)
        assert abs(actual - analytic) / actual < 0.02, (
            arch, actual, analytic)
