"""User-level checkpointing (paper §4.3) for the SPMD path.

Faithful to the paper's design decisions:
  - checkpointing is library code over primitive save/restore, not runtime
    magic; policies (retention, best-metric, cadence) are user-configurable;
  - one writer per host maximizes I/O bandwidth (here: one process, one
    manifest + one .npy per pytree leaf);
  - checkpoints are NOT consistent by default; callers who need consistency
    take them between synchronous steps (our train driver does);
  - restore + re-shard enables fine-tuning AND elastic restarts: the arrays
    are host-loaded then device_put against the *new* mesh's shardings
    (checkpoint/elastic.py), so a job can resume on a different topology.
"""

from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict, spec):
    if isinstance(spec, dict):
        return {k: _unflatten(
            {p[len(k) + 1:]: v for p, v in flat.items()
             if p.split("/")[0] == k}, spec[k]) for k in spec}
    if isinstance(spec, (list, tuple)):
        vals = [
            _unflatten({p[len(str(i)) + 1:]: v for p, v in flat.items()
                        if p.split("/")[0] == str(i)}, s)
            for i, s in enumerate(spec)]
        return type(spec)(vals)
    assert len(flat) == 1, flat.keys()
    return next(iter(flat.values()))


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3,
                 keep_best: int = 0, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.keep_best = keep_best
        self.async_save = async_save
        self._pending: threading.Thread | None = None
        self._scores: dict[int, float] = self._load_scores()

    # -- save -----------------------------------------------------------------

    def save(self, step: int, state: dict, metric: float | None = None):
        """state: pytree of arrays (params/opt/whatever). Blocking host copy,
        async disk write (the step can proceed while I/O drains)."""
        flat = {k: np.asarray(v) for k, v in _flatten(state).items()}
        if self._pending is not None:
            self._pending.join()

        def write():
            path = self.dir / f"step_{step:08d}"
            tmp = self.dir / f".tmp_{step:08d}_{time.time_ns()}"
            tmp.mkdir(parents=True)
            manifest = {}
            for name, arr in flat.items():
                fn = name.replace("/", "__") + ".npy"
                logical = str(arr.dtype)
                if logical == "bfloat16":      # numpy can't serialize bf16
                    np.save(tmp / fn, arr.view(np.uint16))
                else:
                    np.save(tmp / fn, arr)
                manifest[name] = {"file": fn, "shape": list(arr.shape),
                                  "dtype": logical}
            (tmp / "manifest.json").write_text(json.dumps(
                {"step": step, "metric": metric, "leaves": manifest}))
            if path.exists():
                shutil.rmtree(path)
            tmp.rename(path)
            if metric is not None:
                self._scores[step] = metric
                self._save_scores()
            self._gc()

        if self.async_save:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        else:
            write()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # -- restore ----------------------------------------------------------------

    def steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*"))

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, spec, step: int | None = None) -> tuple[int, dict]:
        """spec: a pytree prototype (shapes irrelevant; structure used)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"step_{step:08d}"
        manifest = json.loads((path / "manifest.json").read_text())

        def load(meta):
            arr = np.load(path / meta["file"])
            if meta["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            return arr

        flat = {name: load(meta)
                for name, meta in manifest["leaves"].items()}
        return step, _unflatten(flat, spec)

    # -- retention ---------------------------------------------------------------

    def _gc(self):
        steps = self.steps()
        protected: set[int] = set(steps[-self.keep:]) if self.keep else set()
        if self.keep_best and self._scores:
            best = sorted(self._scores, key=self._scores.get)
            protected.update(best[:self.keep_best])
        for s in steps:
            if s not in protected:
                shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def _load_scores(self):
        f = self.dir / "scores.json"
        if f.exists():
            return {int(k): v for k, v in json.loads(f.read_text()).items()}
        return {}

    def _save_scores(self):
        (self.dir / "scores.json").write_text(json.dumps(self._scores))
