"""Elastic restart: restore a checkpoint onto a *different* mesh.

The paper's fault-tolerance story (§4.3) assumes non-dedicated resources —
a restarted job may come back with fewer or more machines. Arrays are saved
as host/global numpy; on restore they are device_put against whatever
shardings the NEW mesh produces from the same logical specs, so DP/TP
degrees can change between runs. Re-sharding = replacement placement.
"""

from __future__ import annotations

import jax

from repro.checkpoint.checkpoint import CheckpointManager


def restore_for_mesh(mgr: CheckpointManager, spec, shardings,
                     step: int | None = None):
    """Restore a pytree and place it with the given shardings tree."""
    step, host = mgr.restore(spec, step)

    def put(x, sh):
        return jax.device_put(x, sh)

    placed = jax.tree.map(put, host, shardings)
    return step, placed


def save_global(mgr: CheckpointManager, step: int, state, metric=None):
    """Gather device arrays to host (fully addressable single-process) and
    save. On multi-host this would be a per-shard write + manifest merge."""
    host = jax.tree.map(lambda x: jax.device_get(x), state)
    mgr.save(step, host, metric=metric)
