"""Qwen2-VL-2B — VLM text backbone with M-RoPE; vision patch frontend STUBBED
(input_specs provides patch embeddings / 3D rope position ids).
[arXiv:2409.12191; hf]"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    rope_theta=1000000.0,
    rope_sections=(16, 24, 24),   # M-RoPE temporal/height/width sections
    mlp_activation="silu",
    norm="rmsnorm",
    tie_embeddings=True,
    frontend="vision",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b-smoke",
        family="vlm",
        num_layers=2,
        d_model=48,
        num_heads=4,
        num_kv_heads=2,
        head_dim=12,
        d_ff=96,
        vocab_size=256,
        rope_sections=(2, 2, 2),
        mlp_activation="silu",
        norm="rmsnorm",
        tie_embeddings=True,
        frontend="vision",
    )
