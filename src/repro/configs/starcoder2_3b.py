"""StarCoder2-3B — dense GQA decoder, ungated GeLU MLP, LayerNorm.
[arXiv:2402.19173; hf]"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    rope_theta=999999.4420358813,
    mlp_activation="gelu_mlp",
    norm="layernorm",
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b-smoke",
        family="dense",
        num_layers=2,
        d_model=48,
        num_heads=6,
        num_kv_heads=2,
        head_dim=8,
        d_ff=192,
        vocab_size=256,
        mlp_activation="gelu_mlp",
        norm="layernorm",
        tie_embeddings=True,
    )
