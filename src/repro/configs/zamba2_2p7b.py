"""Zamba2-2.7B — hybrid: Mamba2 backbone + shared-weight attention block
applied periodically. [arXiv:2411.15242; hf]"""

from repro.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,              # 2560 / 32
    d_ff=10240,
    vocab_size=32000,
    block_pattern=("mamba",),
    shared_attn_period=6,     # one shared attn+mlp block applied every 6 layers
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_kernel=4),
    mlp_activation="gelu",
    norm="rmsnorm",
    tie_embeddings=True,
    sub_quadratic=True,       # runs long_500k (SSM state is O(1) in context)
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-smoke",
        family="hybrid",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        block_pattern=("mamba",),
        shared_attn_period=2,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_kernel=4,
                      chunk_size=8),
        mlp_activation="gelu",
        norm="rmsnorm",
        tie_embeddings=True,
        sub_quadratic=True,
    )
