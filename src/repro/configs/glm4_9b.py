"""GLM-4-9B — dense GQA decoder. [hf:THUDM/glm-4-9b; hf]"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=151552,
    rope_theta=10000.0,
    mlp_activation="silu",
    norm="rmsnorm",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        mlp_activation="silu",
        norm="rmsnorm",
    )
