"""Grok-1 314B — MoE, 8 experts top-2, the largest assigned model.
Requires FSDP + TP-within-expert sharding (see spmd/sharding.py).
[hf:xai-org/grok-1; unverified]"""

from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,                # per-expert intermediate size
    vocab_size=131072,
    rope_theta=10000.0,
    attn_logit_softcap=30.0,   # grok-1 tanh attn-logit cap
    final_logit_softcap=30.0,
    moe=MoEConfig(num_experts=8, experts_per_token=2, d_ff_expert=32768),
    mlp_activation="gelu",
    norm="rmsnorm",
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=64,
        vocab_size=256,
        attn_logit_softcap=30.0,
        final_logit_softcap=30.0,
        moe=MoEConfig(num_experts=4, experts_per_token=2, d_ff_expert=64),
        mlp_activation="gelu",
        norm="rmsnorm",
        tie_embeddings=True,
    )
