"""Qwen3-32B — dense GQA decoder with qk-norm. [hf:Qwen/Qwen3-8B family; hf]"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    rope_theta=1000000.0,
    qk_norm=True,
    mlp_activation="silu",
    norm="rmsnorm",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=8,
        num_kv_heads=2,
        head_dim=8,
        d_ff=160,
        vocab_size=256,
        qk_norm=True,
        mlp_activation="silu",
        norm="rmsnorm",
    )
