"""Gemma 2 27B — local/global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    rope_theta=10000.0,
    block_pattern=("local", "attn"),
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    attn_scale=(4608 // 32) ** -0.5,  # query_pre_attn_scalar = d_model/num_heads
    mlp_activation="gelu",            # GeGLU
    norm="rmsnorm",
    post_block_norm=True,
    tie_embeddings=True,
    embedding_scale=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b-smoke",
        family="dense",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=256,
        vocab_size=256,
        block_pattern=("local", "attn"),
        sliding_window=16,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        attn_scale=(64 // 4) ** -0.5,
        mlp_activation="gelu",
        norm="rmsnorm",
        post_block_norm=True,
        tie_embeddings=True,
        embedding_scale=True,
    )
