"""Mamba2-370m — pure SSM (attention-free), SSD state-space duality.
[arXiv:2405.21060; unverified]"""

from repro.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,              # attention-free
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,                   # no MLP blocks; mamba blocks carry the capacity
    vocab_size=50280,
    block_pattern=("mamba",),
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_kernel=4),
    norm="rmsnorm",
    tie_embeddings=True,
    sub_quadratic=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=256,
        block_pattern=("mamba",),
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_kernel=4,
                      chunk_size=8),
        norm="rmsnorm",
        tie_embeddings=True,
        sub_quadratic=True,
    )
