"""Whisper large-v3 — encoder-decoder audio backbone; conv frontend STUBBED
(input_specs provides precomputed frame embeddings). [arXiv:2212.04356]"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,            # decoder layers
    encoder_layers=32,
    encoder_seq_len=1500,     # 30s of audio after 2x conv subsampling
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,          # MHA
    head_dim=64,              # 1280 / 20
    d_ff=5120,
    vocab_size=51866,
    rope_theta=10000.0,       # backbone uses RoPE in our port (see DESIGN.md)
    mlp_activation="gelu_mlp",
    norm="layernorm",
    frontend="audio",
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3-smoke",
        family="audio",
        num_layers=2,
        encoder_layers=2,
        encoder_seq_len=16,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        mlp_activation="gelu_mlp",
        norm="layernorm",
        frontend="audio",
        tie_embeddings=True,
    )
