"""Qwen3-30B-A3B — MoE, 128 experts top-8, per-expert d_ff=768, qk-norm.
[hf:Qwen/Qwen3-30B-A3B; hf]"""

from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,                  # per-expert intermediate size
    vocab_size=151936,
    rope_theta=1000000.0,
    qk_norm=True,
    moe=MoEConfig(num_experts=128, experts_per_token=8, d_ff_expert=768),
    mlp_activation="silu",
    norm="rmsnorm",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=32,
        vocab_size=256,
        qk_norm=True,
        moe=MoEConfig(num_experts=8, experts_per_token=2, d_ff_expert=32),
        mlp_activation="silu",
        norm="rmsnorm",
    )
