"""Per-request token sampling for the serving engine.

Every slot samples with its own ``SamplingParams``: temperature 0 is exact
greedy (argmax, no RNG), otherwise temperature + optional top-k truncation
with a counter-based PRNG — key = fold_in(fold_in(PRNGKey(seed), rid),
counter). Folding the *request id* in keeps two same-seed requests on
distinct streams, and keying by ``counter`` (= tokens generated so far,
i.e. the request's own decode step) makes a request's stream a pure
function of (seed, rid, step): reproducible regardless of batch
composition, slot assignment, or recompute preemption.

Speculative decoding adds three more streams per (seed, rid, counter)
triple, each a distinct tag folded into the same base key so none of them
collides with the plain sampling stream:

* ``_DRAFT``  — the draft model's proposal at that counter,
* ``_ACCEPT`` — the accept/reject uniform of standard rejection sampling,
* ``_RESID``  — the residual-distribution sample emitted on rejection.

Because every stream is keyed only by (seed, rid, counter), a speculative
run replays identically across preemption-recompute and is independent of
batch composition — and with ``k = 0`` draft tokens the verify step
consumes exactly the plain stream, so it degenerates byte-identically to
non-speculative decoding (``speculative_verify`` with K = 0 is
``sample_tokens``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1.0e30

# stream tags folded into the per-(seed, rid, counter) base key
_DRAFT = 1
_ACCEPT = 2
_RESID = 3


def _base_key(seed, rid, counter):
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), rid), counter)


def _prep_logits(lg, t, k):
    """Temperature-scale + top-k-truncate one (V,) logit row. This is the
    one distribution transform every sampling path shares — draft proposals
    (q), target verification (p), and plain sampling must all see the same
    truncated distribution or rejection sampling would not preserve p."""
    V = lg.shape[-1]
    lg = lg / jnp.maximum(t, 1e-6)
    kth = jnp.sort(lg)[V - jnp.clip(k, 1, V)]        # k-th largest
    return jnp.where((k > 0) & (lg < kth), NEG, lg)


def _sample_stream(logits, temps, top_ks, seeds, rids, counters, tag=None):
    """One greedy / temperature / top-k sampling pass over (B, V) logit
    rows. ``tag`` selects an independent stream off the same per-(seed,
    rid, counter) base key — the single implementation keeps the plain
    and draft streams' distributions provably identical, which the
    rejection sampler's p/q consistency depends on."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def one(lg, t, k, s, r, c):
        key = _base_key(s, r, c)
        if tag is not None:
            key = jax.random.fold_in(key, tag)
        return jax.random.categorical(
            key, _prep_logits(lg, t, k)).astype(jnp.int32)

    sampled = jax.vmap(one)(logits, temps, top_ks, seeds, rids, counters)
    return jnp.where(temps <= 0.0, greedy, sampled)


def sample_tokens(logits, temps, top_ks, seeds, rids, counters):
    """logits: (B, V) fp32; temps/seeds/rids/counters: (B,); top_ks: (B,)
    int32 (0 disables truncation). Returns (B,) int32 tokens."""
    return _sample_stream(logits, temps, top_ks, seeds, rids, counters)


def propose_tokens(logits, temps, top_ks, seeds, rids, counters):
    """Draft-model proposals for speculative decoding: same greedy /
    temperature / top-k semantics as :func:`sample_tokens`, but drawn from
    the ``_DRAFT``-tagged stream so a proposal never consumes the
    randomness the verify step will use at the same counter."""
    return _sample_stream(logits, temps, top_ks, seeds, rids, counters,
                          tag=_DRAFT)


def speculative_verify(draft_tokens, draft_logits, target_logits,
                       temps, top_ks, seeds, rids, counters):
    """Accept/reject K draft tokens against K+1 target-logit rows.

    draft_tokens: (B, K) int32 proposals (sampled via
    :func:`propose_tokens`); draft_logits: (B, K, V) the logits they were
    sampled from; target_logits: (B, K+1, V) — row i is the target model's
    distribution for the token at counter ``counters + i``. Returns
    ``(out_tokens (B, K+1) int32, n_accept (B,) int32)``: the realized new
    tokens for row b are ``out_tokens[b, :n_accept[b] + 1]``.

    * temperature 0: accept while the draft token equals the target argmax;
      the emitted tokens are exactly the target argmaxes, so greedy
      speculative decode is byte-identical to plain greedy decode.
    * temperature > 0: standard rejection sampling — accept draft token d
      at position i with probability min(1, p_i(d)/q_i(d)); on the first
      rejection emit one sample from the residual ``max(p_i - q_i, 0)``;
      if all K are accepted emit a bonus sample from ``p_K`` using the
      *plain* stream key, which is what makes K = 0 degenerate exactly to
      :func:`sample_tokens`. The realized tokens are distributed exactly
      as sequential sampling from p (Leviathan et al. 2023), though for
      K > 0 they are not sample-identical to the non-speculative stream.
    """
    B, K1, V = target_logits.shape
    K = K1 - 1

    def one(d_toks, d_lg, t_lg, t, k, s, r, c0):
        t_arg = jnp.argmax(t_lg, axis=-1).astype(jnp.int32)     # (K+1,)
        p_lg = jax.vmap(_prep_logits, (0, None, None))(t_lg, t, k)
        if K == 0:
            fresh = jax.random.categorical(
                _base_key(s, r, c0), p_lg[0]).astype(jnp.int32)
            out = jnp.where(t <= 0.0, t_arg, fresh[None])
            return out, jnp.zeros((), jnp.int32)
        q_lg = jax.vmap(_prep_logits, (0, None, None))(d_lg, t, k)
        p = jax.nn.softmax(p_lg, axis=-1)                       # (K+1, V)
        q = jax.nn.softmax(q_lg, axis=-1)                       # (K, V)
        cs = c0 + jnp.arange(K, dtype=jnp.int32)
        u = jax.vmap(lambda c: jax.random.uniform(
            jax.random.fold_in(_base_key(s, r, c), _ACCEPT)))(cs)
        p_d = jnp.take_along_axis(p[:K], d_toks[:, None], axis=1)[:, 0]
        q_d = jnp.take_along_axis(q, d_toks[:, None], axis=1)[:, 0]
        acc_temp = u < p_d / jnp.maximum(q_d, 1e-37)
        acc = jnp.where(t <= 0.0, d_toks == t_arg[:K], acc_temp)
        n_acc = jnp.sum(jnp.cumprod(acc.astype(jnp.int32)))
        # residual sample for every possible rejection point (only the
        # n_acc-th is ever consumed); fall back to p when p <= q pointwise
        # (then rejection is impossible and the row is never used)
        resid = jnp.clip(p[:K] - q, 0.0, None)
        r_lg = jnp.where(resid.sum(-1, keepdims=True) > 0,
                         jnp.log(jnp.maximum(resid, 1e-37)), p_lg[:K])
        r_toks = jax.vmap(lambda c, lg: jax.random.categorical(
            jax.random.fold_in(_base_key(s, r, c), _RESID), lg))(
                cs, r_lg).astype(jnp.int32)
        # bonus token when all K accepted: the plain stream at counter c0+K
        fresh = jax.random.categorical(
            _base_key(s, r, c0 + K), p_lg[K]).astype(jnp.int32)
        out_temp = jnp.concatenate(
            [jnp.where(jnp.arange(K) < n_acc, d_toks, r_toks), fresh[None]])
        out = jnp.where(t <= 0.0, t_arg, out_temp)
        return out, n_acc

    return jax.vmap(one)(draft_tokens, draft_logits, target_logits,
                         temps, top_ks, seeds, rids, counters)
