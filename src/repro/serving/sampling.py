"""Per-request token sampling for the serving engine.

Every slot samples with its own ``SamplingParams``: temperature 0 is exact
greedy (argmax, no RNG), otherwise temperature + optional top-k truncation
with a counter-based PRNG — key = fold_in(fold_in(PRNGKey(seed), rid),
counter). Folding the *request id* in keeps two same-seed requests on
distinct streams, and keying by ``counter`` (= tokens generated so far,
i.e. the request's own decode step) makes a request's stream a pure
function of (seed, rid, step): reproducible regardless of batch
composition, slot assignment, or recompute preemption.

Speculative decoding adds three more streams per (seed, rid, counter)
triple, each a distinct tag folded into the same base key so none of them
collides with the plain sampling stream:

* ``_DRAFT``  — the draft model's proposal at that counter,
* ``_ACCEPT`` — the accept/reject uniform of standard rejection sampling,
* ``_RESID``  — the residual-distribution sample emitted on rejection.

Because every stream is keyed only by (seed, rid, counter), a speculative
run replays identically across preemption-recompute and is independent of
batch composition — and with ``k = 0`` draft tokens the verify step
consumes exactly the plain stream, so it degenerates byte-identically to
non-speculative decoding (``speculative_verify`` with K = 0 is
``sample_tokens``).

Two sampling paths share these streams (docs/sampling.md):

* the **plain path** (`sample_tokens` / `propose_tokens` /
  `speculative_verify`) covers greedy / temperature / top-k — the
  transform is `_prep_logits`, and pure-greedy batches never trace
  anything else;
* the **full path** (`sample_tokens_full` / `propose_tokens_full` /
  `speculative_verify_full`) adds repetition/presence/frequency
  penalties (backed by per-slot token-count arrays), top-p and min-p
  truncation (one shared sorted-logits pass with top-k), and per-step
  logprobs. Every full-path transform is an exact bitwise identity at
  its default parameter value, so a temperature/top-k-only request
  sampled through the full path (because a batchmate needs it) draws
  byte-identical tokens to the plain path — the replay and mixed-batch
  equivalence tests pin this.

:class:`SamplingBuffer` is the host-side dense per-slot state backing
the full path: param rows, prompt-presence masks, generated-token count
arrays and stop-sequence rings, bound at admission and rebuilt from the
request's own (prompt, out) on every re-bind — which is what makes
preemption-recompute, swap-in and speculative rollback replay for free.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG = -1.0e30

# stream tags folded into the per-(seed, rid, counter) base key
_DRAFT = 1
_ACCEPT = 2
_RESID = 3


def _base_key(seed, rid, counter):
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), rid), counter)


def _prep_logits(lg, t, k):
    """Temperature-scale + top-k-truncate one (V,) logit row. This is the
    one distribution transform every sampling path shares — draft proposals
    (q), target verification (p), and plain sampling must all see the same
    truncated distribution or rejection sampling would not preserve p."""
    V = lg.shape[-1]
    lg = lg / jnp.maximum(t, 1e-6)
    kth = jnp.sort(lg)[V - jnp.clip(k, 1, V)]        # k-th largest
    return jnp.where((k > 0) & (lg < kth), NEG, lg)


def _sample_stream(logits, temps, top_ks, seeds, rids, counters, tag=None):
    """One greedy / temperature / top-k sampling pass over (B, V) logit
    rows.

    Key derivation, in this exact order: ``key = fold_in(fold_in(
    PRNGKey(seed), rid), counter)``, then — only when ``tag`` is given —
    ``key = fold_in(key, tag)``. The tag is folded *last*, onto the
    fully-derived base key, so ``tag=None`` (the plain stream) and each
    tagged stream (``_DRAFT``/``_ACCEPT``/``_RESID``) are independent
    streams off the same (seed, rid, counter) triple; a tagged stream at
    one counter never collides with the plain stream at *any* counter.
    Greedy rows (temperature <= 0) take the argmax and consume **no**
    randomness — the key is derived but never advances any state, so
    mixing greedy and sampled rows in one batch cannot shift anyone's
    stream. The single implementation keeps the plain and draft streams'
    distributions provably identical, which the rejection sampler's p/q
    consistency depends on. Pinned by the seeded key-stream regression
    test (tests/test_sampling.py) so refactors can't silently break
    preemption replay."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def one(lg, t, k, s, r, c):
        key = _base_key(s, r, c)
        if tag is not None:
            key = jax.random.fold_in(key, tag)
        return jax.random.categorical(
            key, _prep_logits(lg, t, k)).astype(jnp.int32)

    sampled = jax.vmap(one)(logits, temps, top_ks, seeds, rids, counters)
    return jnp.where(temps <= 0.0, greedy, sampled)


def sample_tokens(logits, temps, top_ks, seeds, rids, counters):
    """logits: (B, V) fp32; temps/seeds/rids/counters: (B,); top_ks: (B,)
    int32 (0 disables truncation). Returns (B,) int32 tokens."""
    return _sample_stream(logits, temps, top_ks, seeds, rids, counters)


def propose_tokens(logits, temps, top_ks, seeds, rids, counters):
    """Draft-model proposals for speculative decoding: same greedy /
    temperature / top-k semantics as :func:`sample_tokens`, but drawn from
    the ``_DRAFT``-tagged stream so a proposal never consumes the
    randomness the verify step will use at the same counter."""
    return _sample_stream(logits, temps, top_ks, seeds, rids, counters,
                          tag=_DRAFT)


def speculative_verify(draft_tokens, draft_logits, target_logits,
                       temps, top_ks, seeds, rids, counters):
    """Accept/reject K draft tokens against K+1 target-logit rows.

    draft_tokens: (B, K) int32 proposals (sampled via
    :func:`propose_tokens`); draft_logits: (B, K, V) the logits they were
    sampled from; target_logits: (B, K+1, V) — row i is the target model's
    distribution for the token at counter ``counters + i``. Returns
    ``(out_tokens (B, K+1) int32, n_accept (B,) int32)``: the realized new
    tokens for row b are ``out_tokens[b, :n_accept[b] + 1]``.

    * temperature 0: accept while the draft token equals the target argmax;
      the emitted tokens are exactly the target argmaxes, so greedy
      speculative decode is byte-identical to plain greedy decode.
    * temperature > 0: standard rejection sampling — accept draft token d
      at position i with probability min(1, p_i(d)/q_i(d)); on the first
      rejection emit one sample from the residual ``max(p_i - q_i, 0)``;
      if all K are accepted emit a bonus sample from ``p_K`` using the
      *plain* stream key, which is what makes K = 0 degenerate exactly to
      :func:`sample_tokens`. The realized tokens are distributed exactly
      as sequential sampling from p (Leviathan et al. 2023), though for
      K > 0 they are not sample-identical to the non-speculative stream.
    """
    B, K1, V = target_logits.shape
    K = K1 - 1

    def one(d_toks, d_lg, t_lg, t, k, s, r, c0):
        t_arg = jnp.argmax(t_lg, axis=-1).astype(jnp.int32)     # (K+1,)
        p_lg = jax.vmap(_prep_logits, (0, None, None))(t_lg, t, k)
        if K == 0:
            fresh = jax.random.categorical(
                _base_key(s, r, c0), p_lg[0]).astype(jnp.int32)
            out = jnp.where(t <= 0.0, t_arg, fresh[None])
            return out, jnp.zeros((), jnp.int32)
        q_lg = jax.vmap(_prep_logits, (0, None, None))(d_lg, t, k)
        p = jax.nn.softmax(p_lg, axis=-1)                       # (K+1, V)
        q = jax.nn.softmax(q_lg, axis=-1)                       # (K, V)
        cs = c0 + jnp.arange(K, dtype=jnp.int32)
        u = jax.vmap(lambda c: jax.random.uniform(
            jax.random.fold_in(_base_key(s, r, c), _ACCEPT)))(cs)
        p_d = jnp.take_along_axis(p[:K], d_toks[:, None], axis=1)[:, 0]
        q_d = jnp.take_along_axis(q, d_toks[:, None], axis=1)[:, 0]
        acc_temp = u < p_d / jnp.maximum(q_d, 1e-37)
        acc = jnp.where(t <= 0.0, d_toks == t_arg[:K], acc_temp)
        n_acc = jnp.sum(jnp.cumprod(acc.astype(jnp.int32)))
        # residual sample for every possible rejection point (only the
        # n_acc-th is ever consumed); fall back to p when p <= q pointwise
        # (then rejection is impossible and the row is never used)
        resid = jnp.clip(p[:K] - q, 0.0, None)
        r_lg = jnp.where(resid.sum(-1, keepdims=True) > 0,
                         jnp.log(jnp.maximum(resid, 1e-37)), p_lg[:K])
        r_toks = jax.vmap(lambda c, lg: jax.random.categorical(
            jax.random.fold_in(_base_key(s, r, c), _RESID), lg))(
                cs, r_lg).astype(jnp.int32)
        # bonus token when all K accepted: the plain stream at counter c0+K
        fresh = jax.random.categorical(
            _base_key(s, r, c0 + K), p_lg[K]).astype(jnp.int32)
        out_temp = jnp.concatenate(
            [jnp.where(jnp.arange(K) < n_acc, d_toks, r_toks), fresh[None]])
        out = jnp.where(t <= 0.0, t_arg, out_temp)
        return out, n_acc

    return jax.vmap(one)(draft_tokens, draft_logits, target_logits,
                         temps, top_ks, seeds, rids, counters)


# -- full sampling path: penalties + top-p/min-p/top-k + logprobs ----------
#
# Array-dict keys every full-path entry point consumes ("sp"): the per-row
# param vectors plus the dense per-row count state. All (N,) float32 unless
# noted. Built host-side by the engine from SamplingBuffer rows.
SP_KEYS = ("temps", "top_ks", "top_ps", "min_ps", "rep_pens", "pres_pens",
           "freq_pens", "seeds", "rids", "counters", "pmask", "ocounts")


def _penalize(lg, pmask, ocounts, rep, pres, freq):
    """Repetition / presence / frequency penalties on one (V,) row.

    vLLM semantics: repetition penalty divides positive logits (and
    multiplies negative ones) by ``rep`` for every token present in the
    prompt or the output so far; frequency subtracts ``freq *
    count(token in output)``; presence subtracts ``pres`` once per
    distinct output token. At the defaults (rep=1, pres=freq=0) every op
    is an exact bitwise identity (x/1.0, x*1.0, x-0.0), which the
    mixed-batch byte-identity guarantee relies on."""
    seen = pmask | (ocounts > 0)
    lg = jnp.where(seen, jnp.where(lg > 0, lg / rep, lg * rep), lg)
    return (lg - freq * ocounts.astype(lg.dtype)
            - pres * (ocounts > 0).astype(lg.dtype))


def _truncate(lg, k, top_p, min_p):
    """Top-k + top-p + min-p truncation of one temperature-scaled (V,)
    row. One shared ``jnp.sort`` serves all three: the k-th-largest
    threshold, the descending cumulative-mass prefix for top-p (kept
    ranks are those whose mass *before* them is < top_p, so at least one
    survives), and the row max for the min-p threshold ``max + log(
    min_p)``. Gates ``top_p < 1`` / ``min_p > 0`` / ``k > 0`` make each
    mask empty at its default, so the composed output is bitwise equal
    to the plain ``_prep_logits`` there. If every position ends up
    masked (degenerate params), fall back to keeping the argmax."""
    V = lg.shape[-1]
    srt = jnp.sort(lg)                               # one shared sort
    kth = srt[V - jnp.clip(k, 1, V)]                 # k-th largest
    mask = (k > 0) & (lg < kth)
    desc = srt[::-1]
    probs = jax.nn.softmax(desc)
    before = jnp.cumsum(probs) - probs               # mass ahead of rank i
    n_keep = jnp.maximum(
        jnp.sum((before < top_p).astype(jnp.int32)), 1)
    mask |= (top_p < 1.0) & (lg < desc[n_keep - 1])
    mask |= (min_p > 0.0) & (lg < srt[-1] + jnp.log(min_p))
    out = jnp.where(mask, NEG, lg)
    return jnp.where(jnp.all(mask),
                     jnp.where(jnp.arange(V) == jnp.argmax(lg), lg, NEG),
                     out)


def _prep_logits_full(lg, pmask, ocounts, t, k, top_p, min_p,
                      rep, pres, freq):
    """Full-path analogue of :func:`_prep_logits` for one (V,) row:
    penalties, then the *identical* temperature scale, then the shared-
    sort truncation. With default penalties/top-p/min-p this is bitwise
    equal to ``_prep_logits(lg, t, k)``."""
    pen = _penalize(lg, pmask, ocounts, rep, pres, freq)
    return _truncate(pen / jnp.maximum(t, 1e-6), k, top_p, min_p)


def _row_logprobs(pen, t, tok, n_top):
    """Log-probabilities reported per emitted token: log-softmax of the
    *penalized, pre-truncation* logits — the model's post-penalty
    distribution, comparable across truncation settings. Sampled rows
    scale by their temperature; greedy rows report the unscaled
    distribution (t -> 0 would degenerate to a one-hot)."""
    scale = jnp.where(t > 0.0, jnp.maximum(t, 1e-6), 1.0)
    logp = jax.nn.log_softmax(pen / scale)
    top_lp, top_ids = jax.lax.top_k(logp, n_top)
    return logp[tok], top_lp, top_ids.astype(jnp.int32)


def _sample_stream_full(logits, sp, tag=None, max_logprobs=8):
    """Full-pipeline counterpart of :func:`_sample_stream` over (N, V)
    rows: same key derivation (tag folded last onto the (seed, rid,
    counter) base key; greedy rows consume no randomness), same
    categorical draw — only the logits transform is richer. Returns
    ``(tokens (N,), lp)`` with ``lp = {"chosen": (N,), "top_lp": (N, L),
    "top_ids": (N, L)}`` where L = min(max_logprobs, V)."""
    L = min(max_logprobs, logits.shape[-1])

    def one(lg, pm, oc, t, k, tp, mp, rp, pp, fp, s, r, c):
        pen = _penalize(lg, pm, oc, rp, pp, fp)
        trunc = _truncate(pen / jnp.maximum(t, 1e-6), k, tp, mp)
        key = _base_key(s, r, c)
        if tag is not None:
            key = jax.random.fold_in(key, tag)
        samp = jax.random.categorical(key, trunc).astype(jnp.int32)
        # greedy rows argmax the *transformed* row: identical index to
        # argmax(raw) at default params (positive scaling and masks that
        # never drop the max preserve the argmax), penalty-aware otherwise
        tok = jnp.where(t <= 0.0,
                        jnp.argmax(trunc).astype(jnp.int32), samp)
        chosen, top_lp, top_ids = _row_logprobs(pen, t, tok, L)
        return tok, chosen, top_lp, top_ids

    toks, chosen, top_lp, top_ids = jax.vmap(one)(
        logits, sp["pmask"], sp["ocounts"], sp["temps"], sp["top_ks"],
        sp["top_ps"], sp["min_ps"], sp["rep_pens"], sp["pres_pens"],
        sp["freq_pens"], sp["seeds"], sp["rids"], sp["counters"])
    return toks, {"chosen": chosen, "top_lp": top_lp, "top_ids": top_ids}


def sample_tokens_full(logits, sp, *, max_logprobs=8):
    """Full-pipeline sampling over (N, V) rows. ``sp`` holds the
    :data:`SP_KEYS` arrays — (N,) param vectors plus ``pmask`` (N, V)
    bool and ``ocounts`` (N, V) int32. Returns ``(tokens, lp)``; see
    :func:`_sample_stream_full`."""
    return _sample_stream_full(logits, sp, max_logprobs=max_logprobs)


def propose_tokens_full(logits, sp):
    """Full-pipeline draft proposals (``_DRAFT`` stream). The caller
    passes ``sp`` with ``ocounts`` already including every *earlier*
    proposal of this speculative window (one-hot accumulated), so
    proposal i and verify row i see identical counts."""
    return _sample_stream_full(logits, sp, tag=_DRAFT)[0]


def speculative_verify_full(draft_tokens, draft_logits, target_logits,
                            sp, *, max_logprobs=8):
    """Full-pipeline accept/reject, same protocol and streams as
    :func:`speculative_verify` but with p and q both produced by the
    full transform (:func:`_prep_logits_full`) — rejection sampling
    preserves the *transformed* target distribution for any per-slot
    parameter combination, because draft and target share it exactly.

    Verify row i (and the bonus row K) transforms with counts =
    ``sp["ocounts"]`` + one-hots of draft tokens < i — the counts the
    sequential sampler would have had after committing those tokens,
    matching what :func:`propose_tokens_full` used for proposal i.
    Greedy rows accept while the draft token equals the argmax of the
    *transformed* target row (bitwise the raw argmax at default params).

    Returns ``(out_tokens (B, K+1), n_accept (B,), lp)`` with per-
    position logprob arrays ``lp = {"chosen": (B, K+1), "top_lp":
    (B, K+1, L), "top_ids": (B, K+1, L)}``.
    """
    B, K1, V = target_logits.shape
    K = K1 - 1
    L = min(max_logprobs, V)
    oh = jax.nn.one_hot(draft_tokens, V, dtype=sp["ocounts"].dtype)
    counts = jnp.concatenate(
        [sp["ocounts"][:, None],
         sp["ocounts"][:, None] + jnp.cumsum(oh, axis=1)], axis=1)

    def one(d_toks, d_lg, t_lg, cnts, pm, t, k, tp, mp, rp, pp, fp,
            s, r, c0):
        pen = jax.vmap(lambda lg, oc: _penalize(lg, pm, oc, rp, pp, fp))(
            t_lg, cnts)                                         # (K+1, V)
        p_lg = jax.vmap(lambda x: _truncate(
            x / jnp.maximum(t, 1e-6), k, tp, mp))(pen)
        t_arg = jnp.argmax(p_lg, axis=-1).astype(jnp.int32)     # (K+1,)
        if K == 0:
            fresh = jax.random.categorical(
                _base_key(s, r, c0), p_lg[0]).astype(jnp.int32)
            out = jnp.where(t <= 0.0, t_arg, fresh[None])
            n_acc = jnp.zeros((), jnp.int32)
        else:
            q_pen = jax.vmap(
                lambda lg, oc: _penalize(lg, pm, oc, rp, pp, fp))(
                    d_lg, cnts[:K])
            q_lg = jax.vmap(lambda x: _truncate(
                x / jnp.maximum(t, 1e-6), k, tp, mp))(q_pen)
            p = jax.nn.softmax(p_lg, axis=-1)                   # (K+1, V)
            q = jax.nn.softmax(q_lg, axis=-1)                   # (K, V)
            cs = c0 + jnp.arange(K, dtype=jnp.int32)
            u = jax.vmap(lambda c: jax.random.uniform(
                jax.random.fold_in(_base_key(s, r, c), _ACCEPT)))(cs)
            p_d = jnp.take_along_axis(p[:K], d_toks[:, None], axis=1)[:, 0]
            q_d = jnp.take_along_axis(q, d_toks[:, None], axis=1)[:, 0]
            acc_temp = u < p_d / jnp.maximum(q_d, 1e-37)
            acc = jnp.where(t <= 0.0, d_toks == t_arg[:K], acc_temp)
            n_acc = jnp.sum(jnp.cumprod(acc.astype(jnp.int32)))
            resid = jnp.clip(p[:K] - q, 0.0, None)
            r_lg = jnp.where(resid.sum(-1, keepdims=True) > 0,
                             jnp.log(jnp.maximum(resid, 1e-37)), p_lg[:K])
            r_toks = jax.vmap(lambda c, lg: jax.random.categorical(
                jax.random.fold_in(_base_key(s, r, c), _RESID), lg))(
                    cs, r_lg).astype(jnp.int32)
            fresh = jax.random.categorical(
                _base_key(s, r, c0 + K), p_lg[K]).astype(jnp.int32)
            out_temp = jnp.concatenate(
                [jnp.where(jnp.arange(K) < n_acc, d_toks, r_toks),
                 fresh[None]])
            out = jnp.where(t <= 0.0, t_arg, out_temp)
        chosen, top_lp, top_ids = jax.vmap(
            lambda pe, tk: _row_logprobs(pe, t, tk, L))(pen, out)
        return out, n_acc, chosen, top_lp, top_ids

    out, n_acc, chosen, top_lp, top_ids = jax.vmap(one)(
        draft_tokens, draft_logits, target_logits, counts, sp["pmask"],
        sp["temps"], sp["top_ks"], sp["top_ps"], sp["min_ps"],
        sp["rep_pens"], sp["pres_pens"], sp["freq_pens"], sp["seeds"],
        sp["rids"], sp["counters"])
    return out, n_acc, {"chosen": chosen, "top_lp": top_lp,
                        "top_ids": top_ids}


class SamplingBuffer:
    """Host-side dense per-slot sampling state for the full path.

    The layout follows the dense ``SequenceBuffer`` idiom: one row per
    batch slot holding the request's sampling params, its prompt-
    presence mask (V,), its generated-token counts (V,), and a small
    ring of its most recent tokens for stop-sequence matching. Rows are
    bound at admission (``bind``), updated as tokens commit
    (``commit``), and released at retire/abort/preempt (``free``).

    Replay for free: ``bind`` rebuilds the mask, counts and ring from
    the request's own ``(prompt, out)``, and only *accepted* tokens are
    ever committed — so preemption-recompute, swap-in and speculative
    rollback all land back in exactly the state the uninterrupted run
    would have had, with no explicit rewind path.

    ``needs_pipeline`` over the bound requests is the engine's per-step
    fast-path switch: a batch of requests none of which needs the full
    pipeline runs the plain (greedy/temperature/top-k) executables,
    tracing none of the penalty/top-p/logprob work.
    """

    def __init__(self, max_batch: int, vocab_size: int, *,
                 max_stop_len: int = 8, max_logprobs: int = 8):
        self.max_batch = max_batch
        self.vocab_size = vocab_size
        self.max_stop_len = max_stop_len
        self.max_logprobs = max_logprobs
        self.pmask = np.zeros((max_batch, vocab_size), bool)
        self.ocounts = np.zeros((max_batch, vocab_size), np.int32)
        self.rings = np.zeros((max_batch, max_stop_len), np.int32)
        self.ring_len = np.zeros(max_batch, np.int32)
        self._slot_of: dict[int, int] = {}

    # -- validation (scheduler.validate delegates here) --------------------

    def validate(self, req) -> None:
        sp = req.sampling
        if not 0.0 < sp.top_p <= 1.0:
            raise ValueError(f"request {req.rid}: top_p={sp.top_p} "
                             "must be in (0, 1]")
        if not 0.0 <= sp.min_p <= 1.0:
            raise ValueError(f"request {req.rid}: min_p={sp.min_p} "
                             "must be in [0, 1]")
        if sp.repetition_penalty <= 0.0:
            raise ValueError(
                f"request {req.rid}: repetition_penalty="
                f"{sp.repetition_penalty} must be > 0")
        if sp.logprobs < 0 or sp.logprobs > self.max_logprobs:
            raise ValueError(
                f"request {req.rid}: logprobs={sp.logprobs} must be in "
                f"[0, max_logprobs={self.max_logprobs}] (raise the "
                "engine's max_logprobs knob for more)")
        for s in sp.stop:
            if not s or len(s) > self.max_stop_len:
                raise ValueError(
                    f"request {req.rid}: stop sequence length {len(s)} "
                    f"must be in [1, max_stop_len={self.max_stop_len}]")
        if req.min_new > req.max_new:
            raise ValueError(
                f"request {req.rid}: min_new={req.min_new} exceeds "
                f"max_new={req.max_new}")

    # -- bind / free (scheduler admission & release paths) -----------------

    def bind(self, req, slot: int) -> None:
        """(Re)bind a request's row: rebuild mask/counts/ring from its
        current (prompt, out) — the replay property."""
        self._slot_of[req.rid] = slot
        self.pmask[slot] = False
        ids = np.asarray(req.prompt, np.int64)
        self.pmask[slot][ids[ids < self.vocab_size]] = True
        self.ocounts[slot] = 0
        if req.out:
            out = np.asarray(req.out, np.int64)
            np.add.at(self.ocounts[slot], out[out < self.vocab_size], 1)
        tail = req.out[-self.max_stop_len:]
        self.rings[slot] = 0
        self.rings[slot, :len(tail)] = tail
        self.ring_len[slot] = len(tail)

    def free(self, rid: int) -> None:
        """Release a request's row (retire/abort/preempt). Unknown rids
        are a no-op — aborting a still-waiting request never bound."""
        slot = self._slot_of.pop(rid, None)
        if slot is None:
            return
        self.pmask[slot] = False
        self.ocounts[slot] = 0
        self.rings[slot] = 0
        self.ring_len[slot] = 0

    # -- per-token updates (engine append path) ----------------------------

    def commit(self, rid: int, tok: int) -> None:
        """Account one accepted token: bump its count, push the ring."""
        slot = self._slot_of[rid]
        if 0 <= tok < self.vocab_size:
            self.ocounts[slot, tok] += 1
        n = int(self.ring_len[slot])
        if n < self.max_stop_len:
            self.rings[slot, n] = tok
            self.ring_len[slot] = n + 1
        else:
            self.rings[slot, :-1] = self.rings[slot, 1:]
            self.rings[slot, -1] = tok

    def check_stop(self, rid: int, stops) -> tuple | None:
        """Return the first stop sequence matching the ring's tail (the
        request's most recent tokens), or None."""
        slot = self._slot_of[rid]
        n = int(self.ring_len[slot])
        for s in stops:
            m = len(s)
            if m <= n and list(self.rings[slot, n - m:n]) == list(s):
                return tuple(s)
        return None

    # -- row access (engine array building) --------------------------------

    def row(self, rid: int) -> tuple:
        """(pmask_row, ocounts_row) views for one bound request."""
        slot = self._slot_of[rid]
        return self.pmask[slot], self.ocounts[slot]
