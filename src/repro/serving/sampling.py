"""Per-request token sampling for the serving engine.

Every slot samples with its own ``SamplingParams``: temperature 0 is exact
greedy (argmax, no RNG), otherwise temperature + optional top-k truncation
with a counter-based PRNG — key = fold_in(fold_in(PRNGKey(seed), rid),
counter). Folding the *request id* in keeps two same-seed requests on
distinct streams, and keying by ``counter`` (= tokens generated so far,
i.e. the request's own decode step) makes a request's stream a pure
function of (seed, rid, step): reproducible regardless of batch
composition, slot assignment, or recompute preemption.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1.0e30


def sample_tokens(logits, temps, top_ks, seeds, rids, counters):
    """logits: (B, V) fp32; temps/seeds/rids/counters: (B,); top_ks: (B,)
    int32 (0 disables truncation). Returns (B,) int32 tokens."""
    B, V = logits.shape
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def one(lg, t, k, s, r, c):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(s), r), c)
        lg = lg / jnp.maximum(t, 1e-6)
        kth = jnp.sort(lg)[V - jnp.clip(k, 1, V)]        # k-th largest
        lg = jnp.where((k > 0) & (lg < kth), NEG, lg)
        return jax.random.categorical(key, lg).astype(jnp.int32)

    sampled = jax.vmap(one)(logits, temps, top_ks, seeds, rids, counters)
    return jnp.where(temps <= 0.0, greedy, sampled)
