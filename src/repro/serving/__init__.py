"""Continuous-batching serving subsystem (cache kinds + per-family model
runners + scheduler + engine). See README.md in this directory for the
architecture. The request-facing async streaming front-end (driver,
SLO admission control, HTTP/SSE, /metrics) lives in
``repro.serving.frontend``."""

from repro.serving.cache import EncoderCache, PagedKVCache, SlotStateCache
from repro.serving.engine import InferenceEngine
from repro.serving.kv_cache import (BlockManager, SharedPrefixIndex,
                                    init_paged_cache)
from repro.serving.router import ReplicaRouter, RouterStream
from repro.serving.runners import (EncDecRunner, HybridRunner, ModelRunner,
                                   SpeculativeRunner, SSMRunner,
                                   TransformerRunner, make_runner)
from repro.serving.scheduler import Request, SamplingParams, Scheduler

__all__ = ["InferenceEngine", "BlockManager", "SharedPrefixIndex",
           "ReplicaRouter", "RouterStream", "PagedKVCache",
           "SlotStateCache", "EncoderCache", "init_paged_cache",
           "ModelRunner", "TransformerRunner", "SSMRunner", "HybridRunner",
           "EncDecRunner", "SpeculativeRunner", "make_runner",
           "Request", "SamplingParams", "Scheduler"]
