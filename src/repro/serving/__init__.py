"""Continuous-batching serving subsystem (paged KV cache + scheduler +
engine). See README.md in this directory for the architecture."""

from repro.serving.engine import InferenceEngine
from repro.serving.kv_cache import BlockManager, init_paged_cache
from repro.serving.scheduler import Request, SamplingParams, Scheduler

__all__ = ["InferenceEngine", "BlockManager", "init_paged_cache",
           "Request", "SamplingParams", "Scheduler"]
