"""Data-parallel replica routing with a shared prefix index.

``ReplicaRouter`` puts N :class:`~repro.serving.engine.InferenceEngine`
replicas — each with its own :class:`AsyncEngineDriver` step-loop thread
— behind one admission queue. Replicas constructed with a common
:class:`~repro.serving.kv_cache.SharedPrefixIndex` share the content-hash
prefix cache across the fleet: blocks one replica hashed are adopted by
any replica's admission through the existing host-copy path, so a prompt
prefix is prefilled at most once *per fleet*, not once per replica.

Routing policy (deterministic, so the replica-equivalence harness in
tests/test_router.py can pin dp∈{1,2,3} byte-for-byte): each request goes
to the replica with the **least outstanding tokens** (sum of
``len(prompt) + max_new`` over its unfinished assignments), ties broken
by lowest replica index; requests are considered strictly in submission
order (FCFS). With submissions made before ``start()`` — the harness
shape, mirroring ``engine.run(arrival_steps=...)`` — the whole placement
is a pure function of the workload.

Byte-identity argument (docs/multi-host.md): a request's tokens are a
function of (params, token prefix, sampling stream) only. All replicas
hold identical params; adopted KV equals recomputed KV (prefix caching's
qualification — KV is a pure function of the token prefix); and sampling
streams are keyed ``(seed, rid, len(out))``, independent of placement,
step timing, preemption, or adoption. So *where* a request runs and *how
much* of its prefix was adopted cannot change its output — which is
exactly what lets one queue feed N replicas safely.

Disaggregated prefill/decode (``disaggregate=True``): the first
``n_prefill`` replicas take the prefill role, the rest decode. A request
is split into a 1-token probe on a prefill replica (prompt KV computed
and hash-registered there; the engine's stream-close publish barrier
commits every full block to the shared index before the probe's stream
ends) and a continuation on a decode replica carrying ``out=[t1]`` — the
preemption-recompute shape, which the scheduler already replays
byte-identically. The continuation's admission adopts the published
prompt blocks, so the decode replica starts decode-ready without
recomputing prefill: the KV handoff unit is the hashed block, moved
through the shared index's host pool.
"""

from __future__ import annotations

import asyncio
import dataclasses
import time

import numpy as np

from repro.serving.frontend.admission import AdmissionController
from repro.serving.frontend.driver import (AsyncEngineDriver, ShedError,
                                           TokenEvent)
from repro.serving.scheduler import Request

__all__ = ["ReplicaRouter", "RouterStream"]

_DONE = object()


class RouterStream:
    """One request's async token stream as seen through the router.

    Mirrors :class:`~repro.serving.frontend.driver.TokenStream`'s
    consumer surface (``async for ev in stream`` yielding
    :class:`TokenEvent`), fed by the router's per-request forwarding task
    on the same event loop — in disaggregated mode the events of both
    phases arrive here as one seamless, contiguously indexed stream.
    """

    def __init__(self, request):
        self.request = request
        self._q: asyncio.Queue = asyncio.Queue()
        self.finished = False
        self.error: BaseException | None = None
        self.submit_wall = time.monotonic()
        self.first_token_wall: float | None = None

    def _put(self, ev: TokenEvent) -> None:
        self._q.put_nowait(ev)

    def _close(self, exc: BaseException | None = None) -> None:
        if exc is not None and self.error is None:
            self.error = exc
        self._q.put_nowait(_DONE)

    def __aiter__(self):
        return self

    async def __anext__(self) -> TokenEvent:
        if self.finished:
            raise StopAsyncIteration
        item = await self._q.get()
        if item is _DONE:
            self.finished = True
            if self.error is not None:
                raise self.error
            raise StopAsyncIteration
        return item


def _phase1(req: Request) -> Request:
    """The 1-token prefill probe: same rid (sampling streams are keyed
    (seed, rid, counter), so token 0 is drawn from the same stream
    position the colocated run uses), same prompt, ``max_new=1``.

    Stop sequences are host-side only (they never shape the sampled
    token), so they are stripped whenever the colocated run would not
    check them at token 1 (``min_new >= 2`` gates the check) — kept
    otherwise, so a token-1 stop match lands exactly like colocated."""
    if req.min_new >= 2:
        sampling = dataclasses.replace(req.sampling, stop=())
        min_new = 0
    else:
        sampling = req.sampling
        min_new = req.min_new
    return Request(req.prompt, max_new=1, sampling=sampling,
                   eos_id=req.eos_id, min_new=min_new, frames=req.frames,
                   rid=req.rid)


def _phase2(req: Request, t1: int, stop_hit: bool) -> Request:
    """The decode continuation: the original request with ``out=[t1]``
    pre-filled — byte-identical to a preemption victim re-admitted after
    its first token, a shape the scheduler replays exactly (sampling
    counters continue at len(out); speculative recompute stops one short
    so the verify window realigns)."""
    cont = Request(req.prompt, max_new=req.max_new, sampling=req.sampling,
                   eos_id=req.eos_id, min_new=req.min_new,
                   frames=req.frames, rid=req.rid)
    cont.out = [int(t1)]
    cont.stop_hit = stop_hit
    return cont


class ReplicaRouter:
    """N engine replicas behind one deterministic admission queue.

    ``engines`` are fully constructed replicas (same config/params; pass
    each the same ``shared_index`` for cross-replica prefix sharing —
    required for ``disaggregate``). The router builds one
    ``AsyncEngineDriver`` per replica on ``start()`` (fresh drivers per
    run: engines and the shared index persist, so prefix state carries
    across runs), and exposes the driver surface ``FrontendServer``
    expects: ``submit`` / ``abort`` / ``drain`` / ``aclose`` /
    ``queue_depth`` / ``draining`` / ``admission``.
    """

    def __init__(self, engines, *, admission: AdmissionController = None,
                 detokenize=None, disaggregate: bool = False,
                 n_prefill: int = 1):
        if not engines:
            raise ValueError("ReplicaRouter needs at least one engine")
        self.engines = list(engines)
        self.dp = len(self.engines)
        self.disaggregate = disaggregate
        if disaggregate:
            if self.dp < 2:
                raise ValueError("disaggregate needs dp >= 2 (at least "
                                 "one prefill and one decode replica)")
            if not 1 <= n_prefill < self.dp:
                raise ValueError(
                    f"n_prefill={n_prefill} must leave both roles "
                    f"populated with dp={self.dp}")
            if any(e.shared_index is None for e in self.engines):
                raise ValueError(
                    "disaggregate requires every replica to share a "
                    "SharedPrefixIndex: the prefill->decode KV handoff "
                    "unit is the published hashed block")
        self.n_prefill = n_prefill if disaggregate else 0
        self._prefill_ids = list(range(self.n_prefill)) or \
            list(range(self.dp))
        self._decode_ids = list(range(self.n_prefill, self.dp))
        self.shared_index = self.engines[0].shared_index
        self.admission = admission or AdmissionController(
            n_replicas=self.dp)
        self._detokenize = detokenize
        self.drivers: list[AsyncEngineDriver] | None = None
        # least-outstanding-tokens routing state (deterministic: mutated
        # only on the event loop, in submission / stream-close order)
        self._outstanding = [0] * self.dp
        self.routed = [0] * self.dp           # submissions per replica
        self.handoffs = 0                     # disagg phase-2 submissions
        self.dropped_streams = 0              # SSE disconnects (http.py)
        self.aborted = 0                      # abort() calls on live rids
        self._assigned: dict[int, int] = {}   # rid -> current replica
        self._fleet_queued: set[int] = set()  # fleet note_admit filter
        self._aborted: set[int] = set()
        self._tasks: dict[int, asyncio.Task] = {}
        self._draining = False

    # -- queries ------------------------------------------------------------

    @property
    def queue_depth(self) -> int:
        if self.drivers is None:
            return 0
        return sum(d.queue_depth for d in self.drivers)

    @property
    def draining(self) -> bool:
        return self._draining

    def replica_stats(self, key: str) -> list:
        return [e.stats[key] for e in self.engines]

    def shared_stats(self) -> dict:
        return (self.shared_index.stats() if self.shared_index is not None
                else {})

    # -- lifecycle ----------------------------------------------------------

    def _ensure_drivers(self) -> None:
        if self.drivers is not None:
            return
        # per-replica controllers are deliberately permissive: shedding
        # is the *fleet* controller's decision (it knows the dp-scaled
        # drain rate); a replica refusing routed work would break FCFS
        self.drivers = [
            AsyncEngineDriver(
                e, admission=AdmissionController(max_queue=1 << 30),
                detokenize=self._detokenize)
            for e in self.engines]
        self._draining = False
        self._outstanding = [0] * self.dp
        self._assigned.clear()
        self._fleet_queued.clear()
        self._aborted.clear()

    async def start(self) -> None:
        self._ensure_drivers()
        for eng, drv in zip(self.engines, self.drivers):
            await drv.start()
            # fleet drain-rate estimator: fold every replica's waiting ->
            # running transitions into the shared controller (the driver
            # installed its own hook in start(); chain onto it)
            inner = eng.sched.on_admit

            def hook(slot, req, _inner=inner):
                _inner(slot, req)
                if req.rid in self._fleet_queued:
                    self._fleet_queued.discard(req.rid)
                    self.admission.note_admit(time.monotonic())
            eng.sched.on_admit = hook

    async def drain(self) -> None:
        """Graceful fleet shutdown: stop admitting, let every forwarding
        task finish (disagg continuations included — a probe mid-flight
        still gets its decode phase), then drain every driver."""
        self._draining = True
        if self._tasks:
            await asyncio.gather(*list(self._tasks.values()),
                                 return_exceptions=True)
        if self.drivers is not None:
            for drv in self.drivers:
                await drv.drain()

    async def aclose(self) -> None:
        try:
            await self.drain()
        finally:
            if self.drivers is not None:
                for drv in self.drivers:
                    await drv.aclose()
            self.drivers = None             # next run builds fresh drivers

    # -- routing ------------------------------------------------------------

    def _pick(self, ids: list[int]) -> int:
        return min(ids, key=lambda i: (self._outstanding[i], i))

    async def submit(self, req: Request, *,
                     arrival_step: int | None = None) -> RouterStream:
        """Admit one request to the fleet, or raise ``ShedError`` /
        ``ValueError`` exactly like ``AsyncEngineDriver.submit``."""
        if self._draining:
            raise ShedError("draining", retry_after_s=1.0)
        self._ensure_drivers()
        self.engines[0].sched.validate(req)   # replicas are identical
        decision = self.admission.decide(self.queue_depth)
        if not decision.admit:
            self.admission.note_shed()
            raise ShedError(decision.reason, decision.retry_after_s,
                            decision.projected_ttft_s)
        self.admission.note_submitted(self.queue_depth)
        self._fleet_queued.add(req.rid)
        stream = RouterStream(req)
        if self.disaggregate:
            task = asyncio.ensure_future(
                self._run_disagg(req, stream, arrival_step))
        else:
            task = asyncio.ensure_future(
                self._run_colocated(req, stream, arrival_step))
        self._tasks[req.rid] = task
        task.add_done_callback(
            lambda _t, rid=req.rid: self._tasks.pop(rid, None))
        # yield once so the forwarding task reaches its inner submit now:
        # routing and driver handoff stay in submission order (FCFS)
        await asyncio.sleep(0)
        return stream

    def abort(self, rid: int) -> None:
        """Cancel an in-flight request fleet-wide (no-op for unknown or
        retired rids). Disaggregated requests between phases skip their
        decode phase; mid-phase ones abort on their current replica."""
        if rid in self._tasks and rid not in self._aborted:
            self.aborted += 1
        self._aborted.add(rid)
        i = self._assigned.get(rid)
        if i is not None and self.drivers is not None:
            self.drivers[i].abort(rid)

    def _note_first_token(self, stream: RouterStream) -> None:
        if stream.first_token_wall is None:
            stream.first_token_wall = time.monotonic()
            self.admission.note_ttft(
                stream.first_token_wall - stream.submit_wall)

    # -- forwarding tasks ----------------------------------------------------

    async def _run_colocated(self, req, stream, arrival_step) -> None:
        i = self._pick(list(range(self.dp)))
        cost = len(req.prompt) + req.max_new
        self._outstanding[i] += cost
        self.routed[i] += 1
        self._assigned[req.rid] = i
        try:
            inner = await self.drivers[i].submit(
                req, arrival_step=arrival_step)
            async for ev in inner:
                self._note_first_token(stream)
                stream._put(ev)
            stream._close()
        except BaseException as e:            # noqa: BLE001 — stream carries it
            stream._close(e)
        finally:
            self._outstanding[i] -= cost
            self._assigned.pop(req.rid, None)
            self._aborted.discard(req.rid)
            self.admission.note_completed()

    async def _run_disagg(self, req, stream, arrival_step) -> None:
        try:
            p1 = _phase1(req)
            i = self._pick(self._prefill_ids)
            cost1 = len(p1.prompt) + 1
            self._outstanding[i] += cost1
            self.routed[i] += 1
            self._assigned[req.rid] = i
            first = None
            try:
                inner = await self.drivers[i].submit(
                    p1, arrival_step=arrival_step)
                async for ev in inner:
                    first = ev
                    self._note_first_token(stream)
                    stream._put(ev)
            finally:
                self._outstanding[i] -= cost1
            if first is None or req.rid in self._aborted:
                stream._close()               # aborted during the probe
                return
            cont = _phase2(req, first.token, p1.stop_hit)
            if cont.done:                     # eos / stop / max_new == 1
                stream._close()
                return
            j = self._pick(self._decode_ids)
            cost2 = len(req.prompt) + req.max_new
            self._outstanding[j] += cost2
            self._assigned[req.rid] = j
            self.handoffs += 1
            try:
                # the probe's stream closed => its publish barrier ran:
                # every full prompt block is committed to the shared
                # index, so this admission adopts them and starts
                # decode-ready (no prefill recompute on the decode side)
                inner2 = await self.drivers[j].submit(cont)
                async for ev in inner2:
                    stream._put(TokenEvent(ev.index + 1, ev.token,
                                           ev.text, ev.logprobs))
            finally:
                self._outstanding[j] -= cost2
            stream._close()
        except BaseException as e:            # noqa: BLE001 — stream carries it
            stream._close(e)
        finally:
            self._assigned.pop(req.rid, None)
            self._aborted.discard(req.rid)
            self.admission.note_completed()

    # -- batch driver (the harness / bench shape) ----------------------------

    def run(self, requests: list[Request],
            arrival_steps: list[int] | None = None) -> dict[int, np.ndarray]:
        """Serve ``requests`` to completion through the fleet, mirroring
        ``engine.run()``: all submissions land before the step loops
        start (deterministic placement), ``arrival_steps`` schedules each
        request on its replica's virtual clock. Returns {rid: tokens}."""
        return asyncio.run(self._run_batch(requests, arrival_steps))

    async def _run_batch(self, requests, arrival_steps):
        if arrival_steps is None:
            arrival_steps = [0] * len(requests)
        self._ensure_drivers()
        streams = [await self.submit(r, arrival_step=t)
                   for r, t in zip(requests, arrival_steps)]
        await self.start()

        async def pull(s):
            return [ev.token async for ev in s]

        outs = await asyncio.gather(*(pull(s) for s in streams))
        await self.aclose()
        return {r.rid: np.asarray(toks, np.int32)
                for r, toks in zip(requests, outs)}
