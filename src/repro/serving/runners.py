"""Per-family model runners for the serving engine.

A :class:`ModelRunner` owns everything family-specific about serving one
model: which cache kinds it needs (paged KV blocks / per-slot SSM state /
read-only encoder state), how to build the zero device cache, and the
jitted budgeted step — one prefill chunk plus the wide decode batch plus
per-slot sampling. ``InferenceEngine`` and the ``Scheduler`` see only the
runner's declared cache needs and its step/encode callables, so admitting
a Mamba request and a transformer request is the same control flow.

Runners:

* :class:`TransformerRunner` — decoder-only attention models (paged KV).
* :class:`SSMRunner` — pure Mamba2 (slot state only; no block horizon).
* :class:`HybridRunner` — zamba2's interleaved mamba + shared attention
  (slot state for the mamba stacks, paged KV for the attention stacks,
  one block table spanning the attention layers).
* :class:`EncDecRunner` — whisper (paged decoder self-KV + per-slot
  read-only cross K/V written by an encode pass at admission).
* :class:`SpeculativeRunner` — draft-and-verify speculative decoding
  over two TransformerRunners (one shared block table indexing a target
  and a draft page-pool set; greedy byte-identical to plain decode).

The step functions are shape-stable: decode always runs ``max_batch``
wide (idle slots masked; their KV writes land in the trash block, their
slot-state rows are reverted after the step), the chunk always runs at
``chunk_width``. Sampling row B is the chunk's last-token logits.

Invariants every runner upholds (the engine equivalence tests pin them):

* an idle decode slot never corrupts state — paged writes land in the
  trash block, slot-state rows are reverted via the ``d_active`` mask;
* a chunk that starts a (re)computed sequence reads zeroed slot state,
  never a previous occupant's;
* token KV/state is identical whether produced by monolithic prefill, a
  chunk, or a decode step (the shared rounding convention — see
  docs/kernels.md), which is what makes chunked prefill, preemption-
  recompute, prefix-cache adoption and greedy speculative decode all
  byte-identical to the plain path;
* runners are mesh-oblivious: tensor parallelism enters only through the
  engine's cache/param placement and the shard_map'd paged-attention
  core (docs/multi-host.md), so a runner's step is byte-identical on
  every mesh shape — the TP equivalence suite pins this per family.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ParallelConfig
from repro.models import encdec, transformer
from repro.serving.cache import init_encoder_cache, init_slot_state
from repro.serving.kv_cache import (init_paged_cache, attn_layer_stacks,
                                    mamba_layer_stacks)
from repro.serving.sampling import (SP_KEYS, propose_tokens,
                                    propose_tokens_full, sample_tokens,
                                    sample_tokens_full, speculative_verify,
                                    speculative_verify_full)

__all__ = ["ModelRunner", "TransformerRunner", "SSMRunner", "HybridRunner",
           "EncDecRunner", "SpeculativeRunner", "make_runner"]


def _slice_slot(tree, slot):
    """Gather one slot row (axis 1 after the layer-stack dim) -> width 1."""
    return jax.tree.map(
        lambda t: jax.lax.dynamic_slice_in_dim(t, slot, 1, axis=1), tree)


def _scatter_slot(full, row, slot):
    """Write a width-1 slot row back (inverse of ``_slice_slot``)."""
    return jax.tree.map(
        lambda f, r: jax.lax.dynamic_update_slice_in_dim(
            f, r.astype(f.dtype), slot, axis=1), full, row)


def _mask_slot_rows(new, old, active):
    """Keep updated state only for active decode slots; idle slots must
    not have their state corrupted by the masked wide-batch compute."""
    def leaf(n, o):
        m = active.reshape((1, -1) + (1,) * (n.ndim - 2))
        return jnp.where(m, n, o)
    return jax.tree.map(leaf, new, old)


class ModelRunner:
    """Family-agnostic interface the engine/scheduler program against."""

    needs_blocks: bool = False        # paged KV pools + block tables
    needs_slots: bool = False         # constant-size per-slot SSM state
    needs_encoder: bool = False       # read-only per-slot cross K/V
    supports_prefix_caching: bool = False
    # can consume multi-chunk (ragged packed-prefill) plans: several
    # prompts' chunks ride one flat token batch per step. SSM/enc-dec
    # runners stay single-chunk (recurrent state and cross-KV slot rows
    # are sliced per chunk sequence, which the flat layout doesn't carry).
    supports_packed_prefill: bool = False
    chunk_quantum: int = 1            # chunk lengths must be multiples
                                      # (except a prompt's final chunk)
    spec_tokens: int = 0              # draft tokens per slot per step
                                      # (speculative decoding lookahead)
    max_logprobs: int = 8             # top-L logprob rows the full path
                                      # returns (engine knob, set at init)

    def __init__(self, cfg: ModelConfig, pcfg: ParallelConfig):
        self.cfg, self.pcfg = cfg, pcfg

    def init_cache(self, num_blocks: int, block_size: int, max_batch: int,
                   kv_dtype: str = "bf16"):
        raise NotImplementedError

    def step(self, params, cache, a, *, has_chunk: bool,
             full_sampling: bool = False):
        """One budgeted step. ``a`` is the engine's array dict (chunk row,
        decode batch, sampling params). Returns (sampled (B+1,), cache);
        with ``full_sampling`` the sampled half is ``(tokens, logprobs)``
        from the full pipeline. Like ``has_chunk``, ``full_sampling`` is
        a *static* jit flag: pure-greedy traffic only ever compiles the
        plain executables and never traces the penalty/top-p/logprob
        work."""
        raise NotImplementedError

    def encode(self, params, cache, slot, frames):
        """Admission-time encode pass (enc-dec only)."""
        raise NotImplementedError

    # -- shared step halves ------------------------------------------------

    def _sample(self, logits_d, logits_c, a, has_chunk,
                full_sampling=False):
        if not has_chunk:
            # sampling rows B.. are sized for the engine's prefill_pack
            # (1 for classic single-chunk, S for the ragged packed path)
            n_extra = a["temps"].shape[0] - logits_d.shape[0]
            logits_c = jnp.zeros((n_extra,) + logits_d.shape[1:],
                                 logits_d.dtype)
        logits = jnp.concatenate([logits_d, logits_c], axis=0)
        if full_sampling:
            return sample_tokens_full(logits, {k: a[k] for k in SP_KEYS},
                                      max_logprobs=self.max_logprobs)
        return sample_tokens(logits, a["temps"], a["top_ks"], a["seeds"],
                             a["rids"], a["counters"])

    @staticmethod
    def _chunk_batch(a):
        return {"tokens": a["c_tok"], "q_start": a["c_start"],
                "q_lens": a["c_len"], "block_tables": a["c_table"],
                "ctx_lens": a["c_start"] + a["c_len"]}

    @staticmethod
    def _ragged_batch(a):
        """Packed multi-chunk prefill batch (``prefill_pack > 1``): one
        flat (1, C) token row carrying several sequences' chunks, each
        owning flat positions [starts[s], ends[s])."""
        return {"tokens": a["c_tok"], "positions": a["c_pos"],
                "starts": a["c_starts"], "ends": a["c_ends"],
                "row_seq": a["c_seq"], "block_tables": a["c_tables"],
                "ctx_lens": a["c_ctx"]}

    @staticmethod
    def _decode_batch(a):
        ctx_lens = jnp.where(a["d_active"], a["d_pos"] + 1, 0)
        return {"token": a["d_tok"][:, None], "pos": a["d_pos"],
                "block_tables": a["d_tables"], "ctx_lens": ctx_lens}


class TransformerRunner(ModelRunner):
    """Decoder-only attention families: everything is paged KV, prefix
    caching applies (KV depends only on the token prefix)."""

    needs_blocks = True
    supports_prefix_caching = True
    supports_packed_prefill = True

    def step(self, params, cache, a, *, has_chunk, full_sampling=False):
        if has_chunk:
            if "c_starts" in a:
                logits_c, cache = transformer.prefill_chunk_ragged(
                    params, cache, self._ragged_batch(a), self.cfg,
                    self.pcfg)
            else:
                logits_c, cache = transformer.prefill_chunk_paged(
                    params, cache, self._chunk_batch(a), self.cfg,
                    self.pcfg)
        else:
            logits_c = None
        logits_d, cache = transformer.decode_step_paged(
            params, cache, self._decode_batch(a), self.cfg, self.pcfg)
        return self._sample(logits_d, logits_c, a, has_chunk,
                            full_sampling), cache

    def init_cache(self, num_blocks, block_size, max_batch,
                   kv_dtype="bf16"):
        return init_paged_cache(self.cfg, num_blocks, block_size,
                                kv_dtype=kv_dtype)


class SSMRunner(ModelRunner):
    """Pure Mamba2: constant-size slot state, no blocks, no horizon.
    Prefix caching is off — a cached block id cannot stand in for the
    recurrent state that produced it."""

    needs_slots = True

    def __init__(self, cfg, pcfg):
        super().__init__(cfg, pcfg)
        self._state_keys = tuple(mamba_layer_stacks(cfg))
        # serving chunk boundaries must land on SSD inner-chunk boundaries
        # so chunked prefill is bit-identical to a monolithic one
        self.chunk_quantum = cfg.ssm.chunk_size
        self.needs_blocks = bool(attn_layer_stacks(cfg))

    def init_cache(self, num_blocks, block_size, max_batch,
                   kv_dtype="bf16"):
        if kv_dtype != "bf16":
            raise ValueError(
                f"kv_dtype={kv_dtype}: SSM/hybrid runners keep bf16 pools "
                "(slot state has no quantized form)")
        cache = (init_paged_cache(self.cfg, num_blocks, block_size)
                 if self.needs_blocks else {})
        cache.update(init_slot_state(self.cfg, max_batch))
        return cache

    def step(self, params, cache, a, *, has_chunk, full_sampling=False):
        logits_c = None
        if has_chunk:
            slot = a["c_slot"][0]
            fresh = a["c_start"][0] == 0
            chunk_cache = {}
            for key, val in cache.items():
                if key in self._state_keys:
                    st = _slice_slot(val, slot)
                    # first chunk after (re)admission starts from zeros —
                    # never from a previous occupant's state
                    st = jax.tree.map(
                        lambda t: jnp.where(fresh, jnp.zeros_like(t), t),
                        st)
                    chunk_cache[key] = st
                else:
                    chunk_cache[key] = val
            logits_c, out = transformer.prefill_chunk_paged(
                params, chunk_cache, self._chunk_batch(a), self.cfg,
                self.pcfg)
            cache = {key: (_scatter_slot(cache[key], out[key], slot)
                           if key in self._state_keys else out[key])
                     for key in cache}
        old_state = {key: cache[key] for key in self._state_keys}
        logits_d, cache = transformer.decode_step_paged(
            params, cache, self._decode_batch(a), self.cfg, self.pcfg)
        for key in self._state_keys:
            cache[key] = _mask_slot_rows(cache[key], old_state[key],
                                         a["d_active"])
        return self._sample(logits_d, logits_c, a, has_chunk,
                            full_sampling), cache


class HybridRunner(SSMRunner):
    """zamba2: mamba stacks carry slot state, the shared attention block
    reads/writes paged KV through one block table per sequence."""


class EncDecRunner(ModelRunner):
    """whisper: paged decoder self-KV + read-only per-slot cross K/V
    (written once by ``encode`` at admission). Prefix caching is off —
    decoder KV depends on the request's encoder output, so equal token
    prefixes do *not* imply equal KV."""

    needs_blocks = True
    needs_encoder = True

    def init_cache(self, num_blocks, block_size, max_batch,
                   kv_dtype="bf16"):
        if kv_dtype != "bf16":
            raise ValueError(
                f"kv_dtype={kv_dtype}: the enc-dec runner keeps bf16 pools "
                "(cross K/V is per-slot, not paged)")
        cfg = self.cfg
        shape = (cfg.num_layers, num_blocks, block_size,
                 cfg.num_kv_heads, cfg.head_dim)
        return {"self": {"k": jnp.zeros(shape, jnp.bfloat16),
                         "v": jnp.zeros(shape, jnp.bfloat16)},
                "cross": init_encoder_cache(cfg, max_batch)}

    def encode(self, params, cache, slot, frames):
        kv = encdec.encode_cross_kv(params, frames[None], self.cfg,
                                    self.pcfg)
        return {"self": cache["self"],
                "cross": _scatter_slot(cache["cross"], kv, slot)}

    def step(self, params, cache, a, *, has_chunk, full_sampling=False):
        logits_c = None
        if has_chunk:
            cross_row = _slice_slot(cache["cross"], a["c_slot"][0])
            logits_c, out = encdec.prefill_chunk_paged(
                params, {"self": cache["self"], "cross": cross_row},
                self._chunk_batch(a), self.cfg, self.pcfg)
            cache = {"self": out["self"], "cross": cache["cross"]}
        logits_d, out = encdec.decode_step_paged(
            params, cache, self._decode_batch(a), self.cfg, self.pcfg)
        cache = {"self": out["self"], "cross": cache["cross"]}
        return self._sample(logits_d, logits_c, a, has_chunk,
                            full_sampling), cache


class SpeculativeRunner(ModelRunner):
    """Draft-and-verify speculative decoding over two TransformerRunners.

    A small *draft* model proposes ``spec_tokens`` (= k) tokens per slot
    per step; the *target* model scores all k+1 candidate positions in one
    widened chunk pass (``prefill_chunk_paged`` with ``all_logits=True``,
    i.e. ``paged_chunk_attention`` with k+1 query rows per slot); the
    longest agreeing prefix is accepted by rejection sampling that
    preserves the target distribution (``sampling.speculative_verify``) —
    greedy outputs stay byte-identical to non-speculative decode.

    Cache design: draft and target KV always cover *the same token
    positions* (the draft writes every token it is fed, the verify pass
    writes the same k+1 positions in the target pools, chunk prefill runs
    through both models), so both live in one pytree
    ``{"tgt": ..., "dft": ...}`` indexed by **one shared block table per
    request** — a single :class:`~repro.serving.kv_cache.BlockManager`
    covers both models, and prefix caching, COW page copies and
    preemption-recompute apply to the pair at once (a cached block's
    content hash vouches for the draft KV exactly as it does for the
    target's, since both are pure functions of the token prefix).

    Per step and slot the draft runs k+1 single-token decodes (the last
    one writes KV for the final proposal so the draft cache never trails
    the accepted stream), the target runs one k+1-wide verify row, and the
    host rolls rejected lookahead blocks back via ``BlockManager.truncate``.
    ``params`` is the pair ``{"tgt": target_params, "dft": draft_params}``.
    """

    needs_blocks = True
    supports_prefix_caching = True
    supports_packed_prefill = True

    def __init__(self, cfg: ModelConfig, pcfg: ParallelConfig,
                 draft_cfg: ModelConfig, spec_tokens: int):
        super().__init__(cfg, pcfg)
        if spec_tokens < 0:
            raise ValueError(f"spec_tokens={spec_tokens} must be >= 0")
        if draft_cfg.vocab_size != cfg.vocab_size:
            raise ValueError(
                f"draft vocab {draft_cfg.vocab_size} != target vocab "
                f"{cfg.vocab_size}: draft proposals must be target ids")
        self.draft_cfg = draft_cfg
        self.spec_tokens = spec_tokens

    def init_cache(self, num_blocks, block_size, max_batch,
                   kv_dtype="bf16"):
        return {"tgt": init_paged_cache(self.cfg, num_blocks, block_size,
                                        kv_dtype=kv_dtype),
                "dft": init_paged_cache(self.draft_cfg, num_blocks,
                                        block_size, kv_dtype=kv_dtype)}

    def step(self, params, cache, a, *, has_chunk, full_sampling=False):
        k = self.spec_tokens
        B = a["d_tok"].shape[0]
        tgt, dft = cache["tgt"], cache["dft"]
        logits_c = None
        if has_chunk:
            if "c_starts" in a:
                # packed ragged chunks run through both models (draft KV
                # must mirror the target's positions exactly)
                rb = self._ragged_batch(a)
                logits_c, tgt = transformer.prefill_chunk_ragged(
                    params["tgt"], tgt, rb, self.cfg, self.pcfg)
                _, dft = transformer.prefill_chunk_ragged(
                    params["dft"], dft, rb, self.draft_cfg, self.pcfg)
            else:
                cb = self._chunk_batch(a)
                logits_c, tgt = transformer.prefill_chunk_paged(
                    params["tgt"], tgt, cb, self.cfg, self.pcfg)
                _, dft = transformer.prefill_chunk_paged(
                    params["dft"], dft, cb, self.draft_cfg, self.pcfg)
        temps, top_ks = a["temps"][:B], a["top_ks"][:B]
        seeds, rids, cnts = a["seeds"][:B], a["rids"][:B], a["counters"][:B]
        sp_d = ({key: a[key][:B] for key in SP_KEYS} if full_sampling
                else None)
        # committed counts, incremented with each proposal's one-hot so
        # proposal i and verify row i share identical penalty counts
        oc = a["ocounts"][:B] if full_sampling else None
        # -- draft phase: k proposals, k+1 KV writes (the last write backs
        # the final proposal so the draft cache mirrors the target's) ----
        toks = [a["d_tok"]]
        dlogits = []
        if k > 0:
            for i in range(k + 1):
                db = {"token": toks[-1][:, None], "pos": a["d_pos"] + i,
                      "block_tables": a["d_tables"],
                      "ctx_lens": jnp.where(a["d_active"],
                                            a["d_pos"] + i + 1, 0)}
                lg, dft = transformer.decode_step_paged(
                    params["dft"], dft, db, self.draft_cfg, self.pcfg)
                if i < k:
                    dlogits.append(lg)
                    if full_sampling:
                        nt = propose_tokens_full(
                            lg, dict(sp_d, ocounts=oc, counters=cnts + i))
                        oc = oc + jax.nn.one_hot(nt, lg.shape[-1],
                                                 dtype=oc.dtype)
                    else:
                        nt = propose_tokens(lg, temps, top_ks, seeds,
                                            rids, cnts + i)
                    toks.append(nt)
        # -- verify phase: one widened target pass over all k+1 positions
        verify_tokens = jnp.stack(toks, axis=1)                  # (B, k+1)
        vb = {"tokens": verify_tokens, "q_start": a["d_pos"],
              "q_lens": jnp.where(a["d_active"], k + 1, 0),
              "block_tables": a["d_tables"],
              "ctx_lens": jnp.where(a["d_active"], a["d_pos"] + k + 1, 0)}
        tlogits, tgt = transformer.prefill_chunk_paged(
            params["tgt"], tgt, vb, self.cfg, self.pcfg, all_logits=True)
        draft_logits = (jnp.stack(dlogits, axis=1) if dlogits else
                        jnp.zeros((B, 0, tlogits.shape[-1]),
                                  tlogits.dtype))
        if full_sampling:
            out_toks, n_acc, lp_d = speculative_verify_full(
                verify_tokens[:, 1:], draft_logits, tlogits, sp_d,
                max_logprobs=self.max_logprobs)
        else:
            out_toks, n_acc = speculative_verify(
                verify_tokens[:, 1:], draft_logits, tlogits,
                temps, top_ks, seeds, rids, cnts)
        if has_chunk:
            if full_sampling:
                c_tok, lp_c = sample_tokens_full(
                    logits_c, {key: a[key][B:] for key in SP_KEYS},
                    max_logprobs=self.max_logprobs)
            else:
                c_tok = sample_tokens(logits_c, a["temps"][B:],
                                      a["top_ks"][B:], a["seeds"][B:],
                                      a["rids"][B:], a["counters"][B:])
        else:
            c_tok = jnp.zeros((1,), jnp.int32)
            if full_sampling:
                S = a["temps"].shape[0] - B
                L = min(self.max_logprobs, tlogits.shape[-1])
                lp_c = {"chosen": jnp.zeros((S,), tlogits.dtype),
                        "top_lp": jnp.zeros((S, L), tlogits.dtype),
                        "top_ids": jnp.zeros((S, L), jnp.int32)}
        if full_sampling:
            return ((out_toks, n_acc, c_tok, lp_d, lp_c),
                    {"tgt": tgt, "dft": dft})
        return (out_toks, n_acc, c_tok), {"tgt": tgt, "dft": dft}


def make_runner(cfg: ModelConfig, pcfg: ParallelConfig, *,
                draft_cfg: ModelConfig | None = None,
                num_speculative_tokens: int = 0) -> ModelRunner:
    """Family dispatch. Raises for configs no runner covers yet.

    With ``draft_cfg`` set, wraps target and draft in a
    :class:`SpeculativeRunner` — both must resolve to the plain paged
    transformer family (slot-state kinds have no fork/rewind story for
    recurrent state yet; see ROADMAP)."""
    if cfg.frontend == "vision":
        raise ValueError(
            f"no serving runner for {cfg.name}: modality frontends need "
            "per-request position streams")
    if draft_cfg is not None:
        base = make_runner(cfg, pcfg)
        draft = make_runner(draft_cfg, pcfg)
        if type(base) is not TransformerRunner \
                or type(draft) is not TransformerRunner:
            raise ValueError(
                "speculative decoding needs paged-transformer target and "
                f"draft, got {type(base).__name__} target / "
                f"{type(draft).__name__} draft")
        return SpeculativeRunner(cfg, pcfg, draft_cfg,
                                 num_speculative_tokens)
    if cfg.encoder_layers:
        return EncDecRunner(cfg, pcfg)
    if cfg.ssm is not None:
        if cfg.shared_attn_period or any(
                k != "mamba" for k in cfg.block_pattern):
            return HybridRunner(cfg, pcfg)
        return SSMRunner(cfg, pcfg)
    return TransformerRunner(cfg, pcfg)
