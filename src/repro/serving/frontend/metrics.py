"""Prometheus text-format rendering of the live serving metrics.

One function, :func:`render_metrics`, snapshots the engine's counters,
the derived rates (cache-hit rate, preemption rate, mean accept length —
the *same accessors* the bench and serve.py print, so every surface
reports identical numbers), the retirement-time TTFT/e2e histograms, and
— when a driver is attached — the front-end queue/shed/drain state. The
output is the Prometheus text exposition format v0.0.4 (`# HELP` /
`# TYPE` comments, cumulative `_bucket{le=...}` histogram lines), which
is what ``GET /metrics`` serves.

Metric catalog: docs/serving-frontend.md.
"""

from __future__ import annotations

from repro.serving.stats import Histogram

__all__ = ["render_metrics", "render_router_metrics", "render_metrics_for",
           "CONTENT_TYPE"]

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

# engine.stats key -> (metric name, help text); all monotone counters
_ENGINE_COUNTERS = (
    ("tokens", "repro_engine_tokens_total",
     "Generated tokens appended across all requests"),
    ("steps", "repro_engine_steps_total",
     "Jitted budgeted engine steps executed"),
    ("prefill_chunks", "repro_engine_prefill_chunks_total",
     "Prefill chunks executed"),
    ("prefill_tokens", "repro_engine_prefill_tokens_total",
     "Prompt tokens whose KV was computed (prefix-cache misses)"),
    ("quantum_dropped_tokens", "repro_engine_quantum_dropped_tokens_total",
     "Prefill budget tokens lost to chunk-quantum rounding on a step's "
     "final chunk"),
    ("cache_hit_tokens", "repro_engine_cache_hit_tokens_total",
     "Prompt tokens whose KV was adopted from the prefix cache"),
    ("preemptions", "repro_engine_preemptions_total",
     "Recompute preemptions (victim returned to the waiting queue)"),
    ("cow_copies", "repro_engine_cow_copies_total",
     "Copy-on-write block copies performed"),
    ("encodes", "repro_engine_encodes_total",
     "Admission-time encoder passes (enc-dec runners)"),
    ("requests", "repro_engine_requests_total",
     "Requests that arrived at the engine"),
    ("requests_done", "repro_engine_requests_done_total",
     "Requests retired (EOS or max_new)"),
    ("spec_decodes", "repro_engine_spec_decodes_total",
     "Speculative decode slot-steps (draft-and-verify)"),
    ("spec_emitted", "repro_engine_spec_emitted_total",
     "Tokens emitted by speculative verify steps"),
    ("stop_hits", "repro_engine_stop_hits_total",
     "Requests retired by a per-request stop sequence match"),
    ("full_sampling_steps", "repro_engine_full_sampling_steps_total",
     "Engine steps that ran the full sampling pipeline (top-p/min-p/"
     "penalties/logprobs); pure-greedy steps stay on the plain "
     "executables"),
    ("aborts", "repro_engine_aborts_total",
     "Requests cancelled before retirement (client disconnect / abort)"),
    ("swap_preemptions", "repro_engine_swap_preemptions_total",
     "Preemptions resolved by swapping KV to the host tier instead of "
     "recompute"),
    ("swap_ins", "repro_engine_swap_ins_total",
     "Swapped-out requests re-admitted from the host tier"),
    ("host_hit_blocks", "repro_engine_host_hit_blocks_total",
     "Prefix-cache hits served by copying host-resident blocks back"),
    ("swapped_out_blocks", "repro_engine_swapped_out_blocks_total",
     "KV blocks copied device-to-host by swap preemptions"),
    ("swapped_in_blocks", "repro_engine_swapped_in_blocks_total",
     "KV blocks copied host-to-device by swap-ins and host prefix hits"),
    ("swapped_out_bytes", "repro_engine_swapped_out_bytes_total",
     "Bytes moved device-to-host by swap preemptions"),
    ("swapped_in_bytes", "repro_engine_swapped_in_bytes_total",
     "Bytes moved host-to-device by swap-ins and host prefix hits"),
    ("shared_hit_blocks", "repro_engine_shared_hit_blocks_total",
     "Prefix-cache hits adopted from the cross-replica shared index"),
    ("shared_published_blocks", "repro_engine_shared_published_blocks_total",
     "Hashed KV blocks this replica published into the shared index"),
)

_HISTOGRAMS = (
    ("ttft_seconds", "repro_engine_ttft_seconds",
     "Time to first token, wall seconds (arrival to first sampled token)"),
    ("e2e_seconds", "repro_engine_e2e_seconds",
     "End-to-end request latency, wall seconds (arrival to retirement)"),
    ("ttft_steps", "repro_engine_ttft_steps",
     "Time to first token in engine steps (deterministic virtual clock)"),
    ("e2e_steps", "repro_engine_e2e_steps",
     "End-to-end request latency in engine steps"),
)


def _scalar(out: list[str], name: str, kind: str, help_: str, value):
    out.append(f"# HELP {name} {help_}")
    out.append(f"# TYPE {name} {kind}")
    out.append(f"{name} {format(float(value), 'g')}")


def render_metrics(engine, driver=None) -> str:
    """Render the serving metrics snapshot; ``driver`` (an
    ``AsyncEngineDriver``) adds the front-end queue/admission section."""
    out: list[str] = []
    s = engine.stats
    for key, name, help_ in _ENGINE_COUNTERS:
        _scalar(out, name, "counter", help_, s[key])
    _scalar(out, "repro_engine_cache_hit_rate", "gauge",
            "Fraction of prefill KV served from the prefix cache",
            engine.cache_hit_rate)
    _scalar(out, "repro_engine_preemption_rate", "gauge",
            "Preemptions per arrived request", engine.preemption_rate)
    _scalar(out, "repro_engine_mean_accept_len", "gauge",
            "Mean realized tokens per speculative decode slot-step",
            engine.mean_accept_len)
    _scalar(out, "repro_engine_peak_block_utilization", "gauge",
            "Peak fraction of the KV block pool in use",
            s["peak_block_utilization"])
    _scalar(out, "repro_engine_peak_blocks_in_use", "gauge",
            "Peak KV blocks in use", s["peak_blocks_in_use"])
    _scalar(out, "repro_engine_kv_cache_mib", "gauge",
            "Device cache footprint, MiB", s["kv_cache_mib"])
    _scalar(out, "repro_engine_swap_space_mib", "gauge",
            "Pinned host-swap tier capacity, MiB (0 = swap off)",
            s["swap_space_mib"])
    out.append("# HELP repro_engine_kv_dtype Serving KV-cache storage "
               "dtype, as a one-hot label")
    out.append("# TYPE repro_engine_kv_dtype gauge")
    out.append(f'repro_engine_kv_dtype{{kv_dtype="{s["kv_dtype"]}"}} 1')
    _scalar(out, "repro_engine_running", "gauge",
            "Requests currently occupying a batch slot",
            len(engine.sched.running))
    _scalar(out, "repro_engine_waiting", "gauge",
            "Requests in the scheduler's waiting queue",
            len(engine.sched.waiting))
    for key, name, help_ in _HISTOGRAMS:
        engine.hist[key].render(name, help_, out)
    if driver is not None:
        _render_frontend(out, driver)
    return "\n".join(out) + "\n"


def _render_frontend(out: list[str], driver) -> None:
    """The front-end queue/admission section — shared between the single-
    engine and router renderers (both expose the same driver surface)."""
    adm = driver.admission
    _scalar(out, "repro_frontend_queue_depth", "gauge",
            "Requests admitted by the front-end but not yet running",
            driver.queue_depth)
    _scalar(out, "repro_frontend_queue_peak", "gauge",
            "Peak front-end queue depth", adm.queue_peak)
    _scalar(out, "repro_frontend_requests_submitted_total", "counter",
            "Requests accepted into the front-end queue", adm.submitted)
    _scalar(out, "repro_frontend_requests_shed_total", "counter",
            "Requests shed by admission control (HTTP 429)", adm.shed)
    _scalar(out, "repro_frontend_requests_completed_total", "counter",
            "Front-end requests whose streams closed cleanly",
            adm.completed)
    _scalar(out, "repro_frontend_dropped_streams_total", "counter",
            "SSE streams whose client disconnected mid-stream "
            "(the request is then aborted)",
            driver.dropped_streams)
    _scalar(out, "repro_frontend_aborted_requests_total", "counter",
            "Requests cancelled before retirement via the driver's "
            "abort path", driver.aborted)
    _scalar(out, "repro_frontend_draining", "gauge",
            "1 while draining (no new admissions), else 0",
            1.0 if driver.draining else 0.0)


def render_router_metrics(router) -> str:
    """Render the fleet-wide snapshot for a ``ReplicaRouter``.

    Every engine counter family gets one unlabeled fleet-sum series plus
    per-replica ``{replica="i"}`` series; TTFT/e2e histograms are merged
    with :meth:`Histogram.merge` (merge == histogram of the concatenated
    samples, so fleet percentiles are exact) and also emitted per replica
    under the same family. Router-level series cover routing, the
    disaggregated handoff count, and the shared prefix index.
    """
    out: list[str] = []
    engines = router.engines
    for key, name, help_ in _ENGINE_COUNTERS:
        vals = [e.stats[key] for e in engines]
        _scalar(out, name, "counter", help_, sum(vals))
        for i, v in enumerate(vals):
            out.append(f'{name}{{replica="{i}"}} {format(float(v), "g")}')
    _scalar(out, "repro_engine_running", "gauge",
            "Requests currently occupying a batch slot (fleet total)",
            sum(len(e.sched.running) for e in engines))
    _scalar(out, "repro_engine_waiting", "gauge",
            "Requests in the schedulers' waiting queues (fleet total)",
            sum(len(e.sched.waiting) for e in engines))
    for key, name, help_ in _HISTOGRAMS:
        merged = Histogram(engines[0].hist[key].uppers)
        for e in engines:
            merged.merge(e.hist[key])
        merged.render(name, help_, out)
        for i, e in enumerate(engines):
            e.hist[key].render(name, help_, out,
                               labels={"replica": str(i)}, header=False)
    _scalar(out, "repro_router_replicas", "gauge",
            "Data-parallel engine replicas behind the router", router.dp)
    out.append("# HELP repro_router_routed_total Requests routed to each "
               "replica (least-outstanding-tokens, FCFS tiebreak)")
    out.append("# TYPE repro_router_routed_total counter")
    for i, n in enumerate(router.routed):
        out.append(f'repro_router_routed_total{{replica="{i}"}} '
                   f'{format(float(n), "g")}')
    _scalar(out, "repro_router_handoffs_total", "counter",
            "Disaggregated prefill->decode handoffs (phase-2 "
            "continuations submitted to a decode replica)",
            router.handoffs)
    shared = router.shared_stats()
    if shared:
        _scalar(out, "repro_shared_index_slots", "gauge",
                "Host-pool slots in the shared prefix index",
                shared["slots"])
        _scalar(out, "repro_shared_index_committed", "gauge",
                "Slots currently holding a committed published block",
                shared["committed"])
        _scalar(out, "repro_shared_index_published_total", "counter",
                "Blocks published into the shared index (fleet-wide)",
                shared["published_blocks"])
        _scalar(out, "repro_shared_index_adopted_total", "counter",
                "Block adoptions served by the shared index (fleet-wide)",
                shared["adopted_blocks"])
        _scalar(out, "repro_shared_index_evicted_total", "counter",
                "Committed blocks evicted (LRU) to make room for new "
                "publishes", shared["evicted_blocks"])
    _render_frontend(out, router)
    return "\n".join(out) + "\n"


def render_metrics_for(driver) -> str:
    """Dispatch on the front-end's engine surface: a ``ReplicaRouter``
    (has ``.engines``) renders the fleet view, an ``AsyncEngineDriver``
    the single-engine view."""
    if hasattr(driver, "engines"):
        return render_router_metrics(driver)
    return render_metrics(driver.engine, driver)
