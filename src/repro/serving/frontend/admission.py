"""SLO-aware admission control for the streaming front-end.

The controller answers one question per arriving request: *if we queue
this now, will its time-to-first-token blow the SLO?* — and sheds (HTTP
429 with a retry signal) instead of letting the queue build unbounded
latency. Estimation is deliberately simple and fully observable:

* a rolling window of realized TTFT samples (seconds from ``submit`` to
  the first streamed token, fed by the driver) gives the *current* p95;
* the rolling mean interval between admissions (waiting -> running
  transitions, fed from the scheduler's ``on_admit`` hook) gives the
  queue drain rate;
* a new request behind ``queue_depth`` others projects to

      projected_ttft_p95 = p95(ttft window)
                           + queue_depth * admit_interval / n_replicas

  — every queued request ahead delays the newcomer's prefill start by
  roughly one admission interval, divided by the number of data-parallel
  replicas draining the shared queue. When ``projected > ttft_slo_p95_s``
  the request is shed with ``retry_after_s ~= projected - target``.

A bounded queue (``max_queue``) backstops the estimator: past that depth
requests are shed regardless of the SLO projection (cold-start windows
are empty, and an estimator must never be the only thing between the
server and an unbounded queue).

Unit-agnostic and dependency-free: samples and targets just have to share
a unit (the driver feeds wall seconds; tests may feed engine steps).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass

__all__ = ["AdmissionController", "AdmissionDecision"]

# shed responses always carry a positive retry hint, even before the
# admit-interval window has samples to derive one from
MIN_RETRY_AFTER_S = 0.05


@dataclass(frozen=True)
class AdmissionDecision:
    admit: bool
    reason: str = ""                 # "", "queue_full", "ttft_slo"
    retry_after_s: float = 0.0       # > 0 on every shed decision
    projected_ttft_s: float = 0.0


class AdmissionController:
    """Shed-or-admit policy over live TTFT stats and queue depth.

    ``ttft_slo_p95_s=None`` disables the SLO projection (the bounded
    queue still applies), which is how the synthetic Poisson bench keeps
    its rows comparable with the direct ``engine.run`` path — same
    admission code, nothing shed.
    """

    def __init__(self, *, ttft_slo_p95_s: float | None = None,
                 max_queue: int = 128, window: int = 256,
                 n_replicas: int = 1):
        if max_queue < 0:
            raise ValueError(f"max_queue={max_queue} must be >= 0")
        if n_replicas < 1:
            raise ValueError(f"n_replicas={n_replicas} must be >= 1")
        self.ttft_slo_p95_s = ttft_slo_p95_s
        self.max_queue = max_queue
        # queue-drain parallelism: N data-parallel replicas consume the
        # shared queue N-at-a-time, so a queued newcomer waits only
        # depth/N admit intervals — without this, dp>1 projects the dp=1
        # drain rate and spuriously sheds load the fleet can absorb
        self.n_replicas = n_replicas
        self._ttft = deque(maxlen=window)
        self._admit_marks = deque(maxlen=window)
        # counters the /metrics endpoint exports
        self.submitted = 0          # accepted into the front-end queue
        self.shed = 0
        self.completed = 0
        self.queue_peak = 0

    # -- observations (driver-fed) ----------------------------------------

    def note_ttft(self, seconds: float) -> None:
        self._ttft.append(float(seconds))

    def note_admit(self, t: float) -> None:
        """One waiting -> running transition at monotonic time ``t``."""
        self._admit_marks.append(float(t))

    def note_submitted(self, queue_depth: int) -> None:
        self.submitted += 1
        self.queue_peak = max(self.queue_peak, queue_depth + 1)

    def note_completed(self) -> None:
        self.completed += 1

    # -- estimation --------------------------------------------------------

    def ttft_p95(self) -> float:
        if not self._ttft:
            return 0.0
        xs = sorted(self._ttft)
        return xs[min(len(xs) - 1, int(0.95 * len(xs)))]

    def mean_admit_interval(self) -> float:
        m = self._admit_marks
        if len(m) < 2:
            return 0.0
        return (m[-1] - m[0]) / (len(m) - 1)

    def projected_ttft_p95(self, queue_depth: int) -> float:
        return self.ttft_p95() \
            + queue_depth * self.mean_admit_interval() / self.n_replicas

    # -- the decision ------------------------------------------------------

    def decide(self, queue_depth: int) -> AdmissionDecision:
        """Pure read (no counter mutation): the driver records the
        outcome via ``note_submitted`` / ``note_shed``."""
        projected = self.projected_ttft_p95(queue_depth)
        if queue_depth >= self.max_queue:
            retry = max(self.mean_admit_interval() * queue_depth,
                        MIN_RETRY_AFTER_S)
            return AdmissionDecision(False, "queue_full", retry, projected)
        if (self.ttft_slo_p95_s is not None and self._ttft
                and projected > self.ttft_slo_p95_s):
            retry = max(projected - self.ttft_slo_p95_s, MIN_RETRY_AFTER_S)
            return AdmissionDecision(False, "ttft_slo", retry, projected)
        return AdmissionDecision(True, projected_ttft_s=projected)

    def note_shed(self) -> None:
        self.shed += 1
