"""Stdlib-only asyncio HTTP layer for the streaming front-end.

No web framework, no new runtime deps: a minimal HTTP/1.1 server on
``asyncio.start_server`` exposing exactly the surface a serving replica
needs behind a load balancer:

* ``POST /generate`` — JSON body, Server-Sent-Events response: one
  ``data: {"index", "token", "text"}`` event per generated token as the
  engine retires it, a final ``data: {"done": true, ...}`` summary, then
  ``data: [DONE]``. Sheds with ``429`` + ``Retry-After`` (SLO admission
  control), ``400`` on invalid bodies, ``503`` while draining.
* ``GET /health`` — JSON liveness/readiness (``200 ok`` serving,
  ``503 draining`` during graceful shutdown, so LBs stop routing here).
* ``GET /metrics`` — Prometheus text format (``frontend/metrics.py``).

Connections are ``Connection: close`` (one request per connection): the
SSE stream has no predeclared length, and keeping the parser trivial
keeps it auditable. A client that disconnects mid-stream **cancels** the
request: the driver's abort path releases its batch slot, KV blocks and
any host-swapped pages between engine steps, so abandoned work stops
consuming the token budget (docs/serving-frontend.md).

Request body schema (all but ``prompt`` optional; see docs/sampling.md
for field semantics)::

    {"prompt": [int, ...], "max_new": 16, "min_new": 0,
     "temperature": 0.0, "top_k": 0, "top_p": 1.0, "min_p": 0.0,
     "repetition_penalty": 1.0, "presence_penalty": 0.0,
     "frequency_penalty": 0.0, "logprobs": 0,
     "stop": [[int, ...], ...], "seed": 0, "eos_id": null}

With ``logprobs: n`` each SSE event carries a ``logprobs`` object:
``{"token_logprob": float, "top": [[id, lp], ...]}`` (top-n of the
post-penalty distribution the token was drawn from).
"""

from __future__ import annotations

import asyncio
import json
import math

import numpy as np

from repro.serving.frontend import metrics as metrics_mod
from repro.serving.frontend.driver import ShedError
from repro.serving.scheduler import Request, SamplingParams

__all__ = ["FrontendServer"]

_MAX_BODY = 1 << 20
_MAX_HEADER_LINES = 100


def _response_head(status: int, reason: str, ctype: str, length: int | None,
                   extra: tuple[tuple[str, str], ...] = ()) -> bytes:
    lines = [f"HTTP/1.1 {status} {reason}",
             f"Content-Type: {ctype}",
             "Connection: close"]
    if length is not None:
        lines.append(f"Content-Length: {length}")
    lines += [f"{k}: {v}" for k, v in extra]
    return ("\r\n".join(lines) + "\r\n\r\n").encode()


class _BadRequest(Exception):
    pass


async def _read_request(reader: asyncio.StreamReader):
    """Parse one request: (method, path, headers, body)."""
    line = await reader.readline()
    parts = line.decode("latin-1").rstrip("\r\n").split(" ")
    if len(parts) != 3:
        raise _BadRequest(f"malformed request line: {line!r}")
    method, path, _version = parts
    headers: dict[str, str] = {}
    for _ in range(_MAX_HEADER_LINES):
        h = await reader.readline()
        if h in (b"\r\n", b"\n", b""):
            break
        k, _, v = h.decode("latin-1").partition(":")
        headers[k.strip().lower()] = v.strip()
    else:
        raise _BadRequest("too many header lines")
    try:
        n = int(headers.get("content-length", "0"))
    except ValueError as e:
        raise _BadRequest("bad Content-Length") from e
    if not 0 <= n <= _MAX_BODY:
        raise _BadRequest(f"body too large ({n} bytes)")
    body = await reader.readexactly(n) if n else b""
    return method, path.split("?", 1)[0], headers, body


def _parse_generate(body: bytes) -> Request:
    try:
        payload = json.loads(body.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise _BadRequest(f"invalid JSON body: {e}") from e
    if not isinstance(payload, dict):
        raise _BadRequest("body must be a JSON object")
    prompt = payload.get("prompt")
    if (not isinstance(prompt, list) or not prompt
            or not all(isinstance(t, int) and t >= 0 for t in prompt)):
        raise _BadRequest('"prompt" must be a non-empty list of token ids')
    stop = payload.get("stop", [])
    if (not isinstance(stop, list)
            or not all(isinstance(s, list) and s
                       and all(isinstance(t, int) and t >= 0 for t in s)
                       for s in stop)):
        raise _BadRequest(
            '"stop" must be a list of non-empty token-id lists')
    try:
        sp = SamplingParams(
            temperature=float(payload.get("temperature", 0.0)),
            top_k=int(payload.get("top_k", 0)),
            seed=int(payload.get("seed", 0)),
            top_p=float(payload.get("top_p", 1.0)),
            min_p=float(payload.get("min_p", 0.0)),
            repetition_penalty=float(
                payload.get("repetition_penalty", 1.0)),
            presence_penalty=float(payload.get("presence_penalty", 0.0)),
            frequency_penalty=float(payload.get("frequency_penalty", 0.0)),
            logprobs=int(payload.get("logprobs", 0)),
            stop=tuple(tuple(s) for s in stop))
        max_new = int(payload.get("max_new", 16))
        min_new = int(payload.get("min_new", 0))
        eos_id = payload.get("eos_id")
        eos_id = None if eos_id is None else int(eos_id)
    except (TypeError, ValueError) as e:
        raise _BadRequest(f"bad sampling field: {e}") from e
    if max_new < 1:
        raise _BadRequest('"max_new" must be >= 1')
    if min_new < 0:
        raise _BadRequest('"min_new" must be >= 0')
    return Request(np.asarray(prompt, np.int32), max_new=max_new,
                   sampling=sp, eos_id=eos_id, min_new=min_new)


class FrontendServer:
    """The HTTP front door around an :class:`AsyncEngineDriver` or a
    :class:`~repro.serving.router.ReplicaRouter` (both expose the same
    ``submit`` / ``abort`` / ``draining`` / ``queue_depth`` surface)."""

    def __init__(self, driver, *,
                 host: str = "127.0.0.1", port: int = 0):
        self.driver = driver
        self.host = host
        self.port = port                      # 0 = ephemeral; set by start
        self._server: asyncio.AbstractServer | None = None

    async def start(self) -> None:
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]

    async def aclose(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()

    # -- connection handling ------------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            try:
                method, path, _headers, body = await _read_request(reader)
            except (_BadRequest, asyncio.IncompleteReadError,
                    UnicodeDecodeError) as e:
                await self._json(writer, 400, "Bad Request",
                                 {"error": str(e)})
                return
            if (method, path) == ("POST", "/generate"):
                await self._generate(writer, body)
            elif (method, path) == ("GET", "/health"):
                await self._health(writer)
            elif (method, path) == ("GET", "/metrics"):
                await self._metrics(writer)
            else:
                await self._json(writer, 404, "Not Found",
                                 {"error": f"no route {method} {path}"})
        except (ConnectionResetError, BrokenPipeError):
            pass                          # client went away mid-response
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _json(self, writer, status: int, reason: str, payload: dict,
                    extra=()) -> None:
        body = (json.dumps(payload) + "\n").encode()
        writer.write(_response_head(status, reason, "application/json",
                                    len(body), extra))
        writer.write(body)
        await writer.drain()

    # -- routes -------------------------------------------------------------

    async def _health(self, writer) -> None:
        # the driver is either an AsyncEngineDriver (one engine) or a
        # ReplicaRouter (a fleet: aggregate across `.engines`)
        engines = (self.driver.engines if hasattr(self.driver, "engines")
                   else [self.driver.engine])
        draining = self.driver.draining
        payload = {"status": "draining" if draining else "ok",
                   "model": engines[0].cfg.name,
                   "replicas": len(engines),
                   "running": sum(len(e.sched.running) for e in engines),
                   "queued": self.driver.queue_depth,
                   "steps": sum(e.stats["steps"] for e in engines),
                   "requests_done": sum(e.stats["requests_done"]
                                        for e in engines)}
        if draining:
            await self._json(writer, 503, "Service Unavailable", payload)
        else:
            await self._json(writer, 200, "OK", payload)

    async def _metrics(self, writer) -> None:
        body = metrics_mod.render_metrics_for(self.driver).encode()
        writer.write(_response_head(200, "OK", metrics_mod.CONTENT_TYPE,
                                    len(body)))
        writer.write(body)
        await writer.drain()

    async def _generate(self, writer, body: bytes) -> None:
        try:
            req = _parse_generate(body)
        except _BadRequest as e:
            await self._json(writer, 400, "Bad Request", {"error": str(e)})
            return
        try:
            stream = await self.driver.submit(req)
        except ShedError as e:
            status, reason = ((503, "Service Unavailable")
                              if e.reason == "draining"
                              else (429, "Too Many Requests"))
            await self._json(
                writer, status, reason,
                {"error": str(e), "reason": e.reason,
                 "retry_after_s": e.retry_after_s,
                 "projected_ttft_s": e.projected_ttft_s},
                extra=(("Retry-After",
                        str(max(1, math.ceil(e.retry_after_s)))),))
            return
        except ValueError as e:               # scheduler validation
            await self._json(writer, 400, "Bad Request", {"error": str(e)})
            return
        writer.write(_response_head(
            200, "OK", "text/event-stream",
            None, extra=(("Cache-Control", "no-store"),)))
        await writer.drain()
        n = 0
        try:
            async for ev in stream:
                n += 1
                event = {"index": ev.index, "token": ev.token,
                         "text": ev.text}
                if ev.logprobs is not None:
                    event["logprobs"] = ev.logprobs
                payload = json.dumps(event)
                writer.write(f"data: {payload}\n\n".encode())
                await writer.drain()          # stream, don't batch
            writer.write(
                ("data: " + json.dumps({"done": True, "rid": req.rid,
                                        "n_tokens": n}) + "\n\n"
                 + "data: [DONE]\n\n").encode())
            await writer.drain()
        except (ConnectionResetError, BrokenPipeError):
            # client went away mid-stream: cancel the request — its slot,
            # blocks and host-swap pages free up between steps instead of
            # computing tokens nobody will read
            self.driver.dropped_streams += 1
            self.driver.abort(req.rid)
            raise
