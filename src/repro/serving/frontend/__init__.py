"""Async streaming front-end around the continuous-batching engine.

The request-facing subsystem (docs/serving-frontend.md): an async engine
driver that owns the step loop and streams tokens per request
(``driver.py``), SLO-aware admission control with backpressure
(``admission.py``), a stdlib-only HTTP/SSE surface with live
``/metrics`` + ``/health`` (``http.py``), and Prometheus text rendering
(``metrics.py``).
"""

from repro.serving.frontend.admission import (AdmissionController,
                                              AdmissionDecision)
from repro.serving.frontend.driver import (AsyncEngineDriver, ShedError,
                                           TokenEvent, TokenStream)
from repro.serving.frontend.http import FrontendServer
from repro.serving.frontend.metrics import (render_metrics,
                                            render_metrics_for,
                                            render_router_metrics)

__all__ = ["AsyncEngineDriver", "TokenStream", "TokenEvent", "ShedError",
           "AdmissionController", "AdmissionDecision", "FrontendServer",
           "render_metrics", "render_router_metrics", "render_metrics_for"]
