"""Async streaming driver: the engine's step loop as a long-lived service.

``AsyncEngineDriver`` owns an :class:`~repro.serving.engine.InferenceEngine`
and runs its step loop on a background thread, so requests can be
submitted *at any time* from asyncio code and each one streams its tokens
back the moment a step retires them — the serving shape the batch
``engine.run()`` driver cannot provide. Per request, ``submit`` returns a
:class:`TokenStream`: an async iterator of :class:`TokenEvent`\\ s
(token id + incrementally detokenized text), fed across the thread
boundary with ``loop.call_soon_threadsafe`` and closed when the engine
retires the request.

Equivalence contract (pinned by tests/test_frontend.py): a request
streamed through the driver yields **byte-identical tokens** to the same
request run through ``engine.run()``. Tokens are appended by the very
same ``_append_token`` path (the driver only listens via the engine's
``on_token``/``on_finish`` hooks), and with ``arrival_step`` submissions
the thread loop reproduces ``run()``'s admission order and idle
clock-jumps exactly, so even the *scheduling stats* match the batch
driver on the same workload.

Admission is SLO-aware (``frontend/admission.py``): each ``submit``
consults the controller against the live queue depth and the engine's
realized TTFT window, raising :class:`ShedError` (→ HTTP 429 + Retry-
After) instead of queueing work that would blow the TTFT p95 target.
Graceful drain: ``drain()`` stops admissions (scheduler and driver
both), lets every admitted request retire, flushes and closes all
streams, then stops the thread.
"""

from __future__ import annotations

import asyncio
import heapq
import itertools
import queue
import threading
import time
from collections import deque
from typing import NamedTuple

from repro.serving.frontend.admission import AdmissionController

__all__ = ["AsyncEngineDriver", "TokenStream", "TokenEvent", "ShedError"]


class ShedError(RuntimeError):
    """Request refused by admission control (or a draining server).

    ``retry_after_s`` is always > 0: the wire layer maps it onto the
    HTTP ``Retry-After`` header of the 429 response.
    """

    def __init__(self, reason: str, retry_after_s: float = 0.1,
                 projected_ttft_s: float = 0.0):
        super().__init__(
            f"request shed ({reason}): retry after {retry_after_s:.3f}s")
        self.reason = reason
        self.retry_after_s = retry_after_s
        self.projected_ttft_s = projected_ttft_s


class TokenEvent(NamedTuple):
    index: int                  # position in the request's output stream
    token: int                  # token id, byte-identical to engine.run()
    text: str                   # incremental detokenization of `token`
    # per-token logprobs ({"token_logprob": float, "top": [(id, lp), ...]})
    # when the request asked for them (SamplingParams.logprobs > 0)
    logprobs: dict | None = None


_DONE = object()


class TokenStream:
    """One request's async token stream (returned by ``submit``).

    Engine-thread side: ``_push`` / ``_finish`` / ``_abort`` enqueue onto
    the consumer's asyncio loop. Consumer side: ``async for ev in stream``
    yields :class:`TokenEvent`\\ s until the request retires. Tokens
    buffer unboundedly, so a slow (or absent) consumer never stalls the
    engine — backpressure is admission's job, not the stream's.
    """

    def __init__(self, request, loop, detokenize):
        self.request = request
        self._loop = loop
        self._detok = detokenize
        self._q: asyncio.Queue = asyncio.Queue()
        self._n = 0
        self.finished = False
        self.error: BaseException | None = None
        self.submit_wall = time.monotonic()
        self.first_token_wall: float | None = None

    # -- engine-thread side -------------------------------------------------

    def _push(self, tok: int, logprobs: dict | None = None) -> None:
        if self.first_token_wall is None:
            self.first_token_wall = time.monotonic()
        self._loop.call_soon_threadsafe(
            self._q.put_nowait, (int(tok), logprobs))

    def _finish(self) -> None:
        self._loop.call_soon_threadsafe(self._q.put_nowait, _DONE)

    def _abort(self, exc: BaseException) -> None:
        self.error = exc
        self._loop.call_soon_threadsafe(self._q.put_nowait, _DONE)

    # -- consumer side ------------------------------------------------------

    def __aiter__(self):
        return self

    async def __anext__(self) -> TokenEvent:
        if self.finished:
            raise StopAsyncIteration
        item = await self._q.get()
        if item is _DONE:
            self.finished = True
            if self.error is not None:
                raise self.error
            raise StopAsyncIteration
        tok, logprobs = item
        ev = TokenEvent(self._n, tok, self._detok(tok), logprobs)
        self._n += 1
        return ev


def _default_detokenize(tok: int) -> str:
    """Placeholder incremental detokenizer: the repo serves raw token ids
    (there is no vocabulary file), so "text" is the id followed by a
    space. Real deployments pass ``detokenize=tokenizer.decode_piece``."""
    return f"{tok} "


class AsyncEngineDriver:
    """Background step loop + per-request async token streams.

    Usage::

        driver = AsyncEngineDriver(engine)          # or: async with ...
        await driver.start()
        stream = await driver.submit(Request(...))  # may raise ShedError
        async for ev in stream: ...
        await driver.drain()                        # graceful shutdown

    ``submit`` *before* ``start`` is allowed (arrivals queue up and run
    once the loop starts) — the admission tests rely on it to build a
    deterministic backlog. ``arrival_step`` schedules a submission on the
    engine's virtual clock exactly like ``engine.run(arrival_steps=...)``
    (the Poisson bench path); live traffic omits it.
    """

    def __init__(self, engine, *, admission: AdmissionController = None,
                 detokenize=None, idle_wait_s: float = 0.05):
        self.engine = engine
        self.admission = admission or AdmissionController()
        self.detokenize = detokenize or _default_detokenize
        self._idle_wait_s = idle_wait_s
        self._inbox: queue.Queue = queue.Queue()    # thread-safe handoff
        self._seq = itertools.count()               # FCFS tie-break
        self._streams: dict[int, TokenStream] = {}  # rid -> stream
        self._queued: set[int] = set()              # submitted, not running
        self._loop: asyncio.AbstractEventLoop | None = None
        self._thread: threading.Thread | None = None
        self._done_event: asyncio.Event | None = None
        self._draining = False
        self._stopped = False
        self.error: BaseException | None = None
        # SSE streams whose client disconnected mid-stream (the HTTP
        # layer follows up with abort(), so the request stops computing)
        self.dropped_streams = 0
        # requests cancelled before retirement (client disconnect or an
        # explicit abort): their cache resources were released early
        self.aborted = 0
        # rids whose abort was requested but not yet applied by the
        # engine thread (drained between steps)
        self._abort_q: deque[int] = deque()

    # -- lifecycle ----------------------------------------------------------

    async def __aenter__(self):
        await self.start()
        return self

    async def __aexit__(self, *exc):
        await self.aclose()

    async def start(self) -> None:
        if self._thread is not None:
            return
        self._loop = asyncio.get_running_loop()
        self._done_event = asyncio.Event()
        self.engine.on_token = self._on_token
        self.engine.on_finish = self._on_finish
        self.engine.sched.on_admit = self._on_admit
        self._thread = threading.Thread(
            target=self._run, name="engine-step-loop", daemon=True)
        self._thread.start()

    async def drain(self) -> None:
        """Graceful shutdown: stop admitting (driver and scheduler), let
        every admitted request retire and its stream close, then stop the
        step thread. Raises the engine error if the loop died.

        The scheduler's own drain flag is set by the step thread at exit,
        not here: requests already admitted by the front-end may still be
        in the handoff inbox, and they must reach ``sched.add`` (the
        ``submit`` gate above is what refuses *new* work)."""
        self._draining = True
        if self._thread is None:              # never started: nothing runs
            self.engine.sched.drain()
            self._stopped = True
            exc = RuntimeError("driver drained before start: "
                               "queued requests dropped")
            for stream in self._streams.values():
                stream._abort(exc)
            self._streams.clear()
            return
        self._inbox.put(None)                 # wake the thread
        await self._done_event.wait()
        if self.error is not None:
            raise self.error

    async def aclose(self) -> None:
        """Drain, join the thread, and detach from the engine (hooks
        removed, scheduler drain flag cleared) so the engine can keep
        being used as a plain batch driver afterwards."""
        try:
            await self.drain()
        finally:
            if self._thread is not None:
                self._thread.join(timeout=60)
            self._stopped = True
            self.engine.on_token = None
            self.engine.on_finish = None
            self.engine.sched.on_admit = None
            self.engine.sched.draining = False

    # -- queries ------------------------------------------------------------

    @property
    def queue_depth(self) -> int:
        """Requests admitted by the front-end but not yet running (still
        in the handoff inbox or the scheduler's waiting queue)."""
        return len(self._queued)

    @property
    def draining(self) -> bool:
        return self._draining

    # -- submission ---------------------------------------------------------

    async def submit(self, req, *, arrival_step: int | None = None
                     ) -> TokenStream:
        """Admit one request, or raise.

        Raises ``ShedError`` when draining or when admission control
        sheds (429 + retry signal at the HTTP layer), ``ValueError`` when
        the request can never fit (scheduler validation → HTTP 400).
        """
        if self.error is not None:
            raise self.error
        if self._draining or self._stopped:
            raise ShedError("draining", retry_after_s=1.0)
        self.engine.sched.validate(req)
        decision = self.admission.decide(self.queue_depth)
        if not decision.admit:
            self.admission.note_shed()
            raise ShedError(decision.reason, decision.retry_after_s,
                            decision.projected_ttft_s)
        loop = self._loop or asyncio.get_running_loop()
        stream = TokenStream(req, loop, self.detokenize)
        self._streams[req.rid] = stream
        self._queued.add(req.rid)
        self.admission.note_submitted(self.queue_depth - 1)
        t = -1 if arrival_step is None else int(arrival_step)
        self._inbox.put((t, next(self._seq), req))
        return stream

    def abort(self, rid: int) -> None:
        """Cancel an in-flight request (thread-safe, from any thread or
        the event loop). Applied by the engine thread *between* steps:
        the request stops computing, its blocks / host slots are released
        immediately, and its stream closes. A no-op for unknown or
        already-retired rids."""
        self._inbox.put(("abort", rid))

    def _apply_abort(self, pending: list, rid: int) -> None:
        """Engine-thread side of ``abort``: runs between steps."""
        cancelled = False
        for i, (_, _, req) in enumerate(pending):
            if req.rid == rid:            # never reached the scheduler
                pending.pop(i)
                heapq.heapify(pending)
                cancelled = True
                break
        else:
            cancelled = self.engine.abort(rid)
        self._queued.discard(rid)
        stream = self._streams.pop(rid, None)
        if stream is not None:
            stream._finish()
        if cancelled or stream is not None:
            self.aborted += 1
            self.admission.note_completed()

    # -- engine-thread callbacks (fire inside engine.step) -------------------

    def _on_admit(self, slot, req) -> None:
        if req.rid in self._queued:           # not a preemption re-admit
            self._queued.discard(req.rid)
            self.admission.note_admit(time.monotonic())

    def _on_token(self, req, tok, logprobs=None) -> None:
        stream = self._streams.get(req.rid)
        if stream is None:
            return
        first = stream.first_token_wall is None
        stream._push(tok, logprobs)
        if first:
            self.admission.note_ttft(
                stream.first_token_wall - stream.submit_wall)

    def _on_finish(self, req) -> None:
        stream = self._streams.pop(req.rid, None)
        if stream is not None:
            stream._finish()
            self.admission.note_completed()

    # -- the step loop (background thread) -----------------------------------

    def _run(self) -> None:
        eng = self.engine
        pending: list[tuple[int, int, object]] = []   # (step, seq, req)
        try:
            while True:
                # pull submissions; block only when there is nothing else
                # to do and we are not waiting on a scheduled arrival
                block = not eng.sched.has_work and not pending \
                    and not self._draining
                try:
                    while True:
                        item = self._inbox.get(
                            block=block, timeout=self._idle_wait_s)
                        block = False
                        if item is None:              # None = wake-up ping
                            continue
                        if item[0] == "abort":
                            self._abort_q.append(item[1])
                            continue
                        heapq.heappush(pending, item)
                except queue.Empty:
                    pass
                # cancellations apply between steps, before this tick's
                # admissions, so an aborted request never re-enters a plan
                while self._abort_q:
                    self._apply_abort(pending, self._abort_q.popleft())
                # admit every arrival due on the virtual clock, in
                # submission order — the same order engine.run() uses
                while pending and pending[0][0] <= eng.step_count:
                    _, _, req = heapq.heappop(pending)
                    eng.sched.add(req)
                    eng._note_arrival(req)
                if eng.sched.has_work:
                    if not eng.step():
                        raise RuntimeError(
                            "engine stuck: scheduler made no progress "
                            "with work pending")
                elif pending:
                    # idle with only future arrivals: jump the clock,
                    # exactly like engine.run()
                    eng.step_count = pending[0][0]
                elif self._draining:
                    eng.sched.drain()         # refuse work past this point
                    break                     # drained: all streams closed
        except BaseException as e:            # noqa: BLE001 — report, don't die
            self.error = e
            for stream in list(self._streams.values()):
                stream._abort(e)
            self._streams.clear()
        finally:
            self._stopped = True
            if self._loop is not None and self._done_event is not None:
                self._loop.call_soon_threadsafe(self._done_event.set)
