"""Cache-kind abstraction for the model-agnostic serving runtime.

The engine manages three kinds of per-request device state, mirroring the
paper's argument that shared mutable state should be managed by uniform
primitives rather than per-workload machinery:

* **Paged KV** (:class:`PagedKVCache` — the refcounted, content-hashed
  ``BlockManager`` from :mod:`repro.serving.kv_cache`): growing attention
  K/V, block-granular, shareable across requests (prefix cache, COW).
* **Slot state** (:class:`SlotStateCache`): *constant-size* per-request
  state — a Mamba block's (conv_tail, ssm_state). One slot per running
  request; nothing grows, nothing is shared, there is no block horizon.
  The device half is a pytree with a slot axis (``init_slot_state``).
* **Encoder state** (:class:`EncoderCache`): read-only per-request
  cross-attention K/V, written once by an encode pass at admission and
  never touched by the step (``init_encoder_cache``).

Host-side managers here are pure bookkeeping (which slot belongs to which
request); the scheduler consults them for admission and the engine for
array building. Block-based bookkeeping stays in ``kv_cache.BlockManager``.

Invariants ``check()`` enforces (and the seeded + hypothesis random walks
in tests/test_serving.py exercise): the rid->slot and slot->rid maps are
mutually inverse, every bound slot is in range, and a slot is held by at
most one request for its whole residence — slots are never shared, so
there is no refcounting, no content hashing, and no block horizon. Note
slot-state kinds have no fork/rewind story yet (state would need a copy,
not a refcount), which is why beam search and speculative decoding are
paged-transformer-only for now (see ROADMAP).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.config import ModelConfig
from repro.serving.kv_cache import BlockManager, mamba_layer_stacks

# the paged cache kind IS the refcounted/hashed block manager
PagedKVCache = BlockManager

__all__ = ["PagedKVCache", "SlotStateCache", "EncoderCache",
           "SlotCacheStats", "init_slot_state", "init_encoder_cache",
           "slot_state_bytes", "encoder_cache_bytes"]


@dataclass
class SlotCacheStats:
    n_slots: int
    in_use: int

    @property
    def utilization(self) -> float:
        return self.in_use / max(self.n_slots, 1)


class SlotStateCache:
    """Host-side allocator for constant-size per-slot device state.

    Each running request owns exactly one slot for its whole residence;
    preemption and retirement free the slot (``free``), and a preempted
    request's recompute starts from zeroed state (the runner zeroes the
    slot row on a fresh chunk, so stale state from a previous occupant is
    never read). Slots are never shared — there is no refcounting, no
    content hashing, and no block horizon to validate against.
    """

    def __init__(self, n_slots: int):
        assert n_slots >= 1
        self.n_slots = n_slots
        self._slot_of: dict[int, int] = {}      # rid -> slot
        self._rid_of: dict[int, int] = {}       # slot -> rid

    # -- queries ----------------------------------------------------------

    @property
    def num_free(self) -> int:
        return self.n_slots - len(self._rid_of)

    def free_slots(self) -> list[int]:
        return [s for s in range(self.n_slots) if s not in self._rid_of]

    def slot(self, rid: int) -> int:
        return self._slot_of[rid]

    def owner(self, slot: int) -> int | None:
        return self._rid_of.get(slot)

    def stats(self) -> SlotCacheStats:
        return SlotCacheStats(n_slots=self.n_slots,
                              in_use=len(self._rid_of))

    # -- mutations --------------------------------------------------------

    def allocate(self, rid: int, slot: int | None = None) -> int:
        """Bind ``rid`` to ``slot`` (or the lowest free slot). Raises
        KeyError on double-allocation, MemoryError when no slot is free or
        the requested slot is taken."""
        if rid in self._slot_of:
            raise KeyError(f"request {rid} already holds a slot")
        if slot is None:
            free = self.free_slots()
            if not free:
                raise MemoryError("no free slots")
            slot = free[0]
        else:
            if not (0 <= slot < self.n_slots):
                raise ValueError(f"slot {slot} out of range")
            if slot in self._rid_of:
                raise MemoryError(
                    f"slot {slot} is held by request {self._rid_of[slot]}")
        self._slot_of[rid] = slot
        self._rid_of[slot] = rid
        return slot

    def free(self, rid: int) -> int:
        """Release rid's slot (retire or preempt). Returns the slot."""
        slot = self._slot_of.pop(rid)
        del self._rid_of[slot]
        return slot

    def check(self) -> None:
        """Invariants: rid<->slot maps are a bijection within range."""
        assert len(self._slot_of) == len(self._rid_of)
        for rid, slot in self._slot_of.items():
            assert 0 <= slot < self.n_slots, (rid, slot)
            assert self._rid_of.get(slot) == rid, "slot maps disagree"


class EncoderCache(SlotStateCache):
    """Per-slot *read-only* encoder state (cross-attention K/V).

    Same slot discipline as :class:`SlotStateCache`; the distinguishing
    contract is that the step function never writes it — only the encode
    pass at admission does, so a slot row is immutable for the bound
    request's whole residence (recompute after preemption re-encodes)."""


# ---------------------------------------------------------------------------
# Device-side state builders (the zero pytrees the runners hand to jit)
# ---------------------------------------------------------------------------


def init_slot_state(cfg: ModelConfig, n_slots: int, dtype=jnp.bfloat16):
    """Zero per-slot Mamba state for every mamba layer stack:
    {sub_i: (conv_tail (NP, S, K-1, di+2gn) dtype,
             ssm_state (NP, S, nh, hp, N) fp32)} with S = n_slots.
    Matches ``transformer.init_cache``'s mamba leaves, slot axis = batch."""
    from repro.models.transformer import period_structure
    s = cfg.ssm
    assert s is not None
    _, NP = period_structure(cfg)
    di = s.d_inner(cfg.d_model)
    gn = s.n_groups * s.state_dim
    tail = (NP, n_slots, s.conv_kernel - 1, di + 2 * gn)
    h = (NP, n_slots, s.n_heads(cfg.d_model), s.head_dim, s.state_dim)
    return {name: (jnp.zeros(tail, dtype), jnp.zeros(h, jnp.float32))
            for name in mamba_layer_stacks(cfg)}


def init_encoder_cache(cfg: ModelConfig, n_slots: int, dtype=jnp.bfloat16):
    """Zero per-slot cross-attention K/V: {"xk","xv"} each
    (L, n_slots, T_enc, K, hd), matching ``encdec.encode_cross_kv``."""
    shape = (cfg.num_layers, n_slots, cfg.encoder_seq_len,
             cfg.num_kv_heads, cfg.head_dim)
    return {"xk": jnp.zeros(shape, dtype), "xv": jnp.zeros(shape, dtype)}


def slot_state_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """HBM bytes of one slot's Mamba state across every mamba stack."""
    s = cfg.ssm
    if s is None:
        return 0
    from repro.models.transformer import period_structure
    _, NP = period_structure(cfg)
    n_stacks = len(mamba_layer_stacks(cfg))
    di = s.d_inner(cfg.d_model)
    gn = s.n_groups * s.state_dim
    tail = (s.conv_kernel - 1) * (di + 2 * gn) * dtype_bytes
    h = s.n_heads(cfg.d_model) * s.head_dim * s.state_dim * 4   # fp32
    return NP * n_stacks * (tail + h)


def encoder_cache_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """HBM bytes of one slot's cross-attention K/V."""
    if not cfg.encoder_layers:
        return 0
    return (2 * cfg.num_layers * cfg.encoder_seq_len * cfg.num_kv_heads
            * cfg.head_dim * dtype_bytes)
