"""Token-budget continuous-batching scheduler.

Every engine step the scheduler hands out up to ``max_num_batched_tokens``
of model work in one :class:`StepPlan`:

* every decode-ready running request gets **1 token** (the wide decode
  batch — running decodes are never starved), and
* the remaining budget funds **one prefill chunk**: the next slice of the
  request currently streaming its prompt in, or a freshly admitted one.

Requests track ``num_computed`` — how many of their ``prefill_tokens()``
already have KV in the paged cache. A request whose prompt (or
post-preemption recompute) is longer than the leftover budget streams in
over several steps while everyone else keeps decoding: no full-batch
prefill stall, no prompt-length bucketing.

Admission consults the :class:`~repro.serving.kv_cache.BlockManager`
prefix cache: full blocks whose chained token hash is already resident are
shared (refcount++) instead of recomputed, and ``num_computed`` starts
past them. When the whole prompt is cached the last token is recomputed
for its logits; since its write position lands inside a shared block, the
scheduler emits a copy-on-write (the plan's ``copies`` are device page
copies the engine must perform before the step).

Preemption follows vLLM's recompute strategy: the victim (most recently
joined — oldest requests are closest to done) releases its blocks and
returns to the *front* of the waiting queue carrying the tokens generated
so far; on re-admission it recomputes prompt+generated (prefix-cache hits
on its own just-freed blocks usually make this cheap), so greedy outputs
are preemption-invariant.

With speculative decoding (``spec_tokens`` = k > 0) each decode slot
costs ``1 + k`` budget tokens (the widened verify row) and its block
horizon is ensured at ``context_len + 1 + k`` — the engine rewinds the
rejected tail via ``BlockManager.truncate`` after the step — and a
preemption victim's recompute chunk stops one token short of its stream
so the final token is re-emitted by the verify step with the original
rejection-sampling window alignment (temperature replay invariance).

Invariants this module maintains (asserted by ``validate``, the engine's
``debug_invariants`` checks, and the scheduler tests):

* a request is accepted only if ``prompt + max_new`` fits the per-request
  block-table capacity (``max_context``) — checked once, at submission;
* every decode-ready request owns blocks covering
  ``context_len + 1 + spec_tokens`` before its step runs;
* a step's ``scheduled_tokens`` never exceeds ``max_num_batched_tokens``;
* running decodes are never starved: admission and chunk growth spend
  only the *leftover* budget, and admission never preempts;
* slot-kind caches hold a rid<->slot bijection, bound at admission and
  released exactly once on preempt/retire;
* everything here is mesh-invariant: block ids, tables, hashes and slots
  are global regardless of how the device pools shard over the mesh
  "model" axis (docs/multi-host.md), so the same request stream produces
  the same plans on any mesh shape — pinned by the TP walks and the
  subprocess stats-equality tests in tests/test_serving_tp.py.

Pure host-side and jax-free so the policy is unit-testable in isolation.
"""

from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.serving.kv_cache import BlockManager, extend_chain_hashes

_RID = itertools.count()


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling surface (docs/sampling.md).

    Every default is an exact identity: a request left at the defaults
    samples byte-identically on the plain (greedy/temperature/top-k)
    path and the full pipeline, and ``needs_pipeline`` is what lets the
    engine keep pure-greedy batches on the plain compiled executables.
    ``stop`` holds token-id sequences (tuples, so the dataclass stays
    hashable); matching happens host-side against the SamplingBuffer's
    per-slot ring of recent tokens.
    """

    temperature: float = 0.0       # 0 => greedy
    top_k: int = 0                 # 0 => no truncation
    seed: int = 0
    top_p: float = 1.0             # 1.0 => no nucleus truncation
    min_p: float = 0.0             # 0 => no min-p truncation
    repetition_penalty: float = 1.0
    presence_penalty: float = 0.0
    frequency_penalty: float = 0.0
    logprobs: int = 0              # top-N logprobs per token (0 = off)
    stop: tuple = ()               # stop sequences: tuples of token ids

    def __post_init__(self):
        # normalize list-of-lists from JSON frontends into the hashable
        # tuple-of-tuples form (frozen dataclass: go through __setattr__)
        object.__setattr__(self, "stop",
                           tuple(tuple(int(t) for t in s)
                                 for s in self.stop))

    @property
    def needs_pipeline(self) -> bool:
        """True when sampling this request needs the full in-jit
        pipeline (penalties / top-p / min-p / logprobs). Stop sequences
        and min_new are host-side checks and do *not* force it."""
        return (self.top_p < 1.0 or self.min_p > 0.0
                or self.repetition_penalty != 1.0
                or self.presence_penalty != 0.0
                or self.frequency_penalty != 0.0
                or self.logprobs > 0)


@dataclass
class SwapCostModel:
    """Per-victim swap-vs-recompute decision for preemption.

    Swapping moves ``2 * n_blocks * block_bytes`` over the device<->host
    link (out now, back in later); recomputing replays ``num_computed``
    prefill tokens through the model. Both rates start at conservative
    defaults and are refined online by the engine's measurements (EMA), so
    the policy adapts to the actual machine instead of a guessed ratio.
    jax-free, like everything else in this module.
    """

    block_bytes: int                 # device bytes one block id costs
    policy: str = "auto"             # "always" | "never" | "auto"
    bytes_per_s: float = 4.0e9       # d2h+h2d bandwidth EMA
    prefill_tok_s: float = 2.0e4     # recompute throughput EMA
    ema_alpha: float = 0.2

    def prefer_swap(self, n_blocks: int, n_recompute_tokens: int) -> bool:
        if self.policy == "always":
            return True
        if self.policy == "never":
            return False
        move_s = 2.0 * n_blocks * self.block_bytes \
            / max(self.bytes_per_s, 1.0)
        recompute_s = n_recompute_tokens / max(self.prefill_tok_s, 1.0)
        return move_s < recompute_s

    def observe_swap(self, nbytes: int, seconds: float) -> None:
        if nbytes > 0 and seconds > 0:
            self.bytes_per_s += self.ema_alpha * (nbytes / seconds
                                                  - self.bytes_per_s)

    def observe_prefill(self, n_tokens: int, seconds: float) -> None:
        if n_tokens > 0 and seconds > 0:
            self.prefill_tok_s += self.ema_alpha * (n_tokens / seconds
                                                    - self.prefill_tok_s)


@dataclass
class Request:
    prompt: np.ndarray                      # (prompt_len,) int32
    max_new: int = 16
    sampling: SamplingParams = field(default_factory=SamplingParams)
    eos_id: int | None = None
    # EOS and stop sequences are ignored until min_new tokens exist
    # (max_new still wins; validation rejects min_new > max_new)
    min_new: int = 0
    # set by the engine when a stop sequence matched the output tail;
    # host state on the request, so it survives preemption like `out`
    stop_hit: bool = False
    # enc-dec only: (T_enc, d_model) stub frame embeddings for the
    # admission-time encode pass (zeros when None)
    frames: np.ndarray | None = field(default=None, repr=False)
    rid: int = field(default_factory=lambda: next(_RID))
    out: list[int] = field(default_factory=list)
    num_computed: int = 0                   # prefill_tokens() with KV cached
    n_published: int = 0                    # full blocks hash-registered
    n_preempted: int = 0
    # cached chain of full-block content hashes over prefill_tokens();
    # append-only (tokens only grow), survives preemption
    hash_chain: list = field(default_factory=list, repr=False)

    @property
    def done(self) -> bool:
        if len(self.out) >= self.max_new:
            return True
        if len(self.out) < self.min_new:
            return False               # EOS/stop ignored before min_new
        if self.stop_hit:
            return True
        return bool(self.out) and self.eos_id is not None \
            and self.out[-1] == self.eos_id

    def prefill_tokens(self) -> np.ndarray:
        """Prompt plus already-generated tokens (recompute after preempt)."""
        if not self.out:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.out, np.int32)])

    @property
    def context_len(self) -> int:
        return len(self.prompt) + len(self.out)

    @property
    def decode_ready(self) -> bool:
        """Exactly one token left to compute and a sampled token to feed:
        the request rides the wide decode batch. (The final 1-token slice
        of a recompute is a decode too — same operation.)"""
        return bool(self.out) and self.num_computed == self.context_len - 1


@dataclass
class StepPlan:
    """One step's worth of work, within the token budget."""
    decodes: list[tuple[int, Request]]            # slot -> 1 token each
    # prefill chunks funded by the leftover budget, each (slot, req,
    # n_tokens); more than one only with ``prefill_pack > 1`` (the packed
    # ragged-prefill path runs them in a single flat token batch)
    chunks: list[tuple[int, Request, int]]
    copies: list[tuple[int, int]]                 # device page copies (COW)
    admitted: int = 0                             # waiting -> running joins
    # freshly admitted enc-dec requests needing an encode pass this step
    encodes: list[tuple[int, Request]] = field(default_factory=list)
    # speculative lookahead: each decode slot costs 1 + spec_tokens target
    # positions (the widened verify row)
    spec_tokens: int = 0
    # host-swap copies the engine must perform around this step:
    # swap_outs are (device_block, host_slot) d2h gathers of *pre-step*
    # pool content (issue before anything can rewrite a freed block);
    # swap_ins are (host_slot, device_block) h2d copies that must land
    # before the step (and before COW copies, which may read them)
    swap_outs: list[tuple[int, int]] = field(default_factory=list)
    swap_ins: list[tuple[int, int]] = field(default_factory=list)
    # cross-replica prefix adoption: (shared_index_slot, device_block)
    # h2d copies out of the SharedPrefixIndex pool — same contract as
    # swap_ins (land before the step), different source pool
    shared_ins: list[tuple[int, int]] = field(default_factory=list)

    @property
    def chunk(self) -> tuple[int, Request, int] | None:
        """The single prefill chunk, for the unpacked (``prefill_pack=1``)
        path where at most one exists per step."""
        return self.chunks[0] if self.chunks else None

    @property
    def scheduled_tokens(self) -> int:
        return (len(self.decodes) * (1 + self.spec_tokens)
                + sum(c[2] for c in self.chunks))


class Scheduler:
    """Cache-kind-aware token-budget scheduler.

    ``bm`` is the paged cache's block manager, or None for runners whose
    state is purely slot-based (pure SSM): with no block pool there is no
    block horizon to validate, no growth to ensure, no preemption pressure
    and no prefix cache — admission is slot-limited only. ``slot_cache``
    and ``encoder_cache`` (``serving.cache``) are bound to the scheduler's
    chosen slot at admission and released on preempt/retire.

    ``chunk_quantum`` quantizes non-final prefill chunks down to a
    multiple (SSM runners: the SSD inner chunk size, so a chunked prefill
    re-groups the scan exactly like a monolithic one). Quantization
    rounding only ever drops tokens from the *last* chunk of a step —
    earlier chunks' remainders roll into the next chunk's budget — and the
    dropped count is tracked in ``quantum_dropped_tokens``.

    ``prefill_pack`` caps how many prefill chunks one step may carry
    (ragged packed prefill); 1 reproduces the classic single-chunk plans
    exactly.
    """

    def __init__(self, bm: BlockManager | None, max_batch: int,
                 max_blocks_per_seq: int, max_num_batched_tokens: int,
                 chunk_width: int, *, enable_prefix_caching: bool = True,
                 chunk_quantum: int = 1, slot_cache=None,
                 encoder_cache=None, spec_tokens: int = 0,
                 max_context: int | None = None, prefill_pack: int = 1,
                 swap_cost: SwapCostModel | None = None,
                 sampling_buffer=None):
        if max_num_batched_tokens <= max_batch * (1 + spec_tokens):
            raise ValueError(
                f"max_num_batched_tokens={max_num_batched_tokens} must "
                f"exceed max_batch={max_batch} x (1 + spec_tokens="
                f"{spec_tokens}) (each decode slot costs a 1 + k wide "
                "verify row; a prefill chunk needs leftover budget)")
        if chunk_width < chunk_quantum:
            raise ValueError(
                f"chunk_width={chunk_width} below chunk_quantum="
                f"{chunk_quantum}: no non-final chunk could ever run")
        self.bm = bm
        self.max_batch = max_batch
        self.max_blocks_per_seq = max_blocks_per_seq
        self.max_num_batched_tokens = max_num_batched_tokens
        self.chunk_width = chunk_width
        self.chunk_quantum = chunk_quantum
        self.slot_cache = slot_cache
        self.encoder_cache = encoder_cache
        # speculative lookahead: decodes reserve blocks for k extra
        # positions and cost 1 + k budget tokens (the verify row width).
        # max_context caps prompt+max_new at validation when the engine
        # widened the block tables past max_len to fit the lookahead.
        self.spec_tokens = spec_tokens
        self.max_context = (max_context if max_context is not None
                            else max_blocks_per_seq
                            * (bm.block_size if bm is not None else 0))
        self.enable_prefix_caching = enable_prefix_caching and bm is not None
        if prefill_pack < 1:
            raise ValueError(f"prefill_pack={prefill_pack} must be >= 1")
        self.prefill_pack = prefill_pack
        # host-swap preemption: active only when a cost model is supplied
        # AND the block manager actually has a host tier
        self.swap_cost = swap_cost
        # dense per-slot sampling state (sampling.SamplingBuffer): bound
        # at admission like the slot/encoder caches, rebuilt on re-bind
        # so recompute/swap-in replay penalties and stop rings exactly
        self.sampling_buffer = sampling_buffer
        self.waiting: deque[Request] = deque()
        self.running: dict[int, Request] = {}      # slot -> request
        self._join_order: list[int] = []           # slots, oldest first
        self.n_preemptions = 0
        self.n_swap_preemptions = 0
        self.n_swap_ins = 0
        self.n_aborts = 0
        self.host_hit_blocks = 0
        self.shared_hit_blocks = 0
        # copy pairs accumulated while building the current plan
        self._pending_swap_outs: list[tuple[int, int]] = []
        self._pending_swap_ins: list[tuple[int, int]] = []
        self._pending_shared_ins: list[tuple[int, int]] = []
        self.cache_hit_tokens = 0
        # prefill tokens lost to chunk_quantum rounding on a step's final
        # chunk (earlier chunks' remainders roll into the next chunk)
        self.quantum_dropped_tokens = 0
        # graceful-drain mode: in-flight work finishes, new submissions
        # are refused (the front-end flips this on shutdown)
        self.draining = False
        # front-end hook: called as on_admit(slot, req) whenever a request
        # moves waiting -> running (including preemption re-admissions)
        self.on_admit = None

    # -- queries ----------------------------------------------------------

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    @property
    def _swap_enabled(self) -> bool:
        return (self.swap_cost is not None and self.bm is not None
                and self.bm.num_host_blocks > 0)

    def free_slots(self) -> list[int]:
        return [s for s in range(self.max_batch) if s not in self.running]

    # -- submission -------------------------------------------------------

    def validate(self, req: Request) -> None:
        # A request's full horizon must fit its block-table row — reject at
        # submission instead of crashing mid-run when the table overflows.
        # (Single source of truth: admission relies on this having run.)
        # Slot-state caches are constant-size: no block horizon to check.
        if self.sampling_buffer is not None:
            self.sampling_buffer.validate(req)
        if self.bm is None:
            return
        horizon = len(req.prompt) + req.max_new
        capacity = self.max_context
        if horizon > capacity:
            raise ValueError(
                f"request {req.rid}: prompt+max_new = {horizon} tokens "
                f"exceeds max_len capacity {capacity}")

    def add(self, req: Request) -> None:
        if self.draining:
            raise RuntimeError(
                f"scheduler is draining: request {req.rid} refused "
                "(in-flight work finishes; no new admissions)")
        self.validate(req)
        self.waiting.append(req)

    def drain(self) -> None:
        """Stop accepting new requests; everything already submitted
        (waiting or running) still runs to retirement. Idempotent."""
        self.draining = True

    # -- the budgeted step ------------------------------------------------

    def schedule(self) -> StepPlan:
        """Build one step's plan: decode capacity first (preempting the
        newest requests when the pool runs dry), then spend the leftover
        budget on up to ``prefill_pack`` prefill chunks — continuing
        in-flight prefills and admitting waiting requests (with
        prefix-cache sharing). All chunks of a step share one leftover
        budget and one ``chunk_width`` allowance, so packing never starves
        decodes harder than the single-chunk policy."""
        copies: list[tuple[int, int]] = []
        encodes: list[tuple[int, Request]] = []
        self._pending_swap_outs = []
        self._pending_swap_ins = []
        self._pending_shared_ins = []
        self._ensure_decode_capacity()
        decodes = [(s, r) for s, r in sorted(self.running.items())
                   if r.decode_ready]
        budget_left = self.max_num_batched_tokens \
            - len(decodes) * (1 + self.spec_tokens)

        chunks: list[tuple[int, Request, int]] = []
        admitted = 0
        pres = [(s, r) for s, r in sorted(self.running.items())
                if not r.decode_ready]
        while (len(pres) < self.prefill_pack and budget_left > 0
               and self.waiting and len(self.running) < self.max_batch):
            head = self.waiting[0]
            if (self.bm is not None and self.bm.is_swapped(head.rid)
                    and not self.bm.can_swap_in(head.rid)):
                break           # FCFS: wait for device blocks to free up
            slot, req = self._admit_one(copies, encodes)
            admitted += 1
            if not req.decode_ready:
                pres.append((slot, req))
                                        # else: full cache hit minus one —
                                        # it joins the decode batch next step
        width_left = self.chunk_width
        pending_q_loss = 0
        for slot, req in pres:
            if budget_left <= 0 or width_left <= 0:
                break
            remaining = req.context_len - req.num_computed
            if self.spec_tokens and req.out:
                # speculative preemption-recompute stops one token short:
                # the final token must be re-emitted by the verify step,
                # not resampled from the chunk row, so the rejection-
                # sampling windows stay aligned with the uninterrupted
                # run (a preemption only ever lands on a window boundary)
                # and temperature streams replay identically
                remaining -= 1
            want = min(budget_left, width_left, remaining)
            n = self._quantize(want, remaining)
            # remainder below one quantum: rolls into the next chunk's
            # budget (we only deduct n below); for the step's last chunk
            # there is no next chunk — it is accounted, not silently lost
            pending_q_loss = want - n
            if n > 0:
                n = self._quantize(self._fit_chunk(req, n), remaining)
            if n > 0:
                chunks.append((slot, req, n))
                budget_left -= n
                width_left -= n
        self.quantum_dropped_tokens += pending_q_loss
        plan = StepPlan(decodes=decodes, chunks=chunks, copies=copies,
                        admitted=admitted, encodes=encodes,
                        spec_tokens=self.spec_tokens,
                        swap_outs=self._pending_swap_outs,
                        swap_ins=self._pending_swap_ins,
                        shared_ins=self._pending_shared_ins)
        self._pending_swap_outs = []
        self._pending_swap_ins = []
        self._pending_shared_ins = []
        return plan

    def _quantize(self, n: int, remaining: int) -> int:
        """Round a non-final chunk down to the chunk quantum (SSM runners:
        the SSD inner chunk size, so chunked == monolithic bitwise). The
        final chunk of a prompt is exempt — SSD padding is an exact
        identity step there."""
        if self.chunk_quantum > 1 and n < remaining:
            return n // self.chunk_quantum * self.chunk_quantum
        return n

    def _ensure_decode_capacity(self) -> None:
        """Every decode-ready request must own blocks for context_len + 1
        (the token about to be written) plus ``spec_tokens`` lookahead
        positions the speculative verify row may write (rejected tail
        blocks are rolled back after the step via ``BlockManager.truncate``).
        Preempts newest requests until the survivors fit. Slot-state-only
        runners have constant-size state: decode can never run out of
        capacity."""
        if self.bm is None:
            return
        for slot in list(self._join_order):             # oldest first
            req = self.running.get(slot)
            if req is None or not req.decode_ready:
                continue
            horizon = req.context_len + 1 + self.spec_tokens
            while not self.bm.ensure(req.rid, horizon):
                victim_slot = self._pick_victim()       # newest running
                if victim_slot == slot and len(self.running) == 1 and \
                        self.bm.blocks_for(horizon) \
                        > self.bm.num_blocks - 1:
                    raise MemoryError(
                        f"block pool too small for request {req.rid} "
                        f"at {horizon} tokens")
                self._preempt(victim_slot)
                if victim_slot == slot:
                    break        # self-preempted: back to waiting, move on

    def _fit_chunk(self, req: Request, n: int) -> int:
        """Reserve blocks for the next ``n`` prefill tokens, shrinking the
        chunk to what the pool can actually cover. Admission never preempts
        running work — a starved chunk waits for decodes to retire."""
        if self.bm is None:
            return n                     # slot state: nothing to reserve
        avail = (len(self.bm.table(req.rid)) + self.bm.num_free) \
            * self.bm.block_size - req.num_computed
        n = min(n, avail)
        if n <= 0:
            if len(self.running) == 1:
                raise MemoryError(
                    f"block pool too small for request {req.rid} "
                    f"at {req.num_computed + 1} tokens")
            return 0
        ok = self.bm.ensure(req.rid, req.num_computed + n)
        assert ok, "ensure failed after availability check"
        return n

    def _admit_one(self, copies: list[tuple[int, int]],
                   encodes: list[tuple[int, Request]] | None = None) -> \
            tuple[int, Request]:
        """FCFS admission with prefix-cache sharing (paged kinds only).
        The new table starts as the matched cached blocks (refcounted);
        fresh blocks arrive chunk by chunk via ``_fit_chunk``. Slot-kind
        caches are bound to the chosen slot; enc-dec requests are queued
        for their admission-time encode pass."""
        req = self.waiting.popleft()
        if self.bm is None:
            return self._bind_slot(req, encodes)
        if self.bm.is_swapped(req.rid):
            # swap-preempted victim returning: its KV rows come back from
            # the host tier byte-for-byte — num_computed survived the
            # eviction, so there is no recompute chunk at all (hashed
            # blocks whose device twin is still cached revive copy-free)
            _, pairs = self.bm.swap_in(req.rid)
            self._pending_swap_ins.extend(pairs)
            self.n_swap_ins += 1
            return self._bind_slot(req, encodes)
        bs = self.bm.block_size
        total = req.context_len
        hits: list[int] = []
        hashes: list = []
        if self.enable_prefix_caching:
            hashes = extend_chain_hashes(
                req.hash_chain, req.prefill_tokens(), bs)
            hits = self.bm.match(hashes)
        host_ext: list[int] = []
        if hashes and self._swap_enabled:
            # a swapped request's hashed blocks are findable by *other*
            # requests too: extend the device prefix with host-resident
            # blocks (copied in, not recomputed), capped by free blocks
            # left after adoption revives the cached-free device hits
            hh = self.bm.match_host(hashes)
            if len(hh) > len(hits):
                n_revived = sum(
                    1 for b in hits if self.bm.refcount(b) == 0)
                avail = max(0, self.bm.num_free - n_revived)
                host_ext = hh[len(hits):len(hits) + avail]
        shared_pairs: list[tuple[int, bytes]] = []
        if hashes and self.bm.shared is not None:
            # cross-replica extension: blocks another replica published
            # into the process-global index extend the prefix further
            # (copied from the shared host pool, not recomputed), again
            # capped by the free blocks left after revival + host copies
            n_local = len(hits) + len(host_ext)
            if n_local < len(hashes):
                n_revived = sum(
                    1 for b in hits if self.bm.refcount(b) == 0)
                avail = max(0, self.bm.num_free - n_revived
                            - len(host_ext))
                shared_pairs = self.bm.shared.acquire(
                    hashes[n_local:], limit=avail)
        n_cached = (len(hits) + len(host_ext) + len(shared_pairs)) * bs
        cow_idx = None
        if n_cached > total - 1:
            # Whole stream cached: recompute the last token for its logits.
            # Its KV write lands *inside* the final shared block — COW it,
            # or drop that hit when no spare block exists for the copy.
            # The copy target must still be free *after* adoption revives
            # the matched cached-free blocks out of the free list.
            # (When host_ext/shared_pairs is nonempty the final block is a
            # fresh copy with refcount 1 — always writable in place after
            # the deregister below, so no spare block is ever needed.)
            n_cached = total - 1
            cow_idx = n_cached // bs
            if not host_ext and not shared_pairs:
                n_revived = sum(
                    1 for b in hits if self.bm.refcount(b) == 0)
                if self.bm.refcount(hits[-1]) >= 1 \
                        and self.bm.num_free - n_revived < 1:
                    hits = hits[:-1]
                    n_cached = len(hits) * bs
                    cow_idx = None
        self.bm.adopt(req.rid, hits)
        if host_ext:
            _, pairs = self.bm.host_copy_in(
                req.rid, host_ext,
                hashes[len(hits):len(hits) + len(host_ext)])
            self._pending_swap_ins.extend(pairs)
            self.host_hit_blocks += len(host_ext)
        if shared_pairs:
            # same allocate-and-register path, sourced from the shared
            # pool; pairs stay pinned in the index until the engine's
            # h2d scatter lands (it releases them)
            _, pairs = self.bm.host_copy_in(
                req.rid, [s for s, _ in shared_pairs],
                [h for _, h in shared_pairs])
            self._pending_shared_ins.extend(pairs)
            self.shared_hit_blocks += len(shared_pairs)
        req.num_computed = n_cached
        req.n_published = (len(hits) + len(host_ext)
                           + len(shared_pairs))     # all registered
        self.cache_hit_tokens += n_cached
        if cow_idx is not None:
            src = self.bm.table(req.rid)[cow_idx]
            dst = self.bm.cow(req.rid, cow_idx)
            if dst is not None:
                copies.append((src, dst))
            else:
                # refcount was 1 (a revived cached block, or a fresh host
                # copy): the recompute will write its last position in
                # place, so pull it from the cache index — a concurrent
                # admission must not adopt a block with a pending write.
                # It re-registers via note_progress after the write.
                self.bm.deregister(src)
                req.n_published = cow_idx
        return self._bind_slot(req, encodes)

    def _bind_slot(self, req: Request,
                   encodes: list[tuple[int, Request]] | None) -> \
            tuple[int, Request]:
        slot = self.free_slots()[0]
        self.running[slot] = req
        self._join_order.append(slot)
        if self.sampling_buffer is not None:
            self.sampling_buffer.bind(req, slot)
        if self.slot_cache is not None:
            self.slot_cache.allocate(req.rid, slot)
        if self.encoder_cache is not None:
            self.encoder_cache.allocate(req.rid, slot)
            if encodes is not None:
                encodes.append((slot, req))
        if self.on_admit is not None:
            self.on_admit(slot, req)
        return slot, req

    # -- progress / bookkeeping -------------------------------------------

    def note_progress(self, req: Request) -> None:
        """Publish content hashes for every block req has fully computed,
        making them shareable by later (or preempted-and-returning)
        requests. Called by the engine after each step, before retirement
        frees the blocks (freed blocks keep their hash)."""
        if not self.enable_prefix_caching or self.bm is None:
            return
        bs = self.bm.block_size
        n_full = req.num_computed // bs
        if n_full <= req.n_published:       # nothing newly full this step
            return
        table = self.bm.table(req.rid)
        hashes = extend_chain_hashes(req.hash_chain,
                                     req.prefill_tokens(), bs)
        for j in range(req.n_published, n_full):
            self.bm.register(table[j], hashes[j])
        req.n_published = n_full

    def _pick_victim(self) -> int | None:
        for slot in reversed(self._join_order):         # newest first
            if slot in self.running:
                return slot
        return None

    def _release(self, req: Request) -> None:
        if self.bm is not None:
            self.bm.free(req.rid)
        if self.slot_cache is not None:
            self.slot_cache.free(req.rid)
        if self.encoder_cache is not None:
            self.encoder_cache.free(req.rid)
        if self.sampling_buffer is not None:
            self.sampling_buffer.free(req.rid)

    def _preempt(self, slot: int) -> Request:
        """Evict one running request. With a host tier, the cost model
        picks swap (KV bytes move to pinned host memory; ``num_computed``
        survives) or recompute (blocks freed hash-retained; the prompt +
        generated tokens replay on re-admission) per victim."""
        req = self.running.pop(slot)
        self._join_order.remove(slot)
        if (self._swap_enabled and req.num_computed > 0
                and self.bm.can_swap_out(req.rid)
                and self.swap_cost.prefer_swap(
                    len(self.bm.table(req.rid)), req.num_computed)):
            self._pending_swap_outs.extend(self.bm.swap_out(req.rid))
            if self.slot_cache is not None:
                self.slot_cache.free(req.rid)
            if self.encoder_cache is not None:
                self.encoder_cache.free(req.rid)
            if self.sampling_buffer is not None:
                self.sampling_buffer.free(req.rid)
            self.n_swap_preemptions += 1
            # num_computed / n_published survive: the KV rows themselves
            # come back via swap_in, nothing is recomputed
        else:
            self._release(req)
            req.num_computed = 0
            req.n_published = 0     # re-admission gets a different table
        req.n_preempted += 1
        self.n_preemptions += 1
        self.waiting.appendleft(req)
        return req

    def abort(self, rid: int) -> bool:
        """Cancel a request wherever it currently lives: waiting (dropping
        any host-swapped KV), or running (blocks freed hash-retained, slot
        released). Returns False when the rid is unknown — already retired
        or never submitted — which the caller treats as a no-op."""
        for i, r in enumerate(self.waiting):
            if r.rid == rid:
                del self.waiting[i]
                if self.bm is not None and self.bm.is_swapped(rid):
                    self.bm.swap_discard(rid)
                self.n_aborts += 1
                return True
        for slot, r in list(self.running.items()):
            if r.rid == rid:
                self.running.pop(slot)
                self._join_order.remove(slot)
                self._release(r)
                self.n_aborts += 1
                return True
        return False

    def retire(self, slot: int) -> Request:
        req = self.running.pop(slot)
        self._join_order.remove(slot)
        self._release(req)
        return req
