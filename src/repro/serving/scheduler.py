"""Continuous-batching scheduler: FCFS admission, join-on-free-slot,
retire-on-EOS/max-new, preempt-to-waiting when the block pool runs dry.

Pure host-side and jax-free so the policy is unit-testable in isolation.
The engine drives it:

    joins = sched.admit()            # waiting -> running (slot + blocks)
    preempted = sched.ensure_decode_capacity()
    ... run prefills / one decode step ...
    sched.retire(slot)               # EOS or max_new reached

Preemption follows vLLM's recompute strategy: the victim (most recently
joined — oldest requests are closest to done) releases its blocks and
returns to the *front* of the waiting queue carrying the tokens generated
so far; on re-admission it prefills prompt+generated and continues, so
greedy outputs are preemption-invariant.
"""

from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.serving.kv_cache import BlockManager

_RID = itertools.count()


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0       # 0 => greedy
    top_k: int = 0                 # 0 => no truncation
    seed: int = 0


@dataclass
class Request:
    prompt: np.ndarray                      # (prompt_len,) int32
    max_new: int = 16
    sampling: SamplingParams = field(default_factory=SamplingParams)
    eos_id: int | None = None
    rid: int = field(default_factory=lambda: next(_RID))
    out: list[int] = field(default_factory=list)
    n_preempted: int = 0

    @property
    def done(self) -> bool:
        if len(self.out) >= self.max_new:
            return True
        return bool(self.out) and self.eos_id is not None \
            and self.out[-1] == self.eos_id

    def prefill_tokens(self) -> np.ndarray:
        """Prompt plus already-generated tokens (recompute after preempt)."""
        if not self.out:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.out, np.int32)])

    @property
    def context_len(self) -> int:
        return len(self.prompt) + len(self.out)


class Scheduler:
    def __init__(self, bm: BlockManager, max_batch: int,
                 max_blocks_per_seq: int):
        self.bm = bm
        self.max_batch = max_batch
        self.max_blocks_per_seq = max_blocks_per_seq
        self.waiting: deque[Request] = deque()
        self.running: dict[int, Request] = {}      # slot -> request
        self._join_order: list[int] = []           # slots, oldest first
        self.n_preemptions = 0

    # -- queries ----------------------------------------------------------

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def free_slots(self) -> list[int]:
        return [s for s in range(self.max_batch) if s not in self.running]

    # -- transitions ------------------------------------------------------

    def validate(self, req: Request) -> None:
        # The decode loop conservatively holds blocks for context+1, so a
        # request's full horizon must fit its block-table row — reject at
        # submission instead of crashing mid-run when the table overflows.
        horizon = len(req.prompt) + req.max_new
        capacity = self.max_blocks_per_seq * self.bm.block_size
        if horizon > capacity:
            raise ValueError(
                f"request {req.rid}: prompt+max_new = {horizon} tokens "
                f"exceeds max_len capacity {capacity}")

    def add(self, req: Request) -> None:
        self.validate(req)
        self.waiting.append(req)

    def admit(self) -> list[tuple[int, Request]]:
        """FCFS: admit waiting requests while a slot and blocks exist.
        Blocks are allocated for the prefill context plus one decode token
        so a join can never be preempted before its first step."""
        joins = []
        free = self.free_slots()
        while self.waiting and free:
            req = self.waiting[0]
            need = req.context_len + 1
            if self.bm.blocks_for(need) > self.max_blocks_per_seq:
                raise ValueError(
                    f"request {req.rid}: {need} tokens exceeds "
                    f"max_blocks_per_seq={self.max_blocks_per_seq}")
            if not self.bm.can_allocate(need):
                break
            self.waiting.popleft()
            slot = free.pop(0)
            self.bm.allocate(req.rid, need)
            self.running[slot] = req
            self._join_order.append(slot)
            joins.append((slot, req))
        return joins

    def ensure_decode_capacity(self) -> list[Request]:
        """Before a decode step every running request must own blocks for
        context_len + 1 (the token about to be written). Preempts newest
        requests until the survivors fit. Returns the preempted requests."""
        preempted: list[Request] = []
        for slot in list(self._join_order):             # oldest first
            req = self.running.get(slot)
            if req is None:                             # already preempted
                continue
            while not self.bm.ensure(req.rid, req.context_len + 1):
                victim_slot = self._pick_victim()       # newest running
                if victim_slot is None or (victim_slot == slot
                                           and not self.bm.num_free
                                           and len(self.running) == 1):
                    raise MemoryError(
                        f"block pool too small for request {req.rid} "
                        f"at {req.context_len + 1} tokens")
                preempted.append(self._preempt(victim_slot))
                if victim_slot == slot:
                    break        # self-preempted: back to waiting, move on
        return preempted

    def _pick_victim(self) -> int | None:
        for slot in reversed(self._join_order):         # newest first
            if slot in self.running:
                return slot
        return None

    def _preempt(self, slot: int) -> Request:
        req = self.running.pop(slot)
        self._join_order.remove(slot)
        self.bm.free(req.rid)
        req.n_preempted += 1
        self.n_preemptions += 1
        self.waiting.appendleft(req)
        return req

    def retire(self, slot: int) -> Request:
        req = self.running.pop(slot)
        self._join_order.remove(slot)
        self.bm.free(req.rid)
        return req
