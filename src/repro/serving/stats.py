"""Serving statistics primitives: Prometheus-style histograms.

The engine aggregates per-request TTFT / end-to-end latency into fixed-
bucket :class:`Histogram`\\ s at retirement time, so the rolling
``stats["latency"]`` dict can stay bounded (old per-request records are
evicted) without the metrics surface losing data: a histogram is O(number
of buckets) forever, which is what lets a serve loop run for millions of
requests. ``frontend/metrics.py`` renders these in the Prometheus text
exposition format.

Dependency-free on purpose (no jax, no numpy): the scheduler/engine host
path and the asyncio front-end both import it.
"""

from __future__ import annotations

from bisect import bisect_left

__all__ = ["Histogram", "SECONDS_BUCKETS", "STEP_BUCKETS"]

# wall-clock latency buckets (seconds): spans interpret-mode CPU smoke
# runs (tens of seconds) down to real-accelerator decode steps (ms)
SECONDS_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 120.0)

# virtual-clock buckets (engine steps): deterministic across hosts, the
# unit the scheduler tests and the bench's `steps` percentiles use
STEP_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0,
                512.0, 1024.0)


class Histogram:
    """Fixed-bucket histogram with Prometheus exposition semantics.

    ``uppers`` are inclusive bucket upper bounds (``le``); an implicit
    ``+Inf`` bucket catches the tail. ``render`` emits *cumulative* bucket
    counts plus ``_sum`` / ``_count``, exactly the text format Prometheus
    scrapes. ``percentile`` gives a conservative (bucket-upper-bound)
    estimate for host-side reporting and the admission controller.
    """

    def __init__(self, uppers=SECONDS_BUCKETS):
        self.uppers = tuple(sorted(float(u) for u in uppers))
        if not self.uppers:
            raise ValueError("histogram needs at least one bucket bound")
        self.counts = [0] * (len(self.uppers) + 1)     # + the +Inf bucket
        self.count = 0
        self.total = 0.0

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.uppers, float(v))] += 1
        self.count += 1
        self.total += float(v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Upper bound of the bucket containing the q-th percentile
        (q in [0, 100]); 0.0 when empty, last finite bound for the +Inf
        bucket. Conservative by construction — never underestimates."""
        if not self.count:
            return 0.0
        need = max(1, -(-int(q * self.count) // 100))   # ceil(q% of count)
        seen = 0
        for upper, c in zip(self.uppers, self.counts):
            seen += c
            if seen >= need:
                return upper
        return self.uppers[-1]

    def merge(self, other: "Histogram") -> "Histogram":
        """Add ``other``'s observations into this histogram, in place.
        Bucket bounds must match exactly (fleet aggregation merges
        replicas built from the same constants). Returns self, so
        ``reduce(Histogram.merge, hists, Histogram(b))`` folds a fleet.

        Equivalence contract (pinned by tests): merging N histograms is
        indistinguishable — counts, sum, count, percentiles, rendering —
        from one histogram that observed the concatenated samples."""
        if other.uppers != self.uppers:
            raise ValueError(
                f"bucket mismatch: {self.uppers} != {other.uppers}")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.total += other.total
        return self

    def render(self, name: str, help_: str, out: list[str],
               labels: dict | None = None, header: bool = True) -> None:
        """Append Prometheus text-format lines for this histogram.

        ``labels`` adds constant label pairs to every series (e.g.
        ``{"replica": "0"}`` for per-replica fleet series); ``header``
        False suppresses the HELP/TYPE preamble so several labeled
        histograms can share one metric family."""
        if header:
            out.append(f"# HELP {name} {help_}")
            out.append(f"# TYPE {name} histogram")
        base = "".join(f'{k}="{v}",' for k, v in (labels or {}).items())
        tail = ("{" + base.rstrip(",") + "}") if base else ""
        cum = 0
        for upper, c in zip(self.uppers, self.counts):
            cum += c
            out.append(
                f'{name}_bucket{{{base}le="{format(upper, "g")}"}} {cum}')
        out.append(f'{name}_bucket{{{base}le="+Inf"}} {self.count}')
        out.append(f"{name}_sum{tail} {format(self.total, 'g')}")
        out.append(f"{name}_count{tail} {self.count}")
