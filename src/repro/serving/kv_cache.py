"""Block-table KV-cache management for continuous-batching serving.

The device side is a pytree of page pools, one {"k","v"} pair per scanned
layer stack, each shaped ``(NP, num_blocks, block_size, K, hd)`` — the
vLLM layout with this repo's layer-stacked leading dim. Every layer uses
the *same* block ids (one table per sequence, all layers), so allocating a
block grants one ``block_size``-token slice of KV capacity across the whole
model at once.

The host side is ``BlockManager``: a refcounted allocator with per-request
block tables plus a content-hash index for prefix caching:

* **Refcounts** — a block may appear in several tables at once (shared
  prefix, fork). It returns to the free list only when its last reference
  drops.
* **Content hashes** — a *full* block's identity is the chained hash of
  every token from position 0 through its end, so equal hashes imply equal
  KV content (positions are absolute). ``register`` publishes a full
  block; ``match`` resolves the longest cached prefix of a token stream.
  Freed blocks keep their hash (their pages are never written while free),
  so a later request can *revive* them from the free list — prefix hits
  survive retirement and preemption.
* **Copy-on-write** — a request must never write into a block another
  table can read. ``cow`` swaps a shared table entry for a fresh block and
  tells the caller which device page to copy.
* **Truncate** — ``truncate`` rewinds a table's tail (free semantics,
  hash retained): the speculative-decoding rollback for lookahead blocks
  whose draft tokens were rejected (docs/kv-cache.md, docs/speculative.md).

Block 0 is reserved as the *trash block* — idle serving slots carry
all-zero table rows, so the decode step's unconditional KV write for an
inactive slot lands there and corrupts nothing.

On a tensor-parallel mesh the pools shard over the "model" axis by whole
kv heads (``spmd.sharding.paged_pool_pspec``); block ids index pool rows
on *every* shard at once, so nothing in this module — tables, refcounts,
content hashes, free lists, truncate — ever sees the mesh. The
mesh-invariance walks in tests/test_serving_tp.py pin that property.
"""

from __future__ import annotations

import hashlib
import threading
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import quant
from repro.models.transformer import period_structure

TRASH_BLOCK = 0

_HASH_SEED = b"repro-paged-kv-v1"


def extend_chain_hashes(chain: list[bytes], tokens,
                        block_size: int) -> list[bytes]:
    """Extend ``chain`` in place with hashes for every *full* block of
    ``tokens`` not yet covered — the chain only ever grows (a request's
    token stream is append-only), so callers cache it and each new block
    costs one sha256 instead of re-hashing from position 0."""
    h = chain[-1] if chain else hashlib.sha256(_HASH_SEED).digest()
    for i in range(len(chain), len(tokens) // block_size):
        blk = np.asarray(tokens[i * block_size:(i + 1) * block_size],
                         np.int32).tobytes()
        h = hashlib.sha256(h + blk).digest()
        chain.append(h)
    return chain


def chain_block_hashes(tokens, block_size: int) -> list[bytes]:
    """Chained content hashes for every *full* block of ``tokens``.

    ``h_i`` covers tokens ``[0, (i+1) * block_size)`` — a match on ``h_i``
    implies the whole prefix matches, so a single dict lookup per block
    resolves prefix sharing. sha256 over the token bytes (not Python
    ``hash``): adopting a colliding block would silently splice another
    request's KV into a new table, so collisions must be cryptographically
    improbable.
    """
    return extend_chain_hashes([], tokens, block_size)


def attn_layer_stacks(cfg: ModelConfig) -> list[str]:
    """Names of the scanned cache sub-stacks that hold attention KV."""
    kinds, _ = period_structure(cfg)
    out = [f"sub{i}" for i, k in enumerate(kinds) if k != "mamba"]
    if cfg.shared_attn_period:
        out.append("shared")
    return out


def mamba_layer_stacks(cfg: ModelConfig) -> list[str]:
    """Names of the scanned cache sub-stacks holding per-slot SSM state."""
    kinds, _ = period_structure(cfg)
    return [f"sub{i}" for i, k in enumerate(kinds) if k == "mamba"]


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     dtype=jnp.bfloat16, kv_dtype: str = "bf16"):
    """Zero page pools matching ``transformer.decode_step_paged``.

    Covers the *attention* stacks only; mamba stacks carry constant-size
    per-slot state (``serving.cache.init_slot_state``) rather than paged
    KV — a hybrid model's serving cache is the union of both.

    With a quantized ``kv_dtype`` ("int8" / "fp8") the k/v leaves store
    the narrow dtype and each stack gains fp32 ``k_scale`` / ``v_scale``
    leaves shaped ``(NP, num_blocks, block_size, K, 1)`` — same rank and
    block axis as the pools, so block-indexed copy/COW/swap helpers
    handle value and scale leaves uniformly (docs/kv-cache.md)."""
    kinds, NP = period_structure(cfg)
    shape = (NP, num_blocks, block_size, cfg.num_kv_heads, cfg.head_dim)
    if quant.is_quantized(kv_dtype):
        dtype = quant.KV_DTYPES[kv_dtype]
    sshape = shape[:-1] + (1,)

    def stack():
        c = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
        if quant.is_quantized(kv_dtype):
            c["k_scale"] = jnp.zeros(sshape, jnp.float32)
            c["v_scale"] = jnp.zeros(sshape, jnp.float32)
        return c

    cache = {}
    for i, kind in enumerate(kinds):
        if kind == "mamba":
            continue
        cache[f"sub{i}"] = stack()
    if cfg.shared_attn_period:
        cache["shared"] = stack()
    return cache


def block_bytes(cfg: ModelConfig, block_size: int, dtype_bytes: int = 2,
                tp: int = 1, kv_dtype: str = "bf16"):
    """HBM bytes one block id costs across every layer's k+v pools.

    ``tp`` > 1 gives the *per-shard* cost on a kv-head-sharded mesh
    (docs/multi-host.md): each model shard holds num_kv_heads/tp heads of
    every page, so a block's footprint divides exactly — the accounting
    the mesh-invariance walks pin. Requires tp to divide num_kv_heads
    (the engine validates via ``spmd.sharding.paged_pool_pspec``).

    A quantized ``kv_dtype`` narrows the per-element cost and adds the
    fp32 per-row scale leaves (4 bytes per (token, head) row)."""
    kinds, NP = period_structure(cfg)
    n_stacks = len(attn_layer_stacks(cfg))
    if cfg.num_kv_heads % tp != 0:
        raise ValueError(
            f"num_kv_heads={cfg.num_kv_heads} is not divisible by tp={tp}"
            " (see spmd.sharding.paged_pool_pspec)")
    row_bytes = cfg.head_dim * dtype_bytes
    if quant.is_quantized(kv_dtype):
        row_bytes = cfg.head_dim * quant.kv_dtype_bytes(kv_dtype) + 4
    return (2 * NP * n_stacks * block_size * (cfg.num_kv_heads // tp)
            * row_bytes)


class SharedPrefixIndex:
    """Process-global content-hash index + pinned host payload pool shared
    by every replica's :class:`BlockManager` (docs/multi-host.md §DP).

    The per-replica prefix cache maps ``hash -> device block``; block ids
    are meaningless outside their replica, so cross-replica sharing needs
    a payload medium. This index owns a pool of *host* slots (one slot =
    one block's pages across every layer, same layout as the PR-8 swap
    tier) plus a ``hash -> slot`` map. Replicas **publish**: after a full
    block's hash is registered locally, the engine reserves a slot,
    d2h-gathers the block's pages into the shared pool, and commits the
    hash. Any replica's admission then **adopts**: ``acquire`` resolves
    the longest cached prefix to (slot, hash) pairs, the adopting
    ``BlockManager.host_copy_in`` allocates fresh device blocks, and the
    engine h2d-scatters the shared payload — exactly the existing host
    prefix-hit path, pointed at the shared pool.

    Locking rules (every mutator takes ``self._lock``; replicas run on
    separate step-loop threads):

    * a **reserved** slot (publish in flight) is invisible to ``acquire``
      and immune to eviction until ``commit`` or ``abandon``;
    * an **acquired** slot is pinned until ``release`` (after the h2d
      copy lands), so no adopted block's payload can be evicted or
      rewritten under a pending copy;
    * eviction (pool full on ``reserve``) takes the least-recently-used
      unpinned committed slot; acquire refreshes recency.

    Byte identity needs none of this to be deterministic: adopted KV is a
    pure function of the token prefix (the prefix-caching qualification),
    so a racing miss just recomputes the same bytes. The lock protects
    *bookkeeping*, not output equivalence.
    """

    def __init__(self, num_slots: int):
        assert num_slots >= 1
        self.num_slots = num_slots
        self._lock = threading.Lock()
        self._free = list(range(num_slots - 1, -1, -1))
        self._slot_of: dict[bytes, int] = {}   # hash -> committed slot
        self._hash_of: dict[int, bytes] = {}   # committed slot -> hash
        self._reserved: set[int] = set()       # publish in flight
        self._pins: dict[int, int] = {}        # slot -> acquire count
        self._order: list[int] = []            # committed slots, LRU first
        # pinned numpy payload pool, one array per paged cache leaf
        # (attach_pool; allocated once by the first replica's engine)
        self.pool: list[np.ndarray] = []
        self._pool_key = None
        self.published_blocks = 0
        self.adopted_blocks = 0
        self.evicted_blocks = 0

    # -- payload pool ------------------------------------------------------

    def attach_pool(self, leaf_shapes: list[tuple[tuple, object]]) -> None:
        """Allocate the shared host pool: one ``(num_slots,) + tail`` array
        per paged cache leaf (tail excludes the per-replica num_blocks
        axis, so replicas with different pool sizes still share). First
        replica allocates; later replicas must present the same layout."""
        key = tuple((tuple(shape), np.dtype(dt).str)
                    for shape, dt in leaf_shapes)
        with self._lock:
            if self._pool_key is not None:
                if key != self._pool_key:
                    raise ValueError(
                        "shared prefix pool layout mismatch across "
                        f"replicas: {key} != {self._pool_key}")
                return
            self._pool_key = key
            self.pool = [np.zeros((self.num_slots,) + tuple(shape), dt)
                         for shape, dt in leaf_shapes]

    # -- publish (writer side) ---------------------------------------------

    def contains(self, h: bytes) -> bool:
        with self._lock:
            return h in self._slot_of

    def reserve(self, h: bytes) -> int | None:
        """Claim a slot for publishing ``h``. None when the hash is
        already committed or no slot can be freed (all pinned/reserved).
        The caller copies the payload in, then ``commit``s."""
        with self._lock:
            if h in self._slot_of:
                return None
            if not self._free:
                victim = next((s for s in self._order
                               if not self._pins.get(s)), None)
                if victim is None:
                    return None
                self._evict_locked(victim)
            s = self._free.pop()
            self._reserved.add(s)
            return s

    def commit(self, slot: int, h: bytes) -> None:
        with self._lock:
            assert slot in self._reserved, slot
            self._reserved.discard(slot)
            if h in self._slot_of:
                # two replicas raced the same hash through reserve (the
                # register-time dedup is only best-effort); first commit
                # wins, the loser's copy is dropped
                self._free.append(slot)
                return
            self._slot_of[h] = slot
            self._hash_of[slot] = h
            self._order.append(slot)
            self.published_blocks += 1

    def abandon(self, slot: int) -> None:
        """Return a reserved slot unused (publish aborted)."""
        with self._lock:
            assert slot in self._reserved, slot
            self._reserved.discard(slot)
            self._free.append(slot)

    def _evict_locked(self, slot: int) -> None:
        self._order.remove(slot)
        h = self._hash_of.pop(slot)
        del self._slot_of[h]
        self._free.append(slot)
        self.evicted_blocks += 1

    # -- adopt (reader side) -----------------------------------------------

    def acquire(self, hashes: list[bytes],
                limit: int | None = None) -> list[tuple[int, bytes]]:
        """Longest prefix of ``hashes`` resolving to committed slots, each
        pinned against eviction until ``release``. ``limit`` caps the
        match (the adopter's free-block budget)."""
        out: list[tuple[int, bytes]] = []
        with self._lock:
            for h in hashes if limit is None else hashes[:max(limit, 0)]:
                s = self._slot_of.get(h)
                if s is None:
                    break
                self._pins[s] = self._pins.get(s, 0) + 1
                self._order.remove(s)          # refresh recency (MRU)
                self._order.append(s)
                out.append((s, h))
            self.adopted_blocks += len(out)
        return out

    def release(self, slots: list[int]) -> None:
        """Unpin after the adopter's h2d copies have landed."""
        with self._lock:
            for s in slots:
                n = self._pins[s] - 1
                if n:
                    self._pins[s] = n
                else:
                    del self._pins[s]

    # -- audit -------------------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            return {"slots": self.num_slots,
                    "committed": len(self._slot_of),
                    "pinned": len(self._pins),
                    "published_blocks": self.published_blocks,
                    "adopted_blocks": self.adopted_blocks,
                    "evicted_blocks": self.evicted_blocks}

    def check(self) -> None:
        """Invariants: slot partition exact, maps mutually consistent,
        pins only on committed (payload-bearing) slots — i.e. no adopted
        block can outlive its payload."""
        with self._lock:
            committed = set(self._hash_of)
            free = set(self._free)
            assert len(free) == len(self._free), "free list duplicates"
            assert not (free & committed), "free slot holds a hash"
            assert not (free & self._reserved), "free slot is reserved"
            assert not (self._reserved & committed), "reserved committed"
            assert len(free) + len(committed) + len(self._reserved) \
                == self.num_slots, "slots lost"
            assert sorted(self._order) == sorted(committed), "order drift"
            for h, s in self._slot_of.items():
                assert self._hash_of.get(s) == h, "hash maps disagree"
            assert len(self._slot_of) == len(self._hash_of)
            for s, n in self._pins.items():
                assert n > 0, (s, n)
                assert s in committed, f"pin on a payload-less slot {s}"


@dataclass
class CacheStats:
    num_blocks: int          # allocatable blocks (excludes the trash block)
    blocks_in_use: int       # distinct blocks with refcount > 0
    num_tables: int
    shared_blocks: int = 0   # blocks with refcount >= 2
    cached_free: int = 0     # free blocks still holding a registered hash

    @property
    def utilization(self) -> float:
        return self.blocks_in_use / max(self.num_blocks, 1)


class BlockManager:
    """Refcounted free-list allocator over page-pool rows + block tables.

    Pure host-side bookkeeping: allocation never touches device memory
    (pages are preallocated); it only decides which pool rows a request's
    tokens may occupy. The one device-side consequence is ``cow``, which
    returns the page copy the *caller* must perform.
    """

    def __init__(self, num_blocks: int, block_size: int,
                 num_host_blocks: int = 0,
                 shared_index: SharedPrefixIndex | None = None):
        assert num_blocks >= 2 and block_size >= 1
        self.num_blocks = num_blocks
        self.block_size = block_size
        # Cross-replica prefix sharing: registered hashes are queued for
        # publication into the process-global index (the engine drains the
        # queue and d2h-copies the payloads at step boundaries).
        self.shared = shared_index
        self._publish_q: list[tuple[int, bytes]] = []
        # LIFO free list: recently-freed (cache-warm) blocks are reused first
        self._free = list(range(num_blocks - 1, TRASH_BLOCK, -1))
        self._tables: dict[int, list[int]] = {}
        self._ref: dict[int, int] = {}        # block -> refcount (> 0 only)
        self._hash_of: dict[int, bytes] = {}  # block -> content hash
        self._block_of: dict[bytes, int] = {}  # content hash -> block
        # Host tier (swap-preemption): slots in a pinned host pool, one
        # slot holding one block's pages across every layer. A swapped
        # request owns its slots exclusively until swap_in/swap_discard.
        self.num_host_blocks = num_host_blocks
        self._host_free = list(range(num_host_blocks - 1, -1, -1))
        self._swapped: dict[int, list[int]] = {}      # rid -> host slots
        self._host_hash_of: dict[int, bytes] = {}     # slot -> content hash
        self._host_block_of: dict[bytes, int] = {}    # content hash -> slot

    # -- queries ----------------------------------------------------------

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    @property
    def num_free(self) -> int:
        return len(self._free)

    def can_allocate(self, n_tokens: int) -> bool:
        return self.blocks_for(n_tokens) <= self.num_free

    def table(self, rid: int) -> list[int]:
        return list(self._tables[rid])

    def refcount(self, block: int) -> int:
        return self._ref.get(block, 0)

    def stats(self) -> CacheStats:
        return CacheStats(
            num_blocks=self.num_blocks - 1,
            blocks_in_use=len(self._ref),
            num_tables=len(self._tables),
            shared_blocks=sum(1 for r in self._ref.values() if r >= 2),
            cached_free=sum(1 for b in self._free if b in self._hash_of))

    # -- prefix-cache index -----------------------------------------------

    def register(self, block: int, h: bytes) -> None:
        """Publish a *full* block's content hash so later requests can share
        it. First writer wins; re-registration is a no-op."""
        assert block != TRASH_BLOCK
        if h in self._block_of or block in self._hash_of:
            return
        self._hash_of[block] = h
        self._block_of[h] = block
        if self.shared is not None and not self.shared.contains(h):
            self._publish_q.append((block, h))

    def match(self, hashes: list[bytes]) -> list[int]:
        """Longest prefix of ``hashes`` resolving to cached blocks."""
        out = []
        for h in hashes:
            b = self._block_of.get(h)
            if b is None:
                break
            out.append(b)
        return out

    def deregister(self, block: int) -> None:
        """Withdraw a block from the prefix cache before (re)writing it in
        place — e.g. the final block of a full-prompt hit adopted with
        refcount 1, whose last position is about to be recomputed. Leaving
        it registered would let a concurrent admission adopt a block that
        still has a pending write."""
        self._deregister(block)

    def _deregister(self, block: int) -> None:
        h = self._hash_of.pop(block, None)
        if h is not None:
            del self._block_of[h]

    def drain_publishable(self) -> list[tuple[int, bytes]]:
        """Queued (block, hash) registrations still current — i.e. the
        block still carries that hash in the local index, so its pages
        hold exactly the hashed content. Stale entries (deregistered for
        an in-place write, or evicted and rewritten since registration)
        are dropped. The caller d2h-copies survivors into the shared
        index. Clears the queue."""
        out = [(b, h) for b, h in self._publish_q
               if self._hash_of.get(b) == h]
        self._publish_q.clear()
        return out

    def _pop_free(self) -> int:
        """Take a free block for new content. Prefer blocks with no cached
        hash (LIFO — recently freed, cache-warm on device) so prefix-cache
        entries survive as long as possible; when only cached blocks
        remain, evict the *least recently freed* (front of the list) so
        the warmest entries — e.g. a preemption victim's just-freed
        blocks, which its recompute is about to re-adopt — go last."""
        for i in range(len(self._free) - 1, -1, -1):
            if self._free[i] not in self._hash_of:
                return self._free.pop(i)
        b = self._free.pop(0)
        self._deregister(b)          # its content is about to be rewritten
        return b

    # -- mutations --------------------------------------------------------

    def allocate(self, rid: int, n_tokens: int) -> list[int]:
        """Fresh table covering n_tokens. Raises KeyError on double-alloc,
        MemoryError when the pool can't cover it (caller admits later)."""
        if rid in self._tables:
            raise KeyError(f"request {rid} already has a table")
        n = self.blocks_for(n_tokens)
        if n > self.num_free:
            raise MemoryError(f"need {n} blocks, have {self.num_free}")
        self._tables[rid] = t = []
        for _ in range(n):
            b = self._pop_free()
            self._ref[b] = 1
            t.append(b)
        return self.table(rid)

    def adopt(self, rid: int, blocks: list[int]) -> list[int]:
        """Start rid's table from already-populated (cached/shared) blocks:
        refcount each, reviving any that sit in the free list."""
        if rid in self._tables:
            raise KeyError(f"request {rid} already has a table")
        t = []
        for b in blocks:
            assert b != TRASH_BLOCK
            if self._ref.get(b, 0) == 0:
                self._free.remove(b)          # revive a cached free block
            self._ref[b] = self._ref.get(b, 0) + 1
            t.append(b)
        self._tables[rid] = t
        return self.table(rid)

    def fork(self, src_rid: int, dst_rid: int) -> list[int]:
        """dst shares every block of src (refcount++). Writers must go
        through ``cow`` before touching a shared block."""
        if dst_rid in self._tables:
            raise KeyError(f"request {dst_rid} already has a table")
        t = list(self._tables[src_rid])
        for b in t:
            self._ref[b] += 1
        self._tables[dst_rid] = t
        return self.table(dst_rid)

    def ensure(self, rid: int, n_tokens: int) -> bool:
        """Grow rid's table to cover n_tokens. False (no change) on OOM —
        the caller preempts somebody and retries."""
        t = self._tables[rid]
        need = self.blocks_for(n_tokens) - len(t)
        if need <= 0:
            return True
        if need > self.num_free:
            return False
        for _ in range(need):
            b = self._pop_free()
            self._ref[b] = 1
            t.append(b)
        return True

    def cow(self, rid: int, idx: int) -> int | None:
        """Make table slot ``idx`` exclusively owned before a write.

        Shared (refcount >= 2) -> swap in a fresh block and return its id;
        the caller must copy the old block's pages into it. Exclusive ->
        None (write in place). Raises MemoryError when no block is free."""
        t = self._tables[rid]
        old = t[idx]
        if self._ref[old] <= 1:
            return None
        if not self._free:
            raise MemoryError("copy-on-write needs a free block")
        new = self._pop_free()
        self._ref[old] -= 1
        self._ref[new] = 1
        t[idx] = new
        return new

    def truncate(self, rid: int, n_tokens: int) -> list[int]:
        """Rewind rid's table to cover only ``n_tokens``, freeing the tail.

        The speculative-decoding rollback: a verify step reserves blocks
        for up to k+1 lookahead positions; when fewer draft tokens are
        accepted the tail blocks past the surviving context are returned
        to the pool. Dropped blocks follow ``free`` semantics — refcount
        decremented, content hash retained while on the free list (the
        engine only ever truncates past ``num_computed``, so a dropped
        block is never one whose hash was published for *this* request's
        stream). Returns the freed block ids (newest first)."""
        t = self._tables[rid]
        keep = self.blocks_for(max(n_tokens, 0))
        dropped = []
        while len(t) > keep:
            b = t.pop()
            self._ref[b] -= 1
            if self._ref[b] == 0:
                del self._ref[b]
                self._free.append(b)
            dropped.append(b)
        return dropped

    def free(self, rid: int) -> None:
        """Drop rid's references. Blocks keep their content hash while on
        the free list (pages aren't written while free), so they stay
        matchable until ``_pop_free`` hands them out for new content."""
        for b in self._tables.pop(rid):
            self._ref[b] -= 1
            if self._ref[b] == 0:
                del self._ref[b]
                self._free.append(b)

    # -- host tier (swap-preemption) --------------------------------------

    def is_swapped(self, rid: int) -> bool:
        return rid in self._swapped

    @property
    def num_host_free(self) -> int:
        return len(self._host_free)

    def can_swap_out(self, rid: int) -> bool:
        return len(self._tables.get(rid, ())) <= len(self._host_free)

    def swap_out(self, rid: int) -> list[tuple[int, int]]:
        """Move rid's table to host slots. Returns the (device_block,
        host_slot) copy pairs the *caller* must perform — on the pre-step
        pool contents, before anything in the same step can rewrite a
        freed block (the engine issues the d2h gather first, then lets it
        overlap the jitted step). Device blocks follow ``free`` semantics
        (hash retained while on the free list), so a quick swap-in can
        revive them without any copy at all; hashed blocks also publish
        into the host index so *other* requests' admissions can
        prefix-hit swapped content (``match_host``)."""
        t = self._tables.pop(rid)
        pairs = []
        slots = []
        for b in t:
            s = self._host_free.pop()
            pairs.append((b, s))
            slots.append(s)
            h = self._hash_of.get(b)
            if h is not None and h not in self._host_block_of:
                self._host_hash_of[s] = h
                self._host_block_of[h] = s
            self._ref[b] -= 1
            if self._ref[b] == 0:
                del self._ref[b]
                self._free.append(b)
        self._swapped[rid] = slots
        return pairs

    def can_swap_in(self, rid: int) -> bool:
        # Worst case every slot needs a fresh device block; hashed slots
        # whose device twin survived on the free list revive for free.
        return len(self._swapped.get(rid, ())) <= self.num_free

    def swap_in(self, rid: int) -> tuple[list[int], list[tuple[int, int]]]:
        """Rebuild rid's device table from its host slots. Returns
        (table, copy_pairs) where copy_pairs is the (host_slot,
        device_block) h2d copies the caller must perform *before* the
        step computes over them. A hashed slot whose original device
        block still sits on the free list (hash intact — pages are never
        written while free) is revived in place with no copy."""
        if rid in self._tables:
            raise KeyError(f"request {rid} already has a table")
        slots = self._swapped.pop(rid)
        pairs = []
        t = []
        for s in slots:
            h = self._host_hash_of.pop(s, None)
            if h is not None and self._host_block_of.get(h) == s:
                del self._host_block_of[h]
            b = self._block_of.get(h) if h is not None else None
            if b is not None:
                # device twin survived: revive, no copy
                if self._ref.get(b, 0) == 0:
                    self._free.remove(b)
                self._ref[b] = self._ref.get(b, 0) + 1
            else:
                b = self._pop_free()
                self._ref[b] = 1
                pairs.append((s, b))
                if h is not None:
                    self.register(b, h)
            t.append(b)
            self._host_free.append(s)
        self._tables[rid] = t
        return self.table(rid), pairs

    def swap_discard(self, rid: int) -> None:
        """Drop a swapped-out request's host slots without copying back
        (abort while swapped). Host hashes go with the slots — unlike the
        device free list there is no in-place revival of a freed slot."""
        for s in self._swapped.pop(rid):
            h = self._host_hash_of.pop(s, None)
            if h is not None and self._host_block_of.get(h) == s:
                del self._host_block_of[h]
            self._host_free.append(s)

    def match_host(self, hashes: list[bytes]) -> list[int]:
        """Longest prefix of ``hashes`` resolving to *host* slots — used
        by admission after the device index runs dry, so a prefix that
        only survives swapped-out is copied back instead of recomputed."""
        out = []
        for h in hashes:
            s = self._host_block_of.get(h)
            if s is None:
                break
            out.append(s)
        return out

    def host_copy_in(self, rid: int, slots: list[int],
                     hashes: list[bytes]) -> tuple[list[int],
                                                   list[tuple[int, int]]]:
        """Non-destructive host prefix hit: copy ``slots`` (still owned
        by their swapped-out request) into freshly allocated device
        blocks appended to rid's table (created if absent — admission
        adopts the device-hit prefix first, then extends it from here),
        registering ``hashes`` on the new blocks. Returns (blocks,
        (host_slot, device_block) copy pairs)."""
        if len(slots) > self.num_free:
            raise MemoryError(
                f"need {len(slots)} blocks, have {self.num_free}")
        t = self._tables.setdefault(rid, [])
        pairs = []
        for s, h in zip(slots, hashes):
            b = self._pop_free()
            self._ref[b] = 1
            t.append(b)
            pairs.append((s, b))
            self.register(b, h)
        return self.table(rid), pairs

    def check(self) -> None:
        """Invariants: refcounts == table references, free list exact,
        hash index consistent, no trash block anywhere."""
        counts: dict[int, int] = {}
        for rid, t in self._tables.items():
            assert len(set(t)) == len(t), f"table {rid} repeats a block"
            for b in t:
                assert b != TRASH_BLOCK, (rid, t)
                counts[b] = counts.get(b, 0) + 1
        assert counts == self._ref, "refcounts drifted from table refs"
        free_set = set(self._free)
        assert len(free_set) == len(self._free), "free list duplicates"
        assert not (free_set & set(self._ref)), "free list overlaps tables"
        assert len(self._ref) + len(self._free) == self.num_blocks - 1
        for b, h in self._hash_of.items():
            assert b != TRASH_BLOCK
            assert self._block_of.get(h) == b, "hash maps disagree"
        assert len(self._block_of) == len(self._hash_of)
        # host tier
        owned = [s for slots in self._swapped.values() for s in slots]
        assert len(set(owned)) == len(owned), "host slot double-owned"
        host_free = set(self._host_free)
        assert len(host_free) == len(self._host_free), "host free dups"
        assert not (host_free & set(owned)), "host free overlaps swapped"
        assert len(owned) + len(self._host_free) == self.num_host_blocks
        for s, h in self._host_hash_of.items():
            assert s not in host_free, "hashed host slot is free"
            assert self._host_block_of.get(h) == s, "host hash disagree"
        assert len(self._host_block_of) == len(self._host_hash_of)
