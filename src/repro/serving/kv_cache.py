"""Block-table KV-cache management for continuous-batching serving.

The device side is a pytree of page pools, one {"k","v"} pair per scanned
layer stack, each shaped ``(NP, num_blocks, block_size, K, hd)`` — the
vLLM layout with this repo's layer-stacked leading dim. Every layer uses
the *same* block ids (one table per sequence, all layers), so allocating a
block grants one ``block_size``-token slice of KV capacity across the whole
model at once.

The host side is ``BlockManager``: a free list plus per-request block
tables. Block 0 is reserved as the *trash block* — idle serving slots carry
all-zero table rows, so the decode step's unconditional KV write for an
inactive slot lands there and corrupts nothing.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.transformer import period_structure

TRASH_BLOCK = 0


def attn_layer_stacks(cfg: ModelConfig) -> list[str]:
    """Names of the scanned cache sub-stacks that hold attention KV."""
    kinds, _ = period_structure(cfg)
    out = [f"sub{i}" for i, k in enumerate(kinds) if k != "mamba"]
    if cfg.shared_attn_period:
        out.append("shared")
    return out


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     dtype=jnp.bfloat16):
    """Zero page pools matching ``transformer.decode_step_paged``."""
    kinds, NP = period_structure(cfg)
    shape = (NP, num_blocks, block_size, cfg.num_kv_heads, cfg.head_dim)
    cache = {}
    for i, kind in enumerate(kinds):
        if kind == "mamba":
            raise ValueError("paged cache: attention-only models")
        cache[f"sub{i}"] = {"k": jnp.zeros(shape, dtype),
                            "v": jnp.zeros(shape, dtype)}
    if cfg.shared_attn_period:
        cache["shared"] = {"k": jnp.zeros(shape, dtype),
                           "v": jnp.zeros(shape, dtype)}
    return cache


def block_bytes(cfg: ModelConfig, block_size: int, dtype_bytes: int = 2):
    """HBM bytes one block id costs across every layer's k+v pools."""
    kinds, NP = period_structure(cfg)
    n_stacks = len(attn_layer_stacks(cfg))
    return (2 * NP * n_stacks * block_size * cfg.num_kv_heads
            * cfg.head_dim * dtype_bytes)


@dataclass
class CacheStats:
    num_blocks: int          # allocatable blocks (excludes the trash block)
    blocks_in_use: int
    num_tables: int

    @property
    def utilization(self) -> float:
        return self.blocks_in_use / max(self.num_blocks, 1)


class BlockManager:
    """Free-list allocator over page-pool rows + per-request block tables.

    Pure host-side bookkeeping: allocation never touches device memory
    (pages are preallocated); it only decides which pool rows a request's
    tokens may occupy.
    """

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks >= 2 and block_size >= 1
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO free list: recently-freed (cache-warm) blocks are reused first
        self._free = list(range(num_blocks - 1, TRASH_BLOCK, -1))
        self._tables: dict[int, list[int]] = {}

    # -- queries ----------------------------------------------------------

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    @property
    def num_free(self) -> int:
        return len(self._free)

    def can_allocate(self, n_tokens: int) -> bool:
        return self.blocks_for(n_tokens) <= self.num_free

    def table(self, rid: int) -> list[int]:
        return list(self._tables[rid])

    def stats(self) -> CacheStats:
        in_use = sum(len(t) for t in self._tables.values())
        return CacheStats(num_blocks=self.num_blocks - 1,
                          blocks_in_use=in_use,
                          num_tables=len(self._tables))

    # -- mutations --------------------------------------------------------

    def allocate(self, rid: int, n_tokens: int) -> list[int]:
        """Fresh table covering n_tokens. Raises KeyError on double-alloc,
        MemoryError when the pool can't cover it (caller admits later)."""
        if rid in self._tables:
            raise KeyError(f"request {rid} already has a table")
        n = self.blocks_for(n_tokens)
        if n > self.num_free:
            raise MemoryError(f"need {n} blocks, have {self.num_free}")
        self._tables[rid] = [self._free.pop() for _ in range(n)]
        return self.table(rid)

    def ensure(self, rid: int, n_tokens: int) -> bool:
        """Grow rid's table to cover n_tokens. False (no change) on OOM —
        the caller preempts somebody and retries."""
        t = self._tables[rid]
        need = self.blocks_for(n_tokens) - len(t)
        if need <= 0:
            return True
        if need > self.num_free:
            return False
        for _ in range(need):
            t.append(self._free.pop())
        return True

    def free(self, rid: int) -> None:
        for b in self._tables.pop(rid):
            self._free.append(b)

    def check(self) -> None:
        """Invariants: disjoint tables, no trash block, full accounting."""
        seen: set[int] = set()
        for rid, t in self._tables.items():
            for b in t:
                assert b != TRASH_BLOCK, (rid, t)
                assert b not in seen, f"block {b} double-owned"
                seen.add(b)
        assert not (seen & set(self._free)), "free list overlaps tables"
        assert len(seen) + len(self._free) == self.num_blocks - 1
