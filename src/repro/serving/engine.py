"""Continuous-batching inference engine over a block-paged KV cache.

One ``InferenceEngine`` owns: model params, the paged KV pools, a
``BlockManager`` and a ``Scheduler``. Every iteration is **one jitted
step** spending a token budget (``max_num_batched_tokens``):

    while work:
        plan = scheduler.schedule()       # decodes (1 tok each) + one
                                          # prefill chunk, within budget
        apply the plan's COW page copies
        one jitted step:
            chunk: C-token slice of one prompt, attention against the
                paged cache (prior chunks read through the block table,
                this chunk's KV scattered in), logits at its last token
            decode: full max_batch-wide batch, one token per running slot
            per-slot sampling over decode logits + the chunk's logits
        append sampled tokens; retire on EOS/max_new; publish content
            hashes of newly-full blocks (prefix cache)

The decode half always runs at the full ``max_batch`` width — idle slots
are masked with ctx_len 0 and their KV writes land in the trash block.
The chunk half always runs at the fixed ``chunk_width``. So there are
exactly **two** compiled executables (step with / without a chunk)
regardless of occupancy or prompt length — the per-prompt-length bucket
compilation family is gone, and a long prompt streams in chunk by chunk
while running decodes keep making progress every step.

Time is measured in engine steps; request arrivals are given in the same
unit so runs are deterministic and testable (launch/serve.py maps Poisson
arrival times onto it).
"""

from __future__ import annotations

import functools
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ParallelConfig
from repro.models import api
from repro.models import transformer
from repro.serving.kv_cache import (TRASH_BLOCK, BlockManager, block_bytes,
                                    init_paged_cache)
from repro.serving.sampling import sample_tokens
from repro.serving.scheduler import (Request, SamplingParams, Scheduler,
                                     StepPlan)

__all__ = ["InferenceEngine", "Request", "SamplingParams"]

# oldest per-request latency records are dropped past this, so a
# long-running serve loop doesn't grow stats["latency"] without bound
LATENCY_RECORD_CAP = 4096


def _engine_supported(cfg: ModelConfig) -> str | None:
    if cfg.ssm is not None:
        return "SSM state is not block-pageable"
    if cfg.encoder_layers:
        return "encoder-decoder cross caches are not paged"
    if cfg.frontend is not None:
        return "modality frontends need per-request position streams"
    return None


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, mesh, pcfg: ParallelConfig = None,
                 *, max_batch: int = 8, block_size: int = 16,
                 max_len: int = 128, num_blocks: int | None = None,
                 max_num_batched_tokens: int | None = None,
                 enable_prefix_caching: bool = True,
                 debug_invariants: bool = False,
                 seed: int = 0, params=None):
        why = _engine_supported(cfg)
        if why is not None:
            raise ValueError(
                f"paged engine does not support {cfg.name}: {why}; "
                "use the static launch.serve.Server path")
        self.cfg, self.mesh = cfg, mesh
        self.pcfg = pcfg or ParallelConfig(remat="none")
        self.block_size = block_size
        self.max_len = max_len
        self.max_blocks_per_seq = -(-max_len // block_size)
        if num_blocks is None:
            # every slot can reach max_len; +1 for the trash block
            num_blocks = max_batch * self.max_blocks_per_seq + 1
        if max_num_batched_tokens is None:
            max_num_batched_tokens = max_batch + 2 * block_size
        self.max_num_batched_tokens = max_num_batched_tokens
        # static chunk-buffer width: a full decode batch plus a full chunk
        # together stay within the budget; no chunk can exceed max_len, so
        # a huge budget must not widen the compiled buffer past it
        self.chunk_width = min(max_num_batched_tokens - max_batch, max_len)
        self.bm = BlockManager(num_blocks, block_size)
        self.sched = Scheduler(self.bm, max_batch, self.max_blocks_per_seq,
                               max_num_batched_tokens, self.chunk_width,
                               enable_prefix_caching=enable_prefix_caching)
        self.max_batch = max_batch
        self.debug_invariants = debug_invariants

        with jax.set_mesh(mesh):
            if params is None:
                params_f32, _ = api.init_model(cfg, jax.random.key(seed))
                params = jax.tree.map(
                    lambda x: x.astype(jnp.bfloat16), params_f32)
            self.params = params
            self.cache = init_paged_cache(cfg, num_blocks, block_size)

        self._step_chunk = jax.jit(
            functools.partial(self._step_fn, has_chunk=True),
            donate_argnums=(1,))
        self._step_plain = jax.jit(
            functools.partial(self._step_fn, has_chunk=False),
            donate_argnums=(1,))
        self._copy_block = jax.jit(self._copy_block_fn, donate_argnums=(0,))

        self.stats = {"steps": 0, "prefill_chunks": 0, "preemptions": 0,
                      "tokens": 0, "cache_hit_tokens": 0, "cow_copies": 0,
                      "peak_block_utilization": 0.0, "peak_blocks_in_use": 0,
                      "latency": {},
                      "kv_cache_mib": round(
                          num_blocks * block_bytes(cfg, block_size)
                          / 2 ** 20, 3)}
        self.step_count = 0           # virtual clock: one step() = one tick

    # -- jitted bodies -----------------------------------------------------

    def _step_fn(self, params, cache, c_tok, c_start, c_len, c_table,
                 d_tok, d_pos, d_tables, d_active,
                 temps, top_ks, seeds, counters, *, has_chunk):
        """One budgeted step: optional prefill chunk, then the wide decode.

        The two halves touch disjoint pages — a request is either in the
        chunk or the decode batch, shared prefix blocks are read-only to
        both (COW guarantees no write lands in a shared block) — so their
        in-step order is irrelevant.

        Sampling rows: 0..B-1 are the decode slots, row B is the chunk's
        last valid token (consumed only when the chunk finishes a prompt).
        """
        if has_chunk:
            logits_c, cache = transformer.prefill_chunk_paged(
                params, cache,
                {"tokens": c_tok, "q_start": c_start, "q_lens": c_len,
                 "block_tables": c_table, "ctx_lens": c_start + c_len},
                self.cfg, self.pcfg)
        ctx_lens = jnp.where(d_active, d_pos + 1, 0)
        logits_d, cache = transformer.decode_step_paged(
            params, cache,
            {"token": d_tok[:, None], "pos": d_pos,
             "block_tables": d_tables, "ctx_lens": ctx_lens},
            self.cfg, self.pcfg)
        if not has_chunk:
            logits_c = jnp.zeros_like(logits_d[:1])
        logits = jnp.concatenate([logits_d, logits_c], axis=0)
        nxt = sample_tokens(logits, temps, top_ks, seeds, counters)
        return nxt, cache

    def _copy_block_fn(self, cache, src, dst):
        """Copy one pool row (every layer stack, k and v) — the device half
        of a copy-on-write."""
        return jax.tree.map(lambda p: p.at[:, dst].set(p[:, src]), cache)

    # -- host-side step ----------------------------------------------------

    def _build_arrays(self, plan: StepPlan):
        B, C, nbmax = self.max_batch, self.chunk_width, self.max_blocks_per_seq
        d_tok = np.zeros(B, np.int32)
        d_pos = np.zeros(B, np.int32)
        d_tables = np.zeros((B, nbmax), np.int32)
        d_active = np.zeros(B, bool)
        temps = np.zeros(B + 1, np.float32)
        top_ks = np.zeros(B + 1, np.int32)
        seeds = np.zeros(B + 1, np.int32)
        counters = np.zeros(B + 1, np.int32)

        def samp(i, req):
            temps[i] = req.sampling.temperature
            top_ks[i] = req.sampling.top_k
            seeds[i] = req.sampling.seed
            counters[i] = len(req.out)

        for slot, req in plan.decodes:
            d_active[slot] = True
            d_tok[slot] = req.out[-1]
            d_pos[slot] = req.context_len - 1    # write position of out[-1]
            row = self.bm.table(req.rid)
            d_tables[slot, :len(row)] = row
            samp(slot, req)

        c_tok = np.zeros((1, C), np.int32)
        c_start = np.zeros(1, np.int32)
        c_len = np.zeros(1, np.int32)
        c_table = np.full((1, nbmax), TRASH_BLOCK, np.int32)
        if plan.chunk is not None:
            _, req, n = plan.chunk
            toks = req.prefill_tokens()
            c_tok[0, :n] = toks[req.num_computed:req.num_computed + n]
            c_start[0] = req.num_computed
            c_len[0] = n
            row = self.bm.table(req.rid)
            c_table[0, :len(row)] = row
            samp(B, req)
        return (jnp.asarray(c_tok), jnp.asarray(c_start),
                jnp.asarray(c_len), jnp.asarray(c_table),
                jnp.asarray(d_tok), jnp.asarray(d_pos),
                jnp.asarray(d_tables), jnp.asarray(d_active),
                jnp.asarray(temps), jnp.asarray(top_ks),
                jnp.asarray(seeds), jnp.asarray(counters))

    def _lat(self, rid: int) -> dict:
        return self.stats["latency"].setdefault(rid, {})

    def _note_arrival(self, req: Request) -> None:
        # monotonic: the *_wall fields are only ever differenced, and an
        # NTP step must not produce negative latencies
        self._lat(req.rid).update(arrival_step=self.step_count,
                                  arrival_wall=time.monotonic())

    def _append_token(self, slot: int, req: Request, tok: int) -> None:
        req.out.append(tok)
        self.stats["tokens"] += 1
        if len(req.out) == 1:
            self._lat(req.rid).update(first_token_step=self.step_count,
                                      first_token_wall=time.monotonic())
        self.sched.note_progress(req)
        if req.done:
            self._lat(req.rid).update(done_step=self.step_count,
                                      done_wall=time.monotonic())
            lat = self.stats["latency"]
            if len(lat) > LATENCY_RECORD_CAP:
                # evict oldest *completed* records only — an in-flight
                # request must keep its arrival marks for TTFT reporting
                for rid in list(lat):
                    if "done_step" in lat[rid]:
                        del lat[rid]
                        if len(lat) <= LATENCY_RECORD_CAP:
                            break
            self.sched.retire(slot)

    def step(self) -> bool:
        """One engine iteration. Returns True when any work ran."""
        with jax.set_mesh(self.mesh):
            plan = self.sched.schedule()
            self.stats["preemptions"] = self.sched.n_preemptions
            self.stats["cache_hit_tokens"] = self.sched.cache_hit_tokens
            st = self.bm.stats()
            self.stats["peak_block_utilization"] = max(
                self.stats["peak_block_utilization"], st.utilization)
            self.stats["peak_blocks_in_use"] = max(
                self.stats["peak_blocks_in_use"], st.blocks_in_use)
            if self.debug_invariants:
                self._check_invariants(plan)
            for src, dst in plan.copies:
                self.stats["cow_copies"] += 1
                self.cache = self._copy_block(
                    self.cache, jnp.asarray(src, jnp.int32),
                    jnp.asarray(dst, jnp.int32))
            if plan.scheduled_tokens == 0:
                # no compute, but an admission (e.g. a full prefix-cache
                # hit that is immediately decode-ready) is still progress
                if plan.admitted:
                    self.step_count += 1
                return plan.admitted > 0
            arrays = self._build_arrays(plan)
            step_exec = (self._step_chunk if plan.chunk is not None
                         else self._step_plain)
            nxt, self.cache = step_exec(self.params, self.cache, *arrays)
            nxt = np.asarray(nxt)
            for slot, req in plan.decodes:
                req.num_computed += 1
                self._append_token(slot, req, int(nxt[slot]))
            if plan.chunk is not None:
                slot, req, n = plan.chunk
                req.num_computed += n
                self.stats["prefill_chunks"] += 1
                if req.num_computed == req.context_len:
                    self._append_token(slot, req, int(nxt[self.max_batch]))
                else:
                    self.sched.note_progress(req)
            self.stats["steps"] += 1
            self.step_count += 1
            if self.debug_invariants:
                self.bm.check()
            return True

    def _check_invariants(self, plan: StepPlan) -> None:
        self.bm.check()
        bs = self.block_size
        for slot, req in self.sched.running.items():
            t = self.bm.table(req.rid)
            assert len(t) <= self.max_blocks_per_seq, (req.rid, len(t))
            assert len(t) * bs >= req.num_computed, \
                f"request {req.rid}: table does not cover computed KV"
        if plan.chunk is not None:
            _, req, n = plan.chunk
            t = self.bm.table(req.rid)
            assert len(t) * bs >= req.num_computed + n
            # COW guarantee: the chunk writes only exclusively-owned blocks
            lo, hi = req.num_computed // bs, (req.num_computed + n - 1) // bs
            for j in range(lo, hi + 1):
                assert self.bm.refcount(t[j]) == 1, \
                    f"chunk would write shared block {t[j]}"
        for slot, req in plan.decodes:
            t = self.bm.table(req.rid)
            j = (req.context_len - 1) // bs
            assert self.bm.refcount(t[j]) == 1, \
                f"decode would write shared block {t[j]}"
        assert plan.scheduled_tokens <= self.max_num_batched_tokens

    def run(self, requests: list[Request],
            arrival_steps: list[int] | None = None) -> dict[int, np.ndarray]:
        """Serve ``requests`` to completion. ``arrival_steps[i]`` is the
        engine-step index at which request i becomes visible (default: all
        at step 0). Returns {rid: generated token array}; wall-clock,
        throughput and per-request latency land in ``self.stats``."""
        if arrival_steps is None:
            arrival_steps = [0] * len(requests)
        for r in requests:
            self.sched.validate(r)         # fail fast, not at arrival time
        pending = deque(sorted(zip(arrival_steps, range(len(requests))),
                               key=lambda t: t[0]))
        t0 = time.time()
        tok0 = self.stats["tokens"]
        while pending or self.sched.has_work:
            while pending and pending[0][0] <= self.step_count:
                req = requests[pending.popleft()[1]]
                self.sched.add(req)
                self._note_arrival(req)
            if not self.sched.has_work and pending:
                self.step_count = pending[0][0]      # idle: jump the clock
                continue
            if not self.step():
                # defensive: the scheduler admits whenever a slot is free
                # and raises MemoryError itself when the pool can't ever
                # fit, so reaching this means a scheduling-policy bug
                raise RuntimeError(
                    "engine stuck: scheduler made no progress with work "
                    f"pending — {self.bm.stats()}")
        dt = time.time() - t0
        self.stats["wall_s"] = round(dt, 3)
        self.stats["tok_s"] = round((self.stats["tokens"] - tok0)
                                    / max(dt, 1e-9), 1)
        return {r.rid: np.asarray(r.out, np.int32) for r in requests}
