"""Continuous-batching inference engine over per-family model runners.

One ``InferenceEngine`` owns: model params, a :class:`ModelRunner` (which
declares the cache kinds it needs and builds the device cache), the host
cache managers (``BlockManager`` for paged KV, ``SlotStateCache`` /
``EncoderCache`` for constant-size per-slot state), and a ``Scheduler``.
Every iteration is **one jitted step** spending a token budget
(``max_num_batched_tokens``):

    while work:
        plan = scheduler.schedule()       # decodes (1 tok each) + one
                                          # prefill chunk, within budget
        run admission-time encode passes (enc-dec), apply COW page copies
        one jitted runner step:
            chunk: C-token slice of one prompt (attention against the
                paged cache and/or SSM state continuation), logits at its
                last token
            decode: full max_batch-wide batch, one token per running slot
            per-slot sampling over decode logits + the chunk's logits
        append sampled tokens; retire on EOS/max_new; publish content
            hashes of newly-full blocks (paged prefix cache only)

The decode half always runs at the full ``max_batch`` width — idle slots
are masked with ctx_len 0: their KV writes land in the trash block and
their slot-state rows are reverted after the step. The chunk half always
runs at the fixed ``chunk_width``. So there are exactly **two** compiled
step executables per model family (with / without a chunk) regardless of
occupancy or prompt length, plus one encode executable for enc-dec.

With speculative decoding (``num_speculative_tokens`` = k > 0, paged
transformers only) the decode half is the draft-and-verify step: k draft
proposals per slot, one k+1-wide target verify row, in-jit rejection
sampling (greedy byte-identical to plain decode), and the host appends
the accepted prefix and rewinds rejected lookahead blocks via
``BlockManager.truncate``. See docs/speculative.md.

Time is measured in engine steps; request arrivals are given in the same
unit so runs are deterministic and testable (launch/serve.py maps Poisson
arrival times onto it).
"""

from __future__ import annotations

import functools
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ParallelConfig
from repro.models import api, quant
from repro.serving.cache import (EncoderCache, SlotStateCache,
                                 encoder_cache_bytes, slot_state_bytes)
from repro.serving.kv_cache import (TRASH_BLOCK, BlockManager, block_bytes)
from repro.serving.runners import make_runner
from repro.serving.sampling import SamplingBuffer
from repro.serving.scheduler import (Request, SamplingParams, Scheduler,
                                     StepPlan, SwapCostModel)
from repro.serving.stats import Histogram, SECONDS_BUCKETS, STEP_BUCKETS
from repro.spmd import sharding as shd

__all__ = ["InferenceEngine", "Request", "SamplingParams"]

# oldest completed per-request latency records are dropped past this, so
# a long-running serve loop doesn't grow stats["latency"] without bound;
# nothing is lost — every retirement is first aggregated into the
# fixed-size TTFT/e2e histograms (`self.hist`) that /metrics exports
LATENCY_RECORD_CAP = 4096


def pack_ragged(rows: list[np.ndarray], width: int,
                max_seqs: int) -> tuple[np.ndarray, np.ndarray,
                                        np.ndarray, np.ndarray]:
    """Pack variable-length rows back-to-back into the flat ragged-batch
    layout: ``(tok (width,), seq (width,), starts (S,), ends (S,))`` with
    row i owning flat positions ``[starts[i], ends[i])`` and ``seq``
    holding the owner id per flat position (pad positions keep owner 0 —
    they fall outside every ``[start, end)`` range, so ownership masks
    reject them)."""
    assert len(rows) <= max_seqs
    tok = np.zeros(width, np.int32)
    seq = np.zeros(width, np.int32)
    starts = np.zeros(max_seqs, np.int32)
    ends = np.zeros(max_seqs, np.int32)
    off = 0
    for i, r in enumerate(rows):
        n = len(r)
        assert off + n <= width
        tok[off:off + n] = r
        seq[off:off + n] = i
        starts[i] = off
        ends[i] = off + n
        off += n
    return tok, seq, starts, ends


def unpack_ragged(tok: np.ndarray, starts: np.ndarray, ends: np.ndarray,
                  n_rows: int) -> list[np.ndarray]:
    """Inverse of :func:`pack_ragged` for the first ``n_rows`` rows."""
    return [np.asarray(tok[starts[i]:ends[i]]).copy()
            for i in range(n_rows)]


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, mesh, pcfg: ParallelConfig = None,
                 *, max_batch: int = 8, block_size: int = 16,
                 max_len: int = 128, num_blocks: int | None = None,
                 max_num_batched_tokens: int | None = None,
                 enable_prefix_caching: bool = True,
                 debug_invariants: bool = False,
                 seed: int = 0, params=None,
                 draft_cfg: ModelConfig | None = None,
                 num_speculative_tokens: int = 0, draft_params=None,
                 shard_params: bool = False,
                 latency_record_cap: int = LATENCY_RECORD_CAP,
                 prefill_pack: int = 1, kv_dtype: str = "bf16",
                 swap_space_bytes: int = 0, swap_policy: str = "auto",
                 max_logprobs: int = 8, max_stop_len: int = 8,
                 shared_index=None):
        self.cfg, self.mesh = cfg, mesh
        self.pcfg = pcfg or ParallelConfig(remat="none")
        # tensor parallelism over the mesh "model" axis: page pools and
        # the encoder cache shard by kv head; Mamba slot state and (by
        # default) weights stay replicated so engine outputs are bitwise
        # mesh-invariant. All host-side metadata (tables, refcounts,
        # hashes, slots) stays global, so scheduling is identical on
        # every mesh shape (docs/multi-host.md).
        self.tp = shd.serving_tp(mesh)
        self.shard_params = shard_params
        if num_speculative_tokens and draft_cfg is None:
            draft_cfg = cfg          # self-speculation (a fresh-init draft
            #                          unless draft_params shares weights)
        self.draft_cfg = draft_cfg
        self.runner = make_runner(                  # raises if unsupported
            cfg, self.pcfg, draft_cfg=draft_cfg,
            num_speculative_tokens=num_speculative_tokens)
        if self.tp > 1 and self.runner.needs_blocks:
            # fail at construction, not in the jitted step: pools shard by
            # whole kv heads (target and draft pools alike)
            shd.paged_pool_pspec(cfg.num_kv_heads, self.tp)
            if draft_cfg is not None:
                shd.paged_pool_pspec(draft_cfg.num_kv_heads, self.tp)
        spec = self.runner.spec_tokens
        self.block_size = block_size
        self.max_len = max_len
        # block-table rows are widened past max_len by the speculative
        # lookahead: a verify step writes up to spec positions past the
        # context even on a request that retires before using them
        self.max_blocks_per_seq = -(-max_len // block_size) \
            + -(-spec // block_size)
        if num_blocks is None:
            # every slot can reach max_len (+ lookahead); +1 trash block
            num_blocks = max_batch * self.max_blocks_per_seq + 1
        if max_num_batched_tokens is None:
            max_num_batched_tokens = max_batch * (1 + spec) + 2 * block_size
        self.max_num_batched_tokens = max_num_batched_tokens
        # static chunk-buffer width: a full decode batch plus a full chunk
        # together stay within the budget; no chunk can exceed max_len, so
        # a huge budget must not widen the compiled buffer past it
        self.chunk_width = min(
            max_num_batched_tokens - max_batch * (1 + spec), max_len)
        if kv_dtype not in quant.KV_DTYPES:
            raise ValueError(
                f"kv_dtype={kv_dtype!r} not in {sorted(quant.KV_DTYPES)}")
        self.kv_dtype = kv_dtype
        # host-swap tier: pinned host memory for preempted requests' KV,
        # sized in device block units so the BlockManager can account it.
        # Only pure paged runners qualify — slot-state (SSM/hybrid) and
        # encoder caches have no per-block representation to move.
        self._dev_block_bytes = 0
        if self.runner.needs_blocks:
            self._dev_block_bytes = block_bytes(cfg, block_size,
                                                kv_dtype=kv_dtype)
            if draft_cfg is not None:
                self._dev_block_bytes += block_bytes(draft_cfg, block_size,
                                                     kv_dtype=kv_dtype)
        swap_capable = (self.runner.needs_blocks
                        and not self.runner.needs_slots
                        and not self.runner.needs_encoder)
        if swap_space_bytes and not swap_capable:
            raise ValueError(
                "swap_space_bytes requires a pure paged-KV runner (slot "
                "state and encoder caches have no block-swap form)")
        if shared_index is not None and not swap_capable:
            raise ValueError(
                "shared_index (cross-replica prefix sharing) requires a "
                "pure paged-KV runner — the transfer unit is a hashed "
                "block, which slot-state and encoder caches don't have")
        if shared_index is not None and not enable_prefix_caching:
            raise ValueError(
                "shared_index requires enable_prefix_caching=True: the "
                "shared unit is the content-hashed block")
        self.shared_index = shared_index
        num_host_blocks = (swap_space_bytes // self._dev_block_bytes
                           if swap_space_bytes and self._dev_block_bytes
                           else 0)
        self._swap_cost = (SwapCostModel(block_bytes=self._dev_block_bytes,
                                         policy=swap_policy)
                           if num_host_blocks > 0 else None)
        self.bm = (BlockManager(num_blocks, block_size,
                                num_host_blocks=num_host_blocks,
                                shared_index=shared_index)
                   if self.runner.needs_blocks else None)
        self.slot_cache = (SlotStateCache(max_batch)
                           if self.runner.needs_slots else None)
        self.encoder_cache = (EncoderCache(max_batch)
                              if self.runner.needs_encoder else None)
        # prefix caching requires KV that is a pure function of the token
        # prefix — only the paged transformer kind qualifies
        enable_prefix_caching = (enable_prefix_caching
                                 and self.runner.supports_prefix_caching)
        # ragged packed prefill: several prompts' chunks share one flat
        # token batch per step. Only runners with a ragged prefill path
        # can consume multi-chunk plans; everyone else stays single-chunk.
        if not self.runner.supports_packed_prefill:
            prefill_pack = 1
        self.prefill_pack = max(1, prefill_pack)
        # dense per-slot sampling state (full path): param counts, prompt
        # masks and stop rings, bound/released alongside the slot caches
        self.max_logprobs = max_logprobs
        self.max_stop_len = max_stop_len
        self.runner.max_logprobs = max_logprobs
        self.samp_buf = SamplingBuffer(max_batch, cfg.vocab_size,
                                       max_stop_len=max_stop_len,
                                       max_logprobs=max_logprobs)
        self.sched = Scheduler(self.bm, max_batch, self.max_blocks_per_seq,
                               max_num_batched_tokens, self.chunk_width,
                               enable_prefix_caching=enable_prefix_caching,
                               chunk_quantum=self.runner.chunk_quantum,
                               slot_cache=self.slot_cache,
                               encoder_cache=self.encoder_cache,
                               spec_tokens=spec,
                               max_context=-(-max_len // block_size)
                               * block_size,
                               prefill_pack=self.prefill_pack,
                               swap_cost=self._swap_cost,
                               sampling_buffer=self.samp_buf)
        self.max_batch = max_batch
        self.debug_invariants = debug_invariants

        with jax.set_mesh(mesh):
            if params is None:
                params_f32, _ = api.init_model(cfg, jax.random.key(seed))
                params = jax.tree.map(
                    lambda x: x.astype(jnp.bfloat16), params_f32)
            if draft_cfg is not None:
                if draft_params is None:
                    dp_f32, _ = api.init_model(draft_cfg,
                                               jax.random.key(seed + 1))
                    draft_params = jax.tree.map(
                        lambda x: x.astype(jnp.bfloat16), dp_f32)
                params = {"tgt": self._place_params(params, cfg),
                          "dft": self._place_params(draft_params,
                                                    draft_cfg)}
            else:
                params = self._place_params(params, cfg)
            self.params = params
            self.cache = self.runner.init_cache(num_blocks, block_size,
                                                max_batch,
                                                kv_dtype=kv_dtype)
            if self.tp > 1:
                self.cache = jax.device_put(
                    self.cache, shd.serving_cache_shardings(self.cache,
                                                            mesh))

        self._step_chunk = jax.jit(
            functools.partial(self.runner.step, has_chunk=True),
            donate_argnums=(1,))
        self._step_plain = jax.jit(
            functools.partial(self.runner.step, has_chunk=False),
            donate_argnums=(1,))
        # full-sampling executables are built LAZILY: a deployment that
        # never sees a top-p/penalty/logprobs request never compiles (or
        # traces) the full pipeline — the pure-greedy fast-path guard
        # test asserts this dict stays empty on all-greedy traffic
        self._full_steps: dict[bool, object] = {}
        if self.runner.needs_encoder:
            self._encode = jax.jit(self.runner.encode, donate_argnums=(1,))
        if self.runner.needs_blocks:
            self._copy_block = jax.jit(self._copy_block_fn,
                                       donate_argnums=(0,))

        # host pool: one pinned numpy array per paged cache leaf, block-
        # slot-major, aligned with jax.tree.leaves order (deterministic).
        # Scale leaves ride along automatically — they share the pools'
        # rank-5 num_blocks axis.
        self._host_pool: list[np.ndarray] = []
        self._host_block_nbytes = 0
        if num_host_blocks > 0:
            for p in jax.tree.leaves(self.cache):
                if p.ndim >= 2 and p.shape[1] == num_blocks:
                    shape = (num_host_blocks, p.shape[0]) + p.shape[2:]
                    self._host_pool.append(np.zeros(shape, p.dtype))
                    self._host_block_nbytes += int(
                        np.prod(shape[1:])) * p.dtype.itemsize
        if num_host_blocks > 0 or shared_index is not None:
            # the shared-index publish/adopt path reuses the host-swap
            # gather/scatter executables even with no local host tier
            self._swap_gather = jax.jit(self._swap_gather_fn)
            self._swap_scatter = jax.jit(self._swap_scatter_fn,
                                         donate_argnums=(0,))
        if shared_index is not None:
            # shared pool slots mirror the host-tier layout: one slot =
            # one block's pages across every paged leaf (scale sidecars
            # included — they share the num_blocks axis)
            shared_index.attach_pool(
                [((p.shape[0],) + p.shape[2:], p.dtype)
                 for p in jax.tree.leaves(self.cache)
                 if p.ndim >= 2 and p.shape[1] == num_blocks])

        cache_mib = 0.0
        if self.runner.needs_blocks:
            cache_mib += num_blocks * block_bytes(cfg, block_size,
                                                  kv_dtype=kv_dtype)
        if draft_cfg is not None:
            cache_mib += num_blocks * block_bytes(draft_cfg, block_size,
                                                  kv_dtype=kv_dtype)
        if self.runner.needs_slots:
            cache_mib += max_batch * slot_state_bytes(cfg)
        if self.runner.needs_encoder:
            cache_mib += max_batch * encoder_cache_bytes(cfg)
        self.stats = {"steps": 0, "prefill_chunks": 0, "preemptions": 0,
                      "tokens": 0, "prefill_tokens": 0,
                      "quantum_dropped_tokens": 0,
                      "cache_hit_tokens": 0, "cow_copies": 0,
                      "encodes": 0, "requests": 0, "requests_done": 0,
                      "spec_decodes": 0, "spec_emitted": 0,
                      "peak_block_utilization": 0.0, "peak_blocks_in_use": 0,
                      "latency": {},
                      "kv_cache_mib": round(cache_mib / 2 ** 20, 3),
                      "kv_dtype": kv_dtype, "aborts": 0,
                      "stop_hits": 0, "full_sampling_steps": 0,
                      "swap_preemptions": 0, "swap_ins": 0,
                      "host_hit_blocks": 0,
                      "shared_hit_blocks": 0, "shared_published_blocks": 0,
                      "swapped_out_blocks": 0, "swapped_in_blocks": 0,
                      "swapped_out_bytes": 0, "swapped_in_bytes": 0,
                      "swap_space_mib": round(
                          num_host_blocks * self._dev_block_bytes
                          / 2 ** 20, 3)}
        self.step_count = 0           # virtual clock: one step() = one tick
        self.latency_record_cap = latency_record_cap
        # retirement-time latency aggregation: bounded state the metrics
        # endpoint exports no matter how many requests have flowed through
        self.hist = {"ttft_seconds": Histogram(SECONDS_BUCKETS),
                     "e2e_seconds": Histogram(SECONDS_BUCKETS),
                     "ttft_steps": Histogram(STEP_BUCKETS),
                     "e2e_steps": Histogram(STEP_BUCKETS)}
        # streaming hooks for the async front-end (serving/frontend/):
        # on_token(req, tok) after every appended token, on_finish(req)
        # after the request has retired and released its cache resources
        self.on_token = None
        self.on_finish = None

    def _place_params(self, params, cfg: ModelConfig):
        """Place one model's weights on the mesh.

        Default (``shard_params=False``): explicitly *replicated*. Every
        contraction over weights then happens whole on every shard, in the
        same order as on one device, so engine outputs are bitwise
        mesh-invariant — the property the TP equivalence suite enforces.
        Only the page pools / encoder caches (the memory that actually
        grows with traffic) and the attention compute over them shard.

        ``shard_params=True`` additionally shards the weights with the
        standard logical-axis rules (``spmd.sharding.make_rules``): less
        HBM and TP matmul flops, but GSPMD's partial-sum all-reduces
        reorder float adds, so outputs are only argmax-close, not bitwise
        equal, across mesh shapes — don't combine it with tests that
        demand byte identity."""
        if self.tp <= 1:
            return params
        if not self.shard_params:
            return jax.device_put(
                params, jax.sharding.NamedSharding(
                    self.mesh, jax.sharding.PartitionSpec()))
        _, specs = api.abstract_params(cfg)
        rules = shd.make_rules(cfg, self.pcfg)
        return jax.device_put(
            params, shd.tree_shardings(params, specs, rules, self.mesh))

    # -- derived stats (single code path for bench, serve.py and /metrics) -

    @property
    def cache_hit_rate(self) -> float:
        """Fraction of prefill KV served from the prefix cache instead of
        recomputed: hits / (hits + prefill tokens actually computed).
        0.0 before any prefill work (guarded against division by zero)."""
        hits = self.stats["cache_hit_tokens"]
        denom = hits + self.stats["prefill_tokens"]
        return hits / denom if denom else 0.0

    @property
    def preemption_rate(self) -> float:
        """Recompute preemptions per arrived request (a request preempted
        twice counts twice). 0.0 before any arrivals."""
        n = self.stats["requests"]
        return self.stats["preemptions"] / n if n else 0.0

    @property
    def mean_accept_len(self) -> float:
        """Realized tokens per speculative decode slot-step (1.0 = no
        draft token ever survived, 1 + k is the cap); 0.0 when
        speculation is off / no speculative decode has run yet."""
        n = self.stats["spec_decodes"]
        return self.stats["spec_emitted"] / n if n else 0.0

    # -- jitted bodies -----------------------------------------------------

    def _copy_block_fn(self, cache, src, dst):
        """Copy one pool row (every attention layer stack, k and v) — the
        device half of a copy-on-write. Only paged leaves have a
        num_blocks axis; slot-state and encoder leaves are left alone."""
        nb = self.bm.num_blocks

        def leaf(p):
            if p.ndim >= 2 and p.shape[1] == nb:
                return p.at[:, dst].set(p[:, src])
            return p

        return jax.tree.map(leaf, cache)

    def _swap_gather_fn(self, cache, idx):
        """Pull ``idx`` block rows out of every paged leaf, block-major —
        the device half of a d2h swap-out. Issued on *pre-step* pool
        content and materialized to the host pool later (overlapping the
        jitted step), which is safe because the handle pins the pre-
        donation buffers regardless of what rewrites the pool after."""
        nb = self.bm.num_blocks
        return [jnp.moveaxis(p[:, idx], 1, 0)
                for p in jax.tree.leaves(cache)
                if p.ndim >= 2 and p.shape[1] == nb]

    def _swap_scatter_fn(self, cache, idx, vals):
        """Write host rows back into ``idx`` block slots of every paged
        leaf (h2d swap-in). Pad entries target the trash block."""
        nb = self.bm.num_blocks
        it = iter(vals)

        def leaf(p):
            if p.ndim >= 2 and p.shape[1] == nb:
                return p.at[:, idx].set(jnp.moveaxis(next(it), 0, 1))
            return p

        return jax.tree.map(leaf, cache)

    @staticmethod
    def _pad_pow2(n: int) -> int:
        """Swap batch sizes round up to a power of two so the jitted
        gather/scatter compile O(log) variants, not one per count."""
        return 1 << max(0, n - 1).bit_length()

    def _issue_swap_out(self, pairs):
        """Dispatch the d2h gather for this step's swap-outs. Returns the
        (handle, pairs) token to drain later — after the step for overlap,
        or immediately when this step's swap-ins reuse the slots."""
        m = self._pad_pow2(len(pairs))
        idx = np.full(m, TRASH_BLOCK, np.int32)
        idx[:len(pairs)] = [b for b, _ in pairs]
        return self._swap_gather(self.cache, jnp.asarray(idx)), pairs

    def _drain_swap_out(self, token) -> None:
        """Materialize a pending d2h gather into the host pool."""
        handle, pairs = token
        t0 = time.monotonic()
        slots = [s for _, s in pairs]
        for hp, g in zip(self._host_pool, handle):
            hp[slots] = np.asarray(g[:len(slots)])
        nbytes = len(slots) * self._host_block_nbytes
        self.stats["swapped_out_blocks"] += len(slots)
        self.stats["swapped_out_bytes"] += nbytes
        self._swap_cost.observe_swap(nbytes, time.monotonic() - t0)

    def _swap_in(self, pairs) -> None:
        """h2d: copy host slots into freshly allocated device blocks,
        before COW copies (which may read them) and the step."""
        n = len(pairs)
        m = self._pad_pow2(n)
        idx = np.full(m, TRASH_BLOCK, np.int32)
        idx[:n] = [b for _, b in pairs]
        slots = [s for s, _ in pairs]
        vals = []
        for hp in self._host_pool:
            buf = np.zeros((m,) + hp.shape[1:], hp.dtype)
            buf[:n] = hp[slots]
            vals.append(jnp.asarray(buf))
        self.cache = self._swap_scatter(self.cache, jnp.asarray(idx), vals)
        self.stats["swapped_in_blocks"] += n
        self.stats["swapped_in_bytes"] += n * self._host_block_nbytes

    def _shared_in(self, pairs) -> None:
        """h2d: copy shared-index pool slots (blocks another replica
        published) into freshly allocated device blocks — the ``_swap_in``
        contract with the process-global pool as the source. Admission
        pinned the slots; they are released here, once the payload has
        been captured into the scatter operands."""
        shared = self.shared_index
        n = len(pairs)
        m = self._pad_pow2(n)
        idx = np.full(m, TRASH_BLOCK, np.int32)
        idx[:n] = [b for _, b in pairs]
        slots = [s for s, _ in pairs]
        vals = []
        for hp in shared.pool:
            buf = np.zeros((m,) + hp.shape[1:], hp.dtype)
            buf[:n] = hp[slots]
            vals.append(jnp.asarray(buf))
        shared.release(slots)
        self.cache = self._swap_scatter(self.cache, jnp.asarray(idx), vals)

    def _flush_shared_publish(self) -> None:
        """Publish this replica's newly hash-registered blocks into the
        shared index: d2h-gather their pages into reserved pool slots and
        commit the hashes. Runs at step boundaries (payloads are complete:
        registration happens only after the writing exec has synced) and
        at stream close (``_append_token`` retirement), which is what
        makes cross-replica adoption deterministic — a request submitted
        after a producer's stream finished always finds its blocks."""
        if self.shared_index is None or self.bm is None:
            return
        pend = self.bm.drain_publishable()
        if not pend:
            return
        shared = self.shared_index
        blocks, slots, hashes = [], [], []
        for b, h in pend:
            s = shared.reserve(h)
            if s is None:
                continue     # raced with another replica, or pool pinned full
            blocks.append(b)
            slots.append(s)
            hashes.append(h)
        if not blocks:
            return
        n = len(blocks)
        idx = np.full(self._pad_pow2(n), TRASH_BLOCK, np.int32)
        idx[:n] = blocks
        g = self._swap_gather(self.cache, jnp.asarray(idx))
        for pool, leaf in zip(shared.pool, g):
            pool[slots] = np.asarray(leaf[:n])
        for s, h in zip(slots, hashes):
            shared.commit(s, h)
        self.stats["shared_published_blocks"] += n

    # -- host-side step ----------------------------------------------------

    def _full_step(self, has_chunk: bool):
        """The jitted step with the full sampling pipeline, compiled on
        first use only (see ``_full_steps``)."""
        if has_chunk not in self._full_steps:
            self._full_steps[has_chunk] = jax.jit(
                functools.partial(self.runner.step, has_chunk=has_chunk,
                                  full_sampling=True),
                donate_argnums=(1,))
        return self._full_steps[has_chunk]

    def _build_arrays(self, plan: StepPlan, full: bool = False) -> dict:
        B, C, nbmax = self.max_batch, self.chunk_width, self.max_blocks_per_seq
        S = self.prefill_pack
        a = {"d_tok": np.zeros(B, np.int32),
             "d_pos": np.zeros(B, np.int32),
             "d_tables": np.zeros((B, nbmax), np.int32),
             "d_active": np.zeros(B, bool),
             "temps": np.zeros(B + S, np.float32),
             "top_ks": np.zeros(B + S, np.int32),
             "seeds": np.zeros(B + S, np.int32),
             "rids": np.zeros(B + S, np.int32),
             "counters": np.zeros(B + S, np.int32)}
        if full:
            # full-pipeline rows: identity defaults on every inactive /
            # plain-params row, dense count state gathered per request
            V = self.samp_buf.vocab_size
            a.update({"top_ps": np.ones(B + S, np.float32),
                      "min_ps": np.zeros(B + S, np.float32),
                      "rep_pens": np.ones(B + S, np.float32),
                      "pres_pens": np.zeros(B + S, np.float32),
                      "freq_pens": np.zeros(B + S, np.float32),
                      "pmask": np.zeros((B + S, V), bool),
                      "ocounts": np.zeros((B + S, V), np.int32)})
        if S == 1:
            a.update({"c_tok": np.zeros((1, C), np.int32),
                      "c_start": np.zeros(1, np.int32),
                      "c_len": np.zeros(1, np.int32),
                      "c_slot": np.zeros(1, np.int32),
                      "c_table": np.full((1, nbmax), TRASH_BLOCK, np.int32)})
        else:
            # flat ragged layout: chunk ci owns rows [c_starts[ci],
            # c_ends[ci]) of the (1, C) token batch; pad rows are owned by
            # nobody (row_seq 0 but outside sequence 0's range) so their
            # KV lands in the trash block and their logits are discarded
            a.update({"c_tok": np.zeros((1, C), np.int32),
                      "c_pos": np.zeros((1, C), np.int32),
                      "c_seq": np.zeros(C, np.int32),
                      "c_starts": np.zeros(S, np.int32),
                      "c_ends": np.zeros(S, np.int32),
                      "c_ctx": np.zeros(S, np.int32),
                      "c_tables": np.full((S, nbmax), TRASH_BLOCK,
                                          np.int32)})

        def samp(i, req):
            a["temps"][i] = req.sampling.temperature
            a["top_ks"][i] = req.sampling.top_k
            a["seeds"][i] = req.sampling.seed
            a["rids"][i] = req.rid
            a["counters"][i] = len(req.out)
            if full:
                sp = req.sampling
                a["top_ps"][i] = sp.top_p
                a["min_ps"][i] = sp.min_p
                a["rep_pens"][i] = sp.repetition_penalty
                a["pres_pens"][i] = sp.presence_penalty
                a["freq_pens"][i] = sp.frequency_penalty
                pmask, ocounts = self.samp_buf.row(req.rid)
                a["pmask"][i] = pmask
                a["ocounts"][i] = ocounts

        for slot, req in plan.decodes:
            a["d_active"][slot] = True
            a["d_tok"][slot] = req.out[-1]
            a["d_pos"][slot] = req.context_len - 1  # write position of out[-1]
            if self.bm is not None:
                row = self.bm.table(req.rid)
                a["d_tables"][slot, :len(row)] = row
            samp(slot, req)

        if S == 1:
            if plan.chunk is not None:
                slot, req, n = plan.chunk
                toks = req.prefill_tokens()
                a["c_tok"][0, :n] = \
                    toks[req.num_computed:req.num_computed + n]
                a["c_start"][0] = req.num_computed
                a["c_len"][0] = n
                a["c_slot"][0] = slot
                if self.bm is not None:
                    row = self.bm.table(req.rid)
                    a["c_table"][0, :len(row)] = row
                samp(B, req)
        elif plan.chunks:
            tok_rows, pos_rows = [], []
            for ci, (slot, req, n) in enumerate(plan.chunks):
                toks = req.prefill_tokens()
                tok_rows.append(
                    toks[req.num_computed:req.num_computed + n])
                pos_rows.append(np.arange(req.num_computed,
                                          req.num_computed + n, dtype=np.int32))
                a["c_ctx"][ci] = req.num_computed + n
                if self.bm is not None:
                    row = self.bm.table(req.rid)
                    a["c_tables"][ci, :len(row)] = row
                samp(B + ci, req)
            tok, seq, starts, ends = pack_ragged(tok_rows, C, S)
            pos, _, _, _ = pack_ragged(pos_rows, C, S)
            a["c_tok"][0], a["c_pos"][0] = tok, pos
            a["c_seq"], a["c_starts"], a["c_ends"] = seq, starts, ends
        return {k: jnp.asarray(v) for k, v in a.items()}

    def _lat(self, rid: int) -> dict:
        return self.stats["latency"].setdefault(rid, {})

    def _note_arrival(self, req: Request) -> None:
        # monotonic: the *_wall fields are only ever differenced, and an
        # NTP step must not produce negative latencies
        self.stats["requests"] += 1
        self._lat(req.rid).update(arrival_step=self.step_count,
                                  arrival_wall=time.monotonic())

    def _observe_latency(self, rec: dict) -> None:
        """Fold one completed request's record into the TTFT/e2e
        histograms — the bounded aggregate that survives record eviction
        and backs the /metrics endpoint."""
        if "arrival_step" not in rec:        # driven without _note_arrival
            return                           # (scheduler-level tests)
        self.hist["ttft_steps"].observe(
            rec["first_token_step"] - rec["arrival_step"])
        self.hist["e2e_steps"].observe(
            rec["done_step"] - rec["arrival_step"])
        self.hist["ttft_seconds"].observe(
            rec["first_token_wall"] - rec["arrival_wall"])
        self.hist["e2e_seconds"].observe(
            rec["done_wall"] - rec["arrival_wall"])

    def _req_logprobs(self, req: Request, lp, idx):
        """Format one emitted token's logprobs for the ``on_token`` hook:
        ``{"token_logprob": float, "top": [(id, logprob), ...]}`` trimmed
        to the request's ``logprobs`` count, or None when the request
        didn't ask (or the step ran the plain path)."""
        n = req.sampling.logprobs
        if lp is None or n <= 0:
            return None
        return {"token_logprob": float(lp["chosen"][idx]),
                "top": [(int(t), float(v))
                        for t, v in zip(lp["top_ids"][idx][:n],
                                        lp["top_lp"][idx][:n])]}

    def _append_token(self, slot: int, req: Request, tok: int,
                      logprobs=None) -> None:
        req.out.append(tok)
        self.samp_buf.commit(req.rid, tok)
        self.stats["tokens"] += 1
        rec = self._lat(req.rid)
        if "first_token_step" not in rec:
            # first token emitted *on this engine* — for a request
            # submitted with `out` pre-filled (a disaggregated decode
            # continuation), that's its first locally produced token
            rec.update(first_token_step=self.step_count,
                       first_token_wall=time.monotonic())
        self.sched.note_progress(req)
        if (req.sampling.stop and not req.stop_hit
                and len(req.out) >= req.min_new
                and self.samp_buf.check_stop(req.rid, req.sampling.stop)
                is not None):
            req.stop_hit = True
            self.stats["stop_hits"] += 1
        if self.on_token is not None:
            self.on_token(req, tok, logprobs)
        if req.done:
            rec = self._lat(req.rid)
            rec.update(done_step=self.step_count,
                       done_wall=time.monotonic())
            self._observe_latency(rec)
            self.stats["requests_done"] += 1
            lat = self.stats["latency"]
            if len(lat) > self.latency_record_cap:
                # evict oldest *completed* records only — an in-flight
                # request must keep its arrival marks for TTFT reporting
                for rid in list(lat):
                    if "done_step" in lat[rid]:
                        del lat[rid]
                        if len(lat) <= self.latency_record_cap:
                            break
            if self.shared_index is not None:
                # stream-close publish barrier: before anyone can observe
                # this request as finished (on_finish → its stream ends),
                # every full block it registered is committed to the
                # shared index — so a request submitted *after* a
                # producer's stream closed deterministically adopts its
                # blocks on any replica (docs/multi-host.md)
                self._flush_shared_publish()
            self.sched.retire(slot)
            if self.on_finish is not None:
                self.on_finish(req)

    def abort(self, rid: int) -> bool:
        """Cancel an in-flight request between steps (front-end client
        disconnect). Cache resources are released immediately — blocks
        hash-retained, swapped host slots discarded — and no further
        tokens are produced. Safe no-op for unknown/retired rids."""
        ok = self.sched.abort(rid)
        if ok:
            self.stats["aborts"] = self.sched.n_aborts
        return ok

    def _run_encodes(self, plan: StepPlan) -> None:
        """Admission-time encoder passes: write each new request's cross
        K/V into its slot row before any decoder work touches it."""
        for slot, req in plan.encodes:
            frames = req.frames
            if frames is None:
                frames = np.zeros(
                    (self.cfg.encoder_seq_len, self.cfg.d_model),
                    np.float32)
            self.cache = self._encode(
                self.params, self.cache, jnp.asarray(slot, jnp.int32),
                jnp.asarray(frames, jnp.bfloat16))
            self.stats["encodes"] += 1

    def step(self) -> bool:
        """One engine iteration. Returns True when any work ran."""
        with jax.set_mesh(self.mesh):
            plan = self.sched.schedule()
            self.stats["preemptions"] = self.sched.n_preemptions
            self.stats["swap_preemptions"] = self.sched.n_swap_preemptions
            self.stats["swap_ins"] = self.sched.n_swap_ins
            self.stats["host_hit_blocks"] = self.sched.host_hit_blocks
            self.stats["shared_hit_blocks"] = self.sched.shared_hit_blocks
            self.stats["cache_hit_tokens"] = self.sched.cache_hit_tokens
            self.stats["quantum_dropped_tokens"] = \
                self.sched.quantum_dropped_tokens
            if self.bm is not None:
                st = self.bm.stats()
                self.stats["peak_block_utilization"] = max(
                    self.stats["peak_block_utilization"], st.utilization)
                self.stats["peak_blocks_in_use"] = max(
                    self.stats["peak_blocks_in_use"], st.blocks_in_use)
            if self.debug_invariants:
                self._check_invariants(plan)
            # host-swap copies. The d2h gather is issued FIRST — on the
            # pre-step pool content, before anything (swap-in scatter, COW
            # copies, the step itself) can rewrite a freed block — and
            # materialized to the host pool after the step is dispatched,
            # overlapping the host copy with device compute. Swap-ins must
            # land before COW copies: a host-copied block registered this
            # step can already be a COW source for a later admission.
            d2h_token = None
            if plan.swap_outs:
                d2h_token = self._issue_swap_out(plan.swap_outs)
            if plan.swap_ins:
                if d2h_token is not None:
                    # same-step slot reuse: host content must exist first
                    self._drain_swap_out(d2h_token)
                    d2h_token = None
                self._swap_in(plan.swap_ins)
            if plan.shared_ins:
                # cross-replica adoptions land with the swap-ins, before
                # COW copies (an adopted block can be a COW source)
                self._shared_in(plan.shared_ins)
            self._run_encodes(plan)
            for src, dst in plan.copies:
                self.stats["cow_copies"] += 1
                self.cache = self._copy_block(
                    self.cache, jnp.asarray(src, jnp.int32),
                    jnp.asarray(dst, jnp.int32))
            if plan.scheduled_tokens == 0:
                # no compute, but an admission (e.g. a full prefix-cache
                # hit that is immediately decode-ready) is still progress
                if d2h_token is not None:
                    self._drain_swap_out(d2h_token)
                self._flush_shared_publish()
                if plan.admitted:
                    self.step_count += 1
                return plan.admitted > 0
            # per-step fast-path switch: the full pipeline compiles and
            # runs only when some scheduled request actually needs it —
            # pure-greedy (and temperature/top-k-only) batches stay on
            # the two plain executables, byte-identical to before
            full = (any(r.sampling.needs_pipeline
                        for _, r in plan.decodes)
                    or any(r.sampling.needs_pipeline
                           for _, r, _ in plan.chunks))
            arrays = self._build_arrays(plan, full)
            if full:
                self.stats["full_sampling_steps"] += 1
                step_exec = self._full_step(plan.chunk is not None)
            else:
                step_exec = (self._step_chunk if plan.chunk is not None
                             else self._step_plain)
            t_step = time.monotonic()
            nxt, self.cache = step_exec(self.params, self.cache, arrays)
            if d2h_token is not None:
                self._drain_swap_out(d2h_token)
            chunk_lp = None
            if self.runner.spec_tokens or self.draft_cfg is not None:
                if full:
                    toks, n_acc, c_tok, lp_d, chunk_lp = nxt
                    lp_d = {k: np.asarray(v) for k, v in lp_d.items()}
                    chunk_lp = {k: np.asarray(v)
                                for k, v in chunk_lp.items()}
                else:
                    toks, n_acc, c_tok = nxt
                    lp_d = None
                toks, n_acc = np.asarray(toks), np.asarray(n_acc)
                chunk_toks = np.asarray(c_tok)
                for slot, req in plan.decodes:
                    self.stats["spec_decodes"] += 1
                    # accepted draft prefix + the corrected / bonus token,
                    # cut short by EOS or max_new retirement
                    for i in range(int(n_acc[slot]) + 1):
                        req.num_computed += 1
                        self.stats["spec_emitted"] += 1
                        self._append_token(
                            slot, req, int(toks[slot, i]),
                            self._req_logprobs(req, lp_d, (slot, i)))
                        if req.done:
                            break
                    if self.sched.running.get(slot) is req:
                        # roll back lookahead blocks the rejected draft
                        # tail reserved (in both models' pools at once —
                        # they share the block table)
                        self.bm.truncate(req.rid, req.context_len)
            else:
                if full:
                    toks, lp = nxt
                    nxt = np.asarray(toks)
                    lp = {k: np.asarray(v) for k, v in lp.items()}
                    chunk_lp = {k: v[self.max_batch:]
                                for k, v in lp.items()}
                else:
                    nxt = np.asarray(nxt)
                    lp = None
                chunk_toks = nxt[self.max_batch:]
                for slot, req in plan.decodes:
                    req.num_computed += 1
                    self._append_token(slot, req, int(nxt[slot]),
                                       self._req_logprobs(req, lp, slot))
            for ci, (slot, req, n) in enumerate(plan.chunks):
                req.num_computed += n
                self.stats["prefill_chunks"] += 1
                self.stats["prefill_tokens"] += n
                if req.num_computed == req.context_len:
                    self._append_token(
                        slot, req, int(chunk_toks[ci]),
                        self._req_logprobs(req, chunk_lp, ci))
                else:
                    self.sched.note_progress(req)
            if self._swap_cost is not None and plan.chunks:
                # np.asarray above already synced the step's outputs, so
                # this wall time covers real device work: feed the
                # recompute-throughput EMA the cost model weighs against
                # moving bytes
                self._swap_cost.observe_prefill(
                    sum(c[2] for c in plan.chunks),
                    time.monotonic() - t_step)
            self._flush_shared_publish()
            self.stats["steps"] += 1
            self.step_count += 1
            if self.debug_invariants and self.bm is not None:
                self.bm.check()
                if self.shared_index is not None:
                    self.shared_index.check()
            return True

    def _check_invariants(self, plan: StepPlan) -> None:
        for cache in (self.slot_cache, self.encoder_cache):
            if cache is not None:
                cache.check()
                for slot, req in self.sched.running.items():
                    assert cache.slot(req.rid) == slot, (req.rid, slot)
        assert plan.scheduled_tokens <= self.max_num_batched_tokens
        if self.bm is None:
            return
        self.bm.check()
        bs = self.block_size
        for slot, req in self.sched.running.items():
            t = self.bm.table(req.rid)
            assert len(t) <= self.max_blocks_per_seq, (req.rid, len(t))
            assert len(t) * bs >= req.num_computed, \
                f"request {req.rid}: table does not cover computed KV"
        for _, req, n in plan.chunks:
            t = self.bm.table(req.rid)
            assert len(t) * bs >= req.num_computed + n
            # COW guarantee: the chunk writes only exclusively-owned blocks
            lo, hi = req.num_computed // bs, (req.num_computed + n - 1) // bs
            for j in range(lo, hi + 1):
                assert self.bm.refcount(t[j]) == 1, \
                    f"chunk would write shared block {t[j]}"
        for slot, req in plan.decodes:
            t = self.bm.table(req.rid)
            # the decode (or the speculative verify row) writes positions
            # context_len-1 .. context_len-1+spec: all exclusively owned
            for p in range(req.context_len - 1,
                           req.context_len + plan.spec_tokens):
                assert self.bm.refcount(t[p // bs]) == 1, \
                    f"decode would write shared block {t[p // bs]}"

    def run(self, requests: list[Request],
            arrival_steps: list[int] | None = None) -> dict[int, np.ndarray]:
        """Serve ``requests`` to completion. ``arrival_steps[i]`` is the
        engine-step index at which request i becomes visible (default: all
        at step 0). Returns {rid: generated token array}; wall-clock,
        throughput and per-request latency land in ``self.stats``."""
        if arrival_steps is None:
            arrival_steps = [0] * len(requests)
        for r in requests:
            self.sched.validate(r)         # fail fast, not at arrival time
        pending = deque(sorted(zip(arrival_steps, range(len(requests))),
                               key=lambda t: t[0]))
        t0 = time.time()
        tok0 = self.stats["tokens"]
        while pending or self.sched.has_work:
            while pending and pending[0][0] <= self.step_count:
                req = requests[pending.popleft()[1]]
                self.sched.add(req)
                self._note_arrival(req)
            if not self.sched.has_work and pending:
                self.step_count = pending[0][0]      # idle: jump the clock
                continue
            if not self.step():
                # defensive: the scheduler admits whenever a slot is free
                # and raises MemoryError itself when the pool can't ever
                # fit, so reaching this means a scheduling-policy bug
                state = (self.bm.stats() if self.bm is not None
                         else self.slot_cache.stats())
                raise RuntimeError(
                    "engine stuck: scheduler made no progress with work "
                    f"pending — {state}")
        dt = time.time() - t0
        self.stats["wall_s"] = round(dt, 3)
        self.stats["tok_s"] = round((self.stats["tokens"] - tok0)
                                    / max(dt, 1e-9), 1)
        if self.stats["spec_decodes"]:
            self.stats["mean_accept_len"] = round(self.mean_accept_len, 3)
        return {r.rid: np.asarray(r.out, np.int32) for r in requests}
