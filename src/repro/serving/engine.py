"""Continuous-batching inference engine over a block-paged KV cache.

One ``InferenceEngine`` owns: model params, the paged KV pools, a
``BlockManager`` and a ``Scheduler``. Its loop interleaves prefill for
joining requests with single decode steps over *all* running slots:

    while work:
        admit waiting requests into free slots (FCFS, blocks permitting)
        prefill each joiner (bucketed prompt), scatter its KV into pages,
            sample its first token
        ensure every running slot owns blocks for the next token
            (preempting the newest requests when the pool runs dry)
        one jitted decode step: mixed batch of every running slot,
            gathering KV through block tables; per-slot sampling
        retire slots that hit EOS or max_new (frees blocks immediately)

The decode step always runs at the full ``max_batch`` width — idle slots
are masked with ctx_len 0 and their KV writes land in the trash block — so
there is exactly one compiled decode executable regardless of occupancy.
Prefill compiles once per prompt-length bucket (power-of-two blocks).

Time is measured in decode steps; request arrivals are given in the same
unit so runs are deterministic and testable (launch/serve.py maps Poisson
arrival times onto it).
"""

from __future__ import annotations

import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ParallelConfig
from repro.models import api
from repro.models import transformer
from repro.serving.kv_cache import (TRASH_BLOCK, BlockManager, block_bytes,
                                    init_paged_cache)
from repro.serving.sampling import sample_tokens
from repro.serving.scheduler import Request, SamplingParams, Scheduler

__all__ = ["InferenceEngine", "Request", "SamplingParams"]


def _engine_supported(cfg: ModelConfig) -> str | None:
    if cfg.ssm is not None:
        return "SSM state is not block-pageable"
    if cfg.encoder_layers:
        return "encoder-decoder cross caches are not paged"
    if cfg.frontend is not None:
        return "modality frontends need per-request position streams"
    return None


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, mesh, pcfg: ParallelConfig = None,
                 *, max_batch: int = 8, block_size: int = 16,
                 max_len: int = 128, num_blocks: int | None = None,
                 seed: int = 0, params=None):
        why = _engine_supported(cfg)
        if why is not None:
            raise ValueError(
                f"paged engine does not support {cfg.name}: {why}; "
                "use the static launch.serve.Server path")
        self.cfg, self.mesh = cfg, mesh
        self.pcfg = pcfg or ParallelConfig(remat="none")
        self.block_size = block_size
        self.max_len = max_len
        self.max_blocks_per_seq = -(-max_len // block_size)
        if num_blocks is None:
            # every slot can reach max_len; +1 for the trash block
            num_blocks = max_batch * self.max_blocks_per_seq + 1
        self.bm = BlockManager(num_blocks, block_size)
        self.sched = Scheduler(self.bm, max_batch, self.max_blocks_per_seq)
        self.max_batch = max_batch

        with jax.set_mesh(mesh):
            if params is None:
                params_f32, _ = api.init_model(cfg, jax.random.key(seed))
                params = jax.tree.map(
                    lambda x: x.astype(jnp.bfloat16), params_f32)
            self.params = params
            self.cache = init_paged_cache(cfg, num_blocks, block_size)

        self._prefill = jax.jit(
            lambda p, b: transformer.prefill_logits(p, b, cfg, self.pcfg))
        self._decode = jax.jit(self._decode_fn, donate_argnums=(1,))
        self._scatter = jax.jit(self._scatter_fn, donate_argnums=(0,))
        self._sample1 = jax.jit(sample_tokens)

        self.stats = {"decode_steps": 0, "prefills": 0, "preemptions": 0,
                      "tokens": 0, "peak_block_utilization": 0.0,
                      "kv_cache_mib": round(
                          num_blocks * block_bytes(cfg, block_size)
                          / 2 ** 20, 3)}
        self.step_count = 0           # virtual clock: one decode = one step

    # -- jitted bodies -----------------------------------------------------

    def _decode_fn(self, params, cache, token, pos, tables, active,
                   temps, top_ks, seeds, counters):
        ctx_lens = jnp.where(active, pos + 1, 0)
        logits, cache = transformer.decode_step_paged(
            params, cache,
            {"token": token[:, None], "pos": pos,
             "block_tables": tables, "ctx_lens": ctx_lens},
            self.cfg, self.pcfg)
        nxt = sample_tokens(logits, temps, top_ks, seeds, counters)
        return nxt, cache

    def _scatter_fn(self, cache, dense, row):
        """Write a prefilled dense cache (leaves (NP, 1, Sp, K, hd)) into
        the page pools at the block ids in ``row`` ((Sp/bs,) int32)."""
        bs = self.block_size

        def write(pages, d):
            NP, _, Sp, K, hd = d.shape
            vals = d.reshape(NP, Sp // bs, bs, K, hd).astype(pages.dtype)
            return pages.at[:, row].set(vals)

        return jax.tree.map(write, cache, dense)

    # -- host-side steps ---------------------------------------------------

    def _bucket_blocks(self, n_tokens: int) -> int:
        nb = self.bm.blocks_for(n_tokens)
        b = 1
        while b < nb:
            b *= 2
        return min(b, self.max_blocks_per_seq)

    def _join(self, slot: int, req: Request) -> None:
        toks = req.prefill_tokens()
        P = len(toks)
        nb = self._bucket_blocks(P)
        Sp = nb * self.block_size
        assert P <= Sp, (P, Sp)
        padded = np.zeros((1, Sp), np.int32)
        padded[0, :P] = toks
        batch = {"tokens": jnp.asarray(padded),
                 "last": jnp.asarray([P - 1], jnp.int32)}
        dense, logits = self._prefill(self.params, batch)
        # scatter into the owned blocks; bucket overhang goes to trash
        row = self.bm.table(req.rid)
        row = (row + [TRASH_BLOCK] * nb)[:nb]
        self.cache = self._scatter(self.cache, dense,
                                   jnp.asarray(row, jnp.int32))
        sp = req.sampling
        tok = self._sample1(
            logits, jnp.asarray([sp.temperature], jnp.float32),
            jnp.asarray([sp.top_k], jnp.int32),
            jnp.asarray([sp.seed], jnp.int32),
            jnp.asarray([len(req.out)], jnp.int32))
        req.out.append(int(tok[0]))
        self.stats["prefills"] += 1
        self.stats["tokens"] += 1
        if req.done:
            self.sched.retire(slot)

    def _decode_all(self) -> None:
        B, nbmax = self.max_batch, self.max_blocks_per_seq
        token = np.zeros(B, np.int32)
        pos = np.zeros(B, np.int32)
        tables = np.zeros((B, nbmax), np.int32)
        active = np.zeros(B, bool)
        temps = np.zeros(B, np.float32)
        top_ks = np.zeros(B, np.int32)
        seeds = np.zeros(B, np.int32)
        counters = np.zeros(B, np.int32)
        for slot, req in self.sched.running.items():
            active[slot] = True
            token[slot] = req.out[-1]
            pos[slot] = req.context_len - 1      # write position of out[-1]
            row = self.bm.table(req.rid)
            tables[slot, :len(row)] = row
            temps[slot] = req.sampling.temperature
            top_ks[slot] = req.sampling.top_k
            seeds[slot] = req.sampling.seed
            counters[slot] = len(req.out)
        nxt, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(token), jnp.asarray(pos),
            jnp.asarray(tables), jnp.asarray(active), jnp.asarray(temps),
            jnp.asarray(top_ks), jnp.asarray(seeds), jnp.asarray(counters))
        nxt = np.asarray(nxt)
        for slot, req in list(self.sched.running.items()):
            if not active[slot]:
                continue
            req.out.append(int(nxt[slot]))
            self.stats["tokens"] += 1
            if req.done:
                self.sched.retire(slot)
        self.stats["decode_steps"] += 1
        self.step_count += 1

    def step(self) -> None:
        """One engine iteration: admit + prefill joiners, then one decode."""
        with jax.set_mesh(self.mesh):
            for slot, req in self.sched.admit():
                self._join(slot, req)
            self.sched.ensure_decode_capacity()
            self.stats["preemptions"] = self.sched.n_preemptions
            util = self.bm.stats().utilization
            self.stats["peak_block_utilization"] = max(
                self.stats["peak_block_utilization"], util)
            if self.sched.running:
                self._decode_all()

    def run(self, requests: list[Request],
            arrival_steps: list[int] | None = None) -> dict[int, np.ndarray]:
        """Serve ``requests`` to completion. ``arrival_steps[i]`` is the
        decode-step index at which request i becomes visible (default: all
        at step 0). Returns {rid: generated token array}; wall-clock and
        throughput land in ``self.stats``."""
        if arrival_steps is None:
            arrival_steps = [0] * len(requests)
        for r in requests:
            self.sched.validate(r)         # fail fast, not at arrival time
        pending = deque(sorted(zip(arrival_steps, range(len(requests))),
                               key=lambda t: t[0]))
        t0 = time.time()
        tok0 = self.stats["tokens"]
        while pending or self.sched.has_work:
            while pending and pending[0][0] <= self.step_count:
                self.sched.add(requests[pending.popleft()[1]])
            if not self.sched.has_work and pending:
                self.step_count = pending[0][0]      # idle: jump the clock
                continue
            before = (self.stats["tokens"], self.stats["decode_steps"])
            self.step()
            if (self.stats["tokens"], self.stats["decode_steps"]) == before:
                raise RuntimeError(
                    "engine stuck: head-of-line request cannot be admitted "
                    "with an empty machine (block pool or max_batch too "
                    f"small?) — {self.bm.stats()}")
        dt = time.time() - t0
        self.stats["wall_s"] = round(dt, 3)
        self.stats["tok_s"] = round((self.stats["tokens"] - tok0)
                                    / max(dt, 1e-9), 1)
        return {r.rid: np.asarray(r.out, np.int32) for r in requests}
