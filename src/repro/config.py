"""Configuration system for repro.

Dataclass-based, mirroring the paper's separation between the *model graph*
(what computation), *placement* (where it runs = mesh/sharding here), and the
*step* being executed (train / prefill / decode).

Every assigned architecture lives in ``repro.configs.<id>`` exposing
``CONFIG`` (full-size, dry-run only) and ``smoke_config()`` (reduced, runs on
CPU). ``repro.config.get_config(arch)`` resolves either.
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Any, Sequence

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

# Layer kinds used by blocks patterns.
ATTN = "attn"            # full global attention block
LOCAL_ATTN = "local"     # sliding-window attention block
MAMBA = "mamba"          # Mamba2 SSD block
SHARED_ATTN = "shared"   # zamba2-style shared-weight attention block


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # n_shared_experts etc. could go here; none of the assigned archs need it.


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int            # N (ssm_state)
    head_dim: int = 64        # P
    expand: int = 2           # d_inner = expand * d_model
    conv_kernel: int = 4
    chunk_size: int = 256
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- attention options -------------------------------------------------
    rope_theta: float = 10000.0
    rope_sections: tuple[int, ...] | None = None   # M-RoPE (qwen2-vl): (t,h,w)
    qk_norm: bool = False                           # qwen3 family
    attn_logit_softcap: float | None = None         # gemma2 (50.0), grok
    final_logit_softcap: float | None = None        # gemma2 (30.0)
    sliding_window: int | None = None               # local-attn window size
    attn_scale: float | None = None                 # override 1/sqrt(head_dim)
    # --- block structure ----------------------------------------------------
    # Pattern of layer kinds, tiled to num_layers. Examples:
    #   ("attn",)                      -> plain decoder
    #   ("local", "attn")              -> gemma2 alternating
    #   ("mamba",)*5 + ("mamba+shared",)  -> zamba2 period (see transformer.py)
    block_pattern: tuple[str, ...] = (ATTN,)
    shared_attn_period: int = 0      # zamba2: apply shared attn block every k layers
    # --- MLP ------------------------------------------------------------------
    mlp_activation: str = "silu"     # silu (SwiGLU) | gelu (GeGLU) | gelu_mlp (ungated)
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    post_block_norm: bool = False    # gemma2 applies post-norms as well
    tie_embeddings: bool = False
    embedding_scale: bool = False    # gemma2 scales embeddings by sqrt(d_model)
    # --- mixture / ssm -----------------------------------------------------
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    # --- encoder-decoder ----------------------------------------------------
    encoder_layers: int = 0          # >0 => enc-dec (whisper)
    encoder_seq_len: int = 0         # fixed encoder context (1500 audio frames)
    # --- modality frontend stub ----------------------------------------------
    frontend: str | None = None      # "audio" | "vision" -> input_specs stubs
    # --- numerics -------------------------------------------------------------
    dtype: str = "bfloat16"
    sub_quadratic: bool = False      # eligible for long_500k

    @property
    def padded_vocab_size(self) -> int:
        """Vocab padded to a multiple of 256 so the table shards over any
        mesh "model" axis (Megatron-style); losses mask padded columns."""
        return -(-self.vocab_size // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def layer_kinds(self) -> tuple[str, ...]:
        """Expanded per-layer kind list of length num_layers."""
        pat = self.block_pattern
        reps = -(-self.num_layers // len(pat))
        return (pat * reps)[: self.num_layers]

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d  # lm head
        for kind in self.layer_kinds():
            if kind in (ATTN, LOCAL_ATTN):
                n += self._attn_params() + self._mlp_params() + 2 * d
            elif kind == MAMBA:
                n += self._mamba_params() + d
        if self.shared_attn_period:
            n += self._attn_params() + self._mlp_params() + 2 * self.d_model
        if self.encoder_layers:
            # encoder self-attn + mlp, decoder already counted; add cross-attn
            n += self.encoder_layers * (
                self._attn_params() + self._mlp_params() + 2 * d
            )
            n += self.num_layers * (self._attn_params() + d)  # cross attn
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        per_expert = 3 * self.d_model * m.d_ff_expert
        inactive = (m.num_experts - m.experts_per_token) * per_expert
        return full - self.num_layers * inactive

    def _attn_params(self) -> int:
        d = self.d_model
        return d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d

    def _mlp_params(self) -> int:
        if self.moe is not None:
            m = self.moe
            return self.d_model * m.num_experts + (
                m.num_experts * 3 * self.d_model * m.d_ff_expert
            )
        mats = 2 if self.mlp_activation == "gelu_mlp" else 3
        return mats * self.d_model * self.d_ff

    def _mamba_params(self) -> int:
        assert self.ssm is not None
        s = self.ssm
        d, di = self.d_model, s.d_inner(self.d_model)
        nh, N = s.n_heads(self.d_model), s.state_dim
        in_proj = d * (2 * di + 2 * s.n_groups * N + nh)
        conv = s.conv_kernel * (di + 2 * s.n_groups * N)
        return in_proj + conv + nh + nh + di * d + di  # A, D, out_proj, norm


# ---------------------------------------------------------------------------
# Shapes (assigned input-shape set)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch, shape) cell runs, per DESIGN.md §4."""
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, "skipped (full attention; long_500k needs sub-quadratic)"
    return True, ""


# ---------------------------------------------------------------------------
# Parallelism / run configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParallelConfig:
    # How weight matrices map to the mesh; see spmd/sharding.py.
    fsdp: bool = False            # shard params over "data" too (all-gather in scan)
    zero1: bool = True            # shard optimizer state over "data"
    remat: str = "full"           # none | dots | full
    microbatches: int = 1         # gradient accumulation
    seq_shard_activations: bool = False  # sequence-parallel saved activations
    expert_ff_2d: bool = False    # serving: shard expert d_ff over (data,model)
                                  # instead of FSDP (kills per-step gathers)
    # note: decode KV caches are always sequence-sharded over "model" when
    # divisible (flash-decode LSE stitch); see spmd/steps.cache_shardings.


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    schedule: str = "cosine"       # constant | cosine | linear
    total_steps: int = 10_000
    compression: str = "none"      # none | int8_ef (error-feedback int8 all-reduce)
    slot_dtype: str = "float32"    # "bfloat16" halves moment memory
                                   # (masters stay fp32; math in fp32)


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    seed: int = 0

    def replace(self, **kw: Any) -> "RunConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCHS: tuple[str, ...] = (
    "glm4_9b",
    "starcoder2_3b",
    "gemma2_27b",
    "qwen3_32b",
    "whisper_large_v3",
    "zamba2_2p7b",
    "qwen2_vl_2b",
    "qwen3_moe_30b_a3b",
    "grok1_314b",
    "mamba2_370m",
)

# Accept dashed ids from the assignment table as aliases.
_ALIASES = {
    "glm4-9b": "glm4_9b",
    "starcoder2-3b": "starcoder2_3b",
    "gemma2-27b": "gemma2_27b",
    "qwen3-32b": "qwen3_32b",
    "whisper-large-v3": "whisper_large_v3",
    "zamba2-2.7b": "zamba2_2p7b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "grok-1-314b": "grok1_314b",
    "mamba2-370m": "mamba2_370m",
}


def canonical_arch(arch: str) -> str:
    arch = _ALIASES.get(arch, arch)
    if arch not in ARCHS and arch != "lstm_lm":
        raise ValueError(f"unknown arch {arch!r}; known: {ARCHS}")
    return arch


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    arch = canonical_arch(arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.smoke_config() if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> dict[str, ModelConfig]:
    return {a: get_config(a, smoke=smoke) for a in ARCHS}
