"""Optimizers as user-level pytree code (paper §4.1).

The paper's argument: optimizers must not be privileged runtime code. In
DistBelief, adding Momentum meant editing the C++ parameter server; in
TensorFlow (and here) an optimizer is a pure function over (param, grad,
slots) built from primitive ops. We implement the paper's §4.1 list —
SGD, Momentum, Adagrad, Adadelta, RMSProp, Adam — plus AdamW (the default
for the LM zoo). L-BFGS is a documented non-goal (DESIGN.md §7).

All state is a pytree of slot variables mirroring the params, so ZeRO-1
sharding (spmd/zero.py) and checkpointing treat it like any other state.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig

PyTree = Any


def _zeros_like_tree(params, dtype=None):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, dtype or p.dtype), params)


def init_train_state(ocfg: OptimizerConfig, params_f32: PyTree) -> dict:
    """Mixed-precision training state: fp32 master weights live INSIDE the
    optimizer state (ZeRO-sharded over "data" with the slots); the working
    params handed to forward/backward are bf16 casts. The all-gather after
    the sharded update therefore moves bf16, not fp32."""
    return {"master": params_f32, **init_opt_state(ocfg, params_f32)}


def apply_updates_master(ocfg: OptimizerConfig, state: dict, grads: PyTree,
                         step, out_dtype=jnp.bfloat16):
    """Returns (new working params in out_dtype, new state)."""
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    slots = {k: v for k, v in state.items() if k != "master"}
    new_master, new_slots = apply_updates(ocfg, state["master"], g32, slots,
                                          step)
    params = jax.tree.map(lambda p: p.astype(out_dtype), new_master)
    return params, {"master": new_master, **new_slots}


def init_opt_state(ocfg: OptimizerConfig, params: PyTree) -> dict:
    name = ocfg.name
    sd = jnp.dtype(ocfg.slot_dtype)
    if name == "sgd":
        return {}
    if name in ("momentum", "adagrad", "rmsprop"):
        return {"s0": _zeros_like_tree(params, sd)}
    if name == "adadelta":
        return {"s0": _zeros_like_tree(params, sd), "s1": _zeros_like_tree(params, sd)}
    if name in ("adam", "adamw"):
        return {"s0": _zeros_like_tree(params, sd), "s1": _zeros_like_tree(params, sd)}
    raise ValueError(f"unknown optimizer {name!r}")


def schedule(ocfg: OptimizerConfig, step) -> jnp.ndarray:
    """Learning-rate schedule (fp32 scalar)."""
    s = jnp.asarray(step, jnp.float32)
    if ocfg.warmup_steps > 0:
        warm = jnp.minimum((s + 1.0) / ocfg.warmup_steps, 1.0)
    else:
        warm = 1.0
    if ocfg.schedule == "constant":
        dec = 1.0
    elif ocfg.schedule == "linear":
        dec = jnp.maximum(1.0 - s / ocfg.total_steps, 0.0)
    else:  # cosine
        t = jnp.clip(s / ocfg.total_steps, 0.0, 1.0)
        dec = 0.5 * (1.0 + jnp.cos(math.pi * t))
    return ocfg.lr * warm * dec


def global_norm(tree: PyTree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: PyTree, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def apply_updates(ocfg: OptimizerConfig, params: PyTree, grads: PyTree,
                  state: dict, step) -> tuple[PyTree, dict]:
    """One optimizer step. All math in fp32 (params are fp32 masters)."""
    lr = schedule(ocfg, step)
    name = ocfg.name
    b1, b2, eps = ocfg.beta1, ocfg.beta2, ocfg.eps

    if name == "sgd":
        new_p = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        return new_p, state

    if name == "momentum":
        new_v = jax.tree.map(lambda v, g: b1 * v + g, state["s0"], grads)
        new_p = jax.tree.map(lambda p, v: p - lr * v, params, new_v)
        return new_p, {"s0": new_v}

    if name == "adagrad":
        new_a = jax.tree.map(lambda a, g: a + g * g, state["s0"], grads)
        new_p = jax.tree.map(
            lambda p, g, a: p - lr * g / (jnp.sqrt(a) + eps),
            params, grads, new_a)
        return new_p, {"s0": new_a}

    if name == "rmsprop":
        new_a = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g,
                             state["s0"], grads)
        new_p = jax.tree.map(
            lambda p, g, a: p - lr * g / (jnp.sqrt(a) + eps),
            params, grads, new_a)
        return new_p, {"s0": new_a}

    if name == "adadelta":
        rho = b2
        acc_g = jax.tree.map(lambda a, g: rho * a + (1 - rho) * g * g,
                             state["s0"], grads)
        upd = jax.tree.map(
            lambda g, ag, ax: g * jnp.sqrt(ax + eps) / jnp.sqrt(ag + eps),
            grads, acc_g, state["s1"])
        acc_x = jax.tree.map(lambda a, u: rho * a + (1 - rho) * u * u,
                             state["s1"], upd)
        new_p = jax.tree.map(lambda p, u: p - lr * u, params, upd)
        return new_p, {"s0": acc_g, "s1": acc_x}

    if name in ("adam", "adamw"):
        t = jnp.asarray(step, jnp.float32) + 1.0
        c1 = 1.0 - jnp.power(b1, t)
        c2 = 1.0 - jnp.power(b2, t)
        # moment math in fp32, stored back at the slot dtype (slot_dtype
        # "bfloat16" halves moment memory for the largest models)
        new_m = jax.tree.map(
            lambda m, g: (b1 * m.astype(jnp.float32)
                          + (1 - b1) * g).astype(m.dtype),
            state["s0"], grads)
        new_v = jax.tree.map(
            lambda v, g: (b2 * v.astype(jnp.float32)
                          + (1 - b2) * g * g).astype(v.dtype),
            state["s1"], grads)

        def upd(p, mh, vh):
            u = ((mh.astype(jnp.float32) / c1)
                 / (jnp.sqrt(vh.astype(jnp.float32) / c2) + eps))
            if name == "adamw" and ocfg.weight_decay:
                u = u + ocfg.weight_decay * p
            return p - lr * u

        new_p = jax.tree.map(upd, params, new_m, new_v)
        return new_p, {"s0": new_m, "s1": new_v}

    raise ValueError(name)
