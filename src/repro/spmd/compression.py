"""Gradient compression: int8 error-feedback all-reduce.

The wire cost of a ring all-reduce is ~2 x tensor bytes; quantizing the two
transfer stages to int8 cuts it ~4x vs fp32 (2x vs bf16). The algorithm is
the standard EF-compressed reduce-scatter / all-gather:

  1. sender adds its error-feedback residual, quantizes per-chunk to int8
     with an fp32 scale, and keeps e' = g - dequant(q(g)),
  2. all_to_all distributes int8 chunks (reduce-scatter leg),
  3. each rank dequantizes + averages its chunk, requantizes,
  4. all_gather of int8 chunks (all-gather leg), dequantize.

Runs inside shard_map over the reduction axis. On a multi-pod mesh the
intended axis is "pod" (the slow inter-pod links); EXPERIMENTS.md §Perf
measures the collective-bytes reduction on a collective-bound cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _quant(x32, axis_size):
    """Per-chunk symmetric int8 quantization. x32: (n,) fp32, n % A == 0."""
    chunks = x32.reshape(axis_size, -1)
    scale = jnp.max(jnp.abs(chunks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(chunks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def compressed_psum_mean(x, err, axis: str):
    """Mean of x over `axis` with int8 EF compression (inside shard_map).

    x: any-shape fp32/bf16 array (same shape on every rank); err: same
    shape fp32 error-feedback state. Returns (mean, new_err).
    """
    a = jax.lax.axis_size(axis)
    shape = x.shape
    x32 = x.astype(jnp.float32).reshape(-1) + err.reshape(-1)
    n = x32.shape[0]
    pad = (-n) % a
    if pad:
        x32 = jnp.pad(x32, (0, pad))

    q, scale = _quant(x32, a)                        # (a, c) int8, (a,1) f32
    deq = q.astype(jnp.float32) * scale
    new_err = (x32 - deq.reshape(-1))[:n].reshape(shape)

    # reduce-scatter leg: every rank receives chunk r from all ranks
    qt = jax.lax.all_to_all(q[:, None], axis, split_axis=0, concat_axis=1)
    st = jax.lax.all_to_all(scale[:, None], axis, split_axis=0,
                            concat_axis=1)
    # (1, a, c): contributions to MY chunk from every rank
    part = (qt.astype(jnp.float32) * st).sum(axis=1)[0] / a   # (c,)

    q2, s2 = _quant(part, 1)                          # (1, c)
    gq = jax.lax.all_gather(q2[0], axis)              # (a, c) int8
    gs = jax.lax.all_gather(s2[0], axis)              # (a, 1)
    full = (gq.astype(jnp.float32) * gs).reshape(-1)
    out = full[:n].reshape(shape).astype(x.dtype)
    return out, new_err


def compressed_psum_mean_tree(tree, err_tree, axis: str):
    flat, treedef = jax.tree.flatten(tree)
    errs = jax.tree.leaves(err_tree)
    outs, new_errs = [], []
    for x, e in zip(flat, errs):
        o, ne = compressed_psum_mean(x, e, axis)
        outs.append(o)
        new_errs.append(ne)
    return (jax.tree.unflatten(treedef, outs),
            jax.tree.unflatten(treedef, new_errs))


def init_error_state(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)
