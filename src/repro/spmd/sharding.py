"""Logical-axis → mesh-axis sharding rules.

The paper separates the *graph* from its *placement* (§3.3): users express
constraints ("put parameters on PS tasks"), the runtime picks devices. Here
parameters carry logical axis names (repro.models.modules specs) and a rules
table maps them to mesh axes. Changing a parallelism strategy = changing the
rules — the model code never mentions mesh axes (except the explicitly
collective shard_map blocks, which take their axes from helpers here).

Mesh axes: ("pod",)? + ("data", "model"). "pod" is the multi-pod DP/PP axis.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ParallelConfig

Rules = dict[str, Any]   # logical name -> mesh axis | tuple | None


def dp_axes(mesh=None) -> tuple[str, ...]:
    """Data-parallel axes present in the mesh (pod folds into DP by default)."""
    mesh = mesh or jax.sharding.get_abstract_mesh()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_rules(cfg: ModelConfig, pcfg: ParallelConfig) -> Rules:
    """Baseline rules; per-arch auto choices documented in DESIGN.md."""
    moe_ep = cfg.moe is not None and cfg.moe.num_experts >= 16
    rules: Rules = {
        "vocab": "model",
        "embed": "data" if pcfg.fsdp else None,
        "heads": "model",
        "kv_heads": "model",       # dropped automatically if not divisible
        "head_dim": None,
        "ff": "model",
        "experts": "model" if moe_ep else None,
        "expert_ff": (("data", "model") if pcfg.expert_ff_2d
                      else (None if moe_ep else "model")),
        "expert_embed": "data" if (pcfg.fsdp and not pcfg.expert_ff_2d)
                        else None,
        "ssm_inner": "model",
        "ssm_heads": "model",
        "layers": None,
        None: None,
    }
    return rules


def resolve_spec(shape: tuple[int, ...], logical: tuple[str | None, ...],
                 rules: Rules, mesh) -> P:
    """Map logical axes to a PartitionSpec, dropping any assignment whose
    mesh-axis product does not divide the dim (the paper's "feasible set")."""
    out = []
    used: set[str] = set()
    for dim, name in zip(shape, logical):
        ax = rules.get(name, None)
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        axes = tuple(a for a in axes if a in mesh.axis_names and a not in used)
        size = math.prod(mesh.shape[a] for a in axes) if axes else 1
        if axes and dim % size == 0:
            out.append(axes if len(axes) > 1 else axes[0])
            used.update(axes)
        else:
            out.append(None)
    return P(*out)


def tree_shardings(params, specs, rules: Rules, mesh):
    """NamedSharding tree for a (params, logical-specs) pair."""
    def one(p, s):
        return NamedSharding(mesh, resolve_spec(p.shape, s, rules, mesh))
    return _map2(one, params, specs)


def _map2(fn, params, specs):
    if isinstance(params, dict):
        return {k: _map2(fn, params[k], specs[k]) for k in params}
    return fn(params, specs)


def tree_pspecs(params, specs, rules: Rules, mesh):
    def one(p, s):
        return resolve_spec(p.shape, s, rules, mesh)
    return _map2(one, params, specs)


def abstract_params(params):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)


def batch_spec(global_batch: int, mesh, extra_dims: int = 1) -> P:
    """Spec for (B, ...) activations: batch over DP axes when divisible."""
    dp = dp_axes(mesh)
    size = math.prod(mesh.shape[a] for a in dp) if dp else 1
    first = dp if (dp and global_batch % size == 0) else None
    if isinstance(first, tuple) and len(first) == 1:
        first = first[0]
    return P(first, *([None] * extra_dims))


def kv_cache_spec(global_batch: int, seq: int, mesh) -> P:
    """(B, S, K, hd): batch over DP, sequence over "model" (flash-decode)."""
    b = batch_spec(global_batch, mesh, extra_dims=0)
    seq_ax = "model" if ("model" in mesh.axis_names
                         and seq % mesh.shape["model"] == 0) else None
    return P(b[0] if len(b) else None, seq_ax, None, None)
