"""Logical-axis → mesh-axis sharding rules.

The paper separates the *graph* from its *placement* (§3.3): users express
constraints ("put parameters on PS tasks"), the runtime picks devices. Here
parameters carry logical axis names (repro.models.modules specs) and a rules
table maps them to mesh axes. Changing a parallelism strategy = changing the
rules — the model code never mentions mesh axes (except the explicitly
collective shard_map blocks, which take their axes from helpers here).

Mesh axes: ("pod",)? + ("data", "model"). "pod" is the multi-pod DP/PP axis.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ParallelConfig

Rules = dict[str, Any]   # logical name -> mesh axis | tuple | None


def dp_axes(mesh=None) -> tuple[str, ...]:
    """Data-parallel axes present in the mesh (pod folds into DP by default)."""
    mesh = mesh or jax.sharding.get_abstract_mesh()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_rules(cfg: ModelConfig, pcfg: ParallelConfig) -> Rules:
    """Baseline rules; per-arch auto choices documented in DESIGN.md."""
    moe_ep = cfg.moe is not None and cfg.moe.num_experts >= 16
    rules: Rules = {
        "vocab": "model",
        "embed": "data" if pcfg.fsdp else None,
        "heads": "model",
        "kv_heads": "model",       # dropped automatically if not divisible
        "head_dim": None,
        "ff": "model",
        "experts": "model" if moe_ep else None,
        "expert_ff": (("data", "model") if pcfg.expert_ff_2d
                      else (None if moe_ep else "model")),
        "expert_embed": "data" if (pcfg.fsdp and not pcfg.expert_ff_2d)
                        else None,
        "ssm_inner": "model",
        "ssm_heads": "model",
        "layers": None,
        None: None,
    }
    return rules


def resolve_spec(shape: tuple[int, ...], logical: tuple[str | None, ...],
                 rules: Rules, mesh) -> P:
    """Map logical axes to a PartitionSpec, dropping any assignment whose
    mesh-axis product does not divide the dim (the paper's "feasible set")."""
    out = []
    used: set[str] = set()
    for dim, name in zip(shape, logical):
        ax = rules.get(name, None)
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        axes = tuple(a for a in axes if a in mesh.axis_names and a not in used)
        size = math.prod(mesh.shape[a] for a in axes) if axes else 1
        if axes and dim % size == 0:
            out.append(axes if len(axes) > 1 else axes[0])
            used.update(axes)
        else:
            out.append(None)
    return P(*out)


def tree_shardings(params, specs, rules: Rules, mesh):
    """NamedSharding tree for a (params, logical-specs) pair."""
    def one(p, s):
        return NamedSharding(mesh, resolve_spec(p.shape, s, rules, mesh))
    return _map2(one, params, specs)


def _map2(fn, params, specs):
    if isinstance(params, dict):
        return {k: _map2(fn, params[k], specs[k]) for k in params}
    return fn(params, specs)


def tree_pspecs(params, specs, rules: Rules, mesh):
    def one(p, s):
        return resolve_spec(p.shape, s, rules, mesh)
    return _map2(one, params, specs)


def abstract_params(params):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)


def batch_spec(global_batch: int, mesh, extra_dims: int = 1) -> P:
    """Spec for (B, ...) activations: batch over DP axes when divisible."""
    dp = dp_axes(mesh)
    size = math.prod(mesh.shape[a] for a in dp) if dp else 1
    first = dp if (dp and global_batch % size == 0) else None
    if isinstance(first, tuple) and len(first) == 1:
        first = first[0]
    return P(first, *([None] * extra_dims))


def kv_cache_spec(global_batch: int, seq: int, mesh) -> P:
    """(B, S, K, hd): batch over DP, sequence over "model" (flash-decode)."""
    b = batch_spec(global_batch, mesh, extra_dims=0)
    seq_ax = "model" if ("model" in mesh.axis_names
                         and seq % mesh.shape["model"] == 0) else None
    return P(b[0] if len(b) else None, seq_ax, None, None)


# ---------------------------------------------------------------------------
# Serving cache sharding (tensor-parallel paged engine; docs/multi-host.md)
# ---------------------------------------------------------------------------


def serving_tp(mesh) -> int:
    """Tensor-parallel degree of the serving engine: the "model" axis."""
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return mesh.shape["model"]


def paged_pool_pspec(num_kv_heads: int, tp: int) -> P:
    """Spec for a page-pool stack (NP, num_blocks, block_size, K, hd).

    Pools shard over "model" by *whole kv heads* — the one pool dim whose
    slices are self-contained (every query group of a kv head attends only
    that head's K/V), so block tables, refcounts, hashes and every other
    piece of host-side metadata stay global and mesh-invariant. An
    indivisible head count cannot shard this way; raising here (rather
    than silently replicating a cache that exists precisely to be big)
    surfaces the misconfiguration at engine construction.
    """
    if tp > 1 and num_kv_heads % tp != 0:
        raise ValueError(
            f"num_kv_heads={num_kv_heads} is not divisible by the mesh "
            f"model axis ({tp}): page pools shard by whole kv heads. "
            "Choose a model-axis size that divides num_kv_heads, or shard "
            "the blocks axis via the LSE-stitch path (docs/multi-host.md).")
    return P(None, None, None, "model" if tp > 1 else None, None)


def serving_cache_pspec(path, leaf, tp: int) -> P:
    """Spec for one serving-cache leaf, keyed on the cache pytree path.

    * paged pools / encoder K-V (dict leaves "k"/"v"/"xk"/"xv", 5D with kv
      heads on axis 3) shard by kv head — per-head attention over them is
      computed entirely on the owning shard and gathered before any
      cross-head contraction, so outputs stay bitwise mesh-invariant;
    * Mamba slot-state tuples (conv tail, ssm state) stay **replicated**:
      they are constant-size per slot (nothing grows with context), and
      storing the recurrent state sharded lets GSPMD propagate that
      sharding back into the SSD scan's inner contractions, reordering
      float adds — sharding it bitwise-safely needs a shard_map'd SSD
      (ROADMAP);
    * anything else is replicated.
    """
    if tp <= 1:
        return P()
    keys = [k.key for k in path if isinstance(k, jax.tree_util.DictKey)]
    if keys and keys[-1] in ("k", "v", "xk", "xv") and leaf.ndim == 5:
        ok = leaf.shape[3] % tp == 0
        return P(None, None, None, "model" if ok else None, None)
    if keys and keys[-1] in ("k_scale", "v_scale") and leaf.ndim == 5:
        # quantized-pool scale leaves (NP, nb, bs, K, 1): same kv-head
        # sharding as the value pools they describe
        ok = leaf.shape[3] % tp == 0
        return P(None, None, None, "model" if ok else None, None)
    return P()


def serving_cache_shardings(cache, mesh):
    """NamedSharding tree for a runner's device cache (see
    ``serving_cache_pspec``); the engine device_puts the zero cache with
    these at construction and jit/donation keep them in place."""
    tp = serving_tp(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda p, x: NamedSharding(mesh, serving_cache_pspec(p, x, tp)),
        cache)
