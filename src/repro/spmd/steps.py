"""Step builders: train / prefill / decode, plus their sharding assignments.

``make_train_step`` returns a pure function (params, opt_state, step, batch)
-> (params, opt_state, metrics) with gradient-accumulation microbatching.
``shardings_for_*`` compute the NamedShardings handed to jax.jit — the
"placement" half of the paper's model (§3.3): the step function is the
graph; these assignments are where each vertex's state lives.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import (ModelConfig, OptimizerConfig, ParallelConfig,
                          ShapeConfig)
from repro.models import api
from repro.optim import optimizers as opt
from repro.spmd import sharding as shd
from repro.spmd import zero

PyTree = Any


# ---------------------------------------------------------------------------
# Microbatching
# ---------------------------------------------------------------------------

_BATCH_AXIS = {"positions": 1}   # (3, B, S) M-RoPE ids; everything else dim 0


def _split_microbatches(batch: dict, m: int) -> dict:
    def split(name, x):
        ax = _BATCH_AXIS.get(name, 0)
        B = x.shape[ax]
        assert B % m == 0, (name, B, m)
        shp = x.shape[:ax] + (m, B // m) + x.shape[ax + 1:]
        return jnp.moveaxis(x.reshape(shp), ax, 0)
    return {k: split(k, v) for k, v in batch.items()}


def _merge_metrics(ms):
    return ms


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, pcfg: ParallelConfig,
                    ocfg: OptimizerConfig):
    def loss_of(params, mb):
        sampled = mb.pop("sampled_ids") if "sampled_ids" in mb else None
        loss, metr = api.loss_fn(params, mb, cfg, pcfg, sampled_ids=sampled)
        return loss, metr

    def grads_of(params, batch):
        if pcfg.microbatches <= 1:
            (loss, metr), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
            return loss, metr, grads

        mbs = _split_microbatches(batch, pcfg.microbatches)
        # accumulate in fp32 even though per-microbatch grads are bf16
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            gacc, lacc = carry
            (loss, metr), g = jax.value_and_grad(
                loss_of, has_aux=True)(params, mb)
            gacc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32) / pcfg.microbatches,
                gacc, g)
            return (gacc, lacc + loss / pcfg.microbatches), metr

        (grads, loss), metr = jax.lax.scan(
            body, (g0, jnp.zeros((), jnp.float32)), mbs)
        metr = jax.tree.map(lambda x: x.mean(), metr)
        return loss, metr, grads

    def train_step(params, opt_state, step, batch):
        """params: bf16 working copy; opt_state holds fp32 masters + slots."""
        loss, metr, grads = grads_of(params, batch)
        if ocfg.grad_clip:
            grads, gnorm = opt.clip_by_global_norm(grads, ocfg.grad_clip)
        else:
            gnorm = opt.global_norm(grads)
        params, opt_state = opt.apply_updates_master(ocfg, opt_state, grads,
                                                     step)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": opt.schedule(ocfg, step), **metr}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, pcfg: ParallelConfig):
    def prefill_step(params, batch):
        return api.prefill_fn(params, batch, cfg, pcfg)
    return prefill_step


def make_decode_step(cfg: ModelConfig, pcfg: ParallelConfig):
    def decode_step(params, cache, batch):
        return api.decode_fn(params, cache, batch, cfg, pcfg)
    return decode_step


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    out = {}
    for name, (shp, _) in api.batch_shapes(cfg, shape).items():
        ax = _BATCH_AXIS.get(name, 0)
        b = shd.batch_spec(shp[ax], mesh, extra_dims=0)
        entries = [None] * len(shp)
        entries[ax] = b[0] if len(b) else None
        out[name] = NamedSharding(mesh, P(*entries))
    return out


def cache_shardings(cfg: ModelConfig, B: int, S: int, mesh):
    """Shardings for the cache pytree (layer-stacked leading dim)."""
    shapes = api.init_cache_shapes(cfg, B, S)
    dp = shd.batch_spec(B, mesh, extra_dims=0)
    dp0 = dp[0] if len(dp) else None

    def leaf(sds):
        shp = sds.shape
        if len(shp) == 5 and shp[-1] == cfg.head_dim and cfg.num_kv_heads:
            # (L, B, S_or_Te, K, hd) attention cache
            seq = shp[2]
            seq_ax = ("model" if "model" in mesh.axis_names
                      and seq % mesh.shape["model"] == 0 else None)
            return NamedSharding(mesh, P(None, dp0, seq_ax, None, None))
        if len(shp) == 5:          # (L, B, nh, hp, N) ssm state
            nh = shp[2]
            ax = ("model" if "model" in mesh.axis_names
                  and nh % mesh.shape["model"] == 0 else None)
            return NamedSharding(mesh, P(None, dp0, ax, None, None))
        if len(shp) == 4:          # (L, B, K-1, conv_ch) conv tail
            return NamedSharding(mesh, P(None, dp0, None, None))
        return NamedSharding(mesh, P(*([None] * len(shp))))

    return jax.tree.map(leaf, shapes)


def param_shardings(cfg: ModelConfig, pcfg: ParallelConfig, mesh, specs):
    rules = shd.make_rules(cfg, pcfg)
    params_shapes = None  # not needed; resolve per leaf with shapes from specs
    return rules


def resolve_param_shardings(params_or_shapes, specs, cfg, pcfg, mesh):
    rules = shd.make_rules(cfg, pcfg)
    return shd.tree_shardings(params_or_shapes, specs, rules, mesh)


def opt_state_shardings(opt_shapes, params_shapes, specs, cfg, pcfg, mesh):
    rules = shd.make_rules(cfg, pcfg)
    pspecs = shd.tree_pspecs(params_shapes, specs, rules, mesh)
    if pcfg.zero1:
        return zero.zero1_state_shardings(opt_shapes, pspecs, mesh)
    return zero.plain_state_shardings(opt_shapes, pspecs, mesh)
