"""ZeRO-1: shard optimizer slot variables over the data-parallel axes.

With pure DP+TP, optimizer moments replicate across "data" — for grok-1
(314B) that alone exceeds HBM. ZeRO-1 assigns each slot leaf an extra
"data"-axis sharding on its first divisible, otherwise-unsharded dim; GSPMD
then computes the update sharded and all-gathers only the fp32->param
delta. Expressed entirely as out_shardings — no optimizer code changes,
which is the §4.1 extensibility point all over again.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.spmd.sharding import dp_axes


def zero1_leaf_spec(shape, base_spec: P, mesh) -> P:
    """Add DP sharding to the first free, divisible dim of a slot leaf."""
    dp = dp_axes(mesh)
    if not dp:
        return base_spec
    entries = list(base_spec) + [None] * (len(shape) - len(base_spec))
    used = set()
    for e in entries:
        for a in (e if isinstance(e, tuple) else (e,)):
            if a:
                used.add(a)
    free_dp = tuple(a for a in dp if a not in used)
    if not free_dp:
        return base_spec
    import math
    size = math.prod(mesh.shape[a] for a in free_dp)
    for i, (dim, e) in enumerate(zip(shape, entries)):
        if e is None and dim % size == 0 and dim >= size:
            entries[i] = free_dp if len(free_dp) > 1 else free_dp[0]
            return P(*entries)
    return base_spec


def zero1_state_shardings(opt_state_shapes, param_pspecs, mesh):
    """Shardings for the optimizer state given param PartitionSpecs.

    opt_state is {"s0": tree, "s1": tree, ...} with trees mirroring params.
    """
    def shard_slot(tree_shapes, tree_specs):
        def one(shp, spec):
            return NamedSharding(
                mesh, zero1_leaf_spec(shp.shape, spec, mesh))
        return _map2(one, tree_shapes, tree_specs)

    return {k: shard_slot(v, param_pspecs) for k, v in
            opt_state_shapes.items()}


def plain_state_shardings(opt_state_shapes, param_pspecs, mesh):
    def shard_slot(tree_shapes, tree_specs):
        return _map2(lambda shp, spec: NamedSharding(mesh, spec),
                     tree_shapes, tree_specs)
    return {k: shard_slot(v, param_pspecs)
            for k, v in opt_state_shapes.items()}


def _map2(fn, a, b):
    if isinstance(a, dict):
        return {k: _map2(fn, a[k], b[k]) for k in a}
    if isinstance(a, tuple):
        return tuple(_map2(fn, x, y) for x, y in zip(a, b))
    return fn(a, b)
