"""User-level automatic differentiation (paper §4.1).

Breadth-first search from the target (loss) back to the parameters; each
op's registered grad function emits *new graph nodes*; multiple backward
paths into the same tensor are summed with AddN. Exactly the architecture
the paper describes — differentiation is a library over the graph, not a
runtime feature, so users can specialize gradients (the paper cites batch
norm and gradient clipping as user-contributed examples; our ps/ training
loops use these gradients to build SGD/Momentum/Adagrad updates, §4.1).
"""

from __future__ import annotations

from collections import defaultdict, deque

from repro.core.graph import Graph, Operation, Tensor, get_opdef


def gradients(target: Tensor, xs: list[Tensor],
              grad_y: Tensor | None = None) -> list[Tensor | None]:
    graph = target.op.graph

    # ops on a backward path: reverse-reachable from target ∩ forward-
    # reachable from xs (the paper's BFS path identification)
    reach_back: set[str] = set()
    dq = deque([target.op])
    while dq:
        op = dq.popleft()
        if op.name in reach_back:
            continue
        reach_back.add(op.name)
        for t in op.inputs:
            dq.append(t.op)

    # accumulate per-tensor partial gradients
    partials: dict[str, list[Tensor]] = defaultdict(list)
    if grad_y is None:
        grad_y = graph.constant(1.0)
    partials[target.name].append(grad_y)

    order = graph.topo_order({graph.ops[n] for n in reach_back})
    grads_of: dict[str, Tensor] = {}

    def grad_for(t: Tensor) -> Tensor | None:
        if t.name in grads_of:
            return grads_of[t.name]
        ps = partials.get(t.name)
        if not ps:
            return None
        out = ps[0] if len(ps) == 1 else graph.apply("AddN", *ps)
        grads_of[t.name] = out
        return out

    for op in reversed(order):
        out_grads = [grad_for(t) for t in op.outputs]
        if all(gd is None for gd in out_grads):
            continue
        opdef = get_opdef(op.type)
        if opdef.grad is None:
            continue  # non-differentiable leaf (labels, ids, state handles)
        # substitute zeros-like only when an op has mixed known outputs
        gs = [gd if gd is not None else None for gd in out_grads]
        in_grads = opdef.grad(op, *gs)
        for t, gd in zip(op.inputs, in_grads):
            if gd is not None:
                partials[t.name].append(gd)

    return [grad_for(x) for x in xs]
