"""Client Session (paper §3.2-3.3): partial execution with a step cache.

``Session.run(fetches, feeds)`` selects a subgraph (prune), places it,
partitions it with Send/Recv, caches the plan keyed by the (fetches, feeds)
signature, and executes it as one concurrent step. Multiple ``run`` calls
may execute concurrently against the same mutable state — that is the
paper's data-parallel training pattern (§4.4) and our ps/ package uses it.
"""

from __future__ import annotations

import itertools
import threading

from repro.core.cluster import Cluster
from repro.core.executor import prune, run_plan
from repro.core.graph import Graph, Operation, Tensor
from repro.core.partition import partition
from repro.core.placement import place


class Session:
    def __init__(self, graph: Graph, cluster: Cluster | None = None,
                 default_device: str | None = None):
        self.graph = graph
        self.cluster = cluster or Cluster(worker=1)
        self.default_device = default_device or self.cluster.devices[0]
        self._plan_cache: dict = {}
        self._step_counter = itertools.count()
        self._lock = threading.Lock()

    def run(self, fetches, feeds: dict | None = None, timeout: float = 60.0):
        single = False
        if isinstance(fetches, (Tensor, Operation)):
            fetches = [fetches]
            single = True
        feeds = feeds or {}
        fetch_tensors = [f if isinstance(f, Tensor) else f.outputs[0]
                         if f.outputs else None for f in fetches]
        roots = [f for f in fetches if isinstance(f, Operation)]
        fetch_tensors = [t for t in fetch_tensors if t is not None]

        key = (tuple(t.name for t in fetch_tensors),
               tuple(r.name for r in roots),
               tuple(sorted(t.name for t in feeds)))
        with self._lock:
            plan = self._plan_cache.get(key)
            if plan is None:
                ops = prune(self.graph, fetch_tensors, feeds, roots)
                place(ops, self.cluster.devices, self.default_device)
                plan = partition(self.graph, ops, fetch_tensors)
                self._plan_cache[key] = plan
            step_id = next(self._step_counter)

        feed_values = {t.name: v for t, v in feeds.items()}
        out = run_plan(plan, self.cluster.tasks, self.cluster.rendezvous,
                       step_id, feed_values,
                       [t.name for t in fetch_tensors], timeout=timeout)
        return out[0] if single and out else out
