"""Graph partitioning with Send/Recv (paper §3.3).

After placement, the pruned subgraph splits into per-device op lists; every
edge crossing devices is cut and replaced by a Send on the producer and a
Recv on the consumer, matched through a *rendezvous key*
``(tensor_name, step_id)``. Send fires as soon as its input is ready; Recv
blocks until the value arrives — the executor threads give the asynchrony.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field

import numpy as np

from repro.core.graph import OpDef, Operation, Tensor, register


class Rendezvous:
    """In-process rendezvous: blocking key-value exchange between tasks."""

    def __init__(self):
        self._store: dict = {}
        self._cv = threading.Condition()

    def send(self, key, value):
        with self._cv:
            self._store[key] = value
            self._cv.notify_all()

    def recv(self, key, timeout=30.0):
        with self._cv:
            ok = self._cv.wait_for(lambda: key in self._store,
                                   timeout=timeout)
            if not ok:
                raise TimeoutError(f"rendezvous recv timed out: {key}")
            return self._store.pop(key)


def _send(ctx, attrs, value):
    ctx.rendezvous.send((attrs["key"], ctx.step_id), value)
    return ()


def _recv(ctx, attrs):
    return (ctx.rendezvous.recv((attrs["key"], ctx.step_id)),)


register(OpDef("Send", 0, _send, stateful=True))
register(OpDef("Recv", 1, _recv, stateful=True))


@dataclass
class DevicePlan:
    device: str
    ops: list[Operation] = field(default_factory=list)


@dataclass
class Plan:
    """A placed, partitioned, cached execution plan (§3.3 'step cache')."""
    per_device: dict[str, DevicePlan]
    fetch_map: dict[str, tuple[str, str]]   # fetch name -> (device, local)


def partition(graph, ops: list[Operation], fetches: list[Tensor]) -> Plan:
    per_device: dict[str, DevicePlan] = {}
    opset = set(ops)

    def plan_for(device: str) -> DevicePlan:
        if device not in per_device:
            per_device[device] = DevicePlan(device)
        return per_device[device]

    recv_cache: dict[tuple[str, str], Tensor] = {}

    for op in graph.topo_order(opset):
        dev = op.assigned_device
        new_inputs = []
        for t in op.inputs:
            src = t.op.assigned_device
            if src == dev or t.op not in opset:
                new_inputs.append(t)
                continue
            ck = (t.name, dev)
            if ck not in recv_cache:
                key = f"{t.name}->{dev}"
                send = graph.apply("Send", t, key=key,
                                   name=f"send/{key}".replace(":", "_"))
                send_op = send if isinstance(send, Operation) else send.op
                send_op.assigned_device = src
                plan_for(src).ops.append(send_op)
                recv = graph.apply("Recv", key=key,
                                   name=f"recv/{key}".replace(":", "_"))
                recv.op.assigned_device = dev
                plan_for(dev).ops.append(recv.op)
                recv_cache[ck] = recv
            new_inputs.append(recv_cache[ck])
        op.inputs = new_inputs
        plan_for(dev).ops.append(op)

    fetch_map = {}
    for t in fetches:
        fetch_map[t.name] = (t.op.assigned_device, t.name)
    return Plan(per_device, fetch_map)
