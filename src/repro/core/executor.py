"""Dataflow executor (paper §3.2, §5).

Prunes the graph to the subgraph needed by the fetches (dead-code
elimination via reverse BFS from fetches, stopping at feeds), then runs each
device's op list in topological order inside that device's task thread.
Blocking ops (Dequeue, Recv, barrier queues) simply block their step thread,
which is how concurrent steps coordinate through shared state.

Dead-tensor propagation (§3.4): a non-Merge op with any DEAD input skips
execution and emits DEAD on all outputs; Merge forwards its first live
input. This is what makes Switch/Merge conditionals work.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass

import numpy as np

from repro.core.graph import Graph, Operation, Tensor
from repro.core.graph import get_opdef
from repro.core.ops import DEAD


@dataclass
class ExecContext:
    task: object            # owning Task (var_store, queue_store)
    rendezvous: object
    step_id: int


def prune(graph: Graph, fetches: list[Tensor],
          feeds: dict[Tensor, object],
          extra_roots: list[Operation] = ()) -> list[Operation]:
    """Reverse BFS from fetches (+explicit roots), stopping at fed tensors."""
    fed = {t.name for t in feeds}
    seen: set[str] = set()
    stack = [t.op for t in fetches] + list(extra_roots)
    ops: list[Operation] = []
    while stack:
        op = stack.pop()
        if op.name in seen:
            continue
        seen.add(op.name)
        ops.append(op)
        for t in op.inputs:
            if t.name not in fed:
                stack.append(t.op)
        stack.extend(op.control_inputs)
    return ops


class DeviceExecutor:
    """Executes one device's topo-ordered op list for one step."""

    def __init__(self, task):
        self.task = task

    def run(self, ops: list[Operation], feeds: dict[str, object],
            ctx: ExecContext, values: dict[str, object]):
        for op in ops:
            if all(t.name in values or t.name in feeds
                   for t in op.inputs):
                pass
            args = []
            dead = False
            for t in op.inputs:
                v = feeds.get(t.name, values.get(t.name))
                if v is DEAD and op.type != "Merge":
                    dead = True
                args.append(v)
            if dead:
                for out in op.outputs:
                    values[out.name] = DEAD
                continue
            opdef = get_opdef(op.type)
            outs = opdef.compute(ctx, dict(op.attrs), *args)
            for out, v in zip(op.outputs, outs):
                values[out.name] = v
        return values


def run_plan(plan, tasks: dict[str, object], rendezvous, step_id: int,
             feeds: dict[str, object], fetch_names: list[str],
             timeout: float = 60.0):
    """Run a partitioned Plan: one thread per participating device (§3.3:
    'a distributed step ... one small message to each participating task')."""
    results: dict[str, dict] = {}
    errors: list[BaseException] = []

    def run_device(device, dplan):
        task = tasks[device]
        ctx = ExecContext(task=task, rendezvous=rendezvous, step_id=step_id)
        try:
            values: dict[str, object] = {}
            DeviceExecutor(task).run(dplan.ops, feeds, ctx, values)
            results[device] = values
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = []
    for device, dplan in plan.per_device.items():
        th = threading.Thread(target=run_device, args=(device, dplan),
                              daemon=True)
        th.start()
        threads.append(th)
    for th in threads:
        th.join(timeout)
    if errors:
        raise errors[0]
    out = []
    for name in fetch_names:
        device, local = plan.fetch_map[name]
        out.append(results[device][local])
    return out
