"""In-process cluster: named tasks with their own state stores (paper §3.3).

A real deployment maps tasks to processes connected by gRPC/RDMA; here they
are thread domains sharing a Rendezvous — the transport is swappable without
touching the execution model (§5 lists multiple Send/Recv specializations).
Task naming follows the paper's "/job:ps/task:0" scheme, shortened "ps:0".
"""

from __future__ import annotations

from repro.core.partition import Rendezvous
from repro.core.queues import QueueStore
from repro.core.variables import VariableStore


class Task:
    def __init__(self, name: str):
        self.name = name
        self.var_store = VariableStore()
        self.queue_store = QueueStore()

    def __repr__(self):
        return f"<Task {self.name}>"


class Cluster:
    """A set of tasks, e.g. Cluster(ps=2, worker=4)."""

    def __init__(self, **jobs: int):
        self.tasks: dict[str, Task] = {}
        for job, n in jobs.items():
            for i in range(n):
                name = f"{job}:{i}"
                self.tasks[name] = Task(name)
        self.rendezvous = Rendezvous()

    @property
    def devices(self) -> list[str]:
        return list(self.tasks)

    def job(self, job: str) -> list[str]:
        return [d for d in self.tasks if d.startswith(job + ":")]
