"""The dataflow graph (paper §3.1): operations, tensors, mutable state.

A ``Graph`` holds ``Operation`` vertices; each edge carries a ``Tensor``
(dense n-d array at runtime). Operations may own *mutable state* (variables,
queues) — the paper's key departure from batch dataflow: state lives at a
vertex, is read/written by executing ops, and is shared between concurrent
step executions of overlapping subgraphs (§3.2).

Ops are created through the registry in ``core.ops``; gradients (§4.1) are
user-level graph-to-graph construction in ``core.gradients``; placement and
partitioning (§3.3) in ``core.placement`` / ``core.partition``.
"""

from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence


class Tensor:
    """A symbolic output slot of an operation."""

    __slots__ = ("op", "index")

    def __init__(self, op: "Operation", index: int):
        self.op = op
        self.index = index

    @property
    def name(self) -> str:
        return f"{self.op.name}:{self.index}"

    def __repr__(self):
        return f"<Tensor {self.name} ({self.op.type})>"

    # small sugar so user-level code (optimizers §4.1) reads naturally
    def __add__(self, other):
        return self.op.graph.apply("Add", self, _lift(self.op.graph, other))

    def __sub__(self, other):
        return self.op.graph.apply("Sub", self, _lift(self.op.graph, other))

    def __mul__(self, other):
        return self.op.graph.apply("Mul", self, _lift(self.op.graph, other))

    def __neg__(self):
        return self.op.graph.apply("Neg", self)

    def __matmul__(self, other):
        return self.op.graph.apply("MatMul", self,
                                   _lift(self.op.graph, other))


def _lift(graph: "Graph", value) -> Tensor:
    if isinstance(value, Tensor):
        return value
    return graph.constant(value)


class Operation:
    """A vertex: a named, typed unit of computation with attrs (§3.1)."""

    def __init__(self, graph: "Graph", op_type: str, name: str,
                 inputs: Sequence[Tensor], attrs: dict,
                 num_outputs: int, control_inputs: Sequence["Operation"] = (),
                 device: str | None = None):
        self.graph = graph
        self.type = op_type
        self.name = name
        self.inputs = list(inputs)
        self.attrs = dict(attrs)
        self.control_inputs = list(control_inputs)
        self.device = device                  # constraint, e.g. "task:ps0"
        self.colocation: str | None = attrs.pop("_colocate", None)
        self.outputs = [Tensor(self, i) for i in range(num_outputs)]
        self.assigned_device: str | None = None   # set by placement

    def output(self, i: int = 0) -> Tensor:
        return self.outputs[i]

    def __repr__(self):
        return f"<Op {self.name} ({self.type}) on {self.assigned_device}>"


@dataclass
class OpDef:
    """Registered operation type: runtime kernel + optional gradient."""
    name: str
    num_outputs: int
    # compute(ctx, attrs, *input values) -> tuple of outputs
    compute: Callable
    # grad(op, *output grads) -> list of input grads (Tensors or None)
    grad: Callable | None = None
    stateful: bool = False
    # number of outputs may depend on attrs:
    num_outputs_fn: Callable | None = None


_REGISTRY: dict[str, OpDef] = {}


def register(opdef: OpDef):
    _REGISTRY[opdef.name] = opdef
    return opdef


def get_opdef(op_type: str) -> OpDef:
    if op_type not in _REGISTRY:
        raise KeyError(f"unregistered op type {op_type!r}")
    return _REGISTRY[op_type]


class Graph:
    """A single dataflow graph for all computation and state (§3)."""

    def __init__(self):
        self.ops: dict[str, Operation] = {}
        self._counter = itertools.count()
        self._lock = threading.Lock()
        self._device_stack: list[str] = []

    # -- construction -------------------------------------------------------

    def apply(self, op_type: str, *inputs, name: str | None = None,
              control_inputs: Sequence[Operation] = (),
              **attrs):
        opdef = get_opdef(op_type)
        inputs = [_lift(self, x) for x in inputs]
        with self._lock:
            if name is None:
                name = f"{op_type}_{next(self._counter)}"
            if name in self.ops:
                raise ValueError(f"duplicate op name {name}")
            n_out = (opdef.num_outputs_fn(attrs) if opdef.num_outputs_fn
                     else opdef.num_outputs)
            device = attrs.pop("device", None) or (
                self._device_stack[-1] if self._device_stack else None)
            op = Operation(self, op_type, name, inputs, attrs, n_out,
                           control_inputs, device)
            self.ops[name] = op
        if len(op.outputs) == 1:
            return op.outputs[0]
        return tuple(op.outputs) if op.outputs else op

    def constant(self, value, name: str | None = None):
        import numpy as np
        return self.apply("Const", value=np.asarray(value), name=name)

    def placeholder(self, name: str | None = None, shape=None, dtype=None):
        return self.apply("Placeholder", shape=shape, dtype=dtype, name=name)

    def device(self, device: str):
        """Context manager applying a device constraint (§3.3)."""
        graph = self

        class _Ctx:
            def __enter__(self):
                graph._device_stack.append(device)

            def __exit__(self, *a):
                graph._device_stack.pop()

        return _Ctx()

    # -- traversal ----------------------------------------------------------

    def op_of(self, t: Tensor | Operation) -> Operation:
        return t.op if isinstance(t, Tensor) else t

    def topo_order(self, ops: set[Operation]) -> list[Operation]:
        seen: set[str] = set()
        order: list[Operation] = []

        def visit(op: Operation):
            if op.name in seen:
                return
            seen.add(op.name)
            for t in op.inputs:
                if t.op in ops:
                    visit(t.op)
            for c in op.control_inputs:
                if c in ops:
                    visit(c)
            order.append(op)

        for op in sorted(ops, key=lambda o: o.name):
            visit(op)
        return order
