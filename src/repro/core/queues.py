"""Queue operations (paper §3.1): FIFOQueue with blocking Enqueue/Dequeue.

Blocking provides backpressure in input pipelines and acts as the
synchronization primitive for §4.4's replica coordination (barrier queues
and gradient-accumulation queues). Queues are owned state, addressed by a
reference handle like variables.
"""

from __future__ import annotations

import queue as pyqueue
import threading

import numpy as np

from repro.core.graph import OpDef, register


class QueueClosed(Exception):
    pass


class FIFOQueue:
    def __init__(self, capacity: int):
        self.capacity = capacity
        self._q: pyqueue.Queue = pyqueue.Queue(maxsize=capacity)
        self._closed = threading.Event()

    def enqueue(self, item, timeout=None):
        if self._closed.is_set():
            raise QueueClosed()
        self._q.put(item, timeout=timeout)

    def dequeue(self, timeout=None):
        while True:
            try:
                return self._q.get(timeout=0.05 if timeout is None else
                                   min(timeout, 0.05))
            except pyqueue.Empty:
                if self._closed.is_set() and self._q.empty():
                    raise QueueClosed() from None
                if timeout is not None:
                    timeout -= 0.05
                    if timeout <= 0:
                        raise TimeoutError() from None

    def dequeue_many(self, n: int, timeout=None):
        return [self.dequeue(timeout) for _ in range(n)]

    def close(self):
        self._closed.set()

    def size(self) -> int:
        return self._q.qsize()


class QueueStore:
    def __init__(self):
        self._queues: dict[str, FIFOQueue] = {}
        self._lock = threading.Lock()

    def ensure(self, name: str, capacity: int) -> FIFOQueue:
        with self._lock:
            if name not in self._queues:
                self._queues[name] = FIFOQueue(capacity)
            return self._queues[name]

    def get(self, name: str) -> FIFOQueue:
        return self._queues[name]


class QueueHandle:
    __slots__ = ("name", "store")

    def __init__(self, name, store):
        self.name = name
        self.store = store

    @property
    def queue(self) -> FIFOQueue:
        return self.store.get(self.name)


def _fifo_queue(ctx, attrs):
    name = attrs["queue_name"]
    ctx.task.queue_store.ensure(name, attrs.get("capacity", 64))
    return (QueueHandle(name, ctx.task.queue_store),)


def _enqueue(ctx, attrs, handle, value):
    handle.queue.enqueue(np.asarray(value))
    return ()


def _dequeue(ctx, attrs, handle):
    return (handle.queue.dequeue(),)


def _dequeue_many(ctx, attrs, handle):
    items = handle.queue.dequeue_many(attrs["n"])
    return (np.stack(items),)


def _queue_close(ctx, attrs, handle):
    handle.queue.close()
    return ()


def _queue_size(ctx, attrs, handle):
    return (np.asarray(handle.queue.size()),)


register(OpDef("FIFOQueue", 1, _fifo_queue, stateful=True))
register(OpDef("Enqueue", 0, _enqueue, stateful=True))
register(OpDef("Dequeue", 1, _dequeue, stateful=True))
register(OpDef("DequeueMany", 1, _dequeue_many, stateful=True))
register(OpDef("QueueClose", 0, _queue_close, stateful=True))
register(OpDef("QueueSize", 1, _queue_size, stateful=True))
