"""Standard operation library (paper §5: "over 200 standard operations").

Kernels are numpy functions dispatched by the executor; gradients build new
graph nodes (user-level autodiff, §4.1). The subset here covers everything
the paper's case studies need: math, array manipulation, state (variables,
queues via core.variables/core.queues), sparse embedding primitives
(Gather / DynamicPartition / DynamicStitch, §4.2), control flow (Switch /
Merge, §3.4) and checkpointing (Save / Restore, §4.3).
"""

from __future__ import annotations

import numpy as np

from repro.core.graph import Graph, OpDef, Operation, Tensor, register

# A sentinel flowing along untaken conditional branches (§3.4).


class Dead:
    _inst = None

    def __new__(cls):
        if cls._inst is None:
            cls._inst = super().__new__(cls)
        return cls._inst

    def __repr__(self):
        return "<dead>"


DEAD = Dead()


def g(t: Tensor) -> Graph:
    return t.op.graph


# ---------------------------------------------------------------------------
# basic ops
# ---------------------------------------------------------------------------

register(OpDef("Const", 1, lambda ctx, attrs: (attrs["value"],)))
register(OpDef("Placeholder", 1,
               lambda ctx, attrs: (_ for _ in ()).throw(
                   RuntimeError("placeholder must be fed"))))
register(OpDef("NoOp", 0, lambda ctx, attrs: ()))
register(OpDef("Identity", 1, lambda ctx, attrs, x: (x,),
               grad=lambda op, dy: [dy]))


def _binop(name, fn, grad):
    register(OpDef(name, 1, lambda ctx, attrs, a, b: (fn(a, b),), grad=grad))


_binop("Add", lambda a, b: a + b,
       lambda op, dy: [_unbroadcast(dy, op.inputs[0]),
                       _unbroadcast(dy, op.inputs[1])])
_binop("Sub", lambda a, b: a - b,
       lambda op, dy: [_unbroadcast(dy, op.inputs[0]),
                       _unbroadcast(-dy, op.inputs[1])])
_binop("Mul", lambda a, b: a * b,
       lambda op, dy: [_unbroadcast(dy * op.inputs[1], op.inputs[0]),
                       _unbroadcast(dy * op.inputs[0], op.inputs[1])])
_binop("Div", lambda a, b: a / b,
       lambda op, dy: [
           _unbroadcast(dy * g(dy).apply("Reciprocal", op.inputs[1]),
                        op.inputs[0]),
           _unbroadcast(
               -dy * op.inputs[0]
               * g(dy).apply("Reciprocal",
                             op.inputs[1] * op.inputs[1]), op.inputs[1])])
_binop("Maximum", np.maximum, None)
_binop("Pow", np.power, None)
_binop("FloorDiv", lambda a, b: a // b, None)
_binop("Mod", lambda a, b: a % b, None)
_binop("Less", lambda a, b: a < b, None)
_binop("Greater", lambda a, b: a > b, None)
_binop("Equal", lambda a, b: a == b, None)


def _unbroadcast(dy: Tensor, x: Tensor) -> Tensor:
    """Sum dy down to x's shape (runtime-shaped via UnbroadcastTo kernel)."""
    return g(dy).apply("UnbroadcastLike", dy, x)


def _unbroadcast_kernel(ctx, attrs, dy, x):
    dy = np.asarray(dy)
    x = np.asarray(x)
    if dy.shape == x.shape:
        return (dy,)
    extra = dy.ndim - x.ndim
    if extra > 0:
        dy = dy.sum(axis=tuple(range(extra)))
    axes = tuple(i for i, (a, b) in enumerate(zip(dy.shape, x.shape))
                 if b == 1 and a != 1)
    if axes:
        dy = dy.sum(axis=axes, keepdims=True)
    return (dy.reshape(x.shape),)


register(OpDef("UnbroadcastLike", 1, _unbroadcast_kernel))

register(OpDef("Neg", 1, lambda ctx, attrs, x: (-x,),
               grad=lambda op, dy: [-dy]))
register(OpDef("Reciprocal", 1, lambda ctx, attrs, x: (1.0 / x,)))
register(OpDef("Exp", 1, lambda ctx, attrs, x: (np.exp(x),),
               grad=lambda op, dy: [dy * op.outputs[0]]))
register(OpDef("Log", 1, lambda ctx, attrs, x: (np.log(x),),
               grad=lambda op, dy: [
                   dy * g(dy).apply("Reciprocal", op.inputs[0])]))
register(OpDef("Tanh", 1, lambda ctx, attrs, x: (np.tanh(x),),
               grad=lambda op, dy: [
                   dy * (g(dy).constant(1.0)
                         - op.outputs[0] * op.outputs[0])]))
register(OpDef("Sigmoid", 1,
               lambda ctx, attrs, x: (1.0 / (1.0 + np.exp(-x)),),
               grad=lambda op, dy: [
                   dy * op.outputs[0] * (g(dy).constant(1.0)
                                         - op.outputs[0])]))
register(OpDef("Relu", 1, lambda ctx, attrs, x: (np.maximum(x, 0.0),),
               grad=lambda op, dy: [
                   g(dy).apply("ReluGrad", dy, op.inputs[0])]))
register(OpDef("ReluGrad", 1,
               lambda ctx, attrs, dy, x: (dy * (x > 0),)))
register(OpDef("Sqrt", 1, lambda ctx, attrs, x: (np.sqrt(x),)))
register(OpDef("Square", 1, lambda ctx, attrs, x: (np.square(x),),
               grad=lambda op, dy: [dy * op.inputs[0]
                                    * g(dy).constant(2.0)]))


def _matmul_grad(op, dy):
    a, b = op.inputs
    gr = g(dy)
    da = gr.apply("MatMul", dy, gr.apply("Transpose", b))
    db = gr.apply("MatMul", gr.apply("Transpose", a), dy)
    return [da, db]


register(OpDef("MatMul", 1, lambda ctx, attrs, a, b: (a @ b,),
               grad=_matmul_grad))
register(OpDef("Transpose", 1,
               lambda ctx, attrs, x: (np.swapaxes(x, -1, -2),),
               grad=lambda op, dy: [g(dy).apply("Transpose", dy)]))
register(OpDef("Reshape", 1,
               lambda ctx, attrs, x: (np.reshape(x, attrs["shape"]),),
               grad=lambda op, dy: [
                   g(dy).apply("ReshapeLike", dy, op.inputs[0])]))
register(OpDef("ReshapeLike", 1,
               lambda ctx, attrs, x, like: (np.reshape(x, np.shape(like)),)))


def _reduce(name, fn, grad):
    register(OpDef(
        name, 1,
        lambda ctx, attrs, x: (fn(x, axis=attrs.get("axis"),
                                  keepdims=attrs.get("keepdims", False)),),
        grad=grad))


def _sum_grad(op, dy):
    return [g(dy).apply("BroadcastLike", dy, op.inputs[0],
                        axis=op.attrs.get("axis"),
                        keepdims=op.attrs.get("keepdims", False))]


def _mean_grad(op, dy):
    gr = g(dy)
    bl = gr.apply("BroadcastLike", dy, op.inputs[0],
                  axis=op.attrs.get("axis"),
                  keepdims=op.attrs.get("keepdims", False))
    return [gr.apply("MeanScale", bl, op.inputs[0],
                     axis=op.attrs.get("axis"))]


_reduce("ReduceSum", np.sum, _sum_grad)
_reduce("ReduceMean", np.mean, _mean_grad)
_reduce("ReduceMax", np.max, None)


def _broadcast_like(ctx, attrs, dy, x):
    x = np.asarray(x)
    dy = np.asarray(dy)
    axis = attrs.get("axis")
    if not attrs.get("keepdims", False) and axis is not None:
        axes = (axis,) if isinstance(axis, int) else tuple(axis)
        for ax in sorted(a % x.ndim for a in axes):
            dy = np.expand_dims(dy, ax)
    return (np.broadcast_to(dy, x.shape),)


register(OpDef("BroadcastLike", 1, _broadcast_like))
register(OpDef("MeanScale", 1,
               lambda ctx, attrs, bl, x: (
                   bl * _mean_count(np.asarray(x), attrs.get("axis")),)))


def _mean_count(x, axis):
    if axis is None:
        return 1.0 / x.size
    axes = (axis,) if isinstance(axis, int) else tuple(axis)
    n = 1
    for a in axes:
        n *= x.shape[a % x.ndim]
    return 1.0 / n


def _addn(ctx, attrs, *xs):
    out = xs[0]
    for x in xs[1:]:
        out = out + x
    return (out,)


register(OpDef("AddN", 1, _addn,
               grad=lambda op, dy: [dy for _ in op.inputs]))

register(OpDef("Softmax", 1, lambda ctx, attrs, x: (_softmax(x),)))


def _softmax(x):
    m = x.max(axis=-1, keepdims=True)
    e = np.exp(x - m)
    return e / e.sum(axis=-1, keepdims=True)


def _xent(ctx, attrs, logits, labels):
    p = _softmax(logits)
    n = logits.shape[0]
    ll = -np.log(np.maximum(p[np.arange(n), labels], 1e-30))
    return (ll.mean(),)


def _xent_grad(op, dy):
    return [g(dy).apply("SoftmaxXentGrad", dy, op.inputs[0], op.inputs[1]),
            None]


def _xent_grad_kernel(ctx, attrs, dy, logits, labels):
    p = _softmax(logits)
    n = logits.shape[0]
    p[np.arange(n), labels] -= 1.0
    return (dy * p / n,)


register(OpDef("SoftmaxXent", 1, _xent, grad=_xent_grad))
register(OpDef("SoftmaxXentGrad", 1, _xent_grad_kernel))


# ---------------------------------------------------------------------------
# sparse embedding primitives (§4.2): Gather / DynamicPartition / Stitch
# ---------------------------------------------------------------------------


def _gather_grad(op, dy):
    gr = g(dy)
    # sparse gradient: scatter dy rows back at the gathered indices
    return [gr.apply("ScatterAddGrad", dy, op.inputs[0], op.inputs[1]),
            None]


register(OpDef("Gather", 1,
               lambda ctx, attrs, params, ids: (params[ids],),
               grad=_gather_grad))


def _scatter_add_grad(ctx, attrs, dy, params, ids):
    out = np.zeros_like(params)
    np.add.at(out, ids, dy)
    return (out,)


register(OpDef("ScatterAddGrad", 1, _scatter_add_grad))


def _dynamic_partition(ctx, attrs, data, partitions):
    n = attrs["num_partitions"]
    return tuple(data[partitions == i] for i in range(n))


def _dynamic_partition_grad(op, *dys):
    gr = op.graph
    n = op.attrs["num_partitions"]
    idx = gr.apply("DynamicPartitionIndices", op.inputs[1],
                   num_partitions=n)
    idx = idx if isinstance(idx, tuple) else (idx,)
    stitched = gr.apply("DynamicStitch", *idx, *dys, n=n)
    return [stitched, None]


register(OpDef("DynamicPartition", None, _dynamic_partition,
               grad=_dynamic_partition_grad,
               num_outputs_fn=lambda attrs: attrs["num_partitions"]))


def _dp_indices(ctx, attrs, partitions):
    n = attrs["num_partitions"]
    idx = np.arange(len(partitions))
    return tuple(idx[partitions == i] for i in range(n))


register(OpDef("DynamicPartitionIndices", None, _dp_indices,
               num_outputs_fn=lambda attrs: attrs["num_partitions"]))


def _dynamic_stitch(ctx, attrs, *args):
    n = attrs["n"]
    indices, data = args[:n], args[n:]
    total = int(sum(len(i) for i in indices))
    sample = next((d for d in data if len(d)), data[0])
    out = np.zeros((total,) + np.shape(sample)[1:], dtype=sample.dtype)
    for i, d in zip(indices, data):
        out[i] = d
    return (out,)


def _dynamic_stitch_grad(op, dy):
    gr = g(dy)
    n = op.attrs["n"]
    grads = [None] * n
    for i in range(n):
        grads.append(gr.apply("Gather", dy, op.inputs[i]))
    return grads


register(OpDef("DynamicStitch", 1, _dynamic_stitch,
               grad=_dynamic_stitch_grad))


def _concat_kernel(ctx, attrs, *xs):
    return (np.concatenate(xs, axis=attrs.get("axis", -1)),)


def _concat_grad(op, dy):
    gr = g(dy)
    outs = gr.apply("ConcatGrad", dy, *op.inputs,
                    axis=op.attrs.get("axis", -1), n=len(op.inputs))
    outs = outs if isinstance(outs, tuple) else (outs,)
    return list(outs)


def _concat_grad_kernel(ctx, attrs, dy, *xs):
    axis = attrs.get("axis", -1)
    out, off = [], 0
    for x in xs:
        w = np.shape(x)[axis]
        sl = [slice(None)] * np.ndim(dy)
        sl[axis] = slice(off, off + w)
        out.append(np.ascontiguousarray(dy[tuple(sl)]))
        off += w
    return tuple(out)


register(OpDef("ConcatGrad", None, _concat_grad_kernel,
               num_outputs_fn=lambda attrs: attrs["n"]))
register(OpDef("Concat", 1, _concat_kernel, grad=_concat_grad))


# ---------------------------------------------------------------------------
# control flow (§3.4): Switch / Merge with dead propagation
# ---------------------------------------------------------------------------


def _switch(ctx, attrs, data, pred):
    if bool(pred):
        return (DEAD, data)
    return (data, DEAD)


def _merge(ctx, attrs, *xs):
    live = [x for x in xs if x is not DEAD]
    if not live:
        return (DEAD, DEAD)
    return (live[0], np.asarray(len(live)))


register(OpDef("Switch", 2, _switch))
register(OpDef("Merge", 2, _merge))
