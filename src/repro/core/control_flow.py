"""Dynamic control flow (paper §3.4): Switch/Merge conditionals.

``cond`` builds a non-strict conditional subgraph (Figure 2): every input is
demultiplexed by Switch on the predicate; each branch computes on its live
half; Merge forwards whichever branch produced a value, dead tensors
propagating through the untaken side. The executor's dead-propagation rule
(core.executor) makes only the taken branch execute.

Iteration: the paper builds while-loops from Switch/Merge with
timely-dataflow frame structure. We reproduce conditionals at full fidelity
and provide ``while_loop`` as a client-driven iteration over a cached step
(re-firing the loop-body subgraph with state in Variables) — the
simplification and its rationale are recorded in DESIGN.md §7.
"""

from __future__ import annotations

from repro.core.graph import Graph, Tensor


def cond(pred: Tensor, true_fn, false_fn, inputs: list[Tensor]):
    """Non-strict conditional: executes exactly one branch's subgraph."""
    graph = pred.op.graph
    f_in, t_in = [], []
    for x in inputs:
        f, t = graph.apply("Switch", x, pred)
        f_in.append(f)
        t_in.append(t)
    t_out = true_fn(*t_in)
    f_out = false_fn(*f_in)
    if isinstance(t_out, Tensor):
        t_out, f_out = [t_out], [f_out]
    outs = []
    for tv, fv in zip(t_out, f_out):
        merged, _ = graph.apply("Merge", tv, fv)
        outs.append(merged)
    return outs[0] if len(outs) == 1 else outs


def while_loop(session, cond_fetch: Tensor, body_fetches,
               feeds=None, max_iters: int = 10_000) -> int:
    """Client-driven loop: repeatedly run the cached body step while the
    condition fetch is truthy. State lives in Variables, so each firing
    sees the previous iteration's effects (§3.2 concurrent-steps model)."""
    iters = 0
    while iters < max_iters:
        if not bool(session.run(cond_fetch, feeds)):
            break
        session.run(body_fetches, feeds)
        iters += 1
    return iters
