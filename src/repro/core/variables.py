"""Stateful operations: variables (paper §3.1) and checkpoint ops (§4.3).

A ``Variable`` op owns a mutable buffer and emits a *reference handle*; Read
/ Assign / AssignAdd / AssignSub / ScatterAdd / ScatterSub consume the
handle and act on the buffer in place. Buffers live in the ``VariableStore``
of whatever task the Variable was *placed* on — placing a Variable on
"task:ps0" is what makes ps0 a parameter server (§3: the PS architecture is
a placement decision, not privileged code).

Save / Restore (§4.3) are ordinary ops too: one Save per task writes every
connected variable in one file (maximizing I/O bandwidth, per the paper);
Restore + Assign re-materialize state. Consistency is the client's choice.
"""

from __future__ import annotations

import threading

import numpy as np

from repro.core.graph import OpDef, register


class VarHandle:
    """Typed capability for a variable's buffer (paper's 'reference')."""

    __slots__ = ("name", "store")

    def __init__(self, name: str, store: "VariableStore"):
        self.name = name
        self.store = store

    def __repr__(self):
        return f"<VarHandle {self.name}>"


class VariableStore:
    """Per-task mutable state; thread-safe for concurrent steps (§3.2)."""

    def __init__(self):
        self._buffers: dict[str, np.ndarray] = {}
        self._locks: dict[str, threading.Lock] = {}
        self._global_lock = threading.Lock()

    def ensure(self, name: str, initial) -> None:
        with self._global_lock:
            if name not in self._buffers:
                self._buffers[name] = np.array(initial, dtype=np.float32) \
                    if initial is not None else None
                self._locks[name] = threading.Lock()

    def read(self, name: str) -> np.ndarray:
        return self._buffers[name].copy()

    def assign(self, name: str, value) -> None:
        with self._locks[name]:
            self._buffers[name] = np.asarray(value)

    def update(self, name: str, fn) -> np.ndarray:
        with self._locks[name]:
            self._buffers[name] = fn(self._buffers[name])
            return self._buffers[name]

    def names(self):
        return list(self._buffers)


def _variable(ctx, attrs):
    name = attrs["var_name"]
    ctx.task.var_store.ensure(name, attrs.get("initial"))
    return (VarHandle(name, ctx.task.var_store),)


def _read(ctx, attrs, handle):
    return (handle.store.read(handle.name),)


def _assign(ctx, attrs, handle, value):
    handle.store.assign(handle.name, value)
    return (np.asarray(value),)


def _assign_add(ctx, attrs, handle, value):
    return (handle.store.update(handle.name, lambda b: b + value),)


def _assign_sub(ctx, attrs, handle, value):
    return (handle.store.update(handle.name, lambda b: b - value),)


def _scatter_add(ctx, attrs, handle, ids, rows):
    def fn(b):
        np.add.at(b, np.asarray(ids), rows)
        return b
    return (handle.store.update(handle.name, fn),)


def _scatter_sub(ctx, attrs, handle, ids, rows):
    def fn(b):
        np.subtract.at(b, np.asarray(ids), rows)
        return b
    return (handle.store.update(handle.name, fn),)


register(OpDef("Variable", 1, _variable, stateful=True))
register(OpDef("Read", 1, _read, stateful=True))
register(OpDef("Assign", 1, _assign, stateful=True))
register(OpDef("AssignAdd", 1, _assign_add, stateful=True))
register(OpDef("AssignSub", 1, _assign_sub, stateful=True))
register(OpDef("ScatterAdd", 1, _scatter_add, stateful=True))
register(OpDef("ScatterSub", 1, _scatter_sub, stateful=True))


# ---------------------------------------------------------------------------
# checkpointing ops (§4.3)
# ---------------------------------------------------------------------------


def _save(ctx, attrs, *handles):
    path = attrs["path"]
    arrays = {h.name: h.store.read(h.name) for h in handles}
    np.savez(path, **arrays)
    return ()


def _restore(ctx, attrs):
    data = np.load(attrs["path"] + ".npz" if not str(attrs["path"]).endswith(
        ".npz") else attrs["path"])
    return (data[attrs["tensor_name"]],)


register(OpDef("Save", 0, _save, stateful=True))
register(OpDef("Restore", 1, _restore, stateful=True))
