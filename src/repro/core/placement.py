"""Device placement (paper §3.3).

The algorithm mirrors the paper: compute a feasible device set per op from
explicit constraints ("ps:0"), partial constraints ("ps:*" = any PS task),
then compute colocation groups — stateful ops and the ops that consume
their reference handles must share a device — and pick a device per group.
Variables with partial "ps:*" constraints round-robin across PS tasks,
which is exactly how the client-side constructs of §3.3 spread parameters.
"""

from __future__ import annotations

import itertools

from repro.core.graph import Graph, Operation

HANDLE_PRODUCERS = {"Variable", "FIFOQueue"}
HANDLE_CONSUMERS = {"Read", "Assign", "AssignAdd", "AssignSub",
                    "ScatterAdd", "ScatterSub", "Enqueue", "Dequeue",
                    "DequeueMany", "QueueClose", "QueueSize", "Save"}


def _roots(parent, x):
    while parent[x] != x:
        parent[x] = parent[parent[x]]
        x = parent[x]
    return x


def place(ops: list[Operation], devices: list[str],
          default_device: str | None = None) -> None:
    """Assign ``op.assigned_device`` for every op (in place)."""
    default_device = default_device or devices[0]
    parent = {op.name: op.name for op in ops}
    by_name = {op.name: op for op in ops}

    def union(a: str, b: str):
        ra, rb = _roots(parent, a), _roots(parent, b)
        if ra != rb:
            parent[rb] = ra

    # colocation: handle consumers join their handle producer's group
    for op in ops:
        if op.type in HANDLE_CONSUMERS:
            for t in op.inputs:
                if t.op.type in HANDLE_PRODUCERS and t.op.name in parent:
                    union(t.op.name, op.name)
        if op.colocation and op.colocation in parent:
            union(op.colocation, op.name)

    # feasible sets per group = intersection of member constraints
    groups: dict[str, list[Operation]] = {}
    for op in ops:
        groups.setdefault(_roots(parent, op.name), []).append(op)

    rr: dict[str, itertools.cycle] = {}
    for root, members in sorted(groups.items()):
        feasible = list(devices)
        partial = None
        for op in members:
            c = op.device
            if not c:
                continue
            if c.endswith(":*"):
                job = c[:-2]
                feasible = [d for d in feasible if d.startswith(job + ":")]
                partial = job
            else:
                feasible = [d for d in feasible if d == c]
        if not feasible:
            raise ValueError(
                f"unsatisfiable placement for group {root}: "
                f"{[op.name for op in members]}")
        if partial and len(feasible) > 1:
            # round-robin variables across the job's tasks (§3.3 / §4.2)
            cyc = rr.setdefault(partial, itertools.cycle(feasible))
            device = next(cyc)
        elif default_device in feasible and not partial:
            device = default_device if len(feasible) == len(devices) \
                else feasible[0]
        else:
            device = feasible[0]
        for op in members:
            op.assigned_device = device
