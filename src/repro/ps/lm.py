"""LSTM language model on the dataflow graph engine (paper §6.4).

The paper trains LSTM-512-512 on 1B-word with the vocabulary-sharded softmax
of §4.2, comparing *full* softmax (logits computed shard-by-shard, colocated
with the weight shard — Project-Adam style) against *sampled* softmax
(Gather of the true + sampled rows, small local matmul). This builds both
variants as pure graph code: unrolled LSTM cell, embedding + softmax weights
round-robined over ps:*, autodiff through the whole thing.

Scaled-down defaults (vocab 8k, d 64, unroll 8) keep the CPU benchmark
minutes-fast; the *mechanism* (where the matmul runs, what moves over the
network) is the paper's.
"""

from __future__ import annotations

import numpy as np

from repro.core.gradients import gradients
from repro.core.graph import Graph, Tensor
from repro.ps.training import PSModel


def _lstm_cell(g: Graph, x, h, c, w):
    """One LSTM step from per-gate weight dict w (graph Tensors)."""
    gates = {}
    for name in ("i", "f", "o", "g"):
        z = g.apply("Add", g.apply("MatMul", x, w[f"wx_{name}"]),
                    g.apply("MatMul", h, w[f"wh_{name}"]))
        gates[name] = g.apply("Tanh" if name == "g" else "Sigmoid", z)
    c2 = g.apply("Add", g.apply("Mul", gates["f"], c),
                 g.apply("Mul", gates["i"], gates["g"]))
    h2 = g.apply("Mul", gates["o"], g.apply("Tanh", c2))
    return h2, c2


def lstm_lm_model(graph: Graph, *, vocab: int, d: int, unroll: int,
                  n_ps: int, softmax: str = "full", n_sampled: int = 64,
                  seed: int = 0) -> PSModel:
    assert softmax in ("full", "sampled")
    rng = np.random.default_rng(seed)
    g = graph

    def var(name, shape, device="ps:*", scale=0.1):
        h = g.apply("Variable", var_name=name,
                    initial=rng.normal(0, scale, shape).astype(np.float32),
                    device=device)
        return h, g.apply("Read", h)

    handles, reads = [], []
    emb_h, emb_r = var("embedding", (vocab, d))
    handles.append(emb_h)
    reads.append(emb_r)
    cell_w = {}
    for name in ("i", "f", "o", "g"):
        for pre in ("wx", "wh"):
            h, r = var(f"{pre}_{name}", (d, d))
            handles.append(h)
            reads.append(r)
            cell_w[f"{pre}_{name}"] = r
    # vocab-sharded softmax weights: one shard per PS task (§4.2)
    shard = vocab // n_ps
    sm_handles, sm_reads = [], []
    for i in range(n_ps):
        h, r = var(f"softmax_{i}", (d, shard), device=f"ps:{i}")
        handles.append(h)
        reads.append(r)
        sm_handles.append(h)
        sm_reads.append(r)

    def build_replica(reads_, x_ids, y_ids):
        # x_ids: (B, unroll) int ids fed as one placeholder per step slice
        # for graph simplicity the caller feeds a (B*unroll,)-flattened id
        # vector; embedding lookup is a Gather on the (possibly remote) table
        emb = g.apply("Gather", emb_r, x_ids)            # (B*unroll, d)
        # reshape to steps via per-step slices is host-side; we emulate the
        # recurrence by chunking with DynamicPartition on a step index fed
        # alongside — simpler: treat the batch as (B, unroll*d) unrolled
        # input is impractical in pure graph ops, so the driver feeds one
        # batch per step; here we unroll a fixed number of cell steps over
        # the SAME embedded batch (compute-equivalent for throughput).
        hstate = g.apply("Mul", emb, g.constant(np.float32(0.0)))
        cstate = hstate
        for _ in range(unroll):
            hstate, cstate = _lstm_cell(g, emb, hstate, cstate, cell_w)
        if softmax == "full":
            # shard-local matmuls (colocated with the weights), then concat
            logits = [g.apply("MatMul", hstate, r,
                              name=None) for r in sm_reads]
            for t, r in zip(logits, sm_reads):
                t.op.colocation = r.op.name       # run on the weight's task
                t.op.device = None                # colocation wins over
                                                  # the ambient worker device
            full = g.apply("Concat", *logits, axis=-1) \
                if len(logits) > 1 else logits[0]
            loss = g.apply("SoftmaxXent", full, y_ids)
        else:
            # sampled: gather n_sampled/n_ps rows from EACH weight shard
            # (disjoint by construction), small local matmul — the §6.4
            # "78x less data transfer and computation" mechanism.
            per = max(n_sampled // n_ps, 1)
            rows = []
            for i, r in enumerate(sm_reads):
                local_ids = g.constant(
                    rng.choice(shard, per, replace=False).astype(np.int64))
                rt = g.apply("Transpose", r)              # (shard, d)
                got = g.apply("Gather", rt, local_ids)    # (per, d)
                got.op.colocation = r.op.name             # Gather at shard
                got.op.device = None
                rt.op.colocation = r.op.name
                rt.op.device = None
                rows.append(got)
            w_s = (g.apply("Concat", *rows, axis=0) if len(rows) > 1
                   else rows[0])                           # (n_sampled, d)
            logits = g.apply("MatMul", hstate, g.apply("Transpose", w_s))
            y_mod = g.apply("Mod", y_ids,
                            g.constant(np.int64(per * n_ps)))
            loss = g.apply("SoftmaxXent", logits, y_mod)
        grads = gradients(loss, reads_)
        grads = [gr if gr is not None else g.constant(np.float32(0.0))
                 for gr in grads]
        return loss, grads

    return PSModel(graph, handles, reads, build_replica)


def lm_batch_fn(vocab: int, batch: int, unroll: int, seed: int = 0):
    rng = np.random.default_rng(seed)

    def fn(w, s):
        x = rng.integers(0, vocab, batch).astype(np.int64)
        y = rng.integers(0, vocab, batch).astype(np.int64)
        return x, y

    return fn
