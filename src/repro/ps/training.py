"""Parameter-server training with the paper's three coordination modes
(§4.4, Figure 4): asynchronous, synchronous, and synchronous with backup
workers — all built from the core engine's unprivileged primitives
(variables on ps tasks, gradient/token queues, concurrent steps).

  async   (Fig 4a): every worker loop independently reads params, computes
          grads on its device, applies AssignSub directly — hogwild.
  sync    (Fig 4b): workers enqueue (step, grads) into a gradient queue; a
          coordinator dequeues all n, averages, applies atomically, then
          releases n tokens from a barrier queue.
  backup  (Fig 4c): coordinator takes the FIRST m = n - b updates of a step
          and discards stragglers' — proactive straggler mitigation; the
          paper measured up to 15% throughput gain (our Fig-8 benchmark
          reproduces the shape of that curve with injected stragglers).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.cluster import Cluster
from repro.core.gradients import gradients
from repro.core.graph import Graph, Tensor
from repro.core.session import Session
import repro.core.ops        # noqa: F401  (registers kernels)
import repro.core.variables  # noqa: F401
import repro.core.queues     # noqa: F401
import repro.core.partition  # noqa: F401


@dataclass
class PSModel:
    """A model definition over the graph: variables live on ps:*."""
    graph: Graph
    var_handles: list          # Variable handle tensors
    var_reads: list[Tensor]    # Read tensors
    build_replica: callable    # (reads, feeds dict) -> (loss, grads)


def linear_model(graph: Graph, dim_in: int, dim_out: int, n_shards: int,
                 seed: int = 0):
    """Simple dense model, parameters sharded across PS tasks (§6.2-style)."""
    rng = np.random.default_rng(seed)
    handles, reads = [], []
    shard = dim_out // n_shards
    for i in range(n_shards):
        h = graph.apply("Variable", var_name=f"w{i}",
                        initial=rng.normal(0, 0.1, (dim_in, shard)
                                           ).astype(np.float32),
                        device="ps:*")
        handles.append(h)
        reads.append(graph.apply("Read", h))

    def build_replica(reads, x, y):
        logits = graph.apply("Concat", *[
            graph.apply("MatMul", x, r) for r in reads], axis=-1) \
            if len(reads) > 1 else graph.apply("MatMul", x, reads[0])
        loss = graph.apply("SoftmaxXent", logits, y)
        grads = gradients(loss, reads)
        return loss, grads

    return PSModel(graph, handles, reads, build_replica)


@dataclass
class TrainerStats:
    step_times: list[float] = field(default_factory=list)
    losses: list[float] = field(default_factory=list)
    discarded: int = 0


class PSTrainer:
    """Drives n_workers worker threads in one of the three §4.4 modes."""

    def __init__(self, model: PSModel, cluster: Cluster, *, mode: str,
                 n_workers: int, backup_workers: int = 0, lr: float = 0.1,
                 straggler_s: float = 0.0, straggler_every: int = 0):
        assert mode in ("async", "sync", "backup")
        self.model = model
        self.cluster = cluster
        self.mode = mode
        self.n_workers = n_workers
        self.m_required = n_workers - (backup_workers if mode == "backup"
                                       else 0)
        self.lr = lr
        self.straggler_s = straggler_s
        self.straggler_every = straggler_every
        self.graph = model.graph
        self.session = Session(self.graph, cluster,
                               default_device="worker:0")
        self.stats = TrainerStats()
        self._build()

    def _build(self):
        gph, m = self.graph, self.model
        # per-worker replica subgraphs, placed on the worker device (§4.4)
        self.replicas = []
        for w in range(self.n_workers):
            dev = f"worker:{w}"
            with gph.device(dev):
                x = gph.placeholder(f"x_{w}")
                y = gph.placeholder(f"y_{w}")
                loss, grads = m.build_replica(m.var_reads, x, y)
            self.replicas.append((x, y, loss, grads))
        # apply path: placeholders for (averaged) grads -> AssignSub on PS
        self.grad_phs, self.apply_ops = [], []
        lr_c = gph.constant(np.float32(self.lr))
        for i, h in enumerate(m.var_handles):
            ph = gph.placeholder(f"gin_{i}")
            self.grad_phs.append(ph)
            self.apply_ops.append(
                gph.apply("AssignSub", h, gph.apply("Mul", lr_c, ph)))

    # -- worker loops --------------------------------------------------------

    def _maybe_straggle(self, w: int, step: int):
        if self.straggler_s and self.straggler_every and \
                (step + w) % self.straggler_every == 0:
            time.sleep(self.straggler_s)

    def train(self, steps: int, batch_fn) -> TrainerStats:
        if self.mode == "async":
            return self._train_async(steps, batch_fn)
        return self._train_sync(steps, batch_fn)

    def _train_async(self, steps: int, batch_fn) -> TrainerStats:
        lock = threading.Lock()

        def worker(w):
            x, y, loss, grads = self.replicas[w]
            for s in range(steps):
                self._maybe_straggle(w, s)
                xv, yv = batch_fn(w, s)
                t0 = time.perf_counter()
                vals = self.session.run(
                    [loss] + grads, {x: xv, y: yv})
                gvals = vals[1:]
                self.session.run(self.apply_ops, dict(
                    zip(self.grad_phs, gvals)))
                dt = time.perf_counter() - t0
                with lock:
                    self.stats.step_times.append(dt)
                    self.stats.losses.append(float(vals[0]))

        threads = [threading.Thread(target=worker, args=(w,), daemon=True)
                   for w in range(self.n_workers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        return self.stats

    def _train_sync(self, steps: int, batch_fn) -> TrainerStats:
        """Figure 4(b)/(c): gradient queue + barrier tokens, first-m-of-n."""
        import queue as pyq
        grad_q: pyq.Queue = pyq.Queue()
        go_qs = [pyq.Queue() for _ in range(self.n_workers)]

        def worker(w):
            x, y, loss, grads = self.replicas[w]
            for s in range(steps):
                go_qs[w].get()           # barrier: wait for step release
                self._maybe_straggle(w, s)
                xv, yv = batch_fn(w, s)
                vals = self.session.run([loss] + grads, {x: xv, y: yv})
                grad_q.put((s, w, float(vals[0]), vals[1:]))

        threads = [threading.Thread(target=worker, args=(w,), daemon=True)
                   for w in range(self.n_workers)]
        for t in threads:
            t.start()

        for s in range(steps):
            for q in go_qs:
                q.put(s)                 # release all workers
            t0 = time.perf_counter()
            got, losses = [], []
            while len(got) < self.m_required:
                sid, w, lv, gvals = grad_q.get()
                if sid != s:
                    self.stats.discarded += 1
                    continue
                got.append(gvals)
                losses.append(lv)
            # aggregate first-m and apply atomically
            avg = [np.mean([gg[i] for gg in got], axis=0)
                   for i in range(len(self.grad_phs))]
            self.session.run(self.apply_ops,
                             dict(zip(self.grad_phs, avg)))
            # drain stragglers of this step without blocking the next one
            while not grad_q.empty():
                try:
                    sid, *_ = grad_q.get_nowait()
                    self.stats.discarded += 1
                except pyq.Empty:
                    break
            self.stats.step_times.append(time.perf_counter() - t0)
            self.stats.losses.append(float(np.mean(losses)))
        for t in threads:
            t.join(timeout=5.0)
        return self.stats
