"""jax version compatibility shims.

The repo targets the newer explicit-mesh API (``jax.set_mesh``,
``jax.sharding.AxisType``, ``jax.sharding.get_abstract_mesh``,
``jax.shard_map``). On jax 0.4.x those names don't exist yet; the same
machinery is spelled differently:

  jax.set_mesh(m)                 ->  ``with m:`` (Mesh is a context manager
                                      setting the thread-local physical mesh)
  jax.sharding.get_abstract_mesh  ->  the thread-local physical mesh
  jax.shard_map                   ->  jax.experimental.shard_map.shard_map
  jax.make_mesh(axis_types=...)   ->  jax.make_mesh (no axis_types kwarg)

``install()`` (run at import) patches the missing names onto jax itself so
both repo code and tests can use one spelling everywhere. Each shim is only
installed when the real name is absent, so this module is a no-op on newer
jax. Import it before any mesh is built — ``repro/__init__.py`` and
``tests/conftest.py`` both do.
"""

from __future__ import annotations

import contextlib
import enum
import functools
import inspect

import jax

_INSTALLED = False


def _supports_kwarg(fn, name: str) -> bool:
    try:
        return name in inspect.signature(fn).parameters
    except (TypeError, ValueError):  # builtins / C funcs: assume yes
        return True


def install() -> None:
    global _INSTALLED
    if _INSTALLED:
        return
    _INSTALLED = True

    # --- jax.sharding.AxisType --------------------------------------------
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    # --- jax.make_mesh(axis_types=...) ------------------------------------
    if not _supports_kwarg(jax.make_mesh, "axis_types"):
        _real_make_mesh = jax.make_mesh

        @functools.wraps(_real_make_mesh)
        def make_mesh(*args, axis_types=None, **kw):
            return _real_make_mesh(*args, **kw)

        jax.make_mesh = make_mesh

    # --- jax.set_mesh ------------------------------------------------------
    if not hasattr(jax, "set_mesh"):
        @contextlib.contextmanager
        def set_mesh(mesh):
            with mesh:
                yield mesh

        jax.set_mesh = set_mesh

    # --- jax.sharding.get_abstract_mesh ------------------------------------
    if not hasattr(jax.sharding, "get_abstract_mesh"):
        from jax._src import mesh as _mesh_lib

        def get_abstract_mesh():
            """The ambient mesh (physical stands in for abstract on 0.4.x:
            it has the same .shape mapping / .axis_names surface)."""
            return _mesh_lib.thread_resources.env.physical_mesh

        jax.sharding.get_abstract_mesh = get_abstract_mesh

    # --- jax.lax.axis_size --------------------------------------------------
    if not hasattr(jax.lax, "axis_size"):
        def axis_size(axis_name):
            # psum of a literal 1 is folded to the axis size at trace time
            return jax.lax.psum(1, axis_name)

        jax.lax.axis_size = axis_size

    # --- jax.shard_map ------------------------------------------------------
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
                      **kw):
            # check_vma is the new-API spelling of check_rep; 0.4.x's
            # checker predates psum-of-masked-gather patterns used here,
            # so run unchecked either way.
            return _shard_map(f, mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)

        jax.shard_map = shard_map


install()
