"""repro: a jax/pallas reproduction of "TensorFlow: A system for
large-scale machine learning" grown toward a production serving/training
stack. Importing the package installs jax version-compat shims first so
every entry point (launch scripts, tests, benchmarks) sees one API.
"""

from repro import compat as _compat  # noqa: F401  (installs jax shims)
