"""Serving driver: continuous batching through the model-agnostic engine.

Mirrors the paper's training/inference duality (§2.1: same model code for
both). The engine (``repro.serving``) admits requests from a queue as
slots and cache resources free up, retires each on its own EOS/max_new,
and steps every running request in one jitted budgeted step. Per-family
runners cover decoder-only transformers (paged KV + prefix caching), pure
SSM (per-slot Mamba state), hybrid mamba+attention, encoder-decoder
(paged self-KV + per-slot cross K/V), and draft-and-verify speculative
decoding (``--num-speculative-tokens``; docs/speculative.md).

  PYTHONPATH=src python -m repro.launch.serve --arch glm4_9b --smoke
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2_370m --smoke
  PYTHONPATH=src python -m repro.launch.serve --arch whisper_large_v3 --smoke
  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2_3b --smoke \\
      --num-speculative-tokens 2

Tensor-parallel serving (page pools sharded by kv head over the mesh
"model" axis; docs/multi-host.md) — needs that many devices, e.g. a forced
host platform for CPU smoke runs:

  XLA_FLAGS=--xla_force_host_platform_device_count=2 PYTHONPATH=src \\
      python -m repro.launch.serve --arch glm4_9b --smoke --mesh model=2
"""

from __future__ import annotations

import argparse

import numpy as np

from repro import compat as _compat  # noqa: F401  (jax API shims)
from repro.config import get_config


def poisson_arrival_steps(n: int, rate: float, rng) -> list[int]:
    """Arrival step indices for a Poisson process with ``rate`` requests
    per decode step (the engine's virtual clock)."""
    t, out = 0.0, []
    for _ in range(n):
        t += rng.exponential(1.0 / max(rate, 1e-9))
        out.append(int(t))
    return out


def parse_mesh(spec: str | None) -> tuple[int, int]:
    """'model=2' / 'data=2,model=4' -> (data, model); None -> (1, 1)."""
    sizes = {"data": 1, "model": 1}
    if spec:
        for part in spec.split(","):
            name, _, val = part.partition("=")
            if name not in sizes or not val.isdigit() or int(val) < 1:
                raise ValueError(
                    f"bad --mesh entry {part!r}: expected data=N / model=N "
                    "with N >= 1")
            sizes[name] = int(val)
    return sizes["data"], sizes["model"]


def run_engine(cfg, mesh, args):
    from repro.serving import InferenceEngine, Request
    from repro.serving.scheduler import SamplingParams
    draft_cfg = (get_config(args.speculative_draft, smoke=args.smoke)
                 if args.speculative_draft else None)
    eng = InferenceEngine(cfg, mesh, max_batch=args.max_batch,
                          block_size=args.block_size, max_len=args.max_len,
                          max_num_batched_tokens=args.max_batched_tokens,
                          enable_prefix_caching=not args.no_prefix_caching,
                          draft_cfg=draft_cfg,
                          num_speculative_tokens=args.num_speculative_tokens)
    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        # staggered horizons: each request retires on its own max_new
        max_new = max(1, args.max_new - (i % 4) * args.max_new // 4)
        sp = SamplingParams(temperature=args.temperature,
                            top_k=args.top_k, seed=i)
        frames = None
        if cfg.frontend == "audio":
            frames = rng.normal(0, 1, (cfg.encoder_seq_len, cfg.d_model)
                                ).astype(np.float32)
        reqs.append(Request(
            rng.integers(0, cfg.vocab_size, args.prompt_len
                         ).astype(np.int32),
            max_new=max_new, sampling=sp, eos_id=args.eos_id,
            frames=frames))
    arrivals = poisson_arrival_steps(len(reqs), args.rate, rng)
    outs = eng.run(reqs, arrival_steps=arrivals)
    s = eng.stats
    print(f"[serve] mesh=data={mesh.shape['data']},model="
          f"{mesh.shape['model']} tp={eng.tp}")
    print(f"[serve] runner={type(eng.runner).__name__} {len(reqs)} requests "
          f"(poisson rate={args.rate}/step, arrivals={arrivals}), "
          f"{s['tokens']} tokens in {s['wall_s']:.2f}s "
          f"({s['tok_s']:.1f} tok/s incl. compile)")
    print(f"[serve] steps={s['steps']} "
          f"prefill_chunks={s['prefill_chunks']} "
          f"encodes={s['encodes']} "
          f"preemptions={s['preemptions']} "
          f"cache_hit_tokens={s['cache_hit_tokens']} "
          f"cow_copies={s['cow_copies']} "
          f"peak_block_util={s['peak_block_utilization']:.2f}")
    if s["spec_decodes"]:
        print(f"[serve] speculative: k={eng.runner.spec_tokens} "
              f"draft={eng.draft_cfg.name} "
              f"spec_decodes={s['spec_decodes']} "
              f"mean_accept_len={s['mean_accept_len']:.3f}")
    print("[serve] sample output ids:", outs[reqs[0].rid][:8].tolist())
    return outs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4_9b")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="smoke-size config (default; --no-smoke for full)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--max-batched-tokens", type=int, default=None,
                    help="per-step token budget across decodes + one "
                    "prefill chunk (default: max_batch + 2*block_size)")
    ap.add_argument("--no-prefix-caching", action="store_true",
                    help="disable cross-request KV block sharing")
    ap.add_argument("--speculative-draft", default=None,
                    help="draft-model arch for speculative decoding "
                    "(defaults to --arch, i.e. a fresh-init self-draft, "
                    "when --num-speculative-tokens > 0)")
    ap.add_argument("--num-speculative-tokens", type=int, default=0,
                    help="draft tokens proposed per slot per step; the "
                    "target verifies k+1 positions in one widened step "
                    "(0 disables speculation)")
    ap.add_argument("--mesh", default=None,
                    help='mesh axis sizes, e.g. "model=2" or '
                    '"data=2,model=2" (default: 1x1). The "model" axis '
                    "tensor-parallel-shards the page pools by kv head; "
                    "needs that many local devices")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="poisson arrivals per decode step")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = get_config(args.arch, smoke=args.smoke)
    from repro.launch.mesh import make_host_mesh
    data, model = parse_mesh(args.mesh)
    mesh = make_host_mesh(data, model)
    run_engine(cfg, mesh, args)


if __name__ == "__main__":
    main()
