"""Batched serving driver: continuous decode over a request queue.

Mirrors the paper's training/inference duality (§2.1: same model code for
both). Requests carry a prompt; the server batches them, runs one prefill,
then decodes greedily with the KV cache until max_new or EOS. The decode
step is the same jitted function the dry-run lowers at decode_32k.

  PYTHONPATH=src python -m repro.launch.serve --arch glm4_9b --smoke
"""

from __future__ import annotations

import argparse
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ParallelConfig, get_config
from repro.models import api
from repro.spmd import steps as steps_mod


@dataclass
class Request:
    prompt: np.ndarray          # (prompt_len,) int32
    max_new: int = 16


class Server:
    def __init__(self, cfg, mesh, pcfg=None, max_batch: int = 8,
                 prompt_len: int = 32, max_len: int = 128, seed: int = 0):
        self.cfg, self.mesh = cfg, mesh
        self.pcfg = pcfg or ParallelConfig(remat="none")
        self.max_batch, self.prompt_len, self.max_len = (max_batch,
                                                         prompt_len, max_len)
        with jax.set_mesh(mesh):
            params_f32, specs = api.init_model(cfg, jax.random.key(seed))
            self.params = jax.tree.map(
                lambda x: x.astype(jnp.bfloat16), params_f32)
            self._prefill = jax.jit(
                steps_mod.make_prefill_step(cfg, self.pcfg))
            self._decode = jax.jit(
                steps_mod.make_decode_step(cfg, self.pcfg),
                donate_argnums=(1,))

    def serve_batch(self, requests: list[Request]) -> list[np.ndarray]:
        assert len(requests) <= self.max_batch
        B = len(requests)
        toks = np.stack([r.prompt[:self.prompt_len] for r in requests])
        with jax.set_mesh(self.mesh):
            # prefill at full cache capacity: pad prompt region
            batch = {"tokens": jnp.asarray(toks, jnp.int32)}
            if self.cfg.frontend == "vision":
                batch["positions"] = jnp.broadcast_to(
                    jnp.arange(self.prompt_len, dtype=jnp.int32)[None, None],
                    (3, B, self.prompt_len))
            if self.cfg.frontend == "audio":
                batch["frames"] = jnp.zeros(
                    (B, self.cfg.encoder_seq_len, self.cfg.d_model),
                    jnp.bfloat16)
            cache, tok = self._prefill(self.params, batch)
            # grow cache to max_len capacity
            cache = jax.tree.map(self._grow, cache)
            outs = [tok]
            max_new = max(r.max_new for r in requests)
            pos = jnp.full((B,), self.prompt_len, jnp.int32)
            for _ in range(max_new - 1):
                tok, cache = self._decode(
                    self.params, cache,
                    {"token": tok[:, None], "pos": pos})
                outs.append(tok)
                pos = pos + 1
        gen = np.stack([np.asarray(t) for t in outs], axis=1)
        return [gen[i, :requests[i].max_new] for i in range(B)]

    def _grow(self, x):
        # pad attention caches (L, B, S, K, hd) from prompt_len to max_len
        if x.ndim == 5 and x.shape[2] == self.prompt_len and \
                self.cfg.num_kv_heads and x.shape[-1] == self.cfg.head_dim:
            pad = self.max_len - self.prompt_len
            return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return x


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4_9b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    cfg = get_config(args.arch, smoke=True)
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    server = Server(cfg, mesh)
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(0, cfg.vocab_size, 32).astype(np.int32),
                    max_new=args.max_new)
            for _ in range(args.requests)]
    t0 = time.time()
    outs = server.serve_batch(reqs)
    dt = time.time() - t0
    n_tok = sum(len(o) for o in outs)
    print(f"[serve] {len(reqs)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s)")
    print("[serve] sample output ids:", outs[0][:8].tolist())


if __name__ == "__main__":
    main()
