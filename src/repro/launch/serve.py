"""Serving driver: continuous batching over a paged KV cache (default) or
the legacy static-batch path.

Mirrors the paper's training/inference duality (§2.1: same model code for
both). The engine path (``repro.serving``) admits requests from a queue as
slots and cache blocks free up, retires each on its own EOS/max_new, and
decodes every running request in one jitted step through per-request block
tables — no padding to max_len, no decoding to the slowest request's
horizon. The static ``Server`` is kept for SSM/enc-dec models the paged
cache doesn't cover yet, and as the equivalence oracle in tests.

  PYTHONPATH=src python -m repro.launch.serve --arch glm4_9b --smoke
"""

from __future__ import annotations

import argparse
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat as _compat  # noqa: F401  (jax API shims)
from repro.config import ParallelConfig, get_config
from repro.models import api
from repro.spmd import steps as steps_mod


@dataclass
class Request:
    prompt: np.ndarray          # (prompt_len,) int32
    max_new: int = 16


class Server:
    """Legacy static-batch server: pads every request to a common prompt
    length, decodes max(max_new) steps for the whole batch."""

    def __init__(self, cfg, mesh, pcfg=None, max_batch: int = 8,
                 prompt_len: int = 32, max_len: int = 128, seed: int = 0):
        self.cfg, self.mesh = cfg, mesh
        self.pcfg = pcfg or ParallelConfig(remat="none")
        self.max_batch, self.prompt_len, self.max_len = (max_batch,
                                                         prompt_len, max_len)
        with jax.set_mesh(mesh):
            params_f32, specs = api.init_model(cfg, jax.random.key(seed))
            self.params = jax.tree.map(
                lambda x: x.astype(jnp.bfloat16), params_f32)
            self._prefill = jax.jit(
                steps_mod.make_prefill_step(cfg, self.pcfg))
            self._decode = jax.jit(
                steps_mod.make_decode_step(cfg, self.pcfg),
                donate_argnums=(1,))

    def serve_batch(self, requests: list[Request]) -> list[np.ndarray]:
        assert len(requests) <= self.max_batch
        B = len(requests)
        toks = np.stack([r.prompt[:self.prompt_len] for r in requests])
        with jax.set_mesh(self.mesh):
            # prefill at full cache capacity: pad prompt region
            batch = {"tokens": jnp.asarray(toks, jnp.int32)}
            if self.cfg.frontend == "vision":
                batch["positions"] = jnp.broadcast_to(
                    jnp.arange(self.prompt_len, dtype=jnp.int32)[None, None],
                    (3, B, self.prompt_len))
            if self.cfg.frontend == "audio":
                batch["frames"] = jnp.zeros(
                    (B, self.cfg.encoder_seq_len, self.cfg.d_model),
                    jnp.bfloat16)
            cache, tok = self._prefill(self.params, batch)
            # grow attention caches to max_len capacity
            cache = jax.tree_util.tree_map_with_path(self._grow, cache)
            outs = [tok]
            max_new = max(r.max_new for r in requests)
            pos = jnp.full((B,), self.prompt_len, jnp.int32)
            for _ in range(max_new - 1):
                tok, cache = self._decode(
                    self.params, cache,
                    {"token": tok[:, None], "pos": pos})
                outs.append(tok)
                pos = pos + 1
        gen = np.stack([np.asarray(t) for t in outs], axis=1)
        return [gen[i, :requests[i].max_new] for i in range(B)]

    def _grow(self, path, x):
        """Pad self-attention K/V caches (L, B, S, K, hd) from prompt_len
        to max_len. Keyed on the cache pytree *path* (leaves named "k"/"v"),
        not shape sniffing: SSM conv/state leaves and enc-dec cross caches
        ("xk"/"xv") whose shapes happen to collide are left alone."""
        keys = [p.key for p in path
                if isinstance(p, jax.tree_util.DictKey)]
        if not (keys and keys[-1] in ("k", "v")):
            return x
        if not (x.ndim == 5 and x.shape[2] == self.prompt_len
                and x.shape[3] == self.cfg.num_kv_heads
                and x.shape[-1] == self.cfg.head_dim):
            return x
        pad = self.max_len - self.prompt_len
        return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))


def poisson_arrival_steps(n: int, rate: float, rng) -> list[int]:
    """Arrival step indices for a Poisson process with ``rate`` requests
    per decode step (the engine's virtual clock)."""
    t, out = 0.0, []
    for _ in range(n):
        t += rng.exponential(1.0 / max(rate, 1e-9))
        out.append(int(t))
    return out


def run_engine(cfg, mesh, args):
    from repro.serving import InferenceEngine, Request as EngRequest
    from repro.serving.scheduler import SamplingParams
    eng = InferenceEngine(cfg, mesh, max_batch=args.max_batch,
                          block_size=args.block_size, max_len=args.max_len,
                          max_num_batched_tokens=args.max_batched_tokens,
                          enable_prefix_caching=not args.no_prefix_caching)
    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        # staggered horizons: each request retires on its own max_new
        max_new = max(1, args.max_new - (i % 4) * args.max_new // 4)
        sp = SamplingParams(temperature=args.temperature,
                            top_k=args.top_k, seed=i)
        reqs.append(EngRequest(
            rng.integers(0, cfg.vocab_size, args.prompt_len
                         ).astype(np.int32),
            max_new=max_new, sampling=sp, eos_id=args.eos_id))
    arrivals = poisson_arrival_steps(len(reqs), args.rate, rng)
    outs = eng.run(reqs, arrival_steps=arrivals)
    s = eng.stats
    print(f"[serve] engine=paged {len(reqs)} requests "
          f"(poisson rate={args.rate}/step, arrivals={arrivals}), "
          f"{s['tokens']} tokens in {s['wall_s']:.2f}s "
          f"({s['tok_s']:.1f} tok/s incl. compile)")
    print(f"[serve] steps={s['steps']} "
          f"prefill_chunks={s['prefill_chunks']} "
          f"preemptions={s['preemptions']} "
          f"cache_hit_tokens={s['cache_hit_tokens']} "
          f"cow_copies={s['cow_copies']} "
          f"peak_block_util={s['peak_block_utilization']:.2f}")
    print("[serve] sample output ids:", outs[reqs[0].rid][:8].tolist())
    return outs


def run_static(cfg, mesh, args):
    server = Server(cfg, mesh, max_batch=args.max_batch,
                    prompt_len=args.prompt_len, max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rng.integers(0, cfg.vocab_size, args.prompt_len
                                 ).astype(np.int32), max_new=args.max_new)
            for _ in range(min(args.requests, args.max_batch))]
    t0 = time.time()
    outs = server.serve_batch(reqs)
    dt = time.time() - t0
    n_tok = sum(len(o) for o in outs)
    print(f"[serve] engine=static {len(reqs)} requests, {n_tok} tokens "
          f"in {dt:.2f}s ({n_tok/dt:.1f} tok/s)")
    print("[serve] sample output ids:", outs[0][:8].tolist())
    return outs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4_9b")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="smoke-size config (default; --no-smoke for full)")
    ap.add_argument("--engine", choices=("paged", "static"), default="paged")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--max-batched-tokens", type=int, default=None,
                    help="per-step token budget across decodes + one "
                    "prefill chunk (default: max_batch + 2*block_size)")
    ap.add_argument("--no-prefix-caching", action="store_true",
                    help="disable cross-request KV block sharing")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="poisson arrivals per decode step (paged engine)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = get_config(args.arch, smoke=args.smoke)
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    if args.engine == "paged":
        run_engine(cfg, mesh, args)
    else:
        run_static(cfg, mesh, args)


if __name__ == "__main__":
    main()
