"""Serving driver: continuous batching through the model-agnostic engine.

Mirrors the paper's training/inference duality (§2.1: same model code for
both). The engine (``repro.serving``) admits requests from a queue as
slots and cache resources free up, retires each on its own EOS/max_new,
and steps every running request in one jitted budgeted step. Per-family
runners cover decoder-only transformers (paged KV + prefix caching), pure
SSM (per-slot Mamba state), hybrid mamba+attention, encoder-decoder
(paged self-KV + per-slot cross K/V), and draft-and-verify speculative
decoding (``--num-speculative-tokens``; docs/speculative.md).

All traffic — the synthetic Poisson bench below and live HTTP alike —
flows through the async streaming front-end (``repro.serving.frontend``;
docs/serving-frontend.md): the same admission path, token streams, and
metrics surface, so bench rows stay comparable with production serving.

  PYTHONPATH=src python -m repro.launch.serve --arch glm4_9b --smoke
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2_370m --smoke
  PYTHONPATH=src python -m repro.launch.serve --arch whisper_large_v3 --smoke
  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2_3b --smoke \\
      --num-speculative-tokens 2

Long-lived HTTP server (SSE token streaming + /health + /metrics;
graceful drain on SIGINT/SIGTERM — stop admitting, finish in-flight):

  PYTHONPATH=src python -m repro.launch.serve --arch glm4_9b --smoke \\
      --http 127.0.0.1:8311 --ttft-slo-ms 5000 --max-queue 64

Tensor-parallel serving (page pools sharded by kv head over the mesh
"model" axis; docs/multi-host.md) — needs that many devices, e.g. a forced
host platform for CPU smoke runs:

  XLA_FLAGS=--xla_force_host_platform_device_count=2 PYTHONPATH=src \\
      python -m repro.launch.serve --arch glm4_9b --smoke --mesh model=2

Data-parallel replicas behind one router (shared cross-replica prefix
index; add --disaggregate for prefill/decode role split —
docs/multi-host.md):

  PYTHONPATH=src python -m repro.launch.serve --arch glm4_9b --smoke --dp 2
  PYTHONPATH=src python -m repro.launch.serve --arch glm4_9b --smoke \\
      --dp 2 --disaggregate
"""

from __future__ import annotations

import argparse
import asyncio
import signal
import time

import numpy as np

from repro import compat as _compat  # noqa: F401  (jax API shims)
from repro.config import get_config


def poisson_arrival_steps(n: int, rate: float, rng) -> list[int]:
    """Arrival step indices for a Poisson process with ``rate`` requests
    per decode step (the engine's virtual clock)."""
    t, out = 0.0, []
    for _ in range(n):
        t += rng.exponential(1.0 / max(rate, 1e-9))
        out.append(int(t))
    return out


def parse_mesh(spec: str | None) -> tuple[int, int]:
    """'model=2' / 'data=2,model=4' -> (data, model); None -> (1, 1)."""
    sizes = {"data": 1, "model": 1}
    if spec:
        for part in spec.split(","):
            name, _, val = part.partition("=")
            if name not in sizes or not val.isdigit() or int(val) < 1:
                raise ValueError(
                    f"bad --mesh entry {part!r}: expected data=N / model=N "
                    "with N >= 1")
            sizes[name] = int(val)
    return sizes["data"], sizes["model"]


def build_engine(cfg, mesh, args, shared_index=None, params=None):
    from repro.serving import InferenceEngine
    draft_cfg = (get_config(args.speculative_draft, smoke=args.smoke)
                 if args.speculative_draft else None)
    return InferenceEngine(
        cfg, mesh, max_batch=args.max_batch,
        block_size=args.block_size, max_len=args.max_len,
        num_blocks=args.num_blocks,
        max_num_batched_tokens=args.max_batched_tokens,
        enable_prefix_caching=not args.no_prefix_caching,
        draft_cfg=draft_cfg,
        num_speculative_tokens=args.num_speculative_tokens,
        prefill_pack=args.prefill_pack, kv_dtype=args.kv_dtype,
        swap_space_bytes=args.swap_space_bytes,
        swap_policy=args.swap_policy,
        shared_index=shared_index, params=params)


def build_fleet(cfg, mesh, args):
    """N identical engine replicas around one SharedPrefixIndex, plus the
    ReplicaRouter. Params are initialised once on replica 0 and shared by
    reference (replicas must be byte-identical for the routing to be
    output-invariant); the shared index is sized to hold one full replica
    pool's worth of published blocks."""
    from repro.serving import ReplicaRouter, SharedPrefixIndex
    dp = args.dp
    shared = SharedPrefixIndex(num_slots=args.shared_slots)
    first = build_engine(cfg, mesh, args, shared_index=shared)
    # speculative engines hold {"tgt","dft"} param dicts the ctor only
    # assembles from scratch — same seed re-init keeps replicas identical
    share = None if args.num_speculative_tokens else first.params
    engines = [first] + [
        build_engine(cfg, mesh, args, shared_index=shared, params=share)
        for _ in range(dp - 1)]
    return ReplicaRouter(engines, admission=build_controller(args, dp),
                         disaggregate=args.disaggregate,
                         n_prefill=args.n_prefill)


def build_controller(args, n_replicas: int = 1):
    from repro.serving.frontend import AdmissionController
    slo = args.ttft_slo_ms / 1e3 if args.ttft_slo_ms else None
    return AdmissionController(ttft_slo_p95_s=slo, max_queue=args.max_queue,
                               n_replicas=n_replicas)


def make_requests(cfg, args, rng):
    from repro.serving import Request
    from repro.serving.scheduler import SamplingParams
    reqs = []
    for i in range(args.requests):
        # staggered horizons: each request retires on its own max_new
        max_new = max(1, args.max_new - (i % 4) * args.max_new // 4)
        stop = tuple(tuple(int(t) for t in s.split(","))
                     for s in (args.stop or []))
        sp = SamplingParams(temperature=args.temperature,
                            top_k=args.top_k, seed=i,
                            top_p=args.top_p, min_p=args.min_p,
                            repetition_penalty=args.repetition_penalty,
                            presence_penalty=args.presence_penalty,
                            frequency_penalty=args.frequency_penalty,
                            logprobs=args.logprobs, stop=stop)
        frames = None
        if cfg.frontend == "audio":
            frames = rng.normal(0, 1, (cfg.encoder_seq_len, cfg.d_model)
                                ).astype(np.float32)
        reqs.append(Request(
            rng.integers(0, cfg.vocab_size, args.prompt_len
                         ).astype(np.int32),
            max_new=max_new, sampling=sp, eos_id=args.eos_id,
            min_new=args.min_new, frames=frames))
    return reqs


async def _drive(eng, controller, reqs, arrivals):
    """Stream the Poisson workload through the front-end: the same
    admission path live HTTP traffic takes, with per-request token
    streams consumed concurrently. Returns {rid: [tokens]}."""
    from repro.serving.frontend import AsyncEngineDriver
    async with AsyncEngineDriver(eng, admission=controller) as drv:
        streams = [await drv.submit(r, arrival_step=t)
                   for r, t in zip(reqs, arrivals)]

        async def pull(s):
            return [ev.token async for ev in s]

        outs = await asyncio.gather(*(pull(s) for s in streams))
        await drv.drain()
    return {r.rid: np.asarray(t, np.int32) for r, t in zip(reqs, outs)}


def run_engine(cfg, mesh, args):
    eng = build_engine(cfg, mesh, args)
    controller = build_controller(args)
    rng = np.random.default_rng(args.seed)
    reqs = make_requests(cfg, args, rng)
    arrivals = poisson_arrival_steps(len(reqs), args.rate, rng)
    t0 = time.time()
    tok0 = eng.stats["tokens"]
    outs = asyncio.run(_drive(eng, controller, reqs, arrivals))
    dt = time.time() - t0
    s = eng.stats
    s["wall_s"] = round(dt, 3)
    s["tok_s"] = round((s["tokens"] - tok0) / max(dt, 1e-9), 1)
    print(f"[serve] mesh=data={mesh.shape['data']},model="
          f"{mesh.shape['model']} tp={eng.tp} "
          f"prefill_pack={eng.prefill_pack}")
    print(f"[serve] kv_dtype={eng.kv_dtype} "
          f"kv_cache_mib={s['kv_cache_mib']} "
          f"swap_space_mib={s['swap_space_mib']} "
          f"swap_preemptions={s['swap_preemptions']} "
          f"swap_ins={s['swap_ins']} "
          f"swapped_out_blocks={s['swapped_out_blocks']} "
          f"swapped_in_blocks={s['swapped_in_blocks']} "
          f"aborts={s['aborts']}")
    print(f"[serve] runner={type(eng.runner).__name__} {len(reqs)} requests "
          f"(poisson rate={args.rate}/step, arrivals={arrivals}), "
          f"{s['tokens']} tokens in {s['wall_s']:.2f}s "
          f"({s['tok_s']:.1f} tok/s incl. compile)")
    print(f"[serve] steps={s['steps']} "
          f"prefill_chunks={s['prefill_chunks']} "
          f"encodes={s['encodes']} "
          f"preemptions={s['preemptions']} "
          f"cache_hit_tokens={s['cache_hit_tokens']} "
          f"cow_copies={s['cow_copies']} "
          f"peak_block_util={s['peak_block_utilization']:.2f}")
    print(f"[serve] sampling: full_sampling_steps={s['full_sampling_steps']} "
          f"stop_hits={s['stop_hits']}")
    print(f"[serve] frontend: submitted={controller.submitted} "
          f"shed={controller.shed} completed={controller.completed} "
          f"queue_peak={controller.queue_peak} "
          f"cache_hit_rate={eng.cache_hit_rate:.3f} "
          f"preemption_rate={eng.preemption_rate:.3f} "
          f"ttft_p95={eng.hist['ttft_steps'].percentile(95):.0f}steps")
    if s["spec_decodes"]:
        print(f"[serve] speculative: k={eng.runner.spec_tokens} "
              f"draft={eng.draft_cfg.name} "
              f"spec_decodes={s['spec_decodes']} "
              f"mean_accept_len={eng.mean_accept_len:.3f}")
    print("[serve] sample output ids:", outs[reqs[0].rid][:8].tolist())
    return outs


def run_router(cfg, mesh, args):
    """The synthetic Poisson workload through a data-parallel fleet."""
    router = build_fleet(cfg, mesh, args)
    rng = np.random.default_rng(args.seed)
    reqs = make_requests(cfg, args, rng)
    arrivals = poisson_arrival_steps(len(reqs), args.rate, rng)
    t0 = time.time()
    outs = router.run(reqs, arrival_steps=arrivals)
    dt = time.time() - t0
    tokens = sum(router.replica_stats("tokens"))
    tok_s = tokens / max(dt, 1e-9)
    shared = router.shared_stats()
    print(f"[serve] mesh=data={mesh.shape['data']},model="
          f"{mesh.shape['model']} dp={router.dp} "
          f"disaggregate={router.disaggregate}")
    roles = (f" roles=prefill{router._prefill_ids}/decode"
             f"{router._decode_ids}" if router.disaggregate else "")
    print(f"[serve] router: dp={router.dp} routed={router.routed} "
          f"handoffs={router.handoffs} "
          f"shared_hit_blocks={sum(router.replica_stats('shared_hit_blocks'))} "
          f"shared_published_blocks={shared['published_blocks']} "
          f"shared_evicted_blocks={shared['evicted_blocks']}" + roles)
    print(f"[serve] fleet: {len(reqs)} requests "
          f"(poisson rate={args.rate}/step), {tokens} tokens in {dt:.2f}s "
          f"({tok_s:.1f} tok/s incl. compile) "
          f"steps={router.replica_stats('steps')} "
          f"preemptions={router.replica_stats('preemptions')} "
          f"cache_hit_tokens={router.replica_stats('cache_hit_tokens')}")
    ctl = router.admission
    print(f"[serve] frontend: submitted={ctl.submitted} shed={ctl.shed} "
          f"completed={ctl.completed} queue_peak={ctl.queue_peak}")
    print("[serve] sample output ids:", outs[reqs[0].rid][:8].tolist())
    return outs


async def _serve_http(eng, controller, host, port):
    from repro.serving.frontend import AsyncEngineDriver, FrontendServer
    drv = AsyncEngineDriver(eng, admission=controller)
    await drv.start()
    srv = FrontendServer(drv, host=host, port=port)
    await srv.start()
    slo = controller.ttft_slo_p95_s
    print(f"[serve] http listening on {host}:{srv.port} "
          f"(POST /generate, GET /health, GET /metrics; "
          f"ttft_slo_p95={slo if slo is not None else 'off'} "
          f"max_queue={controller.max_queue})", flush=True)
    stop = asyncio.Event()
    loop = asyncio.get_running_loop()
    for sig in (signal.SIGINT, signal.SIGTERM):
        loop.add_signal_handler(sig, stop.set)
    await stop.wait()
    print("[serve] draining: no new admissions, finishing "
          f"{len(eng.sched.running) + drv.queue_depth} in-flight "
          "request(s)", flush=True)
    await drv.drain()
    await srv.aclose()
    s = eng.stats
    print(f"[serve] drained cleanly: requests_done={s['requests_done']} "
          f"tokens={s['tokens']} shed={controller.shed} "
          f"steps={s['steps']}", flush=True)


async def _serve_http_router(router, host, port):
    from repro.serving.frontend import FrontendServer
    await router.start()
    srv = FrontendServer(router, host=host, port=port)
    await srv.start()
    ctl = router.admission
    slo = ctl.ttft_slo_p95_s
    print(f"[serve] http listening on {host}:{srv.port} "
          f"dp={router.dp} disaggregate={router.disaggregate} "
          f"(POST /generate, GET /health, GET /metrics; "
          f"ttft_slo_p95={slo if slo is not None else 'off'} "
          f"max_queue={ctl.max_queue})", flush=True)
    stop = asyncio.Event()
    loop = asyncio.get_running_loop()
    for sig in (signal.SIGINT, signal.SIGTERM):
        loop.add_signal_handler(sig, stop.set)
    await stop.wait()
    running = sum(len(e.sched.running) for e in router.engines)
    print("[serve] draining fleet: no new admissions, finishing "
          f"{running + router.queue_depth} in-flight request(s)",
          flush=True)
    await router.aclose()
    await srv.aclose()
    print(f"[serve] router: dp={router.dp} routed={router.routed} "
          f"handoffs={router.handoffs} "
          f"shared_hit_blocks={sum(router.replica_stats('shared_hit_blocks'))} "
          f"requests_done={sum(router.replica_stats('requests_done'))} "
          f"tokens={sum(router.replica_stats('tokens'))} "
          f"shed={ctl.shed}", flush=True)


def run_http(cfg, mesh, args):
    host, _, port = args.http.rpartition(":")
    if args.dp > 1 or args.disaggregate:
        router = build_fleet(cfg, mesh, args)
        asyncio.run(_serve_http_router(router, host or "127.0.0.1",
                                       int(port)))
        return
    eng = build_engine(cfg, mesh, args)
    asyncio.run(_serve_http(eng, build_controller(args),
                            host or "127.0.0.1", int(port)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4_9b")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="smoke-size config (default; --no-smoke for full)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="KV pool size in blocks (default: sized for "
                         "max_batch x max_len); set low to exercise "
                         "preemption / swap under memory pressure")
    ap.add_argument("--max-batched-tokens", type=int, default=None,
                    help="per-step token budget across decodes + one "
                    "prefill chunk (default: max_batch + 2*block_size)")
    ap.add_argument("--no-prefix-caching", action="store_true",
                    help="disable cross-request KV block sharing")
    ap.add_argument("--kv-dtype", default="bf16",
                    choices=("bf16", "int8", "fp8"),
                    help="KV page-pool storage dtype; int8/fp8 store "
                    "per-row fp32 scales alongside and the kernels "
                    "dequantize fused into attention (docs/kv-cache.md)")
    ap.add_argument("--swap-space-bytes", type=int, default=0,
                    help="pinned host memory for swap-preemption, bytes "
                    "(0 = recompute-only preemption). Preemption victims "
                    "move KV to the host tier and back instead of "
                    "recomputing when the cost model prefers it")
    ap.add_argument("--swap-policy", default="auto",
                    choices=("auto", "always", "never"),
                    help="swap-vs-recompute choice per preemption victim: "
                    "auto = measured-bandwidth cost model, always/never "
                    "force one side (bench + tests)")
    ap.add_argument("--prefill-pack", type=int, default=1,
                    help="max prefill chunks packed into one step's flat "
                    "ragged token batch (1 = classic single-chunk; >1 "
                    "needs a packed-prefill-capable runner)")
    ap.add_argument("--speculative-draft", default=None,
                    help="draft-model arch for speculative decoding "
                    "(defaults to --arch, i.e. a fresh-init self-draft, "
                    "when --num-speculative-tokens > 0)")
    ap.add_argument("--num-speculative-tokens", type=int, default=0,
                    help="draft tokens proposed per slot per step; the "
                    "target verifies k+1 positions in one widened step "
                    "(0 disables speculation)")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel engine replicas behind one "
                    "ReplicaRouter admission queue (threads in-process, "
                    "deterministic least-outstanding-tokens routing, "
                    "cross-replica prefix sharing; docs/multi-host.md)")
    ap.add_argument("--disaggregate", action="store_true",
                    help="prefill/decode disaggregation: the first "
                    "--n-prefill replicas prefill (1-token probe), the "
                    "rest decode; KV hands off as hashed blocks through "
                    "the shared prefix index (implies --dp >= 2)")
    ap.add_argument("--n-prefill", type=int, default=1,
                    help="prefill-role replicas under --disaggregate")
    ap.add_argument("--shared-slots", type=int, default=512,
                    help="host-pool slots in the cross-replica "
                    "SharedPrefixIndex (blocks; LRU-evicted)")
    ap.add_argument("--mesh", default=None,
                    help='mesh axis sizes, e.g. "model=2" or '
                    '"data=2,model=2" (default: 1x1). The "model" axis '
                    "tensor-parallel-shards the page pools by kv head; "
                    "needs that many local devices")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="poisson arrivals per decode step")
    ap.add_argument("--http", default=None, metavar="HOST:PORT",
                    help="serve forever over HTTP instead of the synthetic "
                    "Poisson workload: POST /generate (SSE streaming), "
                    "GET /health, GET /metrics; SIGINT/SIGTERM drains "
                    "gracefully (docs/serving-frontend.md)")
    ap.add_argument("--ttft-slo-ms", type=float, default=None,
                    help="TTFT p95 target in ms; admission sheds (429 + "
                    "Retry-After) when the projection would exceed it "
                    "(default: no SLO, queue bound only)")
    ap.add_argument("--max-queue", type=int, default=128,
                    help="front-end waiting-queue bound; requests past it "
                    "are shed regardless of the SLO projection")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = off); composes "
                    "with --top-k / --min-p (docs/sampling.md)")
    ap.add_argument("--min-p", type=float, default=0.0,
                    help="min-p truncation relative to the max "
                    "probability (0 = off)")
    ap.add_argument("--repetition-penalty", type=float, default=1.0,
                    help="divide positive / multiply negative logits of "
                    "already-seen tokens (1.0 = off)")
    ap.add_argument("--presence-penalty", type=float, default=0.0,
                    help="subtract once per distinct generated token")
    ap.add_argument("--frequency-penalty", type=float, default=0.0,
                    help="subtract per occurrence of a generated token")
    ap.add_argument("--logprobs", type=int, default=0,
                    help="per-token top-N logprobs in the stream (0 = off)")
    ap.add_argument("--stop", action="append", default=None,
                    metavar="IDS",
                    help="stop sequence as comma-separated token ids; "
                    "repeatable (each flag adds one sequence)")
    ap.add_argument("--min-new", type=int, default=0,
                    help="ignore EOS / stop sequences before this many "
                    "generated tokens (max_new still wins)")
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = get_config(args.arch, smoke=args.smoke)
    from repro.launch.mesh import make_host_mesh
    data, model = parse_mesh(args.mesh)
    mesh = make_host_mesh(data, model)
    if args.disaggregate and args.dp < 2:
        ap.error("--disaggregate needs --dp >= 2 (prefill + decode roles)")
    if args.http:
        run_http(cfg, mesh, args)
    elif args.dp > 1:
        run_router(cfg, mesh, args)
    else:
        run_engine(cfg, mesh, args)


if __name__ == "__main__":
    main()
