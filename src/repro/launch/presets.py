"""Per-(arch × shape) parallelism presets for the production meshes.

These are the "placement decisions" a deployment would tune; the dry-run
validates them and the roofline iterates on them. Rationale per arch in
DESIGN.md §4; memory numbers in EXPERIMENTS.md §Dry-run.
"""

from __future__ import annotations

from repro.config import OptimizerConfig, ParallelConfig


def default_ocfg(arch: str, shape_name: str) -> OptimizerConfig:
    # grok-1: fp32 Adam moments alone are 2.5 TB — bf16 moments (fp32
    # masters and math) are what makes the 314B trainable on 2 pods.
    if arch == "grok1_314b" and shape_name.startswith("train"):
        return OptimizerConfig(slot_dtype="bfloat16")
    return OptimizerConfig()

# Training microbatch counts chosen so bf16 activations fit 16 GiB/chip
# alongside weights + ZeRO-1 slots (validated by compiled.memory_analysis()).
_TRAIN_MICRO = {
    "glm4_9b": 4,
    "starcoder2_3b": 2,
    "gemma2_27b": 8,
    "qwen3_32b": 8,
    "whisper_large_v3": 2,
    "zamba2_2p7b": 2,
    "qwen2_vl_2b": 2,
    "qwen3_moe_30b_a3b": 4,
    "grok1_314b": 8,
    "mamba2_370m": 1,
}

_FSDP = {"grok1_314b"}          # 314B cannot replicate over "data"
_FSDP_TRAIN_ONLY = {"qwen3_32b", "gemma2_27b"}  # fp32 masters + slots


# §Perf winners (EXPERIMENTS.md): per-arch training overrides adopted after
# the hypothesis->measure loop. seq-shard is NOT applied to gemma2 (its
# local-attention all-gathers regressed the collective term — refuted
# hypothesis, recorded in §Perf).
_TRAIN_TUNED = {
    "glm4_9b": dict(remat="dots", seq_shard_activations=True,
                    microbatches=2),
    # mb=8 (not the frac-equivalent mb=4): dots-remat saves (T, d_ff/16)
    # matmul outputs and gemma2's d_ff=36864 makes fewer/larger microbatches
    # exceed HBM (memory_analysis: est 29.7 GiB @mb4 vs ~13 GiB @mb8).
    "gemma2_27b": dict(remat="dots", microbatches=8),
    # seq-sharded saved residuals make the fp32-master 314B fit pod2
    # (with bf16 Adam moments from default_ocfg). mb must divide the
    # per-dp-shard batch on BOTH meshes: 256/(2*16 dp shards)=8 -> mb<=8.
    "grok1_314b": dict(seq_shard_activations=True, microbatches=8),
}


def default_pcfg(arch: str, shape_name: str) -> ParallelConfig:
    train = shape_name.startswith("train")
    fsdp = arch in _FSDP or (train and arch in _FSDP_TRAIN_ONLY)
    # §Perf iteration: grok-1 DECODE replaces FSDP (per-step weight
    # gathers) with 2D expert-ff sharding — weights resident, tiny psums.
    # Decode-only: the layout replicates tokens over "data", which is the
    # right trade at 1 token/seq but pathological for 32k-token prefill
    # (measured: prefill tx 3.5 s -> 50.8 s; refuted there, see §Perf).
    from repro.config import SHAPES
    f2d = (arch == "grok1_314b"
           and SHAPES[shape_name].kind == "decode")
    kw = dict(
        fsdp=fsdp and not f2d,
        zero1=True,
        remat="full" if train else "none",
        microbatches=_TRAIN_MICRO.get(arch, 1) if train else 1,
        expert_ff_2d=f2d,
    )
    if train and arch in _TRAIN_TUNED:
        kw.update(_TRAIN_TUNED[arch])
    return ParallelConfig(**kw)
