"""End-to-end training driver (SPMD path).

Runs on anything from 1 CPU device (smoke configs) to the production mesh:
  PYTHONPATH=src python -m repro.launch.train --arch glm4_9b --smoke \
      --steps 200 --batch 8 --seq 64 --mesh 1,1 --ckpt /tmp/ck

Features exercised: queue-fed data pipeline, mixed-precision train step with
microbatching, ZeRO-1 state sharding, periodic consistent checkpoints with
retention, crash-resume (--resume), elastic mesh changes between runs
(checkpoint/elastic.py re-shards on restore).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat as _compat  # noqa: F401  (jax API shims)
from repro.config import (OptimizerConfig, ParallelConfig, ShapeConfig,
                          get_config)
from repro.checkpoint.checkpoint import CheckpointManager
from repro.checkpoint.elastic import restore_for_mesh, save_global
from repro.data.pipeline import Pipeline, ShardedSource
from repro.models import api
from repro.optim import optimizers as opt
from repro.spmd import steps as steps_mod


def build_state(cfg, pcfg, ocfg, mesh, seed=0):
    with jax.set_mesh(mesh):
        params_f32, specs = api.init_model(cfg, jax.random.key(seed))
        opt_state = opt.init_train_state(ocfg, params_f32)
        params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params_f32)
        psh = steps_mod.resolve_param_shardings(params, specs, cfg, pcfg,
                                                mesh)
        osh = steps_mod.opt_state_shardings(
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                         opt_state),
            params_f32, specs, cfg, pcfg, mesh)
        params = jax.tree.map(jax.device_put, params, psh)
        opt_state = jax.tree.map(jax.device_put, opt_state, osh)
    return params, opt_state, specs, psh, osh


def train(cfg, *, steps, batch, seq, mesh, pcfg=None, ocfg=None,
          ckpt_dir=None, ckpt_every=50, resume=False, log_every=10,
          seed=0):
    pcfg = pcfg or ParallelConfig(remat="full", microbatches=1)
    ocfg = ocfg or OptimizerConfig(lr=1e-3, warmup_steps=20,
                                   total_steps=steps)
    params, opt_state, specs, psh, osh = build_state(cfg, pcfg, ocfg, mesh,
                                                     seed)
    start = 0
    mgr = CheckpointManager(ckpt_dir, keep=2, keep_best=1) if ckpt_dir \
        else None
    if resume and mgr and mgr.latest_step() is not None:
        start, state = restore_for_mesh(
            mgr, {"params": params, "opt": opt_state},
            {"params": psh, "opt": osh})
        params, opt_state = state["params"], state["opt"]
        print(f"[train] resumed from step {start}")

    src = ShardedSource(cfg, seq, seed=seed)
    pipe = Pipeline(src, batch, capacity=4)
    step_fn = steps_mod.make_train_step(cfg, pcfg, ocfg)
    with jax.set_mesh(mesh):
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))
        losses, t0 = [], time.time()
        for s in range(start, steps):
            hostb = pipe.get()
            batch_dev = {k: jnp.asarray(v) for k, v in hostb.items()}
            params, opt_state, metr = jitted(
                params, opt_state, jnp.asarray(s, jnp.int32), batch_dev)
            losses.append(float(metr["loss"]))
            if (s + 1) % log_every == 0:
                dt = (time.time() - t0) / log_every
                tok_s = batch * seq / dt
                print(f"[train] step {s+1} loss={losses[-1]:.4f} "
                      f"gnorm={float(metr['grad_norm']):.3f} "
                      f"{dt*1e3:.0f} ms/step {tok_s:.0f} tok/s")
                t0 = time.time()
            if mgr and (s + 1) % ckpt_every == 0:
                save_global(mgr, s + 1,
                            {"params": params, "opt": opt_state},
                            metric=float(np.mean(losses[-10:])))
    pipe.close()
    if mgr:
        mgr.wait()
    return params, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4_9b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mesh", default="1,1")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    d, m = (int(x) for x in args.mesh.split(","))
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(d, m)
    pcfg = ParallelConfig(remat="full", microbatches=args.microbatches)
    _, _, losses = train(cfg, steps=args.steps, batch=args.batch,
                         seq=args.seq, mesh=mesh, pcfg=pcfg,
                         ckpt_dir=args.ckpt, resume=args.resume)
    print(f"[train] done. loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
