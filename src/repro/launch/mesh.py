"""Mesh construction (importing this module never touches jax device state)."""

from __future__ import annotations

import jax

from repro import compat as _compat  # noqa: F401  (jax API shims)


def make_production_mesh(*, multi_pod: bool = False):
    """The assigned production mesh: one pod = 16x16 = 256 chips; the
    multi-pod variant adds a leading 2-pod axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (CPU) devices exist — tests/examples."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
