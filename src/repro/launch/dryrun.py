import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, and dump memory/cost/collective evidence.

This is the §3.3 "placement + partition" validation with XLA's SPMD
partitioner standing in for the paper's graph partitioner: if a sharding
assignment is incoherent (mismatched collective, non-divisible dim, OOM at
compile), it fails HERE, not on a 512-chip reservation.

Usage:
  python -m repro.launch.dryrun --arch glm4_9b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/]
"""

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat as _compat  # noqa: F401  (jax API shims)
from repro.config import (ARCHS, SHAPES, OptimizerConfig, ParallelConfig,
                          get_config, shape_applicable)
from repro.launch.mesh import make_production_mesh
from repro.launch.presets import default_pcfg
from repro.models import api
from repro.optim import optimizers as opt
from repro.spmd import sharding as shd
from repro.spmd import steps as steps_mod


def abstract_tree(shapes_tree, shardings_tree):
    def one(sds, sh):
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh)
    return jax.tree.map(one, shapes_tree, shardings_tree)


def input_specs(arch: str, shape_name: str, mesh, pcfg=None):
    """ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no device
    allocation) for every input of the step being lowered."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    pcfg = pcfg or default_pcfg(arch, shape_name)
    bsh = steps_mod.batch_shardings(cfg, shape, mesh)
    batch = {
        name: jax.ShapeDtypeStruct(shp, dt, sharding=bsh[name])
        for name, (shp, dt) in api.batch_shapes(cfg, shape).items()
    }
    out = {"batch": batch}
    if shape.kind == "decode":
        cshapes = api.init_cache_shapes(cfg, shape.global_batch,
                                        shape.seq_len)
        csh = steps_mod.cache_shardings(cfg, shape.global_batch,
                                        shape.seq_len, mesh)
        out["cache"] = abstract_tree(cshapes, csh)
    return out


def lower_cell(arch: str, shape_name: str, mesh, pcfg=None, ocfg=None):
    """Returns (lowered, compiled, info dict)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    pcfg = pcfg or default_pcfg(arch, shape_name)
    from repro.launch.presets import default_ocfg
    ocfg = ocfg or default_ocfg(arch, shape_name)

    with jax.set_mesh(mesh):
        pshapes, specs = api.abstract_params(cfg)
        psh = steps_mod.resolve_param_shardings(pshapes, specs, cfg, pcfg,
                                                mesh)
        # working params are bf16; fp32 masters live in the optimizer state
        pshapes_bf16 = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16), pshapes)
        params_abs = abstract_tree(pshapes_bf16, psh)
        ins = input_specs(arch, shape_name, mesh, pcfg)
        t0 = time.time()

        if shape.kind == "train":
            oshapes = jax.eval_shape(
                lambda: opt.init_train_state(ocfg, pshapes))
            osh = steps_mod.opt_state_shardings(oshapes, pshapes, specs, cfg,
                                                pcfg, mesh)
            opt_abs = abstract_tree(oshapes, osh)
            step_abs = jax.ShapeDtypeStruct((), jnp.int32)
            fn = steps_mod.make_train_step(cfg, pcfg, ocfg)
            metr_sh = NamedSharding(mesh, P())
            lowered = jax.jit(
                fn,
                in_shardings=(psh, osh, None, {
                    k: v.sharding for k, v in ins["batch"].items()}),
                out_shardings=(psh, osh, None),
                donate_argnums=(0, 1),
            ).lower(params_abs, opt_abs, step_abs, ins["batch"])
        elif shape.kind == "prefill":
            fn = steps_mod.make_prefill_step(cfg, pcfg)
            csh = steps_mod.cache_shardings(cfg, shape.global_batch,
                                            shape.seq_len, mesh)
            lowered = jax.jit(
                fn,
                in_shardings=(psh, {k: v.sharding
                                    for k, v in ins["batch"].items()}),
                out_shardings=(csh, NamedSharding(
                    mesh, steps_mod.shd.batch_spec(
                        shape.global_batch, mesh, extra_dims=0))),
            ).lower(params_abs, ins["batch"])
        else:  # decode
            fn = steps_mod.make_decode_step(cfg, pcfg)
            csh = jax.tree.map(lambda x: x.sharding, ins["cache"])
            lowered = jax.jit(
                fn,
                in_shardings=(psh, csh, {k: v.sharding
                                         for k, v in ins["batch"].items()}),
                out_shardings=(NamedSharding(mesh, steps_mod.shd.batch_spec(
                    shape.global_batch, mesh, extra_dims=0)), csh),
                donate_argnums=(1,),
            ).lower(params_abs, ins["cache"], ins["batch"])

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    n_dev = mesh.devices.size
    info = {
        "arch": arch, "shape": shape_name,
        "mesh": dict(mesh.shape), "devices": int(n_dev),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_per_device": (
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
        },
        "cost": {k: ca[k] for k in ("flops", "bytes accessed")
                 if k in ca} if ca else {},
        "params": get_config(arch).param_count(),
        "active_params": get_config(arch).active_param_count(),
        "microbatches": pcfg.microbatches,
        "remat": pcfg.remat,
        "fsdp": pcfg.fsdp,
    }
    return lowered, compiled, info


def run_cell(arch, shape_name, multi_pod, out_dir: Path | None,
             save_hlo=True, pcfg=None, variant=""):
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    tag = f"{arch}.{shape_name}.{'pod2' if multi_pod else 'pod1'}"
    if variant:
        tag += f".{variant}"
    if not ok:
        print(f"[dryrun] {tag}: {why}")
        return {"arch": arch, "shape": shape_name, "skipped": why}
    lowered, compiled, info = lower_cell(arch, shape_name, mesh, pcfg=pcfg)
    print(f"[dryrun] {tag}: compile={info['compile_s']}s "
          f"peak/device={info['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
          f"flops={info['cost'].get('flops', 0):.3e}")
    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{tag}.json").write_text(json.dumps(info, indent=1))
        if save_hlo:
            import gzip
            hlo = compiled.as_text()
            with gzip.open(out_dir / f"{tag}.hlo.gz", "wt") as f:
                f.write(hlo)
    return info


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-hlo", action="store_true")
    # §Perf hillclimb overrides — lower a variant without touching presets
    ap.add_argument("--variant", default="",
                    help="tag for output files of an overridden config")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--seq-shard-acts", action="store_true", default=None)
    ap.add_argument("--fsdp", type=int, default=None)
    ap.add_argument("--expert-ff-2d", type=int, default=None)
    args = ap.parse_args()

    out = Path(args.out)
    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                pcfg = None
                if any(v is not None for v in (
                        args.remat, args.microbatches, args.seq_shard_acts,
                        args.fsdp, args.expert_ff_2d)):
                    import dataclasses
                    base = default_pcfg(arch, shape_name)
                    kw = {}
                    if args.remat is not None:
                        kw["remat"] = args.remat
                    if args.microbatches is not None:
                        kw["microbatches"] = args.microbatches
                    if args.seq_shard_acts is not None:
                        kw["seq_shard_activations"] = args.seq_shard_acts
                    if args.fsdp is not None:
                        kw["fsdp"] = bool(args.fsdp)
                    if args.expert_ff_2d is not None:
                        kw["expert_ff_2d"] = bool(args.expert_ff_2d)
                    pcfg = dataclasses.replace(base, **kw)
                try:
                    results.append(run_cell(arch, shape_name, mp, out,
                                            save_hlo=not args.no_hlo,
                                            pcfg=pcfg,
                                            variant=args.variant))
                except Exception as e:  # noqa: BLE001 - report and continue
                    print(f"[dryrun] {arch}.{shape_name}."
                          f"{'pod2' if mp else 'pod1'}: FAILED {e}")
                    results.append({"arch": arch, "shape": shape_name,
                                    "multi_pod": mp, "error": str(e)})
    n_fail = sum(1 for r in results if "error" in r)
    print(f"[dryrun] done: {len(results)} cells, {n_fail} failures")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
