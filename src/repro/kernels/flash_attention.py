"""Flash attention as a Pallas TPU kernel.

Layout: grid = (batch * q_heads, num_q_blocks, num_kv_blocks) with the kv
block as the minormost (sequential) dim; an (m, l, acc) streaming-softmax
state lives in VMEM scratch and survives across kv iterations because the
output BlockSpec ignores the kv grid index. Causal + sliding-window masks
skip fully-masked kv blocks via ``pl.when``. GQA uses the repo-wide g-major
convention: q head h reads kv head ``h % K``.

Block shapes: (BLOCK_Q x head_dim) q tiles and (BLOCK_KV x head_dim) kv
tiles — head_dim is 64..128 for every assigned arch, so tiles are MXU-aligned
(multiples of (8,128) lanes) and the VMEM working set is
BLOCK_Q*(hd + BLOCK_KV) * 4B ≈ 2.2 MiB at the defaults, well under ~16 MiB.

Backward: custom_vjp with a recompute-based flash backward (no O(S^2)
residuals; dq/dk/dv from (q,k,v,o,lse,do) in blocked jnp). The Pallas
forward returns lse for exactly this purpose — the production pattern.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128
NEG_INF = -1.0e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
                *, scale, cap, causal, window, block_q, block_kv,
                kv_len, q_offset):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0)
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)

    def compute():
        q = q_ref[...].astype(jnp.float32)         # (block_q, hd)
        k = k_ref[...].astype(jnp.float32)         # (block_kv, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if cap is not None:
            s = cap * jnp.tanh(s / cap)
        mask = k_pos < kv_len
        if causal:
            d = q_pos - k_pos
            mask &= d >= 0
            if window is not None:
                mask &= d < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        m_scr[...] = m_new
        v = v_ref[...].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if causal:
        # skip kv blocks strictly after this q block's last position
        first_q = q_offset + qi * block_q
        last_q = first_q + block_q - 1
        first_k = ki * block_kv
        live = first_k <= last_q
        if window is not None:
            last_k = first_k + block_kv - 1
            live &= last_k > first_q - window
        pl.when(live)(compute)
    else:
        compute()

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-37)
        o_ref[...] = (acc_scr[...] / l).astype(o_ref.dtype)
        lse_ref[...] = (m_scr[...] + jnp.log(l))[:, 0]


def _flash_fwd(q, k, v, *, causal, window, cap, scale, q_offset,
               block_q, block_kv, interpret):
    B, Sq, H, hd = q.shape
    _, Skv, K, _ = k.shape
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    nq = -(-Sq // block_q)
    nk = -(-Skv // block_kv)
    pad_q = nq * block_q - Sq
    pad_kv = nk * block_kv - Skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))

    # (B, S, H, hd) -> (B*H, S, hd) with g-major q->kv head mapping
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, nq * block_q, hd)
    kb = k.transpose(0, 2, 1, 3).reshape(B * K, nk * block_kv, hd)
    vb = v.transpose(0, 2, 1, 3).reshape(B * K, nk * block_kv, hd)

    def kv_index(bh, qi, ki):
        b, h = bh // H, bh % H
        return (b * K + h % K, ki, 0)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, cap=cap, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, kv_len=Skv, q_offset=q_offset)

    o, lse = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((None, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((None, block_kv, hd), kv_index),
            pl.BlockSpec((None, block_kv, hd), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((None, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((None, block_q), lambda bh, qi, ki: (bh, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, nq * block_q, hd), q.dtype),
            jax.ShapeDtypeStruct((B * H, nq * block_q), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qb, kb, vb)

    o = o.reshape(B, H, nq * block_q, hd).transpose(0, 2, 1, 3)[:, :Sq]
    lse = lse.reshape(B, H, nq * block_q).transpose(0, 2, 1)[:, :Sq]
    return o, lse


# ---------------------------------------------------------------------------
# Backward (recompute; blocked jnp — no O(S^2) residuals stored)
# ---------------------------------------------------------------------------


def _bwd_ref(q, k, v, o, lse, do, *, causal, window, cap, scale, q_offset):
    B, Sq, H, hd = q.shape
    _, Skv, K, _ = k.shape
    G = H // K
    q32 = q.astype(jnp.float32).reshape(B, Sq, G, K, hd)
    k32 = k.astype(jnp.float32)
    v32 = v.astype(jnp.float32)
    do32 = do.astype(jnp.float32).reshape(B, Sq, G, K, hd)
    o32 = o.astype(jnp.float32).reshape(B, Sq, G, K, hd)
    lse_g = lse.reshape(B, Sq, G, K)

    u = jnp.einsum("bqgkh,bskh->bqgks", q32, k32) * scale
    if cap is not None:
        z = cap * jnp.tanh(u / cap)
        dz_du = 1.0 - jnp.square(z / cap)
    else:
        z = u
        dz_du = None
    if causal:
        d = (q_offset + jnp.arange(Sq))[:, None] - jnp.arange(Skv)[None, :]
        ok = d >= 0
        if window is not None:
            ok &= d < window
        z = jnp.where(ok[None, :, None, None, :], z, NEG_INF)
    p = jnp.exp(z - lse_g[..., None])
    dv = jnp.einsum("bqgks,bqgkh->bskh", p, do32)
    dp = jnp.einsum("bqgkh,bskh->bqgks", do32, v32)
    delta = jnp.sum(do32 * o32, axis=-1)                  # (B,Sq,G,K)
    ds = p * (dp - delta[..., None])
    if dz_du is not None:
        ds = ds * dz_du
    ds = ds * scale
    dq = jnp.einsum("bqgks,bskh->bqgkh", ds, k32)
    dk = jnp.einsum("bqgks,bqgkh->bskh", ds, q32)
    return (dq.reshape(B, Sq, H, hd).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype))


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10))
def flash_attention(q, k, v, causal=True, window=None, cap=None, scale=None,
                    q_offset=0, block_q=DEFAULT_BLOCK_Q,
                    block_kv=DEFAULT_BLOCK_KV, interpret=False):
    scale = q.shape[-1] ** -0.5 if scale is None else scale
    o, _ = _flash_fwd(q, k, v, causal=causal, window=window, cap=cap,
                      scale=scale, q_offset=q_offset, block_q=block_q,
                      block_kv=block_kv, interpret=interpret)
    return o


def _vjp_fwd(q, k, v, causal, window, cap, scale, q_offset, block_q,
             block_kv, interpret):
    scale = q.shape[-1] ** -0.5 if scale is None else scale
    o, lse = _flash_fwd(q, k, v, causal=causal, window=window, cap=cap,
                        scale=scale, q_offset=q_offset, block_q=block_q,
                        block_kv=block_kv, interpret=interpret)
    return o, (q, k, v, o, lse)


def _vjp_bwd(causal, window, cap, scale, q_offset, block_q, block_kv,
             interpret, res, do):
    q, k, v, o, lse = res
    scale = q.shape[-1] ** -0.5 if scale is None else scale
    dq, dk, dv = _bwd_ref(q, k, v, o, lse, do, causal=causal, window=window,
                          cap=cap, scale=scale, q_offset=q_offset)
    return dq, dk, dv


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)
