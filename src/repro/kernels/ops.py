"""Public kernel entry points with backend dispatch.

Each op has three implementations:
  1. a Pallas TPU kernel (``repro.kernels.<name>``) — the production hot path,
     validated on CPU via ``interpret=True``;
  2. a scalable pure-XLA path (chunked/streaming jnp) used on CPU and for the
     dry-run lowering;
  3. a naive oracle in ``repro.kernels.ref`` used only by tests.

Dispatch: Pallas on TPU backends (or when ``REPRO_FORCE_PALLAS=interpret`` is
set, for kernel validation), XLA path otherwise.
"""

from __future__ import annotations

import os

import jax


def _use_pallas() -> str | None:
    """Returns None (XLA path), "compiled", or "interpret"."""
    force = os.environ.get("REPRO_FORCE_PALLAS", "")
    if force == "interpret":
        return "interpret"
    if force == "off":
        return None
    if jax.default_backend() == "tpu":
        return "compiled"
    return None


def _pages_per_block(pages_per_compute_block) -> int:
    """KV pages fetched per paged-kernel grid step. Explicit argument wins;
    ``REPRO_PAGES_PER_BLOCK`` sets the fleet-wide default (1 = the
    single-page kernel, bit-for-bit)."""
    if pages_per_compute_block is not None:
        return int(pages_per_compute_block)
    return int(os.environ.get("REPRO_PAGES_PER_BLOCK", "1"))


# XLA-path dispatch: dense attention keeps a single bf16 (Sq,Skv) block per
# head and is the right trade under layer remat up to this many kv positions;
# beyond it the streaming chunked form bounds memory at O(chunk).
DENSE_ATTN_MAX_KV = 8192


def flash_attention(q, k, v, *, causal=True, window=None, cap=None,
                    scale=None, chunk_kv=1024, q_offset=0):
    mode = _use_pallas()
    if mode is not None:
        from repro.kernels import flash_attention as fa
        return fa.flash_attention(
            q, k, v, causal=causal, window=window, cap=cap, scale=scale,
            q_offset=q_offset, interpret=(mode == "interpret"))
    from repro.models.attention import (block_causal_attention,
                                        chunked_attention, dense_attention)
    if k.shape[1] <= DENSE_ATTN_MAX_KV:
        return dense_attention(q, k, v, causal=causal, window=window,
                               cap=cap, scale=scale, q_offset=q_offset)
    if causal and q_offset == 0 and q.shape[1] == k.shape[1]:
        # static triangular block skipping: ~2x fewer attention flops
        return block_causal_attention(q, k, v, window=window, cap=cap,
                                      scale=scale, chunk_kv=chunk_kv)
    return chunked_attention(q, k, v, causal=causal, window=window, cap=cap,
                             scale=scale, chunk_kv=chunk_kv,
                             q_offset=q_offset)


def paged_attention(q, k_pages, v_pages, block_tables, ctx_lens, *,
                    window=None, cap=None, scale=None,
                    pages_per_compute_block=None, k_scale=None, v_scale=None):
    """Decode attention through a block table (serving hot path).
    See kernels/paged_attention.py; the XLA path densifies the gather.
    ``k_scale``/``v_scale`` are the per-row fp32 scale pools of a
    quantized page pool (dequant fused into the kernel)."""
    mode = _use_pallas()
    if mode is not None:
        from repro.kernels import paged_attention as pa
        return pa.paged_attention(
            q, k_pages, v_pages, block_tables, ctx_lens, window=window,
            cap=cap, scale=scale, interpret=(mode == "interpret"),
            pages_per_compute_block=_pages_per_block(
                pages_per_compute_block),
            k_scale=k_scale, v_scale=v_scale)
    from repro.kernels.ref import paged_attention_ref
    return paged_attention_ref(q, k_pages, v_pages, block_tables, ctx_lens,
                               window=window, cap=cap, scale=scale,
                               k_scale=k_scale, v_scale=v_scale)


def paged_attention_partial(q, k_pages, v_pages, block_tables, ctx_lens,
                            block_mask, *, window=None, cap=None,
                            scale=None, k_scale=None, v_scale=None):
    """Partial-softmax paged decode over a shard-local block table:
    attends only table entries selected by ``block_mask`` and returns
    ``(o, lse)`` for the cross-shard LSE stitch
    (``models.attention.stitch_paged_partials``). See
    kernels/paged_attention.py."""
    mode = _use_pallas()
    if mode is not None:
        from repro.kernels import paged_attention as pa
        return pa.paged_attention(     # fp32 (o, lse) partials
            q, k_pages, v_pages, block_tables, ctx_lens, window=window,
            cap=cap, scale=scale, block_mask=block_mask, return_lse=True,
            interpret=(mode == "interpret"),
            k_scale=k_scale, v_scale=v_scale)
    from repro.kernels.ref import paged_attention_partial_ref
    return paged_attention_partial_ref(
        q, k_pages, v_pages, block_tables, ctx_lens, block_mask,
        window=window, cap=cap, scale=scale,
        k_scale=k_scale, v_scale=v_scale)


def paged_prefill_attention(q, k_pages, v_pages, block_tables, ctx_lens,
                            q_lens, *, window=None, cap=None, scale=None,
                            pages_per_compute_block=None, k_scale=None,
                            v_scale=None):
    """Chunked-prefill attention through a block table: C queries per
    sequence, causally masked against the paged context. See
    kernels/paged_attention.py; the XLA path densifies the gather and
    mirrors ``dense_attention``'s rounding so chunked and monolithic
    prefill stay greedy-equivalent on CPU."""
    mode = _use_pallas()
    if mode is not None:
        from repro.kernels import paged_attention as pa
        return pa.paged_prefill_attention(
            q, k_pages, v_pages, block_tables, ctx_lens, q_lens,
            window=window, cap=cap, scale=scale,
            interpret=(mode == "interpret"),
            pages_per_compute_block=_pages_per_block(
                pages_per_compute_block),
            k_scale=k_scale, v_scale=v_scale)
    from repro.models.attention import paged_chunk_attention_xla
    return paged_chunk_attention_xla(
        q, k_pages, v_pages, block_tables, ctx_lens, q_lens,
        window=window, cap=cap, scale=scale,
        k_scale=k_scale, v_scale=v_scale)


def ragged_paged_prefill_attention(q, k_pages, v_pages, block_tables,
                                   ctx_lens, starts, ends, row_seq, *,
                                   window=None, cap=None, scale=None,
                                   pages_per_compute_block=None,
                                   k_scale=None, v_scale=None):
    """Packed (ragged) chunked-prefill attention through per-sequence
    block tables: chunks of several sequences ride one flat (T, H, hd)
    batch, sequence s owning flat rows [starts[s], ends[s]). The chunk's
    own KV must already be scattered into the pages. See
    kernels/paged_attention.py; the XLA path gathers the packed rows into
    the dense (S, T) layout and reuses the single-chunk rounding."""
    mode = _use_pallas()
    if mode is not None:
        from repro.kernels import paged_attention as pa
        return pa.ragged_paged_prefill_attention(
            q, k_pages, v_pages, block_tables, ctx_lens, starts, ends,
            window=window, cap=cap, scale=scale,
            interpret=(mode == "interpret"),
            pages_per_compute_block=_pages_per_block(
                pages_per_compute_block),
            k_scale=k_scale, v_scale=v_scale)
    from repro.models.attention import ragged_chunk_attention_xla
    return ragged_chunk_attention_xla(
        q, k_pages, v_pages, block_tables, ctx_lens, starts, ends, row_seq,
        window=window, cap=cap, scale=scale,
        k_scale=k_scale, v_scale=v_scale)


def ragged_prefill_update_attend(q, k_new, v_new, k_pages, v_pages,
                                 block_tables, ctx_lens, starts, ends,
                                 row_seq, *, window=None, cap=None,
                                 scale=None, k_scale=None, v_scale=None):
    """Fused packed-prefill KV scatter + attention: returns
    ``(o, k_pages, v_pages)``. On the Pallas path the scatter rides inside
    the ragged kernel through aliased page-pool outputs (one launch, no
    separate scatter pass); the XLA path scatters then attends — same pool
    bytes, same outputs.

    Quantized pools: ``k_new``/``v_new`` must arrive *already quantized*
    to the pool dtype and ``k_scale``/``v_scale`` must already contain the
    chunk's scattered scale rows (``models.attention`` does both before
    calling) — the kernel reads scale pages for the dequant and only
    aliases the value pools."""
    mode = _use_pallas()
    if mode is not None:
        from repro.kernels import paged_attention as pa
        return pa.ragged_paged_prefill_attention(
            q, k_pages, v_pages, block_tables, ctx_lens, starts, ends,
            k_new=k_new, v_new=v_new, window=window, cap=cap, scale=scale,
            interpret=(mode == "interpret"),
            k_scale=k_scale, v_scale=v_scale)
    from repro.models.attention import (ragged_chunk_attention_xla,
                                        update_paged_cache_ragged)
    kc = update_paged_cache_ragged(k_pages, k_new[None], block_tables,
                                   ctx_lens, starts, ends, row_seq)
    vc = update_paged_cache_ragged(v_pages, v_new[None], block_tables,
                                   ctx_lens, starts, ends, row_seq)
    o = ragged_chunk_attention_xla(
        q, kc, vc, block_tables, ctx_lens, starts, ends, row_seq,
        window=window, cap=cap, scale=scale,
        k_scale=k_scale, v_scale=v_scale)
    return o, kc, vc


def ssd(x, dt, A, B, C, *, chunk, h0=None):
    mode = _use_pallas()
    if mode is not None:
        from repro.kernels import ssd as ssd_k
        return ssd_k.ssd(x, dt, A, B, C, chunk=chunk, h0=h0,
                         interpret=(mode == "interpret"))
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, dt, A, B, C, chunk=chunk, h0=h0)


def sampled_softmax_loss(x, table, labels, sampled_ids, *, cap=None):
    """See kernels/sampled_softmax.py and models/embedding.py."""
    mode = _use_pallas()
    if mode is not None:
        from repro.kernels import sampled_softmax as ss
        return ss.sampled_softmax_loss(
            x, table, labels, sampled_ids, cap=cap,
            interpret=(mode == "interpret"))
    from repro.kernels.ref import sampled_softmax_loss_ref
    return sampled_softmax_loss_ref(x, table, labels, sampled_ids, cap=cap)


def embedding_gather(table, ids):
    mode = _use_pallas()
    if mode is not None:
        from repro.kernels import embedding as emb
        return emb.gather(table, ids, interpret=(mode == "interpret"))
    return table[ids]
