"""Mamba2 SSD (state-space duality) as a Pallas TPU kernel.

Grid = (batch * heads, n_chunks); chunks are the sequential minormost dim and
the (head_dim x state) recurrent state lives in VMEM scratch across chunk
iterations. Within a chunk the SSD quadratic form runs on the MXU:

    y_intra = (C B^T  ⊙ exp(segsum(dt·A))) @ (dt·x)        (Q x Q) @ (Q x hp)
    y_inter = exp(cum) ⊙ (C @ state^T)
    state'  = exp(cum_Q) state + x^T @ (exp(cum_Q - cum) dt ⊙ B)

Q = chunk (256 default), hp = 64, N = 64..128 for the assigned archs, so the
VMEM working set is a few (Q,Q)/(Q,N) fp32 tiles ≈ 1 MiB. dA = dt·A is
always ≤ 0 (A = -exp(A_log)) so every exp() here is ≤ 1 — no overflow.

Forward-only kernel; training uses the chunked XLA path (models/ssm.py)
whose scan JAX differentiates. The oracle is kernels/ref.py:ssd_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref,
                state_scr, *, chunk):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = h0_ref[...].astype(jnp.float32)

    A = a_ref[0, 0].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)          # (Q, hp)
    dt = dt_ref[...].astype(jnp.float32)[:, 0]  # (Q,)
    Bm = b_ref[...].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[...].astype(jnp.float32)         # (Q, N)

    dA = dt * A                                  # (Q,) <= 0
    cs = jnp.cumsum(dA)                          # (Q,)

    # intra-chunk quadratic term
    diff = cs[:, None] - cs[None, :]             # segsum over (j, i]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(diff), 0.0)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    scores = CB * L                              # (Q, Q)
    xdt = x * dt[:, None]                        # (Q, hp)
    y = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk contribution from the carried state
    state = state_scr[...]                       # (hp, N)
    y += jnp.exp(cs)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update
    decay_end = jnp.exp(cs[-1] - cs) * dt        # (Q,)
    state_scr[...] = (jnp.exp(cs[-1]) * state
                      + jax.lax.dot_general(
                          x, Bm * decay_end[:, None],
                          (((0,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32))

    y_ref[...] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        hout_ref[...] = state_scr[...]


def ssd(x, dt, A, B, C, *, chunk, h0=None, interpret=False):
    """x: (b,S,nh,hp); dt: (b,S,nh); A: (nh,); B,C: (b,S,G,N); G must
    divide nh. Returns (y (b,S,nh,hp), h_last (b,nh,hp,N))."""
    b, S, nh, hp = x.shape
    G, N = B.shape[2], B.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    xb = x.transpose(0, 2, 1, 3).reshape(b * nh, S, hp)
    dtb = dt.transpose(0, 2, 1).reshape(b * nh, S, 1)
    Bb = B.transpose(0, 2, 1, 3).reshape(b * G, S, N)
    Cb = C.transpose(0, 2, 1, 3).reshape(b * G, S, N)
    Ab = A.reshape(nh, 1).astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((b, nh, hp, N), jnp.float32)
    h0b = h0.reshape(b * nh, hp, N)
    rep = nh // G

    def bc_index(bh, ci):
        return (bh // nh * G + (bh % nh) // rep, ci, 0)

    y, hout = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(b * nh, nc),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bh, ci: (bh % nh, 0)),       # A
            pl.BlockSpec((None, chunk, hp), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, chunk, 1), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, chunk, N), bc_index),                # B
            pl.BlockSpec((None, chunk, N), bc_index),                # C
            pl.BlockSpec((None, hp, N), lambda bh, ci: (bh, 0, 0)),  # h0
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, hp), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, hp, N), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * nh, S, hp), x.dtype),
            jax.ShapeDtypeStruct((b * nh, hp, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hp, N), jnp.float32)],
        interpret=interpret,
    )(Ab, xb, dtb, Bb, Cb, h0b)

    y = y.reshape(b, nh, S, hp).transpose(0, 2, 1, 3)
    return y, hout.reshape(b, nh, hp, N)
