"""Fused sampled-softmax loss as a Pallas TPU kernel (paper §4.2 / §6.4).

The paper's sampled softmax replaces the (T x V) logit matrix with logits
against {true class} ∪ {n sampled classes}. This kernel fuses the remaining
hot loop — (T x d) @ (d x n) logits, accidental-hit masking, LSE and the
loss reduction — over (BLOCK_T x d) activation tiles, so the (T x n) logit
block never leaves VMEM. Row gathers for w_true/w_samp use the embedding
gather kernel (sparse reads colocated with the vocab shard).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_T = 256
NEG = -1.0e30


def _loss_kernel(x_ref, wt_ref, lab_ref, ws_ref, sid_ref, o_ref, *, cap,
                 t_len, block_t):
    ti = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)           # (Bt, d)
    wt = wt_ref[...].astype(jnp.float32)         # (Bt, d)
    lab = lab_ref[...][:, 0]                     # (Bt,)
    ws = ws_ref[...].astype(jnp.float32)         # (n, d)
    sid = sid_ref[...][:, 0]                     # (n,)

    lt = jnp.sum(x * wt, axis=-1)                # (Bt,)
    ls = jax.lax.dot_general(x, ws, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Bt, n)
    if cap is not None:
        lt = cap * jnp.tanh(lt / cap)
        ls = cap * jnp.tanh(ls / cap)
    hit = sid[None, :] == lab[:, None]
    ls = jnp.where(hit, NEG, ls)
    mx = jnp.maximum(lt, ls.max(axis=-1))
    lse = mx + jnp.log(jnp.exp(lt - mx) + jnp.exp(ls - mx[:, None]).sum(-1))
    rows = jax.lax.broadcasted_iota(jnp.int32, (block_t, 1), 0)[:, 0]
    valid = (ti * block_t + rows) < t_len
    o_ref[0, 0] = jnp.sum(jnp.where(valid, lse - lt, 0.0))


def sampled_softmax_loss(x, table, labels, sampled_ids, *, cap=None,
                         interpret=False):
    """x: (T, d); table: (V, d); labels: (T,); sampled_ids: (n,).
    Mean loss over T tokens (matches kernels/ref.py oracle)."""
    from repro.kernels.embedding import gather
    T, d = x.shape
    n = sampled_ids.shape[0]
    w_true = gather(table, labels, interpret=interpret)       # (T, d)
    w_samp = gather(table, sampled_ids, interpret=interpret)  # (n, d)

    block_t = min(BLOCK_T, T)
    nb = -(-T // block_t)
    pad = nb * block_t - T
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        w_true = jnp.pad(w_true, ((0, pad), (0, 0)))
        labels = jnp.pad(labels, (0, pad), constant_values=-1)

    partial = pl.pallas_call(
        functools.partial(_loss_kernel, cap=cap, t_len=T, block_t=block_t),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
            pl.BlockSpec((block_t, 1), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        interpret=interpret,
    )(x, w_true, labels.astype(jnp.int32)[:, None], w_samp,
      sampled_ids.astype(jnp.int32)[:, None])
    return jnp.sum(partial) / T
