"""Pure-jnp oracles for every kernel. Naive, exact, O(S^2)/O(S·N) memory —
tests only. The scalable XLA paths live in repro.models.*; the TPU paths in
repro.kernels.<name>."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import quant


def _gather_pages(pages, scale, block_tables):
    """Densify a page pool through the block table; a quantized pool
    (per-row scale supplied) dequantizes right after the gather — the
    bf16 round-trip in ``quant.dequantize_kv`` is the same one the
    kernels apply in-tile, so both paths attend identical operands."""
    g = pages[block_tables]
    if scale is not None:
        g = quant.dequantize_kv(g, scale[block_tables])
    return g


def attention_ref(q, k, v, *, causal=True, window=None, cap=None, scale=None,
                  q_offset=0):
    """Naive full-materialization attention. q: (B,Sq,H,hd); k/v: (B,Skv,K,hd)."""
    B, Sq, H, hd = q.shape
    _, Skv, K, _ = k.shape
    G = H // K
    scale = hd ** -0.5 if scale is None else scale
    # g-major GQA grouping (head h uses kv head h % K) — matches models/.
    qg = q.reshape(B, Sq, G, K, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    logits = jnp.einsum("bqgkh,bskh->bqgks", qg, kf) * scale
    if cap is not None:
        logits = cap * jnp.tanh(logits / cap)
    if causal:
        qp = q_offset + jnp.arange(Sq)
        kp = jnp.arange(Skv)
        d = qp[:, None] - kp[None, :]
        ok = d >= 0
        if window is not None:
            ok &= d < window
        logits = jnp.where(ok[None, :, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bqgks,bskh->bqgkh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_tables, ctx_lens, *,
                        window=None, cap=None, scale=None,
                        k_scale=None, v_scale=None):
    """Paged decode attention oracle: densify the block-table gather, then
    the exact masked-softmax math of ``models.attention._decode_attn_local``.

    q: (B, H, hd); pages: (num_blocks, block_size, K, hd);
    block_tables: (B, nb) int32; ctx_lens: (B,) int32 (0 => zero output).
    k_scale/v_scale: optional (num_blocks, block_size, K, 1) fp32 per-row
    scales for a quantized pool (dequantized after the gather).
    """
    B, H, hd = q.shape
    _, bs, K, _ = k_pages.shape
    G = H // K
    scale = hd ** -0.5 if scale is None else scale
    # densify: (B, nb, bs, K, hd) -> (B, S, K, hd), S = nb * bs
    k = _gather_pages(k_pages, k_scale, block_tables).reshape(B, -1, K, hd)
    v = _gather_pages(v_pages, v_scale, block_tables).reshape(B, -1, K, hd)
    S = k.shape[1]
    qg = q.reshape(B, G, K, hd)
    logits = jnp.einsum("bgkh,bskh->bgks", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if cap is not None:
        logits = cap * jnp.tanh(logits / cap)
    k_pos = jnp.arange(S)
    ok = k_pos[None, :] < ctx_lens[:, None]                   # (B, S)
    if window is not None:
        ok &= k_pos[None, :] > ctx_lens[:, None] - 1 - window
    logits = jnp.where(ok[:, None, None, :], logits, -1e30)
    mx = logits.max(axis=-1)
    p = jnp.exp(logits - mx[..., None])
    p = jnp.where(ok[:, None, None, :], p, 0.0)   # ctx=0 rows -> all zero
    sm = jnp.maximum(p.sum(axis=-1), 1e-37)
    # repo-wide rounding convention (matches dense_attention): normalize in
    # fp32, cast, then multiply — so decode-written KV is bit-identical to
    # the same position recomputed by prefill/chunked-prefill.
    p = (p / sm[..., None]).astype(v.dtype)
    o = jnp.einsum("bgks,bskh->bgkh", p, v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, H, hd).astype(q.dtype)


def paged_attention_partial_ref(q, k_pages, v_pages, block_tables, ctx_lens,
                                block_mask, *, window=None, cap=None,
                                scale=None, k_scale=None, v_scale=None):
    """Partial-softmax paged decode oracle for pool-sharded serving.

    Identical math to :func:`paged_attention_ref` except keys are *also*
    masked where their table entry's ``block_mask`` is False (a shard
    attends only the pages it holds), and the per-(b, head) fp32
    log-sum-exp comes back alongside the locally-normalized output —
    ``(o, lse)`` with o (B, H, hd) fp32, lse (B, H). A row that attended
    nothing has o = 0 and lse <= -1e30 (zero weight in the stitch). With a
    full mask, o equals ``paged_attention_ref`` bit for bit (same op
    order) before the final q.dtype cast.
    """
    B, H, hd = q.shape
    _, bs, K, _ = k_pages.shape
    G = H // K
    scale = hd ** -0.5 if scale is None else scale
    k = _gather_pages(k_pages, k_scale, block_tables).reshape(B, -1, K, hd)
    v = _gather_pages(v_pages, v_scale, block_tables).reshape(B, -1, K, hd)
    S = k.shape[1]
    qg = q.reshape(B, G, K, hd)
    logits = jnp.einsum("bgkh,bskh->bgks", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if cap is not None:
        logits = cap * jnp.tanh(logits / cap)
    k_pos = jnp.arange(S)
    ok = k_pos[None, :] < ctx_lens[:, None]                   # (B, S)
    if window is not None:
        ok &= k_pos[None, :] > ctx_lens[:, None] - 1 - window
    ok &= jnp.repeat(block_mask.astype(bool), bs, axis=1)     # shard-local
    logits = jnp.where(ok[:, None, None, :], logits, -1e30)
    mx = logits.max(axis=-1)
    p = jnp.exp(logits - mx[..., None])
    p = jnp.where(ok[:, None, None, :], p, 0.0)
    sm = jnp.maximum(p.sum(axis=-1), 1e-37)
    lse = mx + jnp.log(sm)
    p = (p / sm[..., None]).astype(v.dtype)
    o = jnp.einsum("bgks,bskh->bgkh", p, v,
                   preferred_element_type=jnp.float32)
    return (o.reshape(B, H, hd).astype(jnp.float32),
            lse.reshape(B, H))


def paged_shard_attention_ref(q, k_pages, v_pages, block_tables, ctx_lens,
                              n_shards, *, window=None, cap=None,
                              scale=None, k_scale=None, v_scale=None):
    """LSE-stitch oracle for pool-sharded paged decode attention.

    Simulates ``n_shards`` shards that each hold a disjoint subset of a
    sequence's pages (table entry j belongs to shard ``j % n_shards`` —
    the round-robin stand-in for by-pool-residence ownership), computes
    each shard's partial softmax attention, and stitches the partials with
    the same max/LSE combine ``models.attention.decode_attention`` uses
    for dense flash-decode:

        m   = max_i lse_i
        o   = sum_i o_i * exp(lse_i - m) / sum_i exp(lse_i - m)

    Must agree with :func:`paged_attention_ref` for every n_shards — the
    property the stitch tests pin. Raises ValueError for n_shards < 1.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards={n_shards} must be >= 1")
    B, nb = block_tables.shape
    entry = jnp.arange(nb)[None, :]
    os, lses = [], []
    for s in range(n_shards):
        mask = jnp.broadcast_to(entry % n_shards == s, (B, nb))
        o, lse = paged_attention_partial_ref(
            q, k_pages, v_pages, block_tables, ctx_lens, mask,
            window=window, cap=cap, scale=scale,
            k_scale=k_scale, v_scale=v_scale)
        os.append(o)
        lses.append(lse)
    os, lses = jnp.stack(os), jnp.stack(lses)         # (S, B, H, [hd])
    m = lses.max(axis=0)
    w = jnp.exp(lses - m[None])
    den = jnp.maximum(w.sum(axis=0), 1e-37)
    out = (os * w[..., None]).sum(axis=0) / den[..., None]
    return out.astype(q.dtype)


def paged_prefill_attention_ref(q, k_pages, v_pages, block_tables, ctx_lens,
                                q_lens, *, window=None, cap=None, scale=None,
                                k_scale=None, v_scale=None):
    """Multi-query (chunked-prefill) paged attention oracle.

    q: (B, C, H, hd) — row i of sequence b is the query at absolute
    position ``ctx_lens[b] - q_lens[b] + i`` and attends causally to keys
    ``[0, position]`` gathered through the block table (the chunk's own KV
    is assumed already scattered into the pages). Rows at i >= q_lens[b]
    are padding and produce zeros. q_lens == 1 reduces to the decode
    oracle above.
    """
    B, C, H, hd = q.shape
    _, bs, K, _ = k_pages.shape
    G = H // K
    scale = hd ** -0.5 if scale is None else scale
    k = _gather_pages(k_pages, k_scale, block_tables).reshape(B, -1, K, hd)
    v = _gather_pages(v_pages, v_scale, block_tables).reshape(B, -1, K, hd)
    S = k.shape[1]
    qg = q.reshape(B, C, G, K, hd)
    logits = jnp.einsum("bcgkh,bskh->bcgks", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if cap is not None:
        logits = cap * jnp.tanh(logits / cap)
    q_pos = (ctx_lens - q_lens)[:, None] + jnp.arange(C)[None]      # (B, C)
    k_pos = jnp.arange(S)
    ok = k_pos[None, None] <= q_pos[..., None]                      # causal
    if window is not None:
        ok &= k_pos[None, None] > q_pos[..., None] - window
    ok &= (jnp.arange(C)[None] < q_lens[:, None])[..., None]        # padding
    ok = ok[:, :, None, None, :]                                    # g,k dims
    logits = jnp.where(ok, logits, -1e30)
    mx = logits.max(axis=-1)
    p = jnp.exp(logits - mx[..., None])
    p = jnp.where(ok, p, 0.0)             # fully-masked rows -> all zero
    sm = jnp.maximum(p.sum(axis=-1), 1e-37)
    p = (p / sm[..., None]).astype(v.dtype)   # normalize-then-cast; see
    o = jnp.einsum("bcgks,bskh->bcgkh", p, v,  # paged_attention_ref
                   preferred_element_type=jnp.float32)
    return o.reshape(B, C, H, hd).astype(q.dtype)


def ragged_paged_prefill_attention_ref(q, k_pages, v_pages, block_tables,
                                       ctx_lens, starts, ends, row_seq, *,
                                       window=None, cap=None, scale=None,
                                       k_scale=None, v_scale=None):
    """Packed (ragged) multi-sequence chunked-prefill oracle.

    q: (T, H, hd) — chunks of up to S sequences packed into one flat token
    batch; sequence s owns flat rows [starts[s], ends[s]) and row_seq maps
    each flat row to its owner. Flat row t (owned by s) is the query at
    absolute position ``ctx_lens[s] - (ends[s] - starts[s]) + (t -
    starts[s])`` and attends causally to sequence s's keys gathered through
    block_tables[s] (the chunk's own KV assumed already scattered). Rows
    owned by no sequence (t outside every [start, end)) produce zeros.
    S == 1 with starts = [0] reduces to ``paged_prefill_attention_ref``
    with B == 1.
    """
    T, H, hd = q.shape
    _, bs, K, _ = k_pages.shape
    G = H // K
    S = starts.shape[0]
    scale = hd ** -0.5 if scale is None else scale
    k = _gather_pages(k_pages, k_scale,
                      block_tables).reshape(S, -1, K, hd)  # (S, E, K, hd)
    v = _gather_pages(v_pages, v_scale, block_tables).reshape(S, -1, K, hd)
    E = k.shape[1]
    qg = q.reshape(T, G, K, hd)
    logits = jnp.einsum("tgkh,sekh->tgkse", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if cap is not None:
        logits = cap * jnp.tanh(logits / cap)
    t = jnp.arange(T)
    own = (t[:, None] >= starts[None]) & (t[:, None] < ends[None]) \
        & (row_seq[:, None] == jnp.arange(S)[None])                  # (T, S)
    q_pos = (ctx_lens - (ends - starts))[row_seq] + (t - starts[row_seq])
    k_pos = jnp.arange(E)
    ok = own[:, :, None] & (k_pos[None, None] <= q_pos[:, None, None])
    if window is not None:
        ok &= k_pos[None, None] > q_pos[:, None, None] - window
    ok = ok[:, None, None]                                # (T, 1, 1, S, E)
    logits = jnp.where(ok, logits, -1e30)
    # one softmax over the flattened (sequence, key) axes: exactly one
    # sequence is unmasked per row, so this is that sequence's softmax
    flat = logits.reshape(T, G, K, S * E)
    okf = ok.reshape(T, 1, 1, S * E)
    mx = flat.max(axis=-1)
    p = jnp.exp(flat - mx[..., None])
    p = jnp.where(okf, p, 0.0)            # unowned rows -> all zero
    sm = jnp.maximum(p.sum(axis=-1), 1e-37)
    p = (p / sm[..., None]).astype(v.dtype)   # normalize-then-cast; see
    o = jnp.einsum("tgkf,fkh->tgkh",          # paged_attention_ref
                   p, v.reshape(S * E, K, hd),
                   preferred_element_type=jnp.float32)
    return o.reshape(T, H, hd).astype(q.dtype)


def ssd_ref(x, dt, A, B, C, h0=None):
    """Exact SSD recurrence, step by step (lax.scan over time).

    x: (b,S,nh,hp); dt: (b,S,nh); A: (nh,); B,C: (b,S,G,N).
    Returns (y (b,S,nh,hp), h_last (b,nh,hp,N)).
    """
    b, S, nh, hp = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = nh // G
    x32 = x.astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)   # (b,S,nh,N)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp
        dec = jnp.exp(dtt * A)                            # (b,nh)
        h = h * dec[..., None, None] + jnp.einsum(
            "bh,bhs,bhp->bhps", dtt, Bt, xt)
        y = jnp.einsum("bhs,bhps->bhp", Ct, h)
        return h, y

    h_init = (jnp.zeros((b, nh, hp, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    h_last, ys = jax.lax.scan(
        step, h_init,
        (x32.transpose(1, 0, 2, 3), dt32.transpose(1, 0, 2),
         Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3)))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h_last


def sampled_softmax_loss_ref(x, table, labels, sampled_ids, *, cap=None):
    """Sampled softmax (paper §4.2/§6.4). Per-token loss over the true class
    + a shared set of sampled false classes.

    x: (T, d); table: (V, d); labels: (T,); sampled_ids: (S,).
    Returns mean loss (scalar, fp32). No sampling-correction term (uniform
    proposal, matching the paper's microbenchmark usage).
    """
    x32 = x.astype(jnp.float32)
    w_true = table[labels].astype(jnp.float32)            # (T, d)
    w_samp = table[sampled_ids].astype(jnp.float32)       # (S, d)
    logit_true = jnp.sum(x32 * w_true, axis=-1)           # (T,)
    logit_samp = x32 @ w_samp.T                           # (T, S)
    if cap is not None:
        logit_true = cap * jnp.tanh(logit_true / cap)
        logit_samp = cap * jnp.tanh(logit_samp / cap)
    # mask accidental hits (sampled id == true label)
    hit = sampled_ids[None, :] == labels[:, None]
    logit_samp = jnp.where(hit, -1e30, logit_samp)
    allz = jnp.concatenate([logit_true[:, None], logit_samp], axis=1)
    lse = jax.scipy.special.logsumexp(allz, axis=1)
    return jnp.mean(lse - logit_true)


def softmax_xent_ref(logits, labels):
    """Full-softmax cross entropy oracle. logits: (T, V); labels: (T,)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    true = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - true)
