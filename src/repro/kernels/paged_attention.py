"""Paged-attention decode as a Pallas TPU kernel (vLLM-style).

One query token per sequence attends to a KV cache that lives in fixed-size
*blocks* scattered through two page pools shaped
``(num_blocks, block_size, K, hd)``. A per-sequence *block table* names the
pool rows holding that sequence's KV, in order; the serving block manager
(``repro.serving.kv_cache``) owns the tables and the free list.

Layout: grid = (B * K, max_blocks_per_seq) — one program per (sequence,
kv-head) pair, with the kv-block index as the minormost (sequential) dim so
an (m, l, acc) streaming-softmax state survives across blocks in VMEM
scratch, exactly like ``flash_attention.py``. The block table and the
context lengths are *scalar-prefetched* so the BlockSpec index maps can
gather the right pool row per grid step — the pages are never densified.
The B*K axis is megacore-partitioned (``dimension_semantics`` marks it
"parallel"); the block axis stays "arbitrary" because the scratch
accumulator is carried across it.

``pages_per_compute_block`` batches several KV pages into one grid step:
the kernel takes P separate (k, v) page operands — pool rows named by a
block table are not contiguous, so each page needs its own BlockSpec index
map — concatenates them into a (P*block_size, hd) tile and runs one matmul
over it, cutting grid steps (and per-step DMA turnarounds) by P. P == 1
reproduces the single-page kernel bit-for-bit.

GQA uses the repo-wide g-major convention: q head h reads kv head h % K,
so q is regrouped to (B*K, G, hd) and each program computes all G query
heads of its kv head. Blocks wholly past the context length are skipped via
``pl.when``; a sequence with ctx_len == 0 (inactive serving slot) produces
zeros. ``interpret=True`` runs the same kernel on CPU for tests.

``paged_prefill_attention`` is the multi-query sibling for chunked prefill:
C chunk queries per sequence, each causally masked at its absolute position
against the same paged context (C == 1 reproduces the decode kernel
exactly). The serving engine uses it to stream long prompts in while other
sequences keep decoding.

``ragged_paged_prefill_attention`` packs chunks of *several* sequences into
one flat (T, H, hd) batch (per-sequence [start, end) row offsets, scalar-
prefetched) so one jitted step can prefill many short prompts at once, and
can optionally fuse the chunk's KV scatter into the same kernel via aliased
page-pool outputs. See the function docstring for the layout contract.

Both fixed-shape kernels expose a *partial-softmax return path* for
pool-sharded (multi-host) serving: with ``block_mask`` a shard attends only
the table entries whose pages it holds (a shard-local block table — masked
entries are skipped entirely, never read), and with ``return_lse=True`` it
also returns each row's log-sum-exp so partials from different shards
stitch exactly like ``models.attention.decode_attention`` stitches dense
flash-decode: ``o = Σ o_i·exp(lse_i - m) / Σ exp(lse_i - m)``. The stitch
combiner lives in ``models.attention.stitch_paged_partials``; the oracle
proving the math is ``kernels.ref.paged_shard_attention_ref``. The
kv-head-sharded engine path (docs/multi-host.md) needs no stitch — each
model shard owns whole kv heads — so this path is the substrate for
sharding the *blocks* axis past the kv-head count.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _dequant_tile(x, s):
    """Fused per-row dequant of a gathered page tile: (bs, hd) narrow x
    (bs, 1) fp32 scale -> bf16 -> fp32. The bf16 round-trip matches
    ``quant.dequantize_kv`` exactly, so kernels and oracles attend
    bit-identical operands."""
    return (x.astype(jnp.float32) * s).astype(jnp.bfloat16) \
        .astype(jnp.float32)


def _decode_kernel(bt_ref, ctx_ref, mask_ref, q_ref, *rest, scale, cap,
                   window, block_size, num_kv_heads, pages_per_block,
                   table_width, with_lse, with_scales):
    P = pages_per_block
    k_refs, v_refs = rest[:P], rest[P:2 * P]
    rest = rest[2 * P:]
    ks_refs = vs_refs = None
    if with_scales:
        ks_refs, vs_refs = rest[:P], rest[P:2 * P]
        rest = rest[2 * P:]
    o_ref = rest[0]
    tail = rest[1:]
    if with_lse:
        lse_ref, m_scr, l_scr, acc_scr = tail
    else:
        m_scr, l_scr, acc_scr = tail
    bk = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    b = bk // num_kv_heads
    ctx = ctx_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    first_k = j * (P * block_size)
    # per-page liveness; the step runs if any of its P pages is live
    lives = []
    for i in range(P):
        entry = j * P + i
        seg_first = first_k + i * block_size
        li = (seg_first < ctx) & \
            (mask_ref[b, jnp.minimum(entry, table_width - 1)] != 0)
        if P > 1:
            li &= entry < table_width
        if window is not None:
            li &= seg_first + block_size - 1 > ctx - 1 - window
        lives.append(li)
    live = functools.reduce(lambda a, c: a | c, lives)

    @pl.when(live)
    def _compute():
        q = q_ref[...].astype(jnp.float32)              # (G, hd)
        if with_scales:
            k = jnp.concatenate(
                [_dequant_tile(r[...], sr[...])
                 for r, sr in zip(k_refs, ks_refs)], axis=0)
        else:
            k = jnp.concatenate(
                [r[...] for r in k_refs], axis=0).astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (G, P*block_size)
        if cap is not None:
            s = cap * jnp.tanh(s / cap)
        k_pos = first_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = k_pos < ctx
        if window is not None:
            mask &= k_pos > ctx - 1 - window
        if P > 1:
            # columns of dead pages (past the table, masked out, or wholly
            # past ctx) carry redirected/garbage KV — mask them out
            col_ok = jnp.concatenate(
                [jnp.broadcast_to(li, (block_size,)) for li in lives])
            mask &= col_ok[None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        m_scr[...] = m_new
        if with_scales:
            v = jnp.concatenate(
                [_dequant_tile(r[...], sr[...])
                 for r, sr in zip(v_refs, vs_refs)], axis=0)
        else:
            v = jnp.concatenate(
                [r[...] for r in v_refs], axis=0).astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nj - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-37)
        o_ref[...] = (acc_scr[...] / l).astype(o_ref.dtype)
        if with_lse:
            lse_ref[...] = m_scr[...] + jnp.log(l)


def _head_major(o, B, K, G):
    """(B*K, G, ...) -> g-major (B, G, K, ...) -> (B, H, ...)."""
    tail = o.shape[2:]
    o = o.reshape(B, K, G, *tail)
    perm = (0, 2, 1) + tuple(range(3, o.ndim))
    return o.transpose(*perm).reshape(B, G * K, *tail)


def _page_specs(nb, P, K, block_size, hd, n_extra_scalars):
    """P (k, v) BlockSpecs, each fetching table entry j*P + i.

    Entries past the table width (last grid step when P does not divide
    nb) and block-masked entries redirect the fetch to pool row 0 so a
    shard neither reads nor DMAs pages it does not hold; the kernel's
    per-page liveness masks their columns.
    """
    def mk(i):
        def page_index(bk, j, bt_ref, ctx_ref, *extra):
            mask_ref = extra[n_extra_scalars]
            b = bk // K
            entry = jnp.minimum(j * P + i, nb - 1)
            ok = (j * P + i < nb) & (mask_ref[b, entry] != 0)
            return (jnp.where(ok, bt_ref[b, entry], 0), 0, bk % K, 0)
        return page_index

    return [pl.BlockSpec((None, block_size, None, hd), mk(i))
            for i in range(P)]


def paged_attention(q, k_pages, v_pages, block_tables, ctx_lens, *,
                    window=None, cap=None, scale=None, interpret=False,
                    block_mask=None, return_lse=False,
                    pages_per_compute_block=1,
                    k_scale=None, v_scale=None):
    """q: (B, H, hd) one decode token per sequence.
    k_pages/v_pages: (num_blocks, block_size, K, hd).
    block_tables: (B, max_blocks_per_seq) int32 pool-row ids (padding rows
    are ignored past ctx). ctx_lens: (B,) int32 — tokens visible per
    sequence, 0 for an inactive slot (output row is zeros).
    Returns (B, H, hd) in q.dtype.

    ``pages_per_compute_block`` fetches that many KV pages per grid step
    (one matmul over the concatenated tile); 1 reproduces the single-page
    kernel bit-for-bit, larger values cut the grid (and DMA turnarounds)
    by the same factor at identical math up to fp reduction order.

    ``block_mask`` (B, max_blocks_per_seq) selects the table entries this
    shard holds pages for (None = all): masked entries are skipped, never
    read — the shard-local-table path for pool-sharded serving. With
    ``return_lse`` the output switches to fp32 partials ``(o, lse)`` —
    o the locally-normalized output, lse the per-(b, head) log-sum-exp of
    the attended (masked, in-context) keys — ready for
    ``models.attention.stitch_paged_partials`` (rounding o to q.dtype
    before the stitch would make the result shard-count-dependent). Rows
    that attended nothing return lse <= NEG_INF (zero stitch weight).

    ``k_scale``/``v_scale`` ((num_blocks, block_size, K, 1) fp32) mark a
    quantized pool: each fetched page tile is dequantized in-VMEM (the
    ``quant.dequantize_kv`` bf16 round-trip) before the matmuls — the
    pool itself is never widened.
    """
    B, H, hd = q.shape
    _, block_size, K, _ = k_pages.shape
    G = H // K
    nb = block_tables.shape[1]
    P = max(1, min(int(pages_per_compute_block), nb))
    scale = hd ** -0.5 if scale is None else scale
    with_scales = k_scale is not None
    if block_mask is None:
        block_mask = jnp.ones((B, nb), jnp.int32)

    # g-major regroup: (B, H, hd) -> (B, G, K, hd) -> (B*K, G, hd)
    qg = q.reshape(B, G, K, hd).transpose(0, 2, 1, 3).reshape(B * K, G, hd)

    kernel = functools.partial(
        _decode_kernel, scale=scale, cap=cap, window=window,
        block_size=block_size, num_kv_heads=K, pages_per_block=P,
        table_width=nb, with_lse=return_lse, with_scales=with_scales)

    out_specs = pl.BlockSpec((None, G, hd), lambda bk, j, *_: (bk, 0, 0))
    if return_lse:
        # partials stay fp32: they are re-weighted by exp(lse - m) in the
        # stitch, and rounding them to q.dtype first would make the
        # stitched result depend on the shard count
        out_specs = (out_specs,
                     pl.BlockSpec((None, G, 1), lambda bk, j, *_: (bk, 0, 0)))
        out_shape = (jax.ShapeDtypeStruct((B * K, G, hd), jnp.float32),
                     jax.ShapeDtypeStruct((B * K, G, 1), jnp.float32))
    else:
        out_shape = jax.ShapeDtypeStruct((B * K, G, hd), q.dtype)

    page_specs = _page_specs(nb, P, K, block_size, hd, n_extra_scalars=0)
    scale_specs, scale_operands = [], []
    if with_scales:
        scale_specs = 2 * _page_specs(nb, P, K, block_size, 1,
                                      n_extra_scalars=0)
        scale_operands = [k_scale] * P + [v_scale] * P
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B * K, pl.cdiv(nb, P)),
        in_specs=[
            pl.BlockSpec((None, G, hd), lambda bk, j, *_: (bk, 0, 0)),
            *page_specs,
            *page_specs,
            *scale_specs,
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )

    o = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(block_tables.astype(jnp.int32), ctx_lens.astype(jnp.int32),
      block_mask.astype(jnp.int32), qg,
      *([k_pages] * P), *([v_pages] * P), *scale_operands)

    if return_lse:
        o, lse = o
        return (_head_major(o, B, K, G),
                _head_major(lse[..., 0], B, K, G))
    return _head_major(o, B, K, G)


def _chunk_kernel(bt_ref, ctx_ref, qlen_ref, mask_ref, q_ref, *rest, scale,
                  cap, window, block_size, num_kv_heads, num_groups,
                  pages_per_block, table_width, with_lse, with_scales):
    """Multi-query sibling of ``_decode_kernel`` for chunked prefill.

    One program owns all C chunk queries of one (sequence, kv-head) pair;
    queries are causally masked per absolute position against the paged
    context, so C == 1 reduces exactly to the decode kernel. Rows past
    ``q_len`` are padding: every key masked, and the masked-row guard in
    the streaming softmax (p zeroed where masked, not exp(0)) keeps their
    (l, acc) at zero so they finalize to zeros.
    """
    P = pages_per_block
    k_refs, v_refs = rest[:P], rest[P:2 * P]
    rest = rest[2 * P:]
    ks_refs = vs_refs = None
    if with_scales:
        ks_refs, vs_refs = rest[:P], rest[P:2 * P]
        rest = rest[2 * P:]
    o_ref = rest[0]
    tail = rest[1:]
    if with_lse:
        lse_ref, m_scr, l_scr, acc_scr = tail
    else:
        m_scr, l_scr, acc_scr = tail
    bk = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    b = bk // num_kv_heads
    ctx = ctx_ref[b]                 # visible tokens incl. the whole chunk
    qlen = qlen_ref[b]
    qstart = ctx - qlen              # absolute position of chunk row 0
    G = num_groups

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    first_k = j * (P * block_size)
    lives = []
    for i in range(P):
        entry = j * P + i
        seg_first = first_k + i * block_size
        li = (seg_first < ctx) & \
            (mask_ref[b, jnp.minimum(entry, table_width - 1)] != 0)
        if P > 1:
            li &= entry < table_width
        if window is not None:
            # earliest in-window key over the chunk: qstart - window + 1
            li &= seg_first + block_size - 1 > qstart - window
        lives.append(li)
    live = functools.reduce(lambda a, c: a | c, lives)

    @pl.when(live)
    def _compute():
        C = q_ref.shape[0]
        q = q_ref[...].astype(jnp.float32).reshape(C * G, -1)  # (C*G, hd)
        if with_scales:
            k = jnp.concatenate(
                [_dequant_tile(r[...], sr[...])
                 for r, sr in zip(k_refs, ks_refs)], axis=0)
        else:
            k = jnp.concatenate(
                [r[...] for r in k_refs], axis=0).astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (C*G, P*bs)
        if cap is not None:
            s = cap * jnp.tanh(s / cap)
        k_pos = first_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // G
        q_pos = qstart + row
        mask = (k_pos <= q_pos) & (row < qlen)
        if window is not None:
            mask &= k_pos > q_pos - window
        if P > 1:
            col_ok = jnp.concatenate(
                [jnp.broadcast_to(li, (block_size,)) for li in lives])
            mask &= col_ok[None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        # masked-row guard: exp(NEG_INF - NEG_INF) would be 1, poisoning
        # fully-masked (padding) rows — zero those probabilities instead
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        m_scr[...] = m_new
        if with_scales:
            v = jnp.concatenate(
                [_dequant_tile(r[...], sr[...])
                 for r, sr in zip(v_refs, vs_refs)], axis=0)
        else:
            v = jnp.concatenate(
                [r[...] for r in v_refs], axis=0).astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nj - 1)
    def _finalize():
        C = o_ref.shape[0]
        l = jnp.maximum(l_scr[...], 1e-37)
        o_ref[...] = (acc_scr[...] / l).astype(o_ref.dtype).reshape(
            C, G, -1)
        if with_lse:
            lse_ref[...] = (m_scr[...] + jnp.log(l)).reshape(C, G, 1)


def paged_prefill_attention(q, k_pages, v_pages, block_tables, ctx_lens,
                            q_lens, *, window=None, cap=None, scale=None,
                            interpret=False, block_mask=None,
                            return_lse=False, pages_per_compute_block=1,
                            k_scale=None, v_scale=None):
    """Chunked-prefill attention against a paged KV cache.

    q: (B, C, H, hd) — C chunk queries per sequence; row i sits at absolute
    position ``ctx_lens[b] - q_lens[b] + i`` and attends causally to the
    paged context (the chunk's own KV must already be scattered into the
    pages). q_lens: (B,) valid rows; padding rows produce zeros, as does a
    wholly inactive sequence (q_len == 0). Returns (B, C, H, hd) in q.dtype.

    ``pages_per_compute_block`` / ``block_mask`` / ``return_lse`` /
    ``k_scale``/``v_scale`` are as on :func:`paged_attention`; the lse
    output is (B, C, H) fp32.
    """
    B, C, H, hd = q.shape
    _, block_size, K, _ = k_pages.shape
    G = H // K
    nb = block_tables.shape[1]
    P = max(1, min(int(pages_per_compute_block), nb))
    scale = hd ** -0.5 if scale is None else scale
    with_scales = k_scale is not None
    if block_mask is None:
        block_mask = jnp.ones((B, nb), jnp.int32)

    # g-major regroup: (B,C,H,hd) -> (B,C,G,K,hd) -> (B*K, C, G, hd)
    qg = q.reshape(B, C, G, K, hd).transpose(0, 3, 1, 2, 4) \
        .reshape(B * K, C, G, hd)

    kernel = functools.partial(
        _chunk_kernel, scale=scale, cap=cap, window=window,
        block_size=block_size, num_kv_heads=K, num_groups=G,
        pages_per_block=P, table_width=nb, with_lse=return_lse,
        with_scales=with_scales)

    out_specs = pl.BlockSpec((None, C, G, hd),
                             lambda bk, j, *_: (bk, 0, 0, 0))
    if return_lse:
        # fp32 partials for the stitch; see paged_attention
        out_specs = (out_specs,
                     pl.BlockSpec((None, C, G, 1),
                                  lambda bk, j, *_: (bk, 0, 0, 0)))
        out_shape = (jax.ShapeDtypeStruct((B * K, C, G, hd), jnp.float32),
                     jax.ShapeDtypeStruct((B * K, C, G, 1), jnp.float32))
    else:
        out_shape = jax.ShapeDtypeStruct((B * K, C, G, hd), q.dtype)

    page_specs = _page_specs(nb, P, K, block_size, hd, n_extra_scalars=1)
    scale_specs, scale_operands = [], []
    if with_scales:
        scale_specs = 2 * _page_specs(nb, P, K, block_size, 1,
                                      n_extra_scalars=1)
        scale_operands = [k_scale] * P + [v_scale] * P
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B * K, pl.cdiv(nb, P)),
        in_specs=[
            pl.BlockSpec((None, C, G, hd),
                         lambda bk, j, *_: (bk, 0, 0, 0)),
            *page_specs,
            *page_specs,
            *scale_specs,
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((C * G, 1), jnp.float32),
            pltpu.VMEM((C * G, 1), jnp.float32),
            pltpu.VMEM((C * G, hd), jnp.float32),
        ],
    )

    o = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(block_tables.astype(jnp.int32), ctx_lens.astype(jnp.int32),
      q_lens.astype(jnp.int32), block_mask.astype(jnp.int32),
      qg, *([k_pages] * P), *([v_pages] * P), *scale_operands)

    def head_major(x):
        # (B*K, C, G, t) -> (B, K, C, G, t) -> (B, C, G, K, t) -> (B, C, H, t)
        t = x.shape[-1]
        return x.reshape(B, K, C, G, t).transpose(0, 2, 3, 1, 4) \
            .reshape(B, C, H, t)

    if return_lse:
        o, lse = o
        return head_major(o), head_major(lse)[..., 0]
    return head_major(o)


def _ragged_kernel(start_ref, end_ref, ctx_ref, bt_ref, q_ref, *rest,
                   scale, cap, window, block_size, num_kv_heads,
                   num_groups, pages_per_block, table_width, with_write,
                   with_scales):
    """Packed multi-sequence prefill over one flat (T, G, hd) query batch.

    Grid (K, S, cdiv(nb, P)): program (k, s, j) attends *all* T flat rows
    against kv pages [j*P, (j+1)*P) of packed sequence s, masking rows
    outside [start_s, end_s) — each row's (m, l, acc) state only ever
    advances while its owning sequence is being swept, so the streaming
    softmax per row sees exactly that sequence's keys. The output tile is
    indexed by k alone and stays VMEM-resident across (s, j); each
    sequence's finalize merges only its own rows (read-modify-write),
    rows owned by nobody stay zero.

    With ``with_write`` (P == 1 only — the aliased page outputs must be
    written exactly once per grid step) the chunk's own KV (flat, same
    row layout as q) rides along and each page fetched is *merged* —
    chunk rows whose absolute position lands in this page replace the
    stale pool rows via a (block_size, T) one-hot matmul — before the
    attention reads it, then written back through aliased page-pool
    outputs: the scatter that ``update_paged_cache_ragged`` does as a
    separate XLA pass is fused into the same kernel launch.

    With ``with_scales`` the pools are quantized: fetched page tiles
    dequantize in-VMEM through the per-row scale pages before attending.
    Combined with ``with_write`` the chunk KV arrives *already quantized*
    (and its scale rows already scattered into the scale pool, which the
    kernel's scale-page fetch then sees) — the one-hot merge shuffles
    narrow integer codes exactly (values ≤ qmax are exact in fp32).
    """
    P = pages_per_block
    k_refs, v_refs = rest[:P], rest[P:2 * P]
    rest = rest[2 * P:]
    if with_write:
        kc_ref, vc_ref = rest[:2]
        rest = rest[2:]
    ks_refs = vs_refs = None
    if with_scales:
        ks_refs, vs_refs = rest[:P], rest[P:2 * P]
        rest = rest[2 * P:]
    if with_write:
        o_ref, ko_ref, vo_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    s_id = pl.program_id(1)
    j = pl.program_id(2)
    nj = pl.num_programs(2)
    start = start_ref[s_id]
    end = end_ref[s_id]
    ctx = ctx_ref[s_id]
    qlen = end - start
    qstart = ctx - qlen              # absolute position of flat row `start`
    G = num_groups
    T = q_ref.shape[0]

    @pl.when((s_id == 0) & (j == 0))
    def _init_out():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(j == 0)
    def _init_scratch():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    first_k = j * (P * block_size)
    active = start < end
    # per-page liveness; the step runs if any of its P pages is live
    lives = []
    for i in range(P):
        entry = j * P + i
        seg_first = first_k + i * block_size
        li = (seg_first < ctx) & active
        if P > 1:
            li &= entry < table_width
        if window is not None:
            li &= seg_first + block_size - 1 > qstart - window
        lives.append(li)
    live = functools.reduce(lambda a, c: a | c, lives)

    if with_write:
        # fused chunk-KV scatter: merge this sequence's chunk rows whose
        # absolute position falls in this page, write the page back
        # (unchanged when no row lands here — dead/redirected pages too)
        p_col = first_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_size, 1), 0)                  # (bs, 1)
        in_chunk = (p_col >= qstart) & (p_col < ctx) & active
        t_col = start + (p_col - qstart)                    # flat row per col
        t_row = jax.lax.broadcasted_iota(
            jnp.int32, (block_size, T), 1)
        sel = ((t_col == t_row) & in_chunk).astype(jnp.float32)
        k_blk = jnp.where(
            in_chunk,
            jax.lax.dot_general(
                sel, kc_ref[...].astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32).astype(ko_ref.dtype),
            k_refs[0][...])
        v_blk = jnp.where(
            in_chunk,
            jax.lax.dot_general(
                sel, vc_ref[...].astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32).astype(vo_ref.dtype),
            v_refs[0][...])
        ko_ref[...] = k_blk
        vo_ref[...] = v_blk
        if with_scales:
            k_att = _dequant_tile(k_blk, ks_refs[0][...])
            v_att = _dequant_tile(v_blk, vs_refs[0][...])
        else:
            k_att = k_blk.astype(jnp.float32)
            v_att = v_blk.astype(jnp.float32)
    elif with_scales:
        k_att = jnp.concatenate(
            [_dequant_tile(r[...], sr[...])
             for r, sr in zip(k_refs, ks_refs)], axis=0)
        v_att = jnp.concatenate(
            [_dequant_tile(r[...], sr[...])
             for r, sr in zip(v_refs, vs_refs)], axis=0)
    else:
        k_att = jnp.concatenate(
            [r[...] for r in k_refs], axis=0).astype(jnp.float32)
        v_att = jnp.concatenate(
            [r[...] for r in v_refs], axis=0).astype(jnp.float32)

    @pl.when(live)
    def _compute():
        q = q_ref[...].astype(jnp.float32).reshape(T * G, -1)  # (T*G, hd)
        s = jax.lax.dot_general(
            q, k_att, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (T*G, P*bs)
        if cap is not None:
            s = cap * jnp.tanh(s / cap)
        k_pos = first_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // G
        q_pos = qstart + (row - start)
        mask = (row >= start) & (row < end) & (k_pos <= q_pos)
        if window is not None:
            mask &= k_pos > q_pos - window
        if P > 1:
            # columns of dead pages (past the table or wholly past ctx)
            # carry redirected/garbage KV — mask them out
            col_ok = jnp.concatenate(
                [jnp.broadcast_to(li, (block_size,)) for li in lives])
            mask &= col_ok[None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        # masked-row guard as in _chunk_kernel: rows outside this
        # sequence must not accumulate
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v_att, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when((j == nj - 1) & active)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-37)
        res = (acc_scr[...] / l).astype(o_ref.dtype).reshape(T, G, -1)
        row = jax.lax.broadcasted_iota(jnp.int32, (T, 1, 1), 0)
        mine = (row >= start) & (row < end)
        o_ref[...] = jnp.where(mine, res, o_ref[...])


def ragged_paged_prefill_attention(q, k_pages, v_pages, block_tables,
                                   ctx_lens, starts, ends, *, k_new=None,
                                   v_new=None, window=None, cap=None,
                                   scale=None, interpret=False,
                                   pages_per_compute_block=1,
                                   k_scale=None, v_scale=None):
    """Packed (ragged) chunked-prefill attention against a paged KV cache.

    q: (T, H, hd) — chunks of up to S sequences packed back to back into
    one flat token batch. Sequence s owns flat rows [starts[s], ends[s]);
    its row i sits at absolute position ``ctx_lens[s] - (ends[s] -
    starts[s]) + i`` and attends causally to that sequence's paged context
    (block_tables: (S, max_blocks_per_seq); ctx_lens counts the chunk
    itself). ``starts[s] == ends[s]`` marks an unused pack slot; flat rows
    owned by no sequence produce zeros. Returns (T, H, hd) in q.dtype.

    With ``k_new``/``v_new`` ((T, K, hd), same flat row layout as q) the
    chunk's KV scatter is *fused*: the kernel merges chunk rows into each
    page it fetches before attending and writes the pages back in place
    (aliased outputs), returning ``(o, k_pages, v_pages)``. Without them
    the pages must already contain the chunk KV and only ``o`` returns.

    ``pages_per_compute_block`` batches P pages per grid step on the
    *non-fused* path only — the fused write pins P == 1 because each
    aliased page output must be produced exactly once per grid step, and
    revisiting an output block across a wider step would clobber pages
    the merge did not fetch. ``k_scale``/``v_scale`` mark quantized
    pools as on :func:`paged_attention`; with the fused write the chunk
    KV must arrive already quantized with its scale rows already
    scattered into the scale pools (``models.attention`` does both).
    """
    T, H, hd = q.shape
    _, block_size, K, _ = k_pages.shape
    G = H // K
    S = starts.shape[0]
    nb = block_tables.shape[1]
    scale = hd ** -0.5 if scale is None else scale
    with_write = k_new is not None
    if with_write and v_new is None:
        raise ValueError("k_new and v_new must be given together")
    with_scales = k_scale is not None
    # fused write pins P=1: an aliased page output must be written exactly
    # once, by the single grid step that fetched that page
    P = 1 if with_write else max(1, min(int(pages_per_compute_block), nb))

    # g-major regroup: (T, H, hd) -> (T, G, K, hd) -> (K, T, G, hd)
    qg = q.reshape(T, G, K, hd).transpose(2, 0, 1, 3)

    def mk_page_spec(i, hd_):
        def idx(k, s, j, starts_ref, ends_ref, ctx_ref, bt_ref):
            # entries past the table width or wholly past the context
            # redirect to pool row 0 (never attended: liveness skips them)
            entry = jnp.minimum(j * P + i, nb - 1)
            ok = (j * P + i < nb) & (entry * block_size < ctx_ref[s])
            return (jnp.where(ok, bt_ref[s, entry], 0), 0, k, 0)
        return pl.BlockSpec((None, block_size, None, hd_), idx)

    kernel = functools.partial(
        _ragged_kernel, scale=scale, cap=cap, window=window,
        block_size=block_size, num_kv_heads=K, num_groups=G,
        pages_per_block=P, table_width=nb, with_write=with_write,
        with_scales=with_scales)

    q_spec = pl.BlockSpec((None, T, G, hd), lambda k, s, j, *_: (k, 0, 0, 0))
    page_specs = [mk_page_spec(i, hd) for i in range(P)]
    in_specs = [q_spec, *page_specs, *page_specs]
    operands = [qg, *([k_pages] * P), *([v_pages] * P)]
    out_specs = [pl.BlockSpec((None, T, G, hd),
                              lambda k, s, j, *_: (k, 0, 0, 0))]
    out_shape = [jax.ShapeDtypeStruct((K, T, G, hd), q.dtype)]
    aliases = {}
    if with_write:
        new_spec = pl.BlockSpec((T, None, hd), lambda k, s, j, *_: (0, k, 0))
        in_specs += [new_spec, new_spec]
        operands += [k_new, v_new]
        out_specs += [page_specs[0], page_specs[0]]
        out_shape += [jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
                      jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype)]
        # flattened operand order: 4 prefetched scalars, q, k_pages,
        # v_pages, k_new, v_new[, k_scale, v_scale] -> pages alias the
        # page outputs in place
        aliases = {5: 1, 6: 2}
    if with_scales:
        scale_page_specs = [mk_page_spec(i, 1) for i in range(P)]
        in_specs += [*scale_page_specs, *scale_page_specs]
        operands += [*([k_scale] * P), *([v_scale] * P)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(K, S, pl.cdiv(nb, P)),
        in_specs=in_specs,
        out_specs=tuple(out_specs) if with_write else out_specs[0],
        scratch_shapes=[
            pltpu.VMEM((T * G, 1), jnp.float32),
            pltpu.VMEM((T * G, 1), jnp.float32),
            pltpu.VMEM((T * G, hd), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=tuple(out_shape) if with_write else out_shape[0],
        interpret=interpret,
        input_output_aliases=aliases,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
    )(starts.astype(jnp.int32), ends.astype(jnp.int32),
      ctx_lens.astype(jnp.int32), block_tables.astype(jnp.int32),
      *operands)

    def flat_head_major(o):
        # (K, T, G, hd) -> (T, G, K, hd) -> (T, H, hd)
        return o.transpose(1, 2, 0, 3).reshape(T, H, hd)

    if with_write:
        o, kc, vc = out
        return flat_head_major(o), kc, vc
    return flat_head_major(out)
