"""Paged-attention decode as a Pallas TPU kernel (vLLM-style).

One query token per sequence attends to a KV cache that lives in fixed-size
*blocks* scattered through two page pools shaped
``(num_blocks, block_size, K, hd)``. A per-sequence *block table* names the
pool rows holding that sequence's KV, in order; the serving block manager
(``repro.serving.kv_cache``) owns the tables and the free list.

Layout: grid = (B * K, max_blocks_per_seq) — one program per (sequence,
kv-head) pair, with the kv-block index as the minormost (sequential) dim so
an (m, l, acc) streaming-softmax state survives across blocks in VMEM
scratch, exactly like ``flash_attention.py``. The block table and the
context lengths are *scalar-prefetched* so the BlockSpec index maps can
gather the right pool row per grid step — the pages are never densified.

GQA uses the repo-wide g-major convention: q head h reads kv head h % K,
so q is regrouped to (B*K, G, hd) and each program computes all G query
heads of its kv head. Blocks wholly past the context length are skipped via
``pl.when``; a sequence with ctx_len == 0 (inactive serving slot) produces
zeros. ``interpret=True`` runs the same kernel on CPU for tests.

``paged_prefill_attention`` is the multi-query sibling for chunked prefill:
C chunk queries per sequence, each causally masked at its absolute position
against the same paged context (C == 1 reproduces the decode kernel
exactly). The serving engine uses it to stream long prompts in while other
sequences keep decoding.

Both kernels expose a *partial-softmax return path* for pool-sharded
(multi-host) serving: with ``block_mask`` a shard attends only the table
entries whose pages it holds (a shard-local block table — masked entries
are skipped entirely, never read), and with ``return_lse=True`` it also
returns each row's log-sum-exp so partials from different shards stitch
exactly like ``models.attention.decode_attention`` stitches dense
flash-decode: ``o = Σ o_i·exp(lse_i - m) / Σ exp(lse_i - m)``. The stitch
combiner lives in ``models.attention.stitch_paged_partials``; the oracle
proving the math is ``kernels.ref.paged_shard_attention_ref``. The
kv-head-sharded engine path (docs/multi-host.md) needs no stitch — each
model shard owns whole kv heads — so this path is the substrate for
sharding the *blocks* axis past the kv-head count.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _decode_kernel(bt_ref, ctx_ref, mask_ref, q_ref, k_ref, v_ref, o_ref,
                   *rest, scale, cap, window, block_size, num_kv_heads,
                   with_lse):
    if with_lse:
        lse_ref, m_scr, l_scr, acc_scr = rest
    else:
        m_scr, l_scr, acc_scr = rest
    bk = pl.program_id(0)
    j = pl.program_id(1)
    nb = pl.num_programs(1)
    b = bk // num_kv_heads
    ctx = ctx_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    first_k = j * block_size
    live = (first_k < ctx) & (mask_ref[b, j] != 0)
    if window is not None:
        live &= first_k + block_size - 1 > ctx - 1 - window

    @pl.when(live)
    def _compute():
        q = q_ref[...].astype(jnp.float32)              # (G, hd)
        k = k_ref[...].astype(jnp.float32)              # (block_size, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (G, block_size)
        if cap is not None:
            s = cap * jnp.tanh(s / cap)
        k_pos = first_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = k_pos < ctx
        if window is not None:
            mask &= k_pos > ctx - 1 - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        m_scr[...] = m_new
        v = v_ref[...].astype(jnp.float32)              # (block_size, hd)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nb - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-37)
        o_ref[...] = (acc_scr[...] / l).astype(o_ref.dtype)
        if with_lse:
            lse_ref[...] = m_scr[...] + jnp.log(l)


def _head_major(o, B, K, G):
    """(B*K, G, ...) -> g-major (B, G, K, ...) -> (B, H, ...)."""
    tail = o.shape[2:]
    o = o.reshape(B, K, G, *tail)
    perm = (0, 2, 1) + tuple(range(3, o.ndim))
    return o.transpose(*perm).reshape(B, G * K, *tail)


def paged_attention(q, k_pages, v_pages, block_tables, ctx_lens, *,
                    window=None, cap=None, scale=None, interpret=False,
                    block_mask=None, return_lse=False):
    """q: (B, H, hd) one decode token per sequence.
    k_pages/v_pages: (num_blocks, block_size, K, hd).
    block_tables: (B, max_blocks_per_seq) int32 pool-row ids (padding rows
    are ignored past ctx). ctx_lens: (B,) int32 — tokens visible per
    sequence, 0 for an inactive slot (output row is zeros).
    Returns (B, H, hd) in q.dtype.

    ``block_mask`` (B, max_blocks_per_seq) selects the table entries this
    shard holds pages for (None = all): masked entries are skipped, never
    read — the shard-local-table path for pool-sharded serving. With
    ``return_lse`` the output switches to fp32 partials ``(o, lse)`` —
    o the locally-normalized output, lse the per-(b, head) log-sum-exp of
    the attended (masked, in-context) keys — ready for
    ``models.attention.stitch_paged_partials`` (rounding o to q.dtype
    before the stitch would make the result shard-count-dependent). Rows
    that attended nothing return lse <= NEG_INF (zero stitch weight).
    """
    B, H, hd = q.shape
    _, block_size, K, _ = k_pages.shape
    G = H // K
    nb = block_tables.shape[1]
    scale = hd ** -0.5 if scale is None else scale
    if block_mask is None:
        block_mask = jnp.ones((B, nb), jnp.int32)

    # g-major regroup: (B, H, hd) -> (B, G, K, hd) -> (B*K, G, hd)
    qg = q.reshape(B, G, K, hd).transpose(0, 2, 1, 3).reshape(B * K, G, hd)

    def page_index(bk, j, bt_ref, ctx_ref, mask_ref):
        # masked entries redirect the fetch to pool row 0 (never used —
        # the kernel's `live` guard skips their compute): a shard neither
        # reads nor DMAs pages it does not hold
        b = bk // K
        return (jnp.where(mask_ref[b, j] != 0, bt_ref[b, j], 0),
                0, bk % K, 0)

    kernel = functools.partial(
        _decode_kernel, scale=scale, cap=cap, window=window,
        block_size=block_size, num_kv_heads=K, with_lse=return_lse)

    out_specs = pl.BlockSpec((None, G, hd), lambda bk, j, *_: (bk, 0, 0))
    if return_lse:
        # partials stay fp32: they are re-weighted by exp(lse - m) in the
        # stitch, and rounding them to q.dtype first would make the
        # stitched result depend on the shard count
        out_specs = (out_specs,
                     pl.BlockSpec((None, G, 1), lambda bk, j, *_: (bk, 0, 0)))
        out_shape = (jax.ShapeDtypeStruct((B * K, G, hd), jnp.float32),
                     jax.ShapeDtypeStruct((B * K, G, 1), jnp.float32))
    else:
        out_shape = jax.ShapeDtypeStruct((B * K, G, hd), q.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B * K, nb),
        in_specs=[
            pl.BlockSpec((None, G, hd), lambda bk, j, *_: (bk, 0, 0)),
            pl.BlockSpec((None, block_size, None, hd), page_index),
            pl.BlockSpec((None, block_size, None, hd), page_index),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )

    o = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(block_tables.astype(jnp.int32), ctx_lens.astype(jnp.int32),
      block_mask.astype(jnp.int32), qg, k_pages, v_pages)

    if return_lse:
        o, lse = o
        return (_head_major(o, B, K, G),
                _head_major(lse[..., 0], B, K, G))
    return _head_major(o, B, K, G)


def _chunk_kernel(bt_ref, ctx_ref, qlen_ref, mask_ref, q_ref, k_ref, v_ref,
                  o_ref, *rest, scale, cap, window, block_size,
                  num_kv_heads, num_groups, with_lse):
    """Multi-query sibling of ``_decode_kernel`` for chunked prefill.

    One program owns all C chunk queries of one (sequence, kv-head) pair;
    queries are causally masked per absolute position against the paged
    context, so C == 1 reduces exactly to the decode kernel. Rows past
    ``q_len`` are padding: every key masked, and the masked-row guard in
    the streaming softmax (p zeroed where masked, not exp(0)) keeps their
    (l, acc) at zero so they finalize to zeros.
    """
    if with_lse:
        lse_ref, m_scr, l_scr, acc_scr = rest
    else:
        m_scr, l_scr, acc_scr = rest
    bk = pl.program_id(0)
    j = pl.program_id(1)
    nb = pl.num_programs(1)
    b = bk // num_kv_heads
    ctx = ctx_ref[b]                 # visible tokens incl. the whole chunk
    qlen = qlen_ref[b]
    qstart = ctx - qlen              # absolute position of chunk row 0
    G = num_groups

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    first_k = j * block_size
    live = (first_k < ctx) & (mask_ref[b, j] != 0)
    if window is not None:
        # earliest in-window key over the chunk: qstart - window + 1
        live &= first_k + block_size - 1 > qstart - window

    @pl.when(live)
    def _compute():
        C = q_ref.shape[0]
        q = q_ref[...].astype(jnp.float32).reshape(C * G, -1)  # (C*G, hd)
        k = k_ref[...].astype(jnp.float32)              # (block_size, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (C*G, block_size)
        if cap is not None:
            s = cap * jnp.tanh(s / cap)
        k_pos = first_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // G
        q_pos = qstart + row
        mask = (k_pos <= q_pos) & (row < qlen)
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        # masked-row guard: exp(NEG_INF - NEG_INF) would be 1, poisoning
        # fully-masked (padding) rows — zero those probabilities instead
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        m_scr[...] = m_new
        v = v_ref[...].astype(jnp.float32)              # (block_size, hd)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nb - 1)
    def _finalize():
        C = o_ref.shape[0]
        l = jnp.maximum(l_scr[...], 1e-37)
        o_ref[...] = (acc_scr[...] / l).astype(o_ref.dtype).reshape(
            C, G, -1)
        if with_lse:
            lse_ref[...] = (m_scr[...] + jnp.log(l)).reshape(C, G, 1)


def paged_prefill_attention(q, k_pages, v_pages, block_tables, ctx_lens,
                            q_lens, *, window=None, cap=None, scale=None,
                            interpret=False, block_mask=None,
                            return_lse=False):
    """Chunked-prefill attention against a paged KV cache.

    q: (B, C, H, hd) — C chunk queries per sequence; row i sits at absolute
    position ``ctx_lens[b] - q_lens[b] + i`` and attends causally to the
    paged context (the chunk's own KV must already be scattered into the
    pages). q_lens: (B,) valid rows; padding rows produce zeros, as does a
    wholly inactive sequence (q_len == 0). Returns (B, C, H, hd) in q.dtype.

    ``block_mask`` / ``return_lse`` are the shard-local-table and
    partial-softmax options described on :func:`paged_attention`; the lse
    output is (B, C, H) fp32.
    """
    B, C, H, hd = q.shape
    _, block_size, K, _ = k_pages.shape
    G = H // K
    nb = block_tables.shape[1]
    scale = hd ** -0.5 if scale is None else scale
    if block_mask is None:
        block_mask = jnp.ones((B, nb), jnp.int32)

    # g-major regroup: (B,C,H,hd) -> (B,C,G,K,hd) -> (B*K, C, G, hd)
    qg = q.reshape(B, C, G, K, hd).transpose(0, 3, 1, 2, 4) \
        .reshape(B * K, C, G, hd)

    def page_index(bk, j, bt_ref, ctx_ref, qlen_ref, mask_ref):
        b = bk // K                    # masked -> row 0; see paged_attention
        return (jnp.where(mask_ref[b, j] != 0, bt_ref[b, j], 0),
                0, bk % K, 0)

    kernel = functools.partial(
        _chunk_kernel, scale=scale, cap=cap, window=window,
        block_size=block_size, num_kv_heads=K, num_groups=G,
        with_lse=return_lse)

    out_specs = pl.BlockSpec((None, C, G, hd),
                             lambda bk, j, *_: (bk, 0, 0, 0))
    if return_lse:
        # fp32 partials for the stitch; see paged_attention
        out_specs = (out_specs,
                     pl.BlockSpec((None, C, G, 1),
                                  lambda bk, j, *_: (bk, 0, 0, 0)))
        out_shape = (jax.ShapeDtypeStruct((B * K, C, G, hd), jnp.float32),
                     jax.ShapeDtypeStruct((B * K, C, G, 1), jnp.float32))
    else:
        out_shape = jax.ShapeDtypeStruct((B * K, C, G, hd), q.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B * K, nb),
        in_specs=[
            pl.BlockSpec((None, C, G, hd),
                         lambda bk, j, *_: (bk, 0, 0, 0)),
            pl.BlockSpec((None, block_size, None, hd), page_index),
            pl.BlockSpec((None, block_size, None, hd), page_index),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((C * G, 1), jnp.float32),
            pltpu.VMEM((C * G, 1), jnp.float32),
            pltpu.VMEM((C * G, hd), jnp.float32),
        ],
    )

    o = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(block_tables.astype(jnp.int32), ctx_lens.astype(jnp.int32),
      q_lens.astype(jnp.int32), block_mask.astype(jnp.int32),
      qg, k_pages, v_pages)

    def head_major(x):
        # (B*K, C, G, t) -> (B, K, C, G, t) -> (B, C, G, K, t) -> (B, C, H, t)
        t = x.shape[-1]
        return x.reshape(B, K, C, G, t).transpose(0, 2, 3, 1, 4) \
            .reshape(B, C, H, t)

    if return_lse:
        o, lse = o
        return head_major(o), head_major(lse)[..., 0]
    return head_major(o)
