"""Sparse embedding-row gather as a Pallas TPU kernel (paper §4.2).

The paper's Gather op — "extracts a sparse set of rows from a tensor,
colocated with the variable it reads" — done TPU-style: token ids are
scalar-prefetched into SMEM and drive the BlockSpec index_map, so each grid
step DMAs exactly one (1 x d_model) table row HBM->VMEM. No one-hot matmul,
no full-table read: bytes moved = rows_touched x d x 2, which is the §6.2
"Sparse" curve's defining property (step cost independent of table size).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(ids_ref, table_ref, o_ref):
    o_ref[...] = table_ref[...]


def gather(table, ids, *, interpret=False):
    """table: (V, d); ids: int32 of any shape -> (*ids.shape, d)."""
    shape = ids.shape
    flat = ids.reshape(-1).astype(jnp.int32)
    T = flat.shape[0]
    d = table.shape[1]

    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(T,),
            in_specs=[pl.BlockSpec((1, d), lambda i, ids: (ids[i], 0))],
            out_specs=pl.BlockSpec((1, d), lambda i, ids: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((T, d), table.dtype),
        interpret=interpret,
    )(flat, table)
    return out.reshape(*shape, d)
