"""Compiled-HLO text analysis: collective bytes with while-loop trip-count
scaling.

XLA's ``cost_analysis`` counts a while body ONCE (verified empirically); a
scanned 64-layer model would under-report its collectives and flops by 64x.
This parser:

  1. splits the module into computations,
  2. builds the call graph (while -> body/cond, fusion/call -> computation),
  3. extracts the trip count of each while loop from its condition's
     ``compare(..., constant(N))`` (jax scans lower to counted loops),
  4. attributes every collective op (all-reduce / all-gather / reduce-scatter
     / all-to-all / collective-permute) to its computation and multiplies by
     the product of enclosing trip counts.

Bytes are *per-device shard bytes* (HLO shapes are already per-partition
under SPMD). Ring-cost scaling to link-seconds happens in roofline.py.
"""

from __future__ import annotations

import gzip
import re
from dataclasses import dataclass, field
from pathlib import Path

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\([^)]*\)\s*->", re.M)

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of one HLO shape string like 'bf16[4,128]' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveOp:
    kind: str
    bytes_out: int
    replica_groups: str
    computation: str
    trip_mult: int = 1
    name: str = ""
    dtype: str = ""

    @property
    def scaled_bytes(self) -> int:
        return self.bytes_out * self.trip_mult


@dataclass
class HloAnalysis:
    collectives: list[CollectiveOp] = field(default_factory=list)
    while_trips: dict[str, int] = field(default_factory=dict)
    flops_mult: float = 1.0   # Σ trip-weighted body share (informational)

    def total_bytes(self, kind: str | None = None) -> int:
        return sum(c.scaled_bytes for c in self.collectives
                   if kind is None or c.kind == kind)

    def by_kind(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for c in self.collectives:
            out[c.kind] = out.get(c.kind, 0) + c.scaled_bytes
        return out


def split_computations(text: str) -> dict[str, list[str]]:
    """computation name -> its body lines."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        stripped = line.strip()
        if (line and not line[0].isspace()
                and ("->" in line or stripped.startswith("ENTRY"))
                and stripped.endswith("{")):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            cur = m.group(1) if m else None
            if cur:
                comps[cur] = []
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


_CALL_RE = re.compile(
    r"(?:condition=%?([\w\.\-]+))|(?:body=%?([\w\.\-]+))"
    r"|(?:calls=%?([\w\.\-]+))|(?:to_apply=%?([\w\.\-]+))")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_WHILE_RE = re.compile(
    r"=\s*\([^=]*\)\s*while\(.*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")


def _while_trip_count(cond_lines: list[str], default: int) -> int:
    """jax counted loops compare the induction var against a constant."""
    for line in cond_lines:
        if "compare" in line and "direction=LT" in line:
            # constant may be inline or via a fused computation; search line
            m = _CONST_RE.search(line)
            if m:
                return int(m.group(1))
    # constant might live as a separate line in the condition computation
    consts = [int(m.group(1)) for line in cond_lines
              for m in [_CONST_RE.search(line)] if m]
    if consts:
        return max(consts)
    return default


def analyze(text: str, default_trip: int = 1) -> HloAnalysis:
    comps = split_computations(text)

    # map: computation -> list of (callee, kind)
    calls: dict[str, list[tuple[str, str]]] = {c: [] for c in comps}
    while_of: dict[str, tuple[str, str]] = {}  # body comp -> (cond comp, op)
    for cname, lines in comps.items():
        for line in lines:
            if " while(" in line or line.startswith("while("):
                m = re.search(r"condition=%?([\w\.\-]+)", line)
                b = re.search(r"body=%?([\w\.\-]+)", line)
                if m and b:
                    calls[cname].append((b.group(1), "while"))
                    while_of[b.group(1)] = (m.group(1), cname)
            else:
                for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", line):
                    if m.group(1) in comps:
                        calls[cname].append((m.group(1), "call"))

    # trip multiplier per computation (product over enclosing whiles),
    # computed by BFS from the entry.
    entry = None
    for cname in comps:
        if "main" in cname or entry is None:
            pass
    # entry = computation not called by anyone
    called = {callee for cs in calls.values() for callee, _ in cs}
    roots = [c for c in comps if c not in called]
    mult: dict[str, int] = {}

    def visit(c: str, m: int):
        if mult.get(c, 0) >= m:
            return
        mult[c] = max(mult.get(c, 0), m)
        for callee, kind in calls.get(c, []):
            if kind == "while":
                cond, _ = while_of.get(callee, (None, None))
                trips = _while_trip_count(comps.get(cond, []), default_trip) \
                    if cond else default_trip
                visit(callee, m * max(trips, 1))
                if cond:
                    visit(cond, m * max(trips, 1))
            else:
                visit(callee, m)

    for r in roots:
        visit(r, 1)

    ana = HloAnalysis()
    for cname, lines in comps.items():
        tm = mult.get(cname, 1)
        for line in lines:
            for kind in COLLECTIVES:
                token = f" {kind}(" if not line.startswith(kind) else kind
                if re.search(rf"=\s*[\w\[\],\s{{}}]*{kind}(-start)?\(", line):
                    if f"{kind}-done" in line:
                        continue  # count the -start only
                    # output type sits between '=' and the op token
                    rhs = line.split("=", 1)[1]
                    type_str = rhs.split(kind)[0]
                    b = _shape_bytes(type_str)
                    dm = _SHAPE_RE.search(type_str)
                    m = re.search(
                        r"replica_groups=(\[[\d,]+\]<=\[[\d,]+\]"
                        r"(?:T\([\d,]+\))?|\{\{[\d,\s}{]*\}\})", line)
                    ana.collectives.append(CollectiveOp(
                        kind=kind, bytes_out=b,
                        replica_groups=m.group(1) if m else "",
                        computation=cname, trip_mult=tm,
                        name=line.split("=", 1)[0].strip(),
                        dtype=dm.group(1) if dm else ""))
                    break
    # record while trip counts
    for body, (cond, _) in while_of.items():
        ana.while_trips[body] = _while_trip_count(comps.get(cond, []),
                                                  default_trip)
    return ana


# operands may carry inline types ("dot(f32[32,128]{1,0} %copy.1, ...)")
# depending on the XLA version's HLO printer; both forms must parse.
_OPERAND = r"(?:\w+\[[\d,]*\](?:\{[\d,]*\})?\s+)?%?([\w\.\-]+)"
_DOT_RE = re.compile(
    r"=\s*(\w+\[[\d,]*\])[^=]*\bdot\(\s*" + _OPERAND + r",\s*" + _OPERAND
    + r"\).*?lhs_contracting_dims=\{([\d,]*)\}")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|\w+\[[\d,]*\])")


def _dims_of(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def dot_flops(text_or_comps, default_trip: int = 1) -> float:
    """Trip-scaled MAC flops (2*M*N*K) summed over every dot in the module.

    This is the per-device HLO compute volume that XLA's cost_analysis would
    report if it multiplied while bodies by their trip counts.
    """
    if isinstance(text_or_comps, str):
        comps = split_computations(text_or_comps)
    else:
        comps = text_or_comps
    # trip multipliers (reuse analyze()'s logic via a light re-run)
    ana_mult = _trip_multipliers(comps, default_trip)
    total = 0.0
    for cname, lines in comps.items():
        tm = ana_mult.get(cname, 1)
        shapes: dict[str, str] = {}
        for line in lines:
            dm = _DEF_RE.match(line)
            if dm:
                shapes[dm.group(1)] = dm.group(2)
        for line in lines:
            m = _DOT_RE.search(line)
            if not m:
                continue
            out_t, lhs_name, _, lhs_cdims = m.groups()
            out_n = 1
            for d in _dims_of(out_t):
                out_n *= d
            lhs_t = shapes.get(lhs_name)
            k = 1
            if lhs_t is not None and lhs_cdims:
                ld = _dims_of(lhs_t)
                for ci in lhs_cdims.split(","):
                    if ci and int(ci) < len(ld):
                        k *= ld[int(ci)]
            total += 2.0 * out_n * k * tm
    return total


_SKIP_OPS = ("parameter(", "constant(", "get-tuple-element(", "tuple(",
             "bitcast(", "after-all(", "partition-id(", "replica-id(",
             "iota(")

# Ops that genuinely materialize HBM traffic on TPU. The CPU backend's
# thousands of tiny kLoop fusions / converts / copies fuse away on TPU and
# are EXCLUDED; a fusion-boundary allowance multiplier compensates for the
# handful of real elementwise-chain boundaries per layer. Matching is by
# parsed opcode — op *names* routinely contain substrings like
# "all-reduce_convert_fusion" and must not count.
_TRAFFIC_OPCODES = {
    "dot", "convolution", "dynamic-update-slice", "dynamic-slice",
    "concatenate", "gather", "scatter", "reduce", "reduce-window",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
}

_OPCODE_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\w+\[[\d,]*\](?:\{[\d,]*\})?)\s*"
    r"([a-z][a-z0-9\-]*)\(")

FUSION_BOUNDARY_ALLOWANCE = 1.3


def _opcode(line: str) -> str | None:
    m = _OPCODE_RE.search(line)
    return m.group(1) if m else None


def _f32_corrected(type_str: str, f32_factor: float) -> float:
    """Shape bytes with f32 buffers scaled by f32_factor (CPU float
    normalization widens bf16 model tensors to f32; TPU keeps bf16)."""
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * _DTYPE_BYTES[dt]
        if dt == "f32":
            b *= f32_factor
        total += b
    return total


def hlo_bytes(text_or_comps, default_trip: int = 1,
              f32_factor: float = 0.5) -> float:
    """Trip-scaled HBM-traffic estimate for the TPU target: operand+output
    bytes of every genuinely-materializing op (whitelist above), times a
    fusion-boundary allowance. Loop-correct, unlike cost_analysis."""
    if isinstance(text_or_comps, str):
        comps = split_computations(text_or_comps)
    else:
        comps = text_or_comps
    mult = _trip_multipliers(comps, default_trip)

    fusion_bodies: set[str] = set()
    for lines in comps.values():
        for line in lines:
            if " fusion(" in line or "reduce(" in line:
                for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)",
                                     line):
                    fusion_bodies.add(m.group(1))

    total = 0.0
    for cname, lines in comps.items():
        if cname in fusion_bodies:
            continue
        tm = mult.get(cname, 1)
        shapes: dict[str, str] = {}
        for line in lines:
            dm = _DEF_RE.match(line)
            if dm:
                shapes[dm.group(1)] = dm.group(2)
        for line in lines:
            opcode = _opcode(line)
            if opcode not in _TRAFFIC_OPCODES:
                continue
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            out_b = _f32_corrected(dm.group(2), f32_factor)
            rhs = line.split("=", 1)[1]
            call = rhs[rhs.index("("):] if "(" in rhs else ""
            op_bytes = [
                _f32_corrected(shapes[om.group(1)], f32_factor)
                for om in re.finditer(r"%([\w\.\-]+)", call)
                if om.group(1) in shapes]

            if opcode == "dynamic-update-slice":
                # in-place slice write: the traffic is the written value
                # (second operand), not the carried buffer.
                b = 2 * (op_bytes[1] if len(op_bytes) > 1 else out_b)
            elif opcode in ("dynamic-slice", "gather"):
                b = 2 * out_b           # read selected rows + write out
            elif opcode == "scatter":
                # updates operand r/w; buffer updated in place
                b = 2 * (op_bytes[2] if len(op_bytes) > 2 else out_b)
            elif opcode in ("dot", "convolution"):
                b = out_b + sum(op_bytes[:2])
            elif opcode in ("reduce", "reduce-window"):
                b = out_b + (max(op_bytes) if op_bytes else 0.0)
            else:                        # collectives / concatenate
                b = out_b + sum(op_bytes)
            total += b * tm
    return total * FUSION_BOUNDARY_ALLOWANCE


def _trip_multipliers(comps: dict[str, list[str]],
                      default_trip: int) -> dict[str, int]:
    calls: dict[str, list[tuple[str, str]]] = {c: [] for c in comps}
    while_of: dict[str, tuple[str, str]] = {}
    for cname, lines in comps.items():
        for line in lines:
            if " while(" in line or line.startswith("while("):
                m = re.search(r"condition=%?([\w\.\-]+)", line)
                b = re.search(r"body=%?([\w\.\-]+)", line)
                if m and b:
                    calls[cname].append((b.group(1), "while"))
                    while_of[b.group(1)] = (m.group(1), cname)
            else:
                for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)",
                                     line):
                    if m.group(1) in comps:
                        calls[cname].append((m.group(1), "call"))
    called = {callee for cs in calls.values() for callee, _ in cs}
    roots = [c for c in comps if c not in called]
    mult: dict[str, int] = {}

    def visit(c: str, m: int):
        if mult.get(c, 0) >= m:
            return
        mult[c] = m
        for callee, kind in calls.get(c, []):
            if kind == "while":
                cond, _ = while_of.get(callee, (None, None))
                trips = _while_trip_count(comps.get(cond, []), default_trip) \
                    if cond else default_trip
                visit(callee, m * max(trips, 1))
                if cond:
                    visit(cond, m * max(trips, 1))
            else:
                visit(callee, m)

    for r in roots:
        visit(r, 1)
    return mult


def analyze_file(path: str | Path, default_trip: int = 1) -> HloAnalysis:
    p = Path(path)
    if p.suffix == ".gz":
        text = gzip.open(p, "rt").read()
    else:
        text = p.read_text()
    return analyze(text, default_trip)


def replica_group_size(groups: str) -> int:
    """Parse '[2,4]<=[8]' (iota) or '{{0,1},{2,3}}' forms -> group size."""
    m = re.match(r"\[([\d,]+)\]<=", groups)
    if m:
        dims = [int(x) for x in m.group(1).split(",")]
        # iota groups: [num_groups, group_size]
        return dims[-1]
    m = re.match(r"\{\{([\d,]+)\}", groups)
    if m:
        return len(m.group(1).split(","))
    return 0
