"""Three-term roofline from the dry-run artifacts (TPU v5e target).

    compute    = HLO_FLOPs / (chips x 197e12)
    memory     = HLO_bytes / (chips x 819e9)
    collective = wire_bytes / (chips-normalized links x 50e9)

HLO_FLOPs is the trip-scaled dot-flop volume parsed from the compiled HLO
(per-device; analysis.hlo.dot_flops). HLO_bytes takes XLA's
``cost_analysis()["bytes accessed"]`` re-scaled by the same trip-correction
ratio (XLA counts while bodies once — verified; DESIGN.md §9). Wire bytes use
ring-cost factors per collective kind over bidirectional torus axes (2 links
x 50 GB/s per hop direction).

MODEL_FLOPS = 6·N·D (train) / 2·N·D (forward-only), N = active params,
D = tokens processed; the ratio MODEL_FLOPS/HLO_FLOPs exposes remat and
dispatch waste. ``fraction`` = time the chips would spend at peak on useful
math / the dominant term — an upper bound on achievable MFU under this
sharding, which is the score we hillclimb in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass
from pathlib import Path

from repro.analysis import hlo as hlo_mod
from repro.config import SHAPES, get_config

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link
LINKS_PER_AXIS = 2           # bidirectional torus ring per mesh axis


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops_device: float
    hlo_flops_device: float
    coll_by_kind: dict

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_step(self) -> float:
        """No-overlap step-time estimate = max of the three terms (perfectly
        overlapped) — we report max() as the optimistic bound."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_time(self) -> float:
        return self.model_flops_device / PEAK_FLOPS

    @property
    def fraction(self) -> float:
        """Upper-bound MFU under this sharding (useful time / step bound)."""
        t = self.t_step
        return self.useful_time / t if t > 0 else 0.0

    @property
    def compute_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — 1.0 means zero waste; <1 means remat or
        dispatch overhead; >1 means HLO undercount (flag for review)."""
        return (self.model_flops_device / self.hlo_flops_device
                if self.hlo_flops_device else 0.0)

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": round(self.t_compute, 6),
            "t_memory_s": round(self.t_memory, 6),
            "t_collective_s": round(self.t_collective, 6),
            "dominant": self.dominant,
            "fraction": round(self.fraction, 4),
            "model/hlo_flops": round(self.compute_ratio, 3),
            "coll_by_kind_GiB": {k: round(v / 2**30, 3)
                                 for k, v in self.coll_by_kind.items()},
        }


def wire_bytes(op: hlo_mod.CollectiveOp) -> float:
    """Per-device bytes moved over links, ring-cost model."""
    a = hlo_mod.replica_group_size(op.replica_groups) or 1
    if a <= 1:
        return 0.0
    d = op.scaled_bytes                       # per-device shape bytes (lhs)
    if op.kind == "all-gather":               # lhs = gathered output
        return d * (a - 1) / a
    if op.kind == "reduce-scatter":           # lhs = scattered output
        return d * (a - 1)
    if op.kind == "all-reduce":               # lhs = full tensor
        return 2.0 * d * (a - 1) / a
    if op.kind == "all-to-all":
        return d * (a - 1) / a
    if op.kind == "collective-permute":
        return float(d)
    return float(d)


def model_flops(arch: str, shape_name: str, chips: int) -> float:
    """Useful per-device FLOPs for this step (6ND / 2ND convention)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        total = 2.0 * n * tokens
    return total / chips


def roofline_from_artifacts(json_path: Path, hlo_path: Path) -> Roofline:
    info = json.loads(Path(json_path).read_text())
    arch, shape_name = info["arch"], info["shape"]
    chips = info["devices"]
    cfg = get_config(arch)

    import gzip
    text = gzip.open(hlo_path, "rt").read() if str(hlo_path).endswith(".gz") \
        else Path(hlo_path).read_text()
    comps = hlo_mod.split_computations(text)
    ana = hlo_mod.analyze(text, default_trip=cfg.num_layers)
    flops_dev = hlo_mod.dot_flops(comps, default_trip=cfg.num_layers)
    bytes_dev = hlo_mod.hlo_bytes(comps, default_trip=cfg.num_layers,
                                  f32_factor=0.5 if cfg.dtype == "bfloat16"
                                  else 1.0)

    coll = 0.0
    by_kind: dict[str, float] = {}
    for op in ana.collectives:
        w = wire_bytes(op)
        # f32 collectives of a bf16 model are CPU float-normalization
        # artifacts — on TPU these tensors (activations/grads) stay bf16.
        if op.dtype == "f32" and cfg.dtype == "bfloat16":
            w *= 0.5
        coll += w
        by_kind[op.kind] = by_kind.get(op.kind, 0.0) + w

    mesh = "pod2" if info["mesh"].get("pod") else "pod1"
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh, chips=chips,
        t_compute=flops_dev / PEAK_FLOPS,
        t_memory=bytes_dev / HBM_BW,
        t_collective=coll / (LINKS_PER_AXIS * LINK_BW),
        model_flops_device=model_flops(arch, shape_name, chips),
        hlo_flops_device=flops_dev,
        coll_by_kind=by_kind,
    )


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.json")
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2", "all"])
    args = ap.parse_args()
    d = Path(args.dir)
    rows = []
    for jp in sorted(d.glob("*.json")):
        tag = jp.stem
        if args.mesh != "all" and not tag.endswith(args.mesh):
            continue
        hp = jp.with_suffix("").with_suffix("")  # strip .json
        hp = d / f"{tag}.hlo.gz"
        if not hp.exists():
            continue
        info = json.loads(jp.read_text())
        if "error" in info or "skipped" in info:
            continue
        try:
            r = roofline_from_artifacts(jp, hp)
            rows.append(r.row())
            print(f"{tag}: dom={r.dominant} frac={r.fraction:.3f} "
                  f"tc={r.t_compute*1e3:.1f}ms tm={r.t_memory*1e3:.1f}ms "
                  f"tx={r.t_collective*1e3:.1f}ms "
                  f"ratio={r.compute_ratio:.2f}")
        except Exception as e:  # noqa: BLE001
            print(f"{tag}: roofline FAILED {e}")
    Path(args.out).write_text(json.dumps(rows, indent=1))
    print(f"wrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
