"""Queue-fed input pipeline (paper §3.2, Figure 1).

The paper's training pipeline is concurrent subgraphs joined by queues:
reader -> preprocess -> input queue -> training step, with blocking
enqueue/dequeue providing backpressure. Host-side here: producer threads
synthesize/tokenize batches into a bounded queue; the training loop
dequeues; a slow consumer stalls the producers, never the reverse.

``ShardedSource`` deals each host its disjoint slice of the stream by
(rank, world) — data parallelism's I/O half (§2.1).
"""

from __future__ import annotations

import queue
import threading

import numpy as np

from repro.config import ModelConfig, ShapeConfig
from repro.models import api


class ShardedSource:
    """Deterministic synthetic token stream, sharded by data-parallel rank.

    Draws from a Zipfian unigram distribution with a simple Markov kick so
    models have structure to learn (loss decreases measurably).
    """

    def __init__(self, cfg: ModelConfig, seq_len: int, rank: int = 0,
                 world: int = 1, seed: int = 0):
        self.cfg = cfg
        self.seq_len = seq_len
        self.rank, self.world = rank, world
        self.seed = seed
        v = cfg.vocab_size
        r = np.random.default_rng(seed)
        probs = 1.0 / np.arange(1, v + 1) ** 1.1
        self.probs = probs / probs.sum()
        self.shift = r.integers(1, v)

    def batch(self, index: int, batch_size: int):
        """Global batch index -> this rank's examples."""
        rng = np.random.default_rng(
            (self.seed, index, self.rank))
        n = batch_size // self.world
        toks = rng.choice(self.cfg.vocab_size, size=(n, self.seq_len + 1),
                          p=self.probs).astype(np.int32)
        # Markov kick: half the positions continue deterministically
        cont = rng.random((n, self.seq_len)) < 0.5
        nxt = (toks[:, :-1] + self.shift) % self.cfg.vocab_size
        toks[:, 1:] = np.where(cont, nxt, toks[:, 1:])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Pipeline:
    """Bounded prefetch queue with producer threads (backpressure)."""

    def __init__(self, source: ShardedSource, batch_size: int,
                 capacity: int = 4, producers: int = 1):
        self.source = source
        self.batch_size = batch_size
        self.q: queue.Queue = queue.Queue(maxsize=capacity)
        self._stop = threading.Event()
        self._next = 0
        self._lock = threading.Lock()
        self.threads = [threading.Thread(target=self._produce, daemon=True)
                        for _ in range(producers)]
        for t in self.threads:
            t.start()

    def _produce(self):
        while not self._stop.is_set():
            with self._lock:
                idx = self._next
                self._next += 1
            batch = self.source.batch(idx, self.batch_size)
            while not self._stop.is_set():
                try:
                    self.q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def get(self, timeout: float = 30.0):
        return self.q.get(timeout=timeout)

    def close(self):
        self._stop.set()


def synthetic_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0):
    """One-shot batch via models.api (smoke tests / benchmarks)."""
    return api.make_batch(cfg, shape, seed)
