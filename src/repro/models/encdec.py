"""Whisper-style encoder-decoder backbone.

The conv audio frontend is STUBBED per the assignment: ``input_specs``
supplies precomputed frame embeddings (B, T_enc, d_model). The backbone is
faithful: bidirectional encoder self-attention, causal decoder self-attention
with KV cache, cross-attention whose K/V are computed once at prefill.

Graph-partitioning note (DESIGN.md §4): enc-dec is the cleanest analogue of
the paper's §3.3 partition — encoder and decoder are separable subgraphs
joined by one cross-attention edge (the Send/Recv cut point).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ParallelConfig
from repro.models import modules as m
from repro.models.attention import (attention_scale, decode_attention,
                                    init_attention, out_proj, project_kv,
                                    project_q, sharded_attention,
                                    update_cache)
from repro.models.embedding import (decode_logits_argmax, embed, head_table,
                                    init_embedding, lm_loss)
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm, \
    rope_cos_sin
from repro.kernels import ops as kops


def _init_enc_block(cfg, key):
    ks = m.split_keys(key, 2)
    return m.merge(
        m.named("norm", init_norm(cfg)),
        m.named("attn", init_attention(cfg, ks[0])),
        m.named("norm2", init_norm(cfg)),
        m.named("mlp", init_mlp(cfg, ks[1])),
    )


def _init_dec_block(cfg, key):
    ks = m.split_keys(key, 3)
    return m.merge(
        m.named("norm", init_norm(cfg)),
        m.named("attn", init_attention(cfg, ks[0])),
        m.named("xnorm", init_norm(cfg)),
        m.named("xattn", init_attention(cfg, ks[1])),
        m.named("norm2", init_norm(cfg)),
        m.named("mlp", init_mlp(cfg, ks[2])),
    )


def init_encdec(cfg: ModelConfig, key):
    ks = m.split_keys(key, 4)
    enc, enc_s = m.stack_layer_params(
        [_init_enc_block(cfg, k)
         for k in m.split_keys(ks[0], cfg.encoder_layers)])
    dec, dec_s = m.stack_layer_params(
        [_init_dec_block(cfg, k) for k in m.split_keys(ks[1], cfg.num_layers)])
    return m.merge(
        m.named("embed", init_embedding(cfg, ks[2])),
        ({"encoder": enc}, {"encoder": enc_s}),
        ({"decoder": dec}, {"decoder": dec_s}),
        m.named("enc_final_norm", init_norm(cfg)),
        m.named("final_norm", init_norm(cfg)),
    )


def encode(params, frames, cfg: ModelConfig, pcfg: ParallelConfig):
    """frames: (B, T_enc, d_model) stub embeddings -> encoder output."""
    B, Te, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(Te, dtype=jnp.int32)[None], (B, Te))
    cos_sin = rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)

    def body(x, bp):
        h = apply_norm(bp["norm"], x, cfg)
        q = project_q(bp["attn"], h, cfg, cos_sin)
        k, v = project_kv(bp["attn"], h, cfg, cos_sin)
        y = sharded_attention(q, k, v, cfg, causal=False,
                              scale=attention_scale(cfg),
                              chunk_kv=min(1024, Te))
        x = x + out_proj(bp["attn"], y, x.dtype)
        x = x + apply_mlp(bp["mlp"], apply_norm(bp["norm2"], x, cfg), cfg)
        return x, None

    if pcfg.remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, frames, params["encoder"])
    return apply_norm(params["enc_final_norm"], x, cfg)


def _dec_block_full(bp, x, enc_out, cfg, cos_sin, mode):
    h = apply_norm(bp["norm"], x, cfg)
    q = project_q(bp["attn"], h, cfg, cos_sin)
    k, v = project_kv(bp["attn"], h, cfg, cos_sin)
    y = sharded_attention(q, k, v, cfg, causal=True,
                          scale=attention_scale(cfg),
                          chunk_kv=min(1024, k.shape[1]))
    x = x + out_proj(bp["attn"], y, x.dtype)
    h = apply_norm(bp["xnorm"], x, cfg)
    qx = project_q(bp["xattn"], h, cfg, None)
    kx, vx = project_kv(bp["xattn"], enc_out, cfg, None)
    yx = sharded_attention(qx, kx, vx, cfg, causal=False,
                           scale=attention_scale(cfg),
                           chunk_kv=min(1024, kx.shape[1]))
    x = x + out_proj(bp["xattn"], yx, x.dtype)
    x = x + apply_mlp(bp["mlp"], apply_norm(bp["norm2"], x, cfg), cfg)
    cache = None
    if mode == "prefill":
        cache = {"k": k, "v": v, "xk": kx, "xv": vx}
    return x, cache


def forward_loss(params, batch, cfg: ModelConfig, pcfg: ParallelConfig):
    """batch: frames (B,Te,d), tokens (B,S), labels (B,S)."""
    enc_out = encode(params, batch["frames"], cfg, pcfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed(params["embed"]["table"], tokens, cfg)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    cos_sin = rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)

    def body(x, bp):
        x, _ = _dec_block_full(bp, x, enc_out, cfg, cos_sin, "train")
        return x, None

    if pcfg.remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["decoder"])
    x = apply_norm(params["final_norm"], x, cfg)
    ce = lm_loss(x, head_table(params["embed"], cfg), batch["labels"], cfg)
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


def prefill(params, batch, cfg: ModelConfig, pcfg: ParallelConfig):
    enc_out = encode(params, batch["frames"], cfg, pcfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed(params["embed"]["table"], tokens, cfg)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    cos_sin = rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)

    def body(x, bp):
        x, cache = _dec_block_full(bp, x, enc_out, cfg, cos_sin, "prefill")
        return x, cache

    x, caches = jax.lax.scan(body, x, params["decoder"])
    x = apply_norm(params["final_norm"], x, cfg)
    nxt = decode_logits_argmax(x[:, -1:], head_table(params["embed"], cfg),
                               cfg)
    return caches, nxt


def decode_step(params, cache, batch, cfg: ModelConfig,
                pcfg: ParallelConfig):
    """batch: token (B,1), pos (B,). Cross K/V in cache are read-only."""
    token, pos = batch["token"], batch["pos"]
    x = embed(params["embed"]["table"], token, cfg)
    cos_sin = rope_cos_sin(pos[:, None], cfg.head_dim, cfg.rope_theta)
    scale = attention_scale(cfg)

    def body(x, xs):
        bp, c = xs
        h = apply_norm(bp["norm"], x, cfg)
        q = project_q(bp["attn"], h, cfg, cos_sin)
        k, v = project_kv(bp["attn"], h, cfg, cos_sin)
        kc = update_cache(c["k"], k, pos)
        vc = update_cache(c["v"], v, pos)
        y = decode_attention(q, kc, vc, pos, scale=scale)
        x = x + out_proj(bp["attn"], y, x.dtype)
        h = apply_norm(bp["xnorm"], x, cfg)
        qx = project_q(bp["xattn"], h, cfg, None)
        Te = c["xk"].shape[1]
        full = jnp.full((x.shape[0],), Te - 1, jnp.int32)
        yx = decode_attention(qx, c["xk"], c["xv"], full, scale=scale)
        x = x + out_proj(bp["xattn"], yx, x.dtype)
        x = x + apply_mlp(bp["mlp"], apply_norm(bp["norm2"], x, cfg), cfg)
        return x, {"k": kc, "v": vc, "xk": c["xk"], "xv": c["xv"]}

    x, new_cache = jax.lax.scan(body, x, (params["decoder"], cache))
    x = apply_norm(params["final_norm"], x, cfg)
    nxt = decode_logits_argmax(x, head_table(params["embed"], cfg), cfg)
    return nxt, new_cache
