"""Whisper-style encoder-decoder backbone.

The conv audio frontend is STUBBED per the assignment: ``input_specs``
supplies precomputed frame embeddings (B, T_enc, d_model). The backbone is
faithful: bidirectional encoder self-attention, causal decoder self-attention
with KV cache, cross-attention whose K/V are computed once at prefill.

Graph-partitioning note (DESIGN.md §4): enc-dec is the cleanest analogue of
the paper's §3.3 partition — encoder and decoder are separable subgraphs
joined by one cross-attention edge (the Send/Recv cut point).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ParallelConfig
from repro.models import modules as m
from repro.models.attention import (attention_scale, decode_attention,
                                    decode_attention_local, init_attention,
                                    out_proj, paged_chunk_attention,
                                    paged_decode_attention, project_kv,
                                    project_q, replicate_over_model,
                                    sharded_attention, update_cache,
                                    update_paged_cache,
                                    update_paged_cache_chunk)
from repro.models.embedding import (decode_logits, decode_logits_argmax,
                                    embed, head_table, init_embedding,
                                    lm_loss)
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm, \
    rope_cos_sin
from repro.kernels import ops as kops


def _init_enc_block(cfg, key):
    ks = m.split_keys(key, 2)
    return m.merge(
        m.named("norm", init_norm(cfg)),
        m.named("attn", init_attention(cfg, ks[0])),
        m.named("norm2", init_norm(cfg)),
        m.named("mlp", init_mlp(cfg, ks[1])),
    )


def _init_dec_block(cfg, key):
    ks = m.split_keys(key, 3)
    return m.merge(
        m.named("norm", init_norm(cfg)),
        m.named("attn", init_attention(cfg, ks[0])),
        m.named("xnorm", init_norm(cfg)),
        m.named("xattn", init_attention(cfg, ks[1])),
        m.named("norm2", init_norm(cfg)),
        m.named("mlp", init_mlp(cfg, ks[2])),
    )


def init_encdec(cfg: ModelConfig, key):
    ks = m.split_keys(key, 4)
    enc, enc_s = m.stack_layer_params(
        [_init_enc_block(cfg, k)
         for k in m.split_keys(ks[0], cfg.encoder_layers)])
    dec, dec_s = m.stack_layer_params(
        [_init_dec_block(cfg, k) for k in m.split_keys(ks[1], cfg.num_layers)])
    return m.merge(
        m.named("embed", init_embedding(cfg, ks[2])),
        ({"encoder": enc}, {"encoder": enc_s}),
        ({"decoder": dec}, {"decoder": dec_s}),
        m.named("enc_final_norm", init_norm(cfg)),
        m.named("final_norm", init_norm(cfg)),
    )


def encode(params, frames, cfg: ModelConfig, pcfg: ParallelConfig):
    """frames: (B, T_enc, d_model) stub embeddings -> encoder output."""
    B, Te, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(Te, dtype=jnp.int32)[None], (B, Te))
    cos_sin = rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)

    def body(x, bp):
        h = apply_norm(bp["norm"], x, cfg)
        q = project_q(bp["attn"], h, cfg, cos_sin)
        k, v = project_kv(bp["attn"], h, cfg, cos_sin)
        y = sharded_attention(q, k, v, cfg, causal=False,
                              scale=attention_scale(cfg),
                              chunk_kv=min(1024, Te))
        x = x + out_proj(bp["attn"], y, x.dtype)
        x = x + apply_mlp(bp["mlp"], apply_norm(bp["norm2"], x, cfg), cfg)
        return x, None

    if pcfg.remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, frames, params["encoder"])
    return apply_norm(params["enc_final_norm"], x, cfg)


def _dec_block_full(bp, x, enc_out, cfg, cos_sin, mode):
    h = apply_norm(bp["norm"], x, cfg)
    q = project_q(bp["attn"], h, cfg, cos_sin)
    k, v = project_kv(bp["attn"], h, cfg, cos_sin)
    y = sharded_attention(q, k, v, cfg, causal=True,
                          scale=attention_scale(cfg),
                          chunk_kv=min(1024, k.shape[1]))
    x = x + out_proj(bp["attn"], y, x.dtype)
    h = apply_norm(bp["xnorm"], x, cfg)
    qx = project_q(bp["xattn"], h, cfg, None)
    kx, vx = project_kv(bp["xattn"], enc_out, cfg, None)
    yx = sharded_attention(qx, kx, vx, cfg, causal=False,
                           scale=attention_scale(cfg),
                           chunk_kv=min(1024, kx.shape[1]))
    x = x + out_proj(bp["xattn"], yx, x.dtype)
    x = x + apply_mlp(bp["mlp"], apply_norm(bp["norm2"], x, cfg), cfg)
    cache = None
    if mode == "prefill":
        cache = {"k": k, "v": v, "xk": kx, "xv": vx}
    return x, cache


def forward_loss(params, batch, cfg: ModelConfig, pcfg: ParallelConfig):
    """batch: frames (B,Te,d), tokens (B,S), labels (B,S)."""
    enc_out = encode(params, batch["frames"], cfg, pcfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed(params["embed"]["table"], tokens, cfg)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    cos_sin = rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)

    def body(x, bp):
        x, _ = _dec_block_full(bp, x, enc_out, cfg, cos_sin, "train")
        return x, None

    if pcfg.remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["decoder"])
    x = apply_norm(params["final_norm"], x, cfg)
    ce = lm_loss(x, head_table(params["embed"], cfg), batch["labels"], cfg)
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


def prefill(params, batch, cfg: ModelConfig, pcfg: ParallelConfig):
    enc_out = encode(params, batch["frames"], cfg, pcfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed(params["embed"]["table"], tokens, cfg)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    cos_sin = rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)

    def body(x, bp):
        x, cache = _dec_block_full(bp, x, enc_out, cfg, cos_sin, "prefill")
        return x, cache

    x, caches = jax.lax.scan(body, x, params["decoder"])
    x = apply_norm(params["final_norm"], x, cfg)
    nxt = decode_logits_argmax(x[:, -1:], head_table(params["embed"], cfg),
                               cfg)
    return caches, nxt


def encode_cross_kv(params, frames, cfg: ModelConfig, pcfg: ParallelConfig):
    """Run the encoder once and project every decoder layer's cross K/V.

    frames: (B, T_enc, d_model) stub embeddings. Returns {"xk", "xv"} each
    (L, B, T_enc, K, hd) — the serving ``EncoderCache``'s device half,
    written once per request at admission and read-only afterwards.
    """
    enc_out = encode(params, frames, cfg, pcfg)

    def body(_, bp):
        kx, vx = project_kv(bp["xattn"], enc_out, cfg, None)
        return None, {"xk": kx, "xv": vx}

    _, kv = jax.lax.scan(body, None, params["decoder"])
    return kv


def prefill_chunk_paged(params, cache, batch, cfg: ModelConfig,
                        pcfg: ParallelConfig):
    """One chunk of decoder prompt prefill against a block-paged self-KV
    cache plus the request's read-only cross K/V.

    batch: tokens (B, C), q_start (B,), q_lens (B,), block_tables (B, nb),
    ctx_lens (B,). cache: {"self": {"k","v"} page pools (L, NB, bs, K, hd),
    "cross": {"xk","xv"} (L, B, Te, K, hd) — already sliced to this chunk's
    slot row. Returns (logits (B, V_pad) fp32 at each row's last valid
    token, new_cache)."""
    tokens = batch["tokens"]
    B, C = tokens.shape
    x = embed(params["embed"]["table"], tokens, cfg)
    positions = batch["q_start"][:, None] + jnp.arange(C, dtype=jnp.int32)
    cos_sin = rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta)
    scale = attention_scale(cfg)
    bt, q_start, q_lens = (batch["block_tables"], batch["q_start"],
                           batch["q_lens"])

    def body(x, xs):
        bp, c = xs
        h = apply_norm(bp["norm"], x, cfg)
        q = project_q(bp["attn"], h, cfg, cos_sin)
        k, v = project_kv(bp["attn"], h, cfg, cos_sin)
        kc = update_paged_cache_chunk(c["k"], k, bt, q_start, q_lens)
        vc = update_paged_cache_chunk(c["v"], v, bt, q_start, q_lens)
        y = paged_chunk_attention(q, kc, vc, bt, batch["ctx_lens"], q_lens,
                                  scale=scale)
        x = x + out_proj(bp["attn"], y, x.dtype)
        h = apply_norm(bp["xnorm"], x, cfg)
        qx = project_q(bp["xattn"], h, cfg, None)
        # cross attention has no query-position dependence, so the exact
        # prefill op sequence applies chunk by chunk (row-wise identical).
        # The cross K/V arrives sharded by kv head on a TP mesh; attention
        # is per-head-exact, and the gather before out_proj keeps the
        # residual stream bitwise mesh-invariant (docs/multi-host.md).
        yx = sharded_attention(qx, c["xk"], c["xv"], cfg, causal=False,
                               scale=scale,
                               chunk_kv=min(1024, c["xk"].shape[1]))
        x = x + out_proj(bp["xattn"], replicate_over_model(yx), x.dtype)
        x = x + apply_mlp(bp["mlp"], apply_norm(bp["norm2"], x, cfg), cfg)
        return x, {"k": kc, "v": vc}

    x, new_self = jax.lax.scan(
        body, x, (params["decoder"],
                  {"k": cache["self"]["k"], "v": cache["self"]["v"],
                   "xk": cache["cross"]["xk"], "xv": cache["cross"]["xv"]}))
    x = apply_norm(params["final_norm"], x, cfg)
    last = jnp.clip(q_lens - 1, 0, C - 1)
    x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)
    logits = decode_logits(x_last, head_table(params["embed"], cfg), cfg)
    return logits, {"self": new_self, "cross": cache["cross"]}


def decode_step_paged(params, cache, batch, cfg: ModelConfig,
                      pcfg: ParallelConfig):
    """One decode token per serving slot against the paged self-KV cache
    and each slot's cross K/V. batch: token (B,1), pos (B,), block_tables
    (B, nb), ctx_lens (B,). Returns (logits (B, V_pad) fp32, new_cache)."""
    token, pos = batch["token"], batch["pos"]
    B = token.shape[0]
    x = embed(params["embed"]["table"], token, cfg)
    cos_sin = rope_cos_sin(pos[:, None], cfg.head_dim, cfg.rope_theta)
    scale = attention_scale(cfg)
    bt = batch["block_tables"]

    def body(x, xs):
        bp, c = xs
        h = apply_norm(bp["norm"], x, cfg)
        q = project_q(bp["attn"], h, cfg, cos_sin)
        k, v = project_kv(bp["attn"], h, cfg, cos_sin)
        kc = update_paged_cache(c["k"], k, bt, pos)
        vc = update_paged_cache(c["v"], v, bt, pos)
        y = paged_decode_attention(q, kc, vc, bt, batch["ctx_lens"],
                                   scale=scale)
        x = x + out_proj(bp["attn"], y, x.dtype)
        h = apply_norm(bp["xnorm"], x, cfg)
        qx = project_q(bp["xattn"], h, cfg, None)
        Te = c["xk"].shape[1]
        full = jnp.full((B,), Te - 1, jnp.int32)
        # per-head local attention over the (kv-head-sharded) per-slot
        # cross K/V — not the seq-sharded flash-decode stitch, whose
        # cross-shard psum would reorder float adds and cost the engine
        # its bitwise mesh-invariance; the cross cache is per-slot small,
        # so there is no long sequence axis to shard anyway
        yx = decode_attention_local(qx, c["xk"], c["xv"], full, scale=scale)
        x = x + out_proj(bp["xattn"], replicate_over_model(yx), x.dtype)
        x = x + apply_mlp(bp["mlp"], apply_norm(bp["norm2"], x, cfg), cfg)
        return x, {"k": kc, "v": vc}

    x, new_self = jax.lax.scan(
        body, x, (params["decoder"],
                  {"k": cache["self"]["k"], "v": cache["self"]["v"],
                   "xk": cache["cross"]["xk"], "xv": cache["cross"]["xv"]}))
    x = apply_norm(params["final_norm"], x, cfg)
    logits = decode_logits(x, head_table(params["embed"], cfg), cfg)
    return logits, {"self": new_self, "cross": cache["cross"]}


def decode_step(params, cache, batch, cfg: ModelConfig,
                pcfg: ParallelConfig):
    """batch: token (B,1), pos (B,). Cross K/V in cache are read-only."""
    token, pos = batch["token"], batch["pos"]
    x = embed(params["embed"]["table"], token, cfg)
    cos_sin = rope_cos_sin(pos[:, None], cfg.head_dim, cfg.rope_theta)
    scale = attention_scale(cfg)

    def body(x, xs):
        bp, c = xs
        h = apply_norm(bp["norm"], x, cfg)
        q = project_q(bp["attn"], h, cfg, cos_sin)
        k, v = project_kv(bp["attn"], h, cfg, cos_sin)
        kc = update_cache(c["k"], k, pos)
        vc = update_cache(c["v"], v, pos)
        y = decode_attention(q, kc, vc, pos, scale=scale)
        x = x + out_proj(bp["attn"], y, x.dtype)
        h = apply_norm(bp["xnorm"], x, cfg)
        qx = project_q(bp["xattn"], h, cfg, None)
        Te = c["xk"].shape[1]
        full = jnp.full((x.shape[0],), Te - 1, jnp.int32)
        yx = decode_attention(qx, c["xk"], c["xv"], full, scale=scale)
        x = x + out_proj(bp["xattn"], yx, x.dtype)
        x = x + apply_mlp(bp["mlp"], apply_norm(bp["norm2"], x, cfg), cfg)
        return x, {"k": kc, "v": vc, "xk": c["xk"], "xv": c["xv"]}

    x, new_cache = jax.lax.scan(body, x, (params["decoder"], cache))
    x = apply_norm(params["final_norm"], x, cfg)
    nxt = decode_logits_argmax(x, head_table(params["embed"], cfg), cfg)
    return nxt, new_cache
