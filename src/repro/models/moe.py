"""Mixture-of-Experts with production sharding strategies.

This is the paper's parameter-server insight at its sharpest: *move the
computation to the shard that owns the state*. Expert weights are the sharded
state; tokens are dynamically partitioned (`Part`), computed on the owning
shard (`Gather` + matmul), and stitched back (`Stitch`) — §4.2's
Part/Gather/Stitch pipeline, realized as shard_map + all_to_all / psum.

Strategies (auto-chosen from num_experts vs the mesh "model" size):
  EP  (experts >= model-axis, e.g. qwen3-moe's 128): experts sharded over
      "model".
      - big token counts (train/prefill): tokens additionally split over
        "model" on the sequence dim; dispatch rows travel via all_to_all.
      - small token counts (decode): tokens replicated over "model"; each
        shard computes its own experts' rows and the outputs are stitched
        with a psum.
  TP  (experts < model-axis, e.g. grok-1's 8): every device holds all experts
      but a 1/tp slice of d_ff; dispatch is local, the combine psums partial
      d_ff contributions (Megatron-style).

Both use fixed expert capacity with drop + zero-fill (the standard TPU MoE
formulation) and return an auxiliary load-balancing loss.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models import modules as m
from repro.spmd.sharding import dp_axes

_ACT = {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True)}


def init_moe(cfg: ModelConfig, key):
    mo = cfg.moe
    d, E, f = cfg.d_model, mo.num_experts, mo.d_ff_expert
    ks = m.split_keys(key, 4)
    return m.merge(
        m.named("router", m.dense_init(ks[0], (d, E), ("embed", None))),
        m.named("w_gate", m.dense_init(
            ks[1], (E, d, f), ("experts", "expert_embed", "expert_ff"))),
        m.named("w_in", m.dense_init(
            ks[2], (E, d, f), ("experts", "expert_embed", "expert_ff"))),
        m.named("w_out", m.dense_init(
            ks[3], (E, f, d), ("experts", "expert_ff", "expert_embed"))),
    )


def _route(x, router, k: int):
    """x: (T, d) -> (weights (T,k) fp32, idx (T,k) int32, probs (T,E))."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, idx, probs


def _aux_loss(probs, idx, E: int):
    """Switch-style load-balance loss: E * sum_e f_e * p_e."""
    hits = jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(axis=1)   # (T, E)
    f = hits.mean(axis=0) / max(idx.shape[-1], 1)
    p = probs.mean(axis=0)
    return E * jnp.sum(f * p)


def _positions_in_expert(idx, E: int):
    """idx: (T, k) -> per-assignment rank within its expert (T, k)."""
    flat = idx.reshape(-1)
    oh = jax.nn.one_hot(flat, E, dtype=jnp.int32)
    pos = jnp.cumsum(oh, axis=0) - oh
    pos = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]
    return pos.reshape(idx.shape)


def _expert_ffn(disp, w_gate, w_in, w_out, act):
    """disp: (E?, C, d); weights (E?, d, f)/(E?, f, d) -> (E?, C, d)."""
    g = act(jnp.einsum("ecd,edf->ecf", disp, w_gate))
    h = g * jnp.einsum("ecd,edf->ecf", disp, w_in)
    return jnp.einsum("ecf,efd->ecd", h, w_out)


def _moe_local(x, params, cfg: ModelConfig, *, mode: str, axis: str):
    """Per-shard MoE body (inside shard_map). x: (T_l, d).

    mode: "ep_a2a" | "ep_psum" | "tp".
    """
    mo = cfg.moe
    E, k = mo.num_experts, mo.experts_per_token
    T, d = x.shape
    C = max(8, int(math.ceil(T * k / E * mo.capacity_factor)))
    act = _ACT["gelu" if cfg.mlp_activation == "gelu_mlp"
               else cfg.mlp_activation]

    w, idx, probs = _route(x, params["router"].astype(x.dtype), k)
    aux = _aux_loss(probs, idx, E)
    pos = _positions_in_expert(idx, E)
    keep = pos < C

    wg = params["w_gate"].astype(x.dtype)
    wi = params["w_in"].astype(x.dtype)
    wo = params["w_out"].astype(x.dtype)

    if mode == "ep_psum":
        # experts sharded; tokens replicated over `axis`: each shard builds
        # dispatch rows for its local experts only, outputs stitched by psum.
        tp = jax.lax.axis_size(axis)
        E_l = E // tp
        e0 = jax.lax.axis_index(axis) * E_l
        local = (idx >= e0) & (idx < e0 + E_l) & keep
        lidx = jnp.where(local, idx - e0, 0)
        lpos = jnp.minimum(pos, C - 1)
        xk = jnp.broadcast_to(x[:, None, :], (T, k, d)).reshape(T * k, d)
        contrib = jnp.where(local.reshape(-1, 1), xk, 0)
        disp = jnp.zeros((E_l, C, d), x.dtype).at[
            lidx.reshape(-1), lpos.reshape(-1)].add(contrib)
        comb = _expert_ffn(disp, wg, wi, wo, act)
        got = comb[lidx.reshape(-1), lpos.reshape(-1)].reshape(T, k, d)
        wk = jnp.where(local, w, 0.0).astype(x.dtype)
        y = jnp.einsum("tkd,tk->td", got, wk)
        y = jax.lax.psum(y, axis)
        return y, aux

    # common dispatch build over all E buckets
    lpos = jnp.minimum(pos, C - 1)
    xk = jnp.broadcast_to(x[:, None, :], (T, k, d)).reshape(T * k, d)
    contrib = jnp.where(keep.reshape(-1, 1), xk, 0)
    disp = jnp.zeros((E, C, d), x.dtype).at[
        idx.reshape(-1), lpos.reshape(-1)].add(contrib)

    if mode == "ep_a2a":
        tp = jax.lax.axis_size(axis)
        E_l = E // tp
        snd = disp.reshape(tp, E_l, C, d)
        rcv = jax.lax.all_to_all(snd, axis, split_axis=0, concat_axis=0)
        rows = rcv.transpose(1, 0, 2, 3).reshape(E_l, tp * C, d)
        out_rows = _expert_ffn(rows, wg, wi, wo, act)
        back = out_rows.reshape(E_l, tp, C, d).transpose(1, 0, 2, 3)
        comb = jax.lax.all_to_all(back, axis, split_axis=0, concat_axis=0)
        comb = comb.reshape(E, C, d)
    else:  # tp: all experts local, f sharded over `axis`
        comb = _expert_ffn(disp, wg, wi, wo, act)
        comb = jax.lax.psum(comb, axis)

    got = comb[idx.reshape(-1), lpos.reshape(-1)].reshape(T, k, d)
    wk = jnp.where(keep, w, 0.0).astype(x.dtype)
    y = jnp.einsum("tkd,tk->td", got, wk)
    return y, aux


def moe_block(params, x, cfg: ModelConfig, f2d: bool = False):
    """x: (B, S, d) global -> (y, aux_loss scalar). shard_map wrapper.

    f2d: serving layout for small-E models (grok-1) — expert d_ff sharded
    over BOTH mesh axes, tokens replicated; the partial outputs psum over
    (data, model). No per-step weight gathers (vs FSDP), tiny activation
    psums — the right trade when tokens-per-step is small (decode).
    """
    mesh = jax.sharding.get_abstract_mesh()
    mo = cfg.moe
    tp = mesh.shape.get("model", 1)
    ep = (not f2d) and mo.num_experts >= tp \
        and mo.num_experts % max(tp, 1) == 0
    B, S, d = x.shape
    dp = dp_axes(mesh)
    dp_sz = math.prod(mesh.shape[a] for a in dp) if dp else 1
    dpb = dp if (dp and B % dp_sz == 0) else ()
    dps = (dpb if len(dpb) > 1 else (dpb[0] if dpb else None))
    seq_split = ep and tp > 1 and S % tp == 0

    if not ep:
        mode = "tp"
    elif seq_split:
        mode = "ep_a2a"
    elif tp > 1:
        mode = "ep_psum"
    else:
        mode = "tp"   # single model shard: all experts local, psum trivial

    f_axes = (tuple(dp) + ("model",)) if f2d else ("model",)
    seq_ax = "model" if mode == "ep_a2a" else None
    e_ax = "model" if mode in ("ep_a2a", "ep_psum") else None
    f_ax = (f_axes if len(f_axes) > 1 else f_axes[0]) \
        if mode == "tp" else None
    x_dps = None if f2d else dps
    wspec = {
        "router": P(None, None),
        "w_gate": P(e_ax, None, f_ax),
        "w_in": P(e_ax, None, f_ax),
        "w_out": P(e_ax, f_ax, None),
    }

    def body(params, x):
        b, s, _ = x.shape
        y, aux = _moe_local(x.reshape(b * s, d), params, cfg,
                            mode=mode,
                            axis=f_axes if f2d else "model")
        if dp and not f2d:
            aux = jax.lax.pmean(aux, dp)
        if mode != "ep_psum" and not f2d:
            aux = jax.lax.pmean(aux, "model")
        return y.reshape(b, s, d), aux

    y, aux = jax.shard_map(
        body, mesh=mesh,
        in_specs=(wspec, P(x_dps, seq_ax, None)),
        out_specs=(P(x_dps, seq_ax, None), P()),
    )(params, x)
    return y, aux
