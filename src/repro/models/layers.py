"""Norms, rotary embeddings (incl. M-RoPE) and MLP blocks."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import modules as m

# ---------------------------------------------------------------------------
# Norms — computed in fp32, cast back.
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, dim: int | None = None):
    dim = dim or cfg.d_model
    if cfg.norm == "layernorm":
        p, s = m.merge(m.named("scale", m.ones_init((dim,), ("embed",))),
                       m.named("bias", m.zeros_init((dim,), ("embed",))))
    else:
        p, s = m.named("scale", m.ones_init((dim,), ("embed",)))
    return p, s


def apply_norm(params, x, cfg: ModelConfig, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"] + params["bias"]
    else:
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(var + eps) * params["scale"]
    return y.astype(dt)


def rms_norm_fp32(x, scale, eps: float = 1e-6):
    """Bare RMS-norm used for qk-norm / gated SSM norm."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (y * scale).astype(dt)


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE for qwen2-vl)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def rope_cos_sin(positions, head_dim: int, theta: float,
                 sections: tuple[int, ...] | None = None):
    """cos/sin tables.

    positions: (B, S) int32, or (3, B, S) for M-RoPE where the three planes
    are temporal / height / width position ids. With M-RoPE, frequency slots
    are split into ``sections`` groups (sizes in half-dim units), each group
    indexed by its own plane — the qwen2-vl scheme.
    """
    inv = rope_freqs(head_dim, theta)                      # (hd/2,)
    if positions.ndim == 2:
        ang = positions[..., None].astype(jnp.float32) * inv   # (B,S,hd/2)
    else:
        assert sections is not None and sum(sections) == head_dim // 2
        ang_all = positions[..., None].astype(jnp.float32) * inv  # (3,B,S,hd/2)
        parts, start = [], 0
        for i, sec in enumerate(sections):
            parts.append(ang_all[i, :, :, start:start + sec])
            start += sec
        ang = jnp.concatenate(parts, axis=-1)              # (B,S,hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); cos/sin: (B, S, hd/2). Rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

_ACT = {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True)}


def init_mlp(cfg: ModelConfig, key):
    ks = m.split_keys(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_activation == "gelu_mlp":  # ungated 2-matrix MLP
        return m.merge(
            m.named("w_in", m.dense_init(ks[0], (d, f), ("embed", "ff"))),
            m.named("w_out", m.dense_init(ks[1], (f, d), ("ff", "embed"))),
        )
    return m.merge(
        m.named("w_gate", m.dense_init(ks[0], (d, f), ("embed", "ff"))),
        m.named("w_in", m.dense_init(ks[1], (d, f), ("embed", "ff"))),
        m.named("w_out", m.dense_init(ks[2], (f, d), ("ff", "embed"))),
    )


def apply_mlp(params, x, cfg: ModelConfig):
    w = {k: v.astype(x.dtype) for k, v in params.items()}
    if cfg.mlp_activation == "gelu_mlp":
        h = _ACT["gelu"](jnp.einsum("bsd,df->bsf", x, w["w_in"]))
        return jnp.einsum("bsf,fd->bsd", h, w["w_out"])
    act = _ACT[cfg.mlp_activation]
    g = act(jnp.einsum("bsd,df->bsf", x, w["w_gate"]))
    h = g * jnp.einsum("bsd,df->bsf", x, w["w_in"])
    return jnp.einsum("bsf,fd->bsd", h, w["w_out"])


def softcap(x, cap: float | None):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
