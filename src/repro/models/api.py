"""Family-dispatching model API + synthetic batch builders.

``input_specs`` (launch/dryrun.py) builds ShapeDtypeStruct stand-ins from the
same ``batch_shapes`` used here, so smoke tests and the dry-run cannot drift
apart. Modality frontends (audio conv / vision patches) are stubs: the batch
carries precomputed frame/patch-position embeddings, per the assignment.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ParallelConfig, ShapeConfig
from repro.models import encdec, transformer


def is_encdec(cfg: ModelConfig) -> bool:
    return cfg.encoder_layers > 0


def init_model(cfg: ModelConfig, key):
    if is_encdec(cfg):
        return encdec.init_encdec(cfg, key)
    return transformer.init_lm(cfg, key)


def loss_fn(params, batch, cfg: ModelConfig, pcfg: ParallelConfig,
            sampled_ids=None):
    if is_encdec(cfg):
        return encdec.forward_loss(params, batch, cfg, pcfg)
    return transformer.forward_loss(params, batch, cfg, pcfg, sampled_ids)


def prefill_fn(params, batch, cfg: ModelConfig, pcfg: ParallelConfig):
    if is_encdec(cfg):
        return encdec.prefill(params, batch, cfg, pcfg)
    return transformer.prefill(params, batch, cfg, pcfg)


def decode_fn(params, cache, batch, cfg: ModelConfig, pcfg: ParallelConfig):
    if is_encdec(cfg):
        return encdec.decode_step(params, cache, batch, cfg, pcfg)
    return transformer.decode_step(params, cache, batch, cfg, pcfg)


def abstract_params(cfg: ModelConfig):
    """(ShapeDtypeStruct tree, logical specs) without allocating anything."""
    captured = {}

    def f():
        p, s = init_model(cfg, jax.random.key(0))
        captured["specs"] = s
        return p

    shapes = jax.eval_shape(f)
    return shapes, captured["specs"]


def init_cache_shapes(cfg: ModelConfig, B: int, S: int):
    """Abstract cache pytree (no allocation)."""
    if is_encdec(cfg):
        L, K, hd, Te = (cfg.num_layers, cfg.num_kv_heads, cfg.head_dim,
                        cfg.encoder_seq_len)
        bf = jnp.bfloat16
        return {
            "k": jax.ShapeDtypeStruct((L, B, S, K, hd), bf),
            "v": jax.ShapeDtypeStruct((L, B, S, K, hd), bf),
            "xk": jax.ShapeDtypeStruct((L, B, Te, K, hd), bf),
            "xv": jax.ShapeDtypeStruct((L, B, Te, K, hd), bf),
        }
    return jax.eval_shape(
        lambda: transformer.init_cache(cfg, B, S))


def init_cache(cfg: ModelConfig, B: int, S: int):
    """Concrete zero cache (smoke tests)."""
    if is_encdec(cfg):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            init_cache_shapes(cfg, B, S))
    return transformer.init_cache(cfg, B, S)


# ---------------------------------------------------------------------------
# Batch shapes (shared by smoke tests and the dry-run)
# ---------------------------------------------------------------------------


def batch_shapes(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """name -> (shape, dtype) for every model input except the cache."""
    B, S = shape.global_batch, shape.seq_len
    i32, bf = jnp.int32, jnp.bfloat16
    if shape.kind in ("train", "prefill"):
        d = {"tokens": ((B, S), i32)}
        if shape.kind == "train":
            d["labels"] = ((B, S), i32)
        if cfg.frontend == "audio":
            d["frames"] = ((B, cfg.encoder_seq_len, cfg.d_model), bf)
        if cfg.frontend == "vision":
            d["positions"] = ((3, B, S), i32)
        return d
    # decode: one token against an S-length cache
    return {"token": ((B, 1), i32), "pos": ((B,), i32)}


def make_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0) -> dict:
    """Concrete synthetic batch (numpy RNG; host side)."""
    rng = np.random.default_rng(seed)
    out = {}
    for name, (shp, dt) in batch_shapes(cfg, shape).items():
        if dt == jnp.int32:
            if name == "pos":
                out[name] = jnp.full(shp, shape.seq_len - 1, jnp.int32)
            elif name == "positions":
                B, S = shp[1], shp[2]
                out[name] = jnp.broadcast_to(
                    jnp.arange(S, dtype=jnp.int32)[None, None], shp)
            else:
                out[name] = jnp.asarray(
                    rng.integers(0, cfg.vocab_size, shp), jnp.int32)
        else:
            out[name] = jnp.asarray(rng.normal(0, 1, shp), jnp.float32
                                    ).astype(dt)
    return out
