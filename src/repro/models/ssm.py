"""Mamba2 (SSD — state-space duality) blocks.

Implements the chunked SSD algorithm of arXiv:2405.21060: within a chunk the
recurrence is computed as a masked attention-like quadratic form; across
chunks a small (heads, head_dim, state) recurrent state is carried. The
per-chunk quadratic part is the Pallas-kernel hot spot
(``repro.kernels.ssd``); this module holds the XLA path + decode recurrence.

Shapes (per block):
  x_in   (B, S, d_model)
  z, x   (B, S, d_inner)        d_inner = expand * d_model
  B, C   (B, S, G, N)           G = n_groups (1 for the assigned archs)
  dt     (B, S, nh)             nh = d_inner / head_dim
  state  (B, nh, hp, N)         hp = ssm head_dim
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, SSMConfig
from repro.models import modules as m
from repro.models.layers import rms_norm_fp32


def init_mamba(cfg: ModelConfig, key):
    s = cfg.ssm
    assert s is not None
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    gn = s.n_groups * s.state_dim
    ks = m.split_keys(key, 8)
    pairs = [
        m.named("wz", m.dense_init(ks[0], (d, di), ("embed", "ssm_inner"))),
        m.named("wx", m.dense_init(ks[1], (d, di), ("embed", "ssm_inner"))),
        m.named("wbc", m.dense_init(ks[2], (d, 2 * gn), ("embed", None))),
        m.named("wdt", m.dense_init(ks[3], (d, nh), ("embed", "ssm_heads"))),
        m.named("conv_x", m.dense_init(ks[4], (s.conv_kernel, di),
                                       (None, "ssm_inner"), scale=0.5)),
        m.named("conv_bc", m.dense_init(ks[5], (s.conv_kernel, 2 * gn),
                                        (None, None), scale=0.5)),
        m.named("dt_bias", m.zeros_init((nh,), ("ssm_heads",))),
        # A_log init ~ log(U[1,16]) (mamba2 default); deterministic spread here.
        ("A_log", jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32))),
        m.named("D", m.ones_init((nh,), ("ssm_heads",))),
        m.named("norm_scale", m.ones_init((di,), ("ssm_inner",))),
        m.named("w_out", m.dense_init(ks[6], (di, d), ("ssm_inner", "embed"))),
    ]
    pairs[7] = m.named("A_log", (pairs[7][1], ("ssm_heads",)))
    return m.merge(*pairs)


def _causal_conv(x, w, left=None):
    """Depthwise causal conv. x: (B,S,C); w: (K,C); ``left`` is the K-1 rows
    of pre-sequence context (zeros when None — the fresh-sequence case)."""
    K = w.shape[0]
    if left is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([left.astype(x.dtype), x], axis=1)
    # windowed sum: y_t = sum_k w[k] * x[t-K+1+k]
    y = jnp.zeros_like(x)
    for k in range(K):
        y = y + w[k] * jax.lax.dynamic_slice_in_dim(xp, k, x.shape[1], axis=1)
    return y


def segsum(log_a):
    """Stable 'segment sum': out[..., i, j] = sum_{j < m <= i} log_a[..., m],
    lower-triangular (i >= j), -inf above diagonal. log_a: (..., T)."""
    T = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]      # sum over (j, i]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, h0=None):
    """Chunked SSD scan.

    x: (b, S, nh, hp); dt: (b, S, nh); A: (nh,) negative; B, C: (b, S, G, N).
    Returns y: (b, S, nh, hp) and final state (b, nh, hp, N). fp32 inside.
    """
    b, S, nh, hp = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = nh // G
    dtype = x.dtype
    x, dt, B, C = (t.astype(jnp.float32) for t in (x, dt, B, C))
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)

    # reshape into chunks
    xc = x.reshape(b, nc, chunk, nh, hp)
    dtc = dt.reshape(b, nc, chunk, nh)
    Bc = B.reshape(b, nc, chunk, G, N)
    Cc = C.reshape(b, nc, chunk, G, N)
    Bh = jnp.repeat(Bc, rep, axis=3)                   # (b,nc,Q,nh,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A                                        # (b,nc,Q,nh) log decay
    dA_cum = jnp.cumsum(dA, axis=2)                     # within-chunk cumsum

    # ---- intra-chunk (quadratic) term --------------------------------------
    Lmask = segsum(dA.transpose(0, 1, 3, 2))            # (b,nc,nh,Q,Q)
    CB = jnp.einsum("bnqhs,bnkhs->bnhqk", Ch, Bh)       # (b,nc,nh,Q,Q)
    scores = CB * jnp.exp(Lmask)
    xdt = xc * dtc[..., None]                           # (b,nc,Q,nh,hp)
    y_intra = jnp.einsum("bnhqk,bnkhp->bnqhp", scores, xdt)

    # ---- chunk states + inter-chunk recurrence ------------------------------
    # state contribution of chunk: sum_j exp(dA_cum[Q-1]-dA_cum[j]) * dt_j B_j x_j
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)    # (b,nc,Q,nh)
    states = jnp.einsum("bnqh,bnqhs,bnqhp->bnhps",
                        decay_to_end * dtc, Bh, xc)          # (b,nc,nh,hp,N)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])               # (b,nc,nh)

    def carry_fn(h, inp):
        st, dec = inp                                       # (b,nh,hp,N),(b,nh)
        h_new = h * dec[..., None, None] + st
        return h_new, h                                     # emit h_in per chunk

    h_init = (jnp.zeros((b, nh, hp, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    h_last, h_ins = jax.lax.scan(
        carry_fn, h_init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_ins = h_ins.transpose(1, 0, 2, 3, 4)                  # (b,nc,nh,hp,N)

    # inter-chunk output: y_i += exp(dA_cum_i) * C_i . h_in
    y_inter = jnp.einsum("bnqh,bnqhs,bnhps->bnqhp",
                         jnp.exp(dA_cum), Ch, h_ins)
    y = (y_intra + y_inter).reshape(b, S, nh, hp)
    return y.astype(dtype), h_last


def ssd_decode_step(state, x, dt, A, B, C):
    """Single-token recurrence. x: (b,nh,hp); dt: (b,nh); B,C: (b,G,N);
    state: (b,nh,hp,N). Returns (y, new_state)."""
    G = B.shape[1]
    nh = x.shape[1]
    rep = nh // G
    x32, dt32 = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bh = jnp.repeat(B, rep, axis=1).astype(jnp.float32)      # (b,nh,N)
    Ch = jnp.repeat(C, rep, axis=1).astype(jnp.float32)
    dec = jnp.exp(dt32 * A)                                  # (b,nh)
    new_state = (state * dec[..., None, None]
                 + jnp.einsum("bh,bhs,bhp->bhps", dt32, Bh, x32))
    y = jnp.einsum("bhs,bhps->bhp", Ch, new_state)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Full Mamba2 block
# ---------------------------------------------------------------------------


def _split_proj(params, x, cfg: ModelConfig):
    s = cfg.ssm
    z = jnp.einsum("bsd,de->bse", x, params["wz"].astype(x.dtype))
    xi = jnp.einsum("bsd,de->bse", x, params["wx"].astype(x.dtype))
    bc = jnp.einsum("bsd,de->bse", x, params["wbc"].astype(x.dtype))
    dt = jnp.einsum("bsd,dh->bsh", x, params["wdt"].astype(x.dtype))
    return z, xi, bc, dt


def mamba_block(params, x, cfg: ModelConfig, state=None):
    """Full-sequence Mamba2 block (train / prefill). Returns (y, final_states)
    where final_states = (conv_tail, ssm_state) for decode continuation."""
    s: SSMConfig = cfg.ssm
    B_, S, d = x.shape
    di, nh, gn = s.d_inner(d), s.n_heads(d), s.n_groups * s.state_dim

    z, xi, bc, dt = _split_proj(params, x, cfg)
    conv_in_x, conv_in_bc = xi, bc
    xi = jax.nn.silu(_causal_conv(xi, params["conv_x"].astype(x.dtype)))
    bc = jax.nn.silu(_causal_conv(bc, params["conv_bc"].astype(x.dtype)))
    Bmat = bc[..., :gn].reshape(B_, S, s.n_groups, s.state_dim)
    Cmat = bc[..., gn:].reshape(B_, S, s.n_groups, s.state_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    xh = xi.reshape(B_, S, nh, s.head_dim)
    # pad S to a chunk multiple; dt=0 padding is an exact identity step
    # (decay exp(0)=1, contribution dt*B*x = 0), so the state is untouched.
    pad = (-S) % s.chunk_size
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    from repro.kernels import ops as kops
    y, h_last = kops.ssd(xh, dt, A, Bmat, Cmat, chunk=s.chunk_size,
                         h0=None if state is None else state[1])
    if pad:
        y, xh = y[:, :S], xh[:, :S]
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(B_, S, di)
    y = rms_norm_fp32(y * jax.nn.silu(z.astype(jnp.float32)),
                      params["norm_scale"])
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype),
                     params["w_out"].astype(x.dtype))
    conv_tail = jnp.concatenate(
        [conv_in_x, conv_in_bc], axis=-1)[:, -(s.conv_kernel - 1):, :]
    return out, (conv_tail, h_last)


def mamba_chunk(params, x, cfg: ModelConfig, state, q_lens):
    """One serving prefill chunk with explicit state continuation.

    x: (B, C, d) — a right-padded chunk of the prompt; q_lens: (B,) valid
    tokens per row; state = (conv_tail (B, K-1, di+2gn), ssm_state
    (B, nh, hp, N)) from the previous chunk (all-zeros for a fresh
    sequence, which reproduces ``mamba_block``'s zero conv padding and
    zero h0 exactly). Returns (y (B, C, d), new_state).

    Padding rows are *identity* steps: dt is masked to 0 past q_lens, so
    the decay is exp(0) = 1 and the state contribution dt·B·x = 0 — the
    carried state is bitwise untouched. When every chunk boundary falls on
    a multiple of ``cfg.ssm.chunk_size`` (the serving scheduler's chunk
    quantum; the final chunk is exempt), the inner SSD chunk grouping is
    identical to a monolithic ``mamba_block`` prefill, so chunked and
    monolithic greedy outputs match bit for bit.
    """
    s: SSMConfig = cfg.ssm
    B_, C, d = x.shape
    di, nh, gn = s.d_inner(d), s.n_heads(d), s.n_groups * s.state_dim
    conv_tail, h0 = state

    z, xi, bc, dt = _split_proj(params, x, cfg)
    conv_in = jnp.concatenate([xi, bc], axis=-1)            # (B,C,di+2gn)
    xi = jax.nn.silu(_causal_conv(xi, params["conv_x"].astype(x.dtype),
                                  left=conv_tail[..., :di]))
    bc = jax.nn.silu(_causal_conv(bc, params["conv_bc"].astype(x.dtype),
                                  left=conv_tail[..., di:]))
    Bmat = bc[..., :gn].reshape(B_, C, s.n_groups, s.state_dim)
    Cmat = bc[..., gn:].reshape(B_, C, s.n_groups, s.state_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    valid = jnp.arange(C, dtype=jnp.int32)[None] < q_lens[:, None]
    dt = jnp.where(valid[..., None], dt, 0.0)   # padding: exact identity
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    xh = xi.reshape(B_, C, nh, s.head_dim)
    pad = (-C) % s.chunk_size
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    from repro.kernels import ops as kops
    y, h_new = kops.ssd(xh, dt, A, Bmat, Cmat, chunk=s.chunk_size,
                        h0=h0.astype(jnp.float32))
    if pad:
        y, xh = y[:, :C], xh[:, :C]
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(B_, C, di)
    y = rms_norm_fp32(y * jax.nn.silu(z.astype(jnp.float32)),
                      params["norm_scale"])
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype),
                     params["w_out"].astype(x.dtype))
    # new conv tail = last K-1 conv inputs ending at each row's q_len
    # (rows with q_len 0 keep their previous tail — self-masking)
    full_in = jnp.concatenate(
        [conv_tail.astype(conv_in.dtype), conv_in], axis=1)
    new_tail = jax.vmap(
        lambda f, n: jax.lax.dynamic_slice_in_dim(
            f, n, s.conv_kernel - 1, axis=0))(full_in, q_lens)
    return out, (new_tail, h_new)


def mamba_decode(params, x, cfg: ModelConfig, state):
    """Single-token decode. x: (B,1,d); state = (conv_tail (B,K-1,di+2gn),
    ssm_state (B,nh,hp,N)). Returns (y (B,1,d), new_state)."""
    s: SSMConfig = cfg.ssm
    B_, _, d = x.shape
    di, nh, gn = s.d_inner(d), s.n_heads(d), s.n_groups * s.state_dim
    conv_tail, h = state

    z, xi, bc, dt = _split_proj(params, x, cfg)
    conv_new = jnp.concatenate([xi, bc], axis=-1)       # (B,1,di+2gn)
    window = jnp.concatenate([conv_tail, conv_new], axis=1)  # (B,K,di+2gn)
    wx = params["conv_x"].astype(x.dtype)
    wbc = params["conv_bc"].astype(x.dtype)
    w_full = jnp.concatenate([wx, wbc], axis=-1)        # (K, di+2gn)
    conv_out = jnp.einsum("bkc,kc->bc", window, w_full)
    conv_out = jax.nn.silu(conv_out)
    xi1, bc1 = conv_out[..., :di], conv_out[..., di:]
    Bmat = bc1[..., :gn].reshape(B_, s.n_groups, s.state_dim)
    Cmat = bc1[..., gn:].reshape(B_, s.n_groups, s.state_dim)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xi1.reshape(B_, nh, s.head_dim)
    y, h_new = ssd_decode_step(h, xh, dt1, A, Bmat, Cmat)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B_, di)
    y = rms_norm_fp32(y * jax.nn.silu(z[:, 0].astype(jnp.float32)),
                      params["norm_scale"])
    out = jnp.einsum("be,ed->bd", y.astype(x.dtype),
                     params["w_out"].astype(x.dtype))
    new_tail = window[:, 1:, :]
    return out[:, None, :], (new_tail, h_new)
