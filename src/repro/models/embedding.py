"""Vocab-sharded embeddings and softmax losses (paper §4.2, §6.4).

The embedding table is the canonical "too big to replicate" state. The paper
shards it across PS tasks and builds the lookup as
DynamicPartition → Gather (colocated with the shard) → DynamicStitch.
Here the table is sharded over the "model" mesh axis on its vocab dim and the
same three steps happen inside shard_map:

  Part:    each shard masks the token ids that fall in its vocab range
  Gather:  a local table gather (Pallas kernel on TPU)
  Stitch:  psum over the "model" axis (out-of-range rows contribute zeros)

The LM head is the transpose: vocab-parallel cross-entropy that never
materializes a replicated (T, V) logit matrix (max/lse stitched with
pmax/psum), token-chunked so the live logit block is (chunk, V/tp).
``sampled_softmax_loss`` implements the paper's §6.4 sampled softmax.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models import modules as m
from repro.models.layers import softcap
from repro.spmd.sharding import dp_axes

NEG = -1.0e30


def init_embedding(cfg: ModelConfig, key):
    ks = m.split_keys(key, 2)
    V = cfg.padded_vocab_size
    pairs = [m.named("table", m.dense_init(
        ks[0], (V, cfg.d_model), ("vocab", "embed"), scale=0.02))]
    if not cfg.tie_embeddings:
        pairs.append(m.named("head", m.dense_init(
            ks[1], (V, cfg.d_model), ("vocab", "embed"))))
    return m.merge(*pairs)


def head_table(params, cfg: ModelConfig):
    return params["table"] if cfg.tie_embeddings else params["head"]


def _dp_spec(mesh, n: int):
    dp = dp_axes(mesh)
    sz = math.prod(mesh.shape[a] for a in dp) if dp else 1
    if dp and n % sz == 0:
        return dp if len(dp) > 1 else dp[0]
    return None


def embed(table, tokens, cfg: ModelConfig):
    """tokens: (B, S) int32 -> (B, S, d). Part/Gather/Stitch over "model"."""
    mesh = jax.sharding.get_abstract_mesh()
    dps = _dp_spec(mesh, tokens.shape[0])

    def body(table_l, tok):
        V_l = table_l.shape[0]
        off = jax.lax.axis_index("model") * V_l
        loc = tok - off
        ok = (loc >= 0) & (loc < V_l)
        from repro.kernels import ops as kops
        rows = kops.embedding_gather(table_l, jnp.clip(loc, 0, V_l - 1))
        rows = jnp.where(ok[..., None], rows, 0)
        return jax.lax.psum(rows, "model")

    out = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P("model", None), P(dps, None)),
        out_specs=P(dps, None, None),
    )(table, tokens)
    out = out.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    if cfg.embedding_scale:
        out = out * jnp.asarray(math.sqrt(cfg.d_model), out.dtype)
    return out


def _xent_local(x, table_l, labels, off, V_l, cap, chunk, v_real):
    """Chunked vocab-parallel cross-entropy partials. x: (T, d), any dtype —
    the logits matmul keeps bf16 inputs with an fp32 MXU accumulator
    (half the HBM reads of an fp32 upcast; §Perf iteration 2).

    Returns (lse_partials (T,), true_logit_partials (T,)) before stitching:
    local max/sumexp need a pmax/psum combine by the caller. Columns at or
    beyond ``v_real`` are vocab padding and masked out.
    """
    T, d = x.shape
    nc = max(T // chunk, 1)
    xc = x.reshape(nc, T // nc, d)
    lc = labels.reshape(nc, T // nc)
    col_ok = (off + jnp.arange(V_l)) < v_real

    def body(_, inp):
        xb, lb = inp
        logits = jnp.einsum("td,vd->tv", xb, table_l,
                            preferred_element_type=jnp.float32)
        logits = softcap(logits, cap)
        logits = jnp.where(col_ok[None, :], logits, NEG)
        # LSE is exact for any constant shift -> stop_gradient keeps the
        # backward pass the plain (softmax - onehot) form with no pmax-grad.
        mx = jax.lax.stop_gradient(logits.max(axis=-1))
        loc = lb - off
        ok = (loc >= 0) & (loc < V_l)
        tl = jnp.take_along_axis(
            logits, jnp.clip(loc, 0, V_l - 1)[:, None], axis=1)[:, 0]
        tl = jnp.where(ok, tl, 0.0)
        # stable partial: sum of exp(logits - gmax) needs the global max;
        # emit (mx, sumexp-at-local-max) and let the caller rescale.
        se = jnp.exp(logits - mx[:, None]).sum(axis=-1)
        return None, (mx, se, tl)

    _, (mx, se, tl) = jax.lax.scan(body, None, (xc, lc))
    return mx.reshape(T), se.reshape(T), tl.reshape(T)


def lm_loss(x, table, labels, cfg: ModelConfig, chunk: int = 4096):
    """Mean token cross-entropy. x: (B, S, d); labels: (B, S).

    Vocab-parallel: logits live only as (chunk, V/tp) blocks per shard.
    """
    mesh = jax.sharding.get_abstract_mesh()
    B, S, d = x.shape
    dps = _dp_spec(mesh, B)
    cap = cfg.final_logit_softcap

    def body(x, table_l, labels):
        b, s, _ = x.shape
        T = b * s
        V_l = table_l.shape[0]
        off = jax.lax.axis_index("model") * V_l
        ck = chunk if T % chunk == 0 else T
        mx, se, tl = _xent_local(
            x.reshape(T, d), table_l.astype(x.dtype), labels.reshape(T),
            off, V_l, cap, ck, cfg.vocab_size)
        gmx = jax.lax.stop_gradient(jax.lax.pmax(mx, "model"))
        se = jax.lax.psum(se * jnp.exp(mx - gmx), "model")
        tl = jax.lax.psum(tl, "model")
        loss = jnp.log(se) + gmx - tl
        loss = loss.mean()
        dp = dp_axes(mesh)
        return jax.lax.pmean(loss, dp) if dp else loss

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(dps, None, None), P("model", None), P(dps, None)),
        out_specs=P(),
    )(x, table, labels)


def sampled_softmax_loss(x, table, labels, sampled_ids, cfg: ModelConfig):
    """Paper §4.2/§6.4: softmax over {true class} ∪ {S sampled classes}.

    The (S+1)-row weight slice is gathered from the vocab-sharded table
    (Part/Gather/Stitch again), then the small softmax runs data-parallel.
    x: (B, S, d); labels: (B, S); sampled_ids: (n_samples,).
    """
    mesh = jax.sharding.get_abstract_mesh()
    B, S, d = x.shape
    dps = _dp_spec(mesh, B)
    cap = cfg.final_logit_softcap

    def body(x, table_l, labels, sampled_ids):
        b, s, _ = x.shape
        T = b * s
        xt = x.reshape(T, d).astype(jnp.float32)
        lab = labels.reshape(T)
        V_l = table_l.shape[0]
        off = jax.lax.axis_index("model") * V_l
        tl32 = table_l.astype(jnp.float32)

        def shard_gather(ids):
            loc = ids - off
            ok = (loc >= 0) & (loc < V_l)
            rows = tl32[jnp.clip(loc, 0, V_l - 1)]
            return jax.lax.psum(jnp.where(ok[..., None], rows, 0), "model")

        w_true = shard_gather(lab)                       # (T, d)
        w_samp = shard_gather(sampled_ids)               # (n, d)
        lt = softcap(jnp.sum(xt * w_true, -1), cap)
        ls = softcap(xt @ w_samp.T, cap)
        ls = jnp.where(sampled_ids[None, :] == lab[:, None], NEG, ls)
        mx = jnp.maximum(lt, ls.max(-1))
        lse = mx + jnp.log(jnp.exp(lt - mx) + jnp.exp(ls - mx[:, None]).sum(-1))
        loss = (lse - lt).mean()
        dp = dp_axes(mesh)
        return jax.lax.pmean(loss, dp) if dp else loss

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(dps, None, None), P("model", None), P(dps, None), P(None)),
        out_specs=P(),
    )(x, table, labels, sampled_ids)


def decode_logits(x, table, cfg: ModelConfig):
    """Full vocab-parallel logits for sampling. x: (B, 1, d) -> (B, V_pad)
    fp32, vocab-padding columns masked to NEG; the output stays sharded
    over "model" on its vocab dim (the shard_map out_spec reassembles)."""
    mesh = jax.sharding.get_abstract_mesh()
    B = x.shape[0]
    dps = _dp_spec(mesh, B)
    cap = cfg.final_logit_softcap

    def body(x, table_l):
        V_l = table_l.shape[0]
        off = jax.lax.axis_index("model") * V_l
        logits = jnp.einsum("bd,vd->bv", x[:, 0].astype(jnp.float32),
                            table_l.astype(jnp.float32))
        logits = softcap(logits, cap)
        col_ok = (off + jnp.arange(V_l)) < cfg.vocab_size
        return jnp.where(col_ok[None, :], logits, NEG)

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(dps, None, None), P("model", None)),
        out_specs=P(dps, "model"),
    )(x, table)


def decode_logits_argmax(x, table, cfg: ModelConfig):
    """Greedy next token from vocab-parallel logits. x: (B, 1, d) -> (B,)."""
    mesh = jax.sharding.get_abstract_mesh()
    B = x.shape[0]
    dps = _dp_spec(mesh, B)
    cap = cfg.final_logit_softcap

    def body(x, table_l):
        V_l = table_l.shape[0]
        off = jax.lax.axis_index("model") * V_l
        logits = jnp.einsum("bd,vd->bv", x[:, 0].astype(jnp.float32),
                            table_l.astype(jnp.float32))
        logits = softcap(logits, cap)
        col_ok = (off + jnp.arange(V_l)) < cfg.vocab_size
        logits = jnp.where(col_ok[None, :], logits, NEG)
        mx = logits.max(-1)
        am = off + jnp.argmax(logits, -1).astype(jnp.int32)
        # stitch: pick argmax across shards
        all_mx = jax.lax.all_gather(mx, "model", axis=0)     # (tp, B)
        all_am = jax.lax.all_gather(am, "model", axis=0)
        best = jnp.argmax(all_mx, axis=0)
        return jnp.take_along_axis(all_am, best[None, :], axis=0)[0]

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(dps, None, None), P("model", None)),
        out_specs=P(dps),
        check_vma=False,   # result is replicated over "model" post-gather
    )(x, table)
