"""Decoder-only LM assembly covering all assigned families:

  dense GQA (glm4, starcoder2, qwen3), alternating local/global + softcaps
  (gemma2), M-RoPE VLM backbone (qwen2-vl), MoE (qwen3-moe, grok-1), pure SSM
  (mamba2) and hybrid SSM + shared-attention (zamba2).

Layers are stacked into *periods* and scanned with ``lax.scan`` (one period =
one tile of ``block_pattern``, or ``shared_attn_period`` mamba blocks + one
application of the shared attention block for zamba2). Scanning keeps the
HLO small at 64 layers and is what the dry-run compiles.

Entry points per model: ``loss_fn`` (train), ``prefill`` (build cache, emit
first token), ``decode_step`` (one token against the cache), and the paged
serving pair ``prefill_chunk_paged`` / ``decode_step_paged`` (prompt chunks
and single tokens against block-paged page pools). The serving pair is
mesh-aware through the attention ops: on a mesh with a "model" axis the
page pools arrive sharded by kv head and ``paged_decode_attention`` /
``paged_chunk_attention`` run under shard_map over their local head
slices (docs/multi-host.md); nothing here mentions the mesh.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ParallelConfig
from repro.models import modules as m
from repro.models import moe as moe_mod
from repro.models import quant
from repro.models import ssm as ssm_mod
from repro.models.attention import (attention_scale, decode_attention,
                                    init_attention, out_proj,
                                    paged_chunk_attention,
                                    paged_decode_attention, project_kv,
                                    project_q, ragged_chunk_update_attend,
                                    sharded_attention, update_cache,
                                    update_paged_cache,
                                    update_paged_cache_chunk)
from repro.models.embedding import (decode_logits, decode_logits_argmax,
                                    embed, head_table, init_embedding,
                                    lm_loss, sampled_softmax_loss)
from repro.models.layers import apply_norm, init_mlp, apply_mlp, init_norm, \
    rope_cos_sin

MOE_AUX_COEF = 0.01


# ---------------------------------------------------------------------------
# Structure
# ---------------------------------------------------------------------------


def period_structure(cfg: ModelConfig) -> tuple[tuple[str, ...], int]:
    """(kinds within one period, number of periods)."""
    if cfg.shared_attn_period:
        P = cfg.shared_attn_period
        kinds = cfg.layer_kinds()[:P]
    else:
        kinds = cfg.block_pattern
        P = len(kinds)
    assert cfg.num_layers % P == 0, (cfg.num_layers, P)
    return tuple(kinds), cfg.num_layers // P


def _init_block(kind: str, cfg: ModelConfig, key):
    ks = m.split_keys(key, 4)
    if kind == "mamba":
        return m.merge(
            m.named("norm", init_norm(cfg)),
            m.named("mamba", ssm_mod.init_mamba(cfg, ks[0])),
        )
    pairs = [
        m.named("norm", init_norm(cfg)),
        m.named("attn", init_attention(cfg, ks[0])),
        m.named("norm2", init_norm(cfg)),
    ]
    if cfg.moe is not None:
        pairs.append(m.named("moe", moe_mod.init_moe(cfg, ks[1])))
    else:
        pairs.append(m.named("mlp", init_mlp(cfg, ks[1])))
    if cfg.post_block_norm:
        pairs.append(m.named("post_norm", init_norm(cfg)))
        pairs.append(m.named("post_norm2", init_norm(cfg)))
    return m.merge(*pairs)


def init_lm(cfg: ModelConfig, key):
    kinds, NP = period_structure(cfg)
    ks = m.split_keys(key, NP * len(kinds) + 4)
    ki = iter(ks)
    pairs = [m.named("embed", init_embedding(cfg, next(ki)))]
    blocks_p, blocks_s = {}, {}
    for i, kind in enumerate(kinds):
        per = [_init_block(kind, cfg, next(ki)) for _ in range(NP)]
        p, s = m.stack_layer_params(per)
        blocks_p[f"sub{i}"], blocks_s[f"sub{i}"] = p, s
    pairs.append(({"blocks": blocks_p}, {"blocks": blocks_s}))
    if cfg.shared_attn_period:
        shared_cfg = cfg
        pairs.append(m.named("shared", _init_shared(shared_cfg, next(ki))))
    pairs.append(m.named("final_norm", init_norm(cfg)))
    return m.merge(*pairs)


def _init_shared(cfg: ModelConfig, key):
    ks = m.split_keys(key, 2)
    return m.merge(
        m.named("norm", init_norm(cfg)),
        m.named("attn", init_attention(cfg, ks[0])),
        m.named("norm2", init_norm(cfg)),
        m.named("mlp", init_mlp(cfg, ks[1])),
    )


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _attn_full(bp, x, cfg: ModelConfig, ctx, kind: str):
    """Full-sequence self attention (train / prefill). Returns (y, cache)."""
    window = cfg.sliding_window if kind == "local" else None
    h = apply_norm(bp["norm"], x, cfg)
    q = project_q(bp["attn"], h, cfg, ctx["cos_sin"])
    k, v = project_kv(bp["attn"], h, cfg, ctx["cos_sin"])
    y = sharded_attention(
        q, k, v, cfg, causal=True, window=window,
        cap=cfg.attn_logit_softcap, scale=attention_scale(cfg),
        chunk_kv=min(1024, k.shape[1]))
    y = out_proj(bp["attn"], y, x.dtype)
    if cfg.post_block_norm:
        y = apply_norm(bp["post_norm"], y, cfg)
    x = x + y
    return x, {"k": k, "v": v}


def _mlp_part(bp, x, cfg: ModelConfig, ctx=None):
    h = apply_norm(bp["norm2"], x, cfg)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None and "moe" in bp:
        y, aux = moe_mod.moe_block(bp["moe"], h, cfg,
                                   f2d=bool(ctx and ctx.get("moe_f2d")))
    else:
        y = apply_mlp(bp["mlp"], h, cfg)
    if cfg.post_block_norm:
        y = apply_norm(bp["post_norm2"], y, cfg)
    return x + y, aux


def _attn_decode(bp, x, cfg: ModelConfig, ctx, cache, kind: str):
    window = cfg.sliding_window if kind == "local" else None
    h = apply_norm(bp["norm"], x, cfg)
    q = project_q(bp["attn"], h, cfg, ctx["cos_sin"])
    k, v = project_kv(bp["attn"], h, cfg, ctx["cos_sin"])
    kc = update_cache(cache["k"], k, ctx["pos"])
    vc = update_cache(cache["v"], v, ctx["pos"])
    y = decode_attention(q, kc, vc, ctx["pos"], window=window,
                         cap=cfg.attn_logit_softcap,
                         scale=attention_scale(cfg))
    y = out_proj(bp["attn"], y, x.dtype)
    if cfg.post_block_norm:
        y = apply_norm(bp["post_norm"], y, cfg)
    return x + y, {"k": kc, "v": vc}


def _attn_decode_paged(bp, x, cfg: ModelConfig, ctx, cache, kind: str):
    """One-token attention against a block-paged KV cache (serving engine).
    cache: {"k","v"} page pools (num_blocks, block_size, K, hd), plus
    {"k_scale","v_scale"} fp32 per-row scale pools when quantized — the
    new row quantizes before the scatter (no bf16 pool copy) and the
    dequant is fused into the attention kernel."""
    window = cfg.sliding_window if kind == "local" else None
    h = apply_norm(bp["norm"], x, cfg)
    q = project_q(bp["attn"], h, cfg, ctx["cos_sin"])
    k, v = project_kv(bp["attn"], h, cfg, ctx["cos_sin"])
    if "k_scale" in cache:
        kvd = quant.kv_dtype_name(cache["k"].dtype)
        k, ksr = quant.quantize_kv(k, kvd)
        v, vsr = quant.quantize_kv(v, kvd)
        ksc = update_paged_cache(cache["k_scale"], ksr,
                                 ctx["block_tables"], ctx["pos"])
        vsc = update_paged_cache(cache["v_scale"], vsr,
                                 ctx["block_tables"], ctx["pos"])
        scales = {"k_scale": ksc, "v_scale": vsc}
    else:
        ksc = vsc = None
        scales = {}
    kc = update_paged_cache(cache["k"], k, ctx["block_tables"], ctx["pos"])
    vc = update_paged_cache(cache["v"], v, ctx["block_tables"], ctx["pos"])
    y = paged_decode_attention(q, kc, vc, ctx["block_tables"],
                               ctx["ctx_lens"], window=window,
                               cap=cfg.attn_logit_softcap,
                               scale=attention_scale(cfg),
                               k_scale=ksc, v_scale=vsc)
    y = out_proj(bp["attn"], y, x.dtype)
    if cfg.post_block_norm:
        y = apply_norm(bp["post_norm"], y, cfg)
    return x + y, {"k": kc, "v": vc, **scales}


def _attn_chunk_paged(bp, x, cfg: ModelConfig, ctx, cache, kind: str):
    """Chunked-prefill attention against a block-paged KV cache: scatter
    this chunk's KV into the pages, then attend the chunk's queries
    causally over the whole paged context (prior chunks included).
    cache: {"k","v"} page pools (num_blocks, block_size, K, hd)."""
    window = cfg.sliding_window if kind == "local" else None
    h = apply_norm(bp["norm"], x, cfg)
    q = project_q(bp["attn"], h, cfg, ctx["cos_sin"])
    k, v = project_kv(bp["attn"], h, cfg, ctx["cos_sin"])
    if "k_scale" in cache:
        kvd = quant.kv_dtype_name(cache["k"].dtype)
        k, ksr = quant.quantize_kv(k, kvd)
        v, vsr = quant.quantize_kv(v, kvd)
        ksc = update_paged_cache_chunk(cache["k_scale"], ksr,
                                       ctx["block_tables"], ctx["q_start"],
                                       ctx["q_lens"])
        vsc = update_paged_cache_chunk(cache["v_scale"], vsr,
                                       ctx["block_tables"], ctx["q_start"],
                                       ctx["q_lens"])
        scales = {"k_scale": ksc, "v_scale": vsc}
    else:
        ksc = vsc = None
        scales = {}
    kc = update_paged_cache_chunk(cache["k"], k, ctx["block_tables"],
                                  ctx["q_start"], ctx["q_lens"])
    vc = update_paged_cache_chunk(cache["v"], v, ctx["block_tables"],
                                  ctx["q_start"], ctx["q_lens"])
    y = paged_chunk_attention(q, kc, vc, ctx["block_tables"],
                              ctx["ctx_lens"], ctx["q_lens"], window=window,
                              cap=cfg.attn_logit_softcap,
                              scale=attention_scale(cfg),
                              k_scale=ksc, v_scale=vsc)
    y = out_proj(bp["attn"], y, x.dtype)
    if cfg.post_block_norm:
        y = apply_norm(bp["post_norm"], y, cfg)
    return x + y, {"k": kc, "v": vc, **scales}


def _attn_ragged_paged(bp, x, cfg: ModelConfig, ctx, cache, kind: str):
    """Packed (ragged) chunked-prefill attention against a block-paged KV
    cache: chunks of several sequences ride one flat (1, T, d) row batch.
    The KV scatter and the attention run as one fused op on the Pallas
    path; row-wise projections/MLP are shared across the pack."""
    window = cfg.sliding_window if kind == "local" else None
    h = apply_norm(bp["norm"], x, cfg)
    q = project_q(bp["attn"], h, cfg, ctx["cos_sin"])
    k, v = project_kv(bp["attn"], h, cfg, ctx["cos_sin"])
    if "k_scale" in cache:
        y, kc, vc, ksc, vsc = ragged_chunk_update_attend(
            q, k, v, cache["k"], cache["v"], ctx["block_tables"],
            ctx["ctx_lens"], ctx["starts"], ctx["ends"], ctx["row_seq"],
            window=window, cap=cfg.attn_logit_softcap,
            scale=attention_scale(cfg), k_scale=cache["k_scale"],
            v_scale=cache["v_scale"])
        new_cache = {"k": kc, "v": vc, "k_scale": ksc, "v_scale": vsc}
    else:
        y, kc, vc = ragged_chunk_update_attend(
            q, k, v, cache["k"], cache["v"], ctx["block_tables"],
            ctx["ctx_lens"], ctx["starts"], ctx["ends"], ctx["row_seq"],
            window=window, cap=cfg.attn_logit_softcap,
            scale=attention_scale(cfg))
        new_cache = {"k": kc, "v": vc}
    y = out_proj(bp["attn"], y, x.dtype)
    if cfg.post_block_norm:
        y = apply_norm(bp["post_norm"], y, cfg)
    return x + y, new_cache


def _block_apply(kind, bp, x, cfg, ctx, mode, cache=None):
    """Returns (x, new_cache, aux)."""
    zero = jnp.zeros((), jnp.float32)
    if kind == "mamba":
        h = apply_norm(bp["norm"], x, cfg)
        if mode in ("decode", "decode_paged"):
            # the per-slot (conv_tail, ssm_state) cache is the serving
            # SlotStateCache's device half: same entry for both cache kinds
            y, st = ssm_mod.mamba_decode(bp["mamba"], h, cfg, cache)
            return x + y, st, zero
        if mode == "chunk_paged":
            y, st = ssm_mod.mamba_chunk(bp["mamba"], h, cfg, cache,
                                        ctx["q_lens"])
            return x + y, st, zero
        if mode == "ragged_paged":
            raise NotImplementedError(
                "packed prefill needs per-row chunk state; SSM blocks are "
                "gated out by ModelRunner.supports_packed_prefill")
        y, st = ssm_mod.mamba_block(bp["mamba"], h, cfg)
        return x + y, (st if mode == "prefill" else None), zero
    if mode == "ragged_paged":
        x, c = _attn_ragged_paged(bp, x, cfg, ctx, cache, kind)
        x, aux = _mlp_part(bp, x, cfg, ctx)
        return x, c, aux
    if mode == "chunk_paged":
        x, c = _attn_chunk_paged(bp, x, cfg, ctx, cache, kind)
        x, aux = _mlp_part(bp, x, cfg, ctx)
        return x, c, aux
    if mode == "decode_paged":
        x, c = _attn_decode_paged(bp, x, cfg, ctx, cache, kind)
        x, aux = _mlp_part(bp, x, cfg, ctx)
        return x, c, aux
    if mode == "decode":
        x, c = _attn_decode(bp, x, cfg, ctx, cache, kind)
        x, aux = _mlp_part(bp, x, cfg, ctx)
        return x, c, aux
    x, c = _attn_full(bp, x, cfg, ctx, kind)
    x, aux = _mlp_part(bp, x, cfg, ctx)
    return x, (c if mode == "prefill" else None), aux


# ---------------------------------------------------------------------------
# Context (positions / rope tables)
# ---------------------------------------------------------------------------


def _make_ctx(cfg: ModelConfig, positions, pcfg: ParallelConfig = None):
    cos_sin = rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta,
                           cfg.rope_sections) if cfg.num_heads else None
    pos = positions if positions.ndim == 1 else None
    return {"cos_sin": cos_sin, "pos": pos,
            "moe_f2d": bool(pcfg and pcfg.expert_ff_2d)}


def _default_positions(batch, B, S):
    if "positions" in batch:
        return batch["positions"]
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _scan_periods(params, x, cfg: ModelConfig, ctx, mode: str,
                  pcfg: ParallelConfig, cache=None):
    """Scan the period body over NP periods.

    mode="train":    xs=blocks,         carry=(x, aux), ys=None
    mode="prefill":  xs=blocks,         carry=(x, aux), ys=cache slices
    mode="decode":   xs=(blocks,cache), carry=(x, aux), ys=new cache slices
    """
    kinds, NP = period_structure(cfg)

    # Megatron-style sequence parallelism: keep the residual stream sharded
    # over "model" on the seq dim between blocks. GSPMD then turns the TP
    # activation all-reduces into reduce-scatter + all-gather pairs (half
    # the wire bytes) and the remat-saved carries shrink by the TP degree.
    def _sp_constrain(x):
        if not (pcfg.seq_shard_activations and mode == "train"):
            return x
        mesh = jax.sharding.get_abstract_mesh()
        tp = mesh.shape.get("model", 1)
        if tp <= 1 or x.shape[1] % tp != 0:
            return x
        from repro.spmd.sharding import batch_spec
        from jax.sharding import PartitionSpec as P, NamedSharding
        b = batch_spec(x.shape[0], mesh, extra_dims=0)
        spec = P(b[0] if len(b) else None, "model", None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    def body(carry, xs):
        x, aux = carry
        if mode in ("decode", "decode_paged", "chunk_paged", "ragged_paged"):
            bslices, cslices = xs
        else:
            bslices, cslices = xs, None
        new_cache = {}
        for i, kind in enumerate(kinds):
            cc = None if cslices is None else cslices.get(f"sub{i}")
            x, c, a = _block_apply(kind, bslices[f"sub{i}"], x, cfg, ctx,
                                   mode, cc)
            aux = aux + a
            if c is not None:
                new_cache[f"sub{i}"] = c
        if cfg.shared_attn_period:
            sp = params["shared"]
            cc = None if cslices is None else cslices.get("shared")
            if mode == "chunk_paged":
                x, c = _attn_chunk_paged(sp, x, cfg, ctx, cc, "attn")
            elif mode == "decode_paged":
                x, c = _attn_decode_paged(sp, x, cfg, ctx, cc, "attn")
            elif mode == "decode":
                x, c = _attn_decode(sp, x, cfg, ctx, cc, "attn")
            else:
                x, c = _attn_full(sp, x, cfg, ctx, "attn")
                c = c if mode == "prefill" else None
            h = apply_norm(sp["norm2"], x, cfg)
            x = x + apply_mlp(sp["mlp"], h, cfg)
            if c is not None:
                new_cache["shared"] = c
        x = _sp_constrain(x)
        return (x, aux), (new_cache if new_cache else None)

    if pcfg.remat != "none" and mode == "train":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if pcfg.remat == "dots" else None)
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)

    xs = ((params["blocks"], cache)
          if mode in ("decode", "decode_paged", "chunk_paged", "ragged_paged")
          else params["blocks"])
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, aux, caches


def forward_loss(params, batch, cfg: ModelConfig, pcfg: ParallelConfig,
                 sampled_ids=None):
    """batch: tokens (B,S), labels (B,S) [, positions]. Returns (loss, metr)."""
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    x = embed(params["embed"]["table"], tokens, cfg)
    ctx = _make_ctx(cfg, _default_positions(batch, B, S), pcfg)
    x, aux, _ = _scan_periods(params, x, cfg, ctx, "train", pcfg)
    x = apply_norm(params["final_norm"], x, cfg)
    ht = head_table(params["embed"], cfg)
    if sampled_ids is not None:
        ce = sampled_softmax_loss(x, ht, labels, sampled_ids, cfg)
    else:
        ce = lm_loss(x, ht, labels, cfg)
    loss = ce + MOE_AUX_COEF * aux
    return loss, {"ce": ce, "aux": aux}


def init_cache(cfg: ModelConfig, B: int, S: int, dtype=jnp.bfloat16):
    """Zero cache pytree matching prefill/decode layouts."""
    kinds, NP = period_structure(cfg)
    cache = {}
    for i, kind in enumerate(kinds):
        if kind == "mamba":
            s = cfg.ssm
            di = s.d_inner(cfg.d_model)
            gn = s.n_groups * s.state_dim
            cache[f"sub{i}"] = (
                jnp.zeros((NP, B, s.conv_kernel - 1, di + 2 * gn), dtype),
                jnp.zeros((NP, B, s.n_heads(cfg.d_model), s.head_dim,
                           s.state_dim), jnp.float32))
        else:
            cache[f"sub{i}"] = {
                "k": jnp.zeros((NP, B, S, cfg.num_kv_heads, cfg.head_dim),
                               dtype),
                "v": jnp.zeros((NP, B, S, cfg.num_kv_heads, cfg.head_dim),
                               dtype)}
    if cfg.shared_attn_period:
        cache["shared"] = {
            "k": jnp.zeros((NP, B, S, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((NP, B, S, cfg.num_kv_heads, cfg.head_dim), dtype)}
    return cache


def prefill(params, batch, cfg: ModelConfig, pcfg: ParallelConfig):
    """Process the prompt; returns (cache, next_token (B,)).

    Attention caches hold the prompt's K/V; SSM blocks return their final
    (conv_tail, state). Cache seq capacity == prompt length (the dry-run
    decode shapes supply their own full-length cache).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed(params["embed"]["table"], tokens, cfg)
    ctx = _make_ctx(cfg, _default_positions(batch, B, S), pcfg)
    x, _, caches = _scan_periods(params, x, cfg, ctx, "prefill", pcfg)
    x = apply_norm(params["final_norm"], x, cfg)
    nxt = decode_logits_argmax(x[:, -1:], head_table(params["embed"], cfg),
                               cfg)
    return caches, nxt


def prefill_chunk_paged(params, cache, batch, cfg: ModelConfig,
                        pcfg: ParallelConfig, *, all_logits: bool = False):
    """One chunk of prompt prefill against a block-paged KV cache.

    batch: tokens (B, C) the chunk's token slice (right-padded), q_start
    (B,) absolute position of column 0 (= tokens already computed), q_lens
    (B,) valid columns, block_tables (B, nb), ctx_lens (B,) visible tokens
    including this chunk (= q_start + q_lens).
    Returns (logits (B, V_pad) fp32 at each row's last valid token,
    new_cache). The engine samples from the logits only when the chunk
    completes its prompt. With ``all_logits=True`` the logits cover every
    chunk position — (B, C, V_pad) — which is what the speculative verify
    step needs: one widened pass scoring all K+1 candidate positions.
    """
    tokens = batch["tokens"]
    B, C = tokens.shape
    assert cfg.rope_sections is None, "chunked prefill: no M-RoPE frontends"
    x = embed(params["embed"]["table"], tokens, cfg)
    positions = batch["q_start"][:, None] + jnp.arange(C, dtype=jnp.int32)
    cos_sin = (rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta,
                            cfg.rope_sections) if cfg.num_heads else None)
    ctx = {"cos_sin": cos_sin, "pos": None,
           "q_start": batch["q_start"], "q_lens": batch["q_lens"],
           "block_tables": batch["block_tables"],
           "ctx_lens": batch["ctx_lens"],
           "moe_f2d": bool(pcfg and pcfg.expert_ff_2d)}
    x, _, new_cache = _scan_periods(params, x, cfg, ctx, "chunk_paged",
                                    ParallelConfig(remat="none"), cache)
    x = apply_norm(params["final_norm"], x, cfg)
    ht = head_table(params["embed"], cfg)
    if all_logits:
        logits = decode_logits(x.reshape(B * C, 1, -1), ht, cfg)
        return logits.reshape(B, C, -1), new_cache
    last = jnp.clip(batch["q_lens"] - 1, 0, C - 1)
    x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)   # (B,1,d)
    logits = decode_logits(x_last, ht, cfg)
    return logits, new_cache


def prefill_chunk_ragged(params, cache, batch, cfg: ModelConfig,
                         pcfg: ParallelConfig):
    """Packed (ragged) prompt prefill: chunks of up to S sequences ride one
    flat token batch against the block-paged KV cache.

    batch: tokens (1, T) chunks packed back to back (right-padded),
    positions (1, T) each row's absolute position, starts/ends (S,) flat
    row ranges per packed sequence (start == end marks an unused pack
    slot), row_seq (T,) each row's owning pack slot, block_tables (S, nb),
    ctx_lens (S,) visible tokens including each chunk.
    Returns (logits (S, V_pad) fp32 at each sequence's last packed row,
    new_cache). Row-wise work (embedding, norms, projections, MLP) runs
    once over the flat batch; only the attention is per-sequence. S == 1
    is the single-chunk path in a different layout — the engine keeps
    outputs byte-identical across the two (tests pin it).
    """
    tokens = batch["tokens"]
    _, T = tokens.shape
    assert cfg.rope_sections is None, "packed prefill: no M-RoPE frontends"
    assert cfg.ssm is None and not cfg.shared_attn_period, \
        "packed prefill is attention-only (see supports_packed_prefill)"
    x = embed(params["embed"]["table"], tokens, cfg)
    cos_sin = (rope_cos_sin(batch["positions"], cfg.head_dim, cfg.rope_theta,
                            cfg.rope_sections) if cfg.num_heads else None)
    ctx = {"cos_sin": cos_sin, "pos": None,
           "starts": batch["starts"], "ends": batch["ends"],
           "row_seq": batch["row_seq"],
           "block_tables": batch["block_tables"],
           "ctx_lens": batch["ctx_lens"],
           "moe_f2d": bool(pcfg and pcfg.expert_ff_2d)}
    x, _, new_cache = _scan_periods(params, x, cfg, ctx, "ragged_paged",
                                    ParallelConfig(remat="none"), cache)
    x = apply_norm(params["final_norm"], x, cfg)
    ht = head_table(params["embed"], cfg)
    last = jnp.clip(batch["ends"] - 1, 0, T - 1)                   # (S,)
    x_last = jnp.take(x[0], last, axis=0)[:, None]                 # (S,1,d)
    logits = decode_logits(x_last, ht, cfg)
    return logits, new_cache


def decode_step_paged(params, cache, batch, cfg: ModelConfig,
                      pcfg: ParallelConfig):
    """One decode token against a block-paged KV cache (all serving slots).

    batch: token (B,1), pos (B,) write position, block_tables (B, nb),
    ctx_lens (B,) — visible tokens incl. this one; 0 masks an idle slot.
    cache: pytree of {"k","v"} page pools with leading layer-stack dim.
    Returns (logits (B, V_pad) fp32, new_cache).
    """
    token, pos = batch["token"], batch["pos"]
    B = token.shape[0]
    x = embed(params["embed"]["table"], token, cfg)
    if cfg.rope_sections is not None:
        positions = jnp.broadcast_to(pos[None, :, None], (3, B, 1))
    else:
        positions = pos[:, None]
    cos_sin = (rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta,
                            cfg.rope_sections) if cfg.num_heads else None)
    ctx = {"cos_sin": cos_sin, "pos": pos,
           "block_tables": batch["block_tables"],
           "ctx_lens": batch["ctx_lens"],
           "moe_f2d": bool(pcfg and pcfg.expert_ff_2d)}
    x, _, new_cache = _scan_periods(params, x, cfg, ctx, "decode_paged",
                                    ParallelConfig(remat="none"), cache)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = decode_logits(x, head_table(params["embed"], cfg), cfg)
    return logits, new_cache


def decode_step(params, cache, batch, cfg: ModelConfig,
                pcfg: ParallelConfig):
    """One token. batch: token (B,1), pos (B,) — position to write at.
    Returns (next_token (B,), new_cache)."""
    token, pos = batch["token"], batch["pos"]
    B = token.shape[0]
    x = embed(params["embed"]["table"], token, cfg)
    if cfg.rope_sections is not None:
        positions = jnp.broadcast_to(pos[None, :, None], (3, B, 1))
    else:
        positions = pos[:, None]
    cos_sin = (rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta,
                            cfg.rope_sections) if cfg.num_heads else None)
    ctx = {"cos_sin": cos_sin, "pos": pos,
           "moe_f2d": bool(pcfg and pcfg.expert_ff_2d)}
    x, _, new_cache = _scan_periods(params, x, cfg, ctx, "decode",
                                    ParallelConfig(remat="none"), cache)
    x = apply_norm(params["final_norm"], x, cfg)
    nxt = decode_logits_argmax(x, head_table(params["embed"], cfg), cfg)
    return nxt, new_cache
