"""Quantized KV-cache representations (int8 / fp8 page pools).

The serving page pools can be stored in a narrow dtype with a per-row
fp32 scale carried alongside each pool leaf ("k_scale" / "v_scale" next
to "k" / "v" in every layer-stack dict).  A row here is one (token,
kv-head) vector of head_dim values: symmetric absmax scaling over the
head dim keeps the quantizer a pure elementwise function of the bf16
input, so the repo-wide rounding convention still holds — bit-identical
bf16 K/V across prefill/chunk/decode quantizes to bit-identical int8
pages, and the prefix-cache / COW / preemption byte-identity story
survives quantization unchanged (equivalence vs bf16 itself is
tolerance-based, pinned by tests).

Scale layout: pool leaf (NP, num_blocks, block_size, K, hd) gets a
scale leaf (NP, num_blocks, block_size, K, 1) in fp32 — rank-5 with
num_blocks at axis 1, so the engine's block-indexed copy/COW/swap
helpers treat value and scale leaves uniformly.

Dequantization always round-trips through bf16 — (q.f32 * scale).bf16 —
before entering the attention matmuls, in kernels, XLA mirrors and
oracles alike, so every path sees the same dequantized operands.
"""

from __future__ import annotations

import jax.numpy as jnp

# Serving KV dtypes by CLI/engine name.  fp8 support depends on the
# backend; jnp.float8_e4m3fn exists on every jax we target, but real
# MXU support is TPU-generation dependent — the kernels dequantize to
# bf16 before the matmul either way.
KV_DTYPES = {
    "bf16": jnp.bfloat16,
    "int8": jnp.int8,
    "fp8": jnp.float8_e4m3fn,
}

# Largest representable magnitude per quantized dtype (symmetric).
QMAX = {"int8": 127.0, "fp8": 448.0}

# Guards the absmax so all-zero rows get scale eps/qmax, not 0 (a zero
# scale would turn dequant into 0*inf on any later nonzero write).
_AMAX_EPS = 1e-6


def is_quantized(kv_dtype: str) -> bool:
    return kv_dtype in QMAX


def kv_dtype_bytes(kv_dtype: str) -> int:
    """Bytes per pool element for a serving kv dtype name."""
    return jnp.dtype(KV_DTYPES[kv_dtype]).itemsize


def kv_dtype_name(dtype) -> str:
    """Serving kv-dtype name for a pool leaf dtype (inverse of KV_DTYPES)."""
    d = jnp.dtype(dtype)
    for name, dt in KV_DTYPES.items():
        if jnp.dtype(dt) == d:
            return name
    raise ValueError(f"not a serving kv dtype: {dtype}")


def quantize_kv(x, kv_dtype: str):
    """Quantize new K/V rows to the pool dtype.

    x: (..., hd) bf16/f32.  Returns (q (..., hd) narrow dtype,
    scale (..., 1) fp32).  Symmetric per-row absmax over the head dim;
    deterministic round-half-away handled by jnp.round for int8 and the
    hardware cast for fp8.
    """
    qmax = QMAX[kv_dtype]
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, _AMAX_EPS) / qmax
    y = xf / scale
    if kv_dtype == "int8":
        q = jnp.clip(jnp.round(y), -qmax, qmax).astype(jnp.int8)
    else:
        q = jnp.clip(y, -qmax, qmax).astype(KV_DTYPES[kv_dtype])
    return q, scale


def dequantize_kv(q, scale, out_dtype=jnp.bfloat16):
    """Inverse of quantize_kv: (q (..., hd), scale (..., 1)) -> bf16.

    The bf16 round-trip is load-bearing: kernels, XLA mirrors and the
    oracles all dequantize exactly this way so their attention inputs
    are bit-identical.
    """
    return (q.astype(jnp.float32) * scale).astype(out_dtype)
